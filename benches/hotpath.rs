//! Bench: hot paths — dataflow simulator cycle rate, PJRT batch-1
//! inference latency, and the batching engine throughput (§Perf targets).
use std::time::Instant;
use tinyml_codesign::board::pynq_z2;
use tinyml_codesign::coordinator::engine::{spawn, BatchPolicy};
use tinyml_codesign::data;
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() {
    let art = tinyml_codesign::artifacts_dir();

    // 1. Dataflow simulator rate on the big design (full CNV).
    let g = tinyml_codesign::ir::Graph::load(&art.join("ic_finn_full_topology.json")).unwrap();
    let mut pm = tinyml_codesign::passes::PassManager::for_flow("finn");
    let g = pm.run(&g);
    let d = tinyml_codesign::dataflow::schedule::schedule(&g, &Default::default());
    let sim = tinyml_codesign::dataflow::Simulator::new(d.stage_specs());
    let t0 = Instant::now();
    let r = sim.run_unbounded();
    let dt = t0.elapsed().as_secs_f64();
    let rate = r.simulated_cycles as f64 * d.stages.len() as f64 / dt / 1e6;
    println!("[bench] simulator: {} cycles x {} stages in {:.3} s = {rate:.1} M stage-updates/s",
        r.simulated_cycles, d.stages.len(), dt);

    // 2. PJRT batch-1 inference (the EEMBC request path).
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&art, "kws_mlp_w3a3").unwrap();
    let ts = data::test_set("kws", 64, 0xB);
    m.infer1(&rt, &ts.samples[0].x).unwrap(); // compile + warm
    let t0 = Instant::now();
    let iters = 300;
    for i in 0..iters {
        std::hint::black_box(m.infer1(&rt, &ts.samples[i % 64].x).unwrap());
    }
    let per = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    println!("[bench] PJRT batch-1 inference: {per:.1} us/inference");

    // 3. Batching engine throughput (multi-threaded submitters).
    let (handle, join) = spawn(art.clone(), "kws_mlp_w3a3".into(), BatchPolicy::default());
    let n = 512;
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for t in 0..4 {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let ts = data::test_set("kws", n / 4, 0xC0 + t as u64);
            for s in &ts.samples {
                std::hint::black_box(h.infer(s.x.clone()).unwrap());
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    drop(handle);
    let served = join.join().unwrap().unwrap();
    println!("[bench] engine: {served} requests in {dt:.2} s = {:.0} req/s", served as f64 / dt);

    // 4. End-to-end flow (compiler + sizing + estimate) latency.
    let t0 = Instant::now();
    for _ in 0..3 {
        std::hint::black_box(tables::flow_for(&art, "kws_mlp_w3a3", &pynq_z2()).unwrap());
    }
    println!("[bench] full codesign flow (KWS): {:.1} ms", t0.elapsed().as_secs_f64() * 1e3 / 3.0);
}
