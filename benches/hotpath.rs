//! Bench: the fleet submit→reply hot path at **saturation** — the
//! lock-sharded serving plane (sharded telemetry, striped result cache,
//! pooled zero-allocation replies) A/B'd against the pre-PR global-lock
//! path kept behind `FleetConfig::global_hotpath`.
//!
//! The paper's boards answer in ~20 µs, so at fleet scale the *software*
//! between submit and reply is the bottleneck; this bench removes the
//! simulated device entirely (zero-latency boards, batch-of-1 windows)
//! and hammers the plane with 8 closed-loop client threads, so whatever
//! throughput differences appear are pure serving-plane software:
//!
//! * **Part 1 — cache off.**  Every request crosses router → queue →
//!   worker → telemetry → reply.  Global mode pays the fleet-wide
//!   class/tenant telemetry mutexes per batch plus a fresh reply vector
//!   per request; sharded mode records into the worker's own shard and
//!   replies from a recycled pool buffer.
//! * **Part 2 — cache on (75% repeats / 25% fresh), the headline.**
//!   Hits answer in front of the router: in global mode every one of
//!   the 8 clients serializes on the *single* cache mutex (and misses
//!   pile worker inserts onto it); sharded mode spreads the same keys
//!   over 16 lock stripes and replies from the pooled buffers.  The
//!   `sharded_over_global_throughput` ratio of this part is the gated
//!   headline; the inline floor is **≥ 1.3x**.
//! * **Part 3 — telemetry merge equivalence.**  An identical
//!   deterministic trace recorded into both collectors: the sharded
//!   merge must reproduce the global collector's per-class
//!   served/shed counts and p50/p99 (and tenant counts) *exactly* —
//!   the refactor loses no events.
//! * **Part 4 — tracing overhead.**  The sharded cache-off leg re-run
//!   with lifecycle tracing sampling 1 request in 16
//!   (`FleetConfig::trace_sample`): stage histograms, drift, and event
//!   rings all live.  The `traced_over_untraced_throughput` ratio is a
//!   second gated headline; the inline floor is **≥ 0.9x** — sampled
//!   tracing must stay within 10% of the untraced plane.
//! * **Part 5 — flash crowd (single-flight coalescing).**  An
//!   *open-loop* burst (submit everything, then drain) where 75% of
//!   requests duplicate a 32-input hot set against boards with real
//!   simulated latency, so duplicates arrive while the first copy is
//!   still in flight — the cache can't answer them, but the coalescer
//!   (`FleetConfig::coalesce`) can attach them to the leader's
//!   execution.  Both legs run with the cache on; the only delta is
//!   coalescing.  The `coalesced_over_uncoalesced_throughput` ratio is
//!   the third gated headline; the inline floor is **≥ 1.2x**.
//!
//! Lock contention only exists with real parallelism: below 4 hardware
//! threads the A/B measures scheduler timeslicing, not locking, so the
//! ratio floors are skipped (with a loud warning) and the emitted JSON
//! carries `"parallelism_limited": true`, which `bench-gate` honors by
//! not gating this file on such machines.
//!
//! Writes `BENCH_hotpath.json`; `BENCH_QUICK=1` (used by ci.sh) cuts
//! the trace sizes but keeps every assertion.

use std::time::{Duration, Instant};
use tinyml_codesign::coordinator::engine::BatchPolicy;
use tinyml_codesign::fleet::{
    BoardInstance, Fleet, FleetConfig, Policy, Priority, Registry, RequestTag,
};
use tinyml_codesign::report::json::{num, obj, s, Value};

/// Closed-loop submitter threads (the saturation load).
const CLIENTS: usize = 8;
/// Zero-latency worker replicas behind them.
const BOARDS: usize = 4;
/// Distinct hot inputs in the cache-on trace (all hits after warmup).
const HOT_SET: usize = 256;
/// Distinct hot inputs in the part-5 flash crowd: small enough that
/// every hot key has many in-flight duplicates for the coalescer.
const FLASH_HOT: usize = 32;

#[path = "util.rs"]
mod util;
use util::quick;

struct RunStats {
    submitted: u64,
    served: u64,
    cache_hits: u64,
    shed: u64,
    wall_s: f64,
    throughput_rps: f64,
    ns_per_request: f64,
    class_served: Vec<u64>,
    /// Completed spans folded into the stage histograms (0 untraced).
    stage_spans: u64,
    /// Batches covered by the flow-vs-measured drift accumulators.
    drift_batches: u64,
}

impl RunStats {
    fn to_json(&self) -> Value {
        obj(vec![
            ("submitted", num(self.submitted as f64)),
            ("served", num(self.served as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("shed", num(self.shed as f64)),
            ("wall_s", num(self.wall_s)),
            ("throughput_rps", num(self.throughput_rps)),
            ("ns_per_request", num(self.ns_per_request)),
        ])
    }
}

/// One A/B leg: a fleet of zero-latency boards in sharded or global-lock
/// mode, saturated by `CLIENTS` closed-loop threads.
///
/// Zero device latency + batch-of-1 windows mean every measured
/// nanosecond is serving-plane software; `cache_cap > 0` additionally
/// routes 3 of 4 requests through the memo (the 4th is a fresh input,
/// so workers, telemetry, and cache inserts stay on the clock too).
fn run_saturation(
    global_hotpath: bool,
    cache_cap: usize,
    per_client: usize,
    trace_sample: usize,
) -> RunStats {
    let reg = Registry {
        instances: (0..BOARDS)
            .map(|id| BoardInstance::synthetic(id, "ad", 0.0, 0.0, 1.0))
            .collect(),
    };
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 256,
        // Batch-of-1 windows: per-request accounting, the worst case the
        // sharding targets (every request pays the full record path).
        batch: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
        time_scale: 1.0,
        work_stealing: false,
        cache_cap,
        autoscale: None,
        fifo_queues: false,
        global_hotpath,
        trace_sample,
        ..Default::default()
    };
    let fleet = Fleet::start(reg, cfg).unwrap();
    let dim = tinyml_codesign::data::feature_dim("ad");
    let mut warmed = 0u64;
    if cache_cap > 0 {
        // Populate the hot set once so the measured phase's repeats are
        // all hits (deterministically, in both modes).
        let h = fleet.handle();
        let mut x = vec![0.2f32; dim];
        for j in 0..HOT_SET {
            x[0] = j as f32;
            h.infer("ad", x.clone()).unwrap();
            warmed += 1;
        }
    }
    let t0 = Instant::now();
    let threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let h = fleet.handle();
            std::thread::spawn(move || {
                let mut x = vec![0.2f32; dim];
                for i in 0..per_client {
                    // Deterministic class/tenant mix: all three class
                    // tags (and 8 tenants) stay hot in telemetry.
                    let tag =
                        RequestTag::new(((c + i) % 8) as u32, Priority::ALL[i % 3]);
                    x[0] = if cache_cap == 0 {
                        i as f32
                    } else if i % 4 == 3 {
                        // Fresh input: a guaranteed miss (distinct per
                        // client and iteration; exact in f32 and within
                        // the key quantizer's i32 range).
                        (1_000_000 * (c + 1) + i) as f32
                    } else {
                        (i % HOT_SET) as f32 // hot: a guaranteed hit
                    };
                    h.infer_tagged("ad", x.clone(), tag)
                        .expect("closed-loop request failed");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let measured = (CLIENTS * per_client) as u64;
    let summary = fleet.shutdown();
    let snap = &summary.snapshot;
    let shed: u64 = snap.classes.iter().map(|c| c.shed).sum();
    // Conservation: the closed loop never overruns the 256-slot queues,
    // so everything submitted was served by a board or answered by the
    // cache.
    assert_eq!(shed, 0, "closed-loop trace must not shed");
    assert_eq!(
        snap.served + snap.cache.hits,
        measured + warmed,
        "served + hits must cover the whole trace"
    );
    RunStats {
        submitted: measured,
        served: snap.served,
        cache_hits: snap.cache.hits,
        shed,
        wall_s,
        throughput_rps: measured as f64 / wall_s,
        ns_per_request: wall_s * 1e9 / measured as f64,
        class_served: snap.classes.iter().map(|c| c.served).collect(),
        // Every stage folds one span per sampled request; queue_wait's
        // count is the canonical tally.
        stage_spans: snap
            .classes
            .iter()
            .filter_map(|c| c.stages.as_ref())
            .map(|set| set[0].count)
            .sum(),
        drift_batches: snap
            .per_board
            .iter()
            .filter_map(|b| b.drift)
            .map(|d| d.batches)
            .sum(),
    }
}

/// Part 3: identical deterministic trace into the sharded and the
/// global-lock collector; the merged snapshots must agree exactly.
/// (Shared harness — the telemetry unit test and the proptests drive
/// the same driver at other sizes and seeds.)
fn telemetry_equivalence(batches: usize) -> usize {
    tinyml_codesign::fleet::telemetry::assert_merge_equivalence(
        BOARDS, batches, 0x407B_A7C4,
    )
}

struct FlashStats {
    submitted: u64,
    /// Board-executed requests (leaders + fresh inputs).
    served: u64,
    cache_hits: u64,
    /// Requests resolved by riding another request's execution.
    followers: u64,
    wall_s: f64,
    throughput_rps: f64,
}

impl FlashStats {
    fn to_json(&self) -> Value {
        obj(vec![
            ("submitted", num(self.submitted as f64)),
            ("served", num(self.served as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("followers", num(self.followers as f64)),
            ("wall_s", num(self.wall_s)),
            ("throughput_rps", num(self.throughput_rps)),
        ])
    }
}

/// Part 5: one flash-crowd leg.  Unlike the closed loops above, this is
/// **open-loop**: every client submits its whole trace without waiting,
/// then drains the replies — so duplicates of a hot input genuinely pile
/// up *behind* the in-flight first copy.  Boards have real simulated
/// latency (200 µs + 20 µs/item) for the same reason: a zero-latency
/// board would complete (and cache) every input before its first
/// duplicate arrived, leaving the coalescer nothing to do.
fn run_flash_crowd(coalesce: bool, per_client: usize) -> FlashStats {
    let reg = Registry {
        instances: (0..BOARDS)
            .map(|id| BoardInstance::synthetic(id, "ad", 200.0, 20.0, 1.0))
            .collect(),
    };
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        // Generous: the open-loop burst parks nearly the whole trace in
        // the queues at once, and the conservation check below requires
        // a shed-free run (Standard's admit bound is cap - cap/16).
        queue_cap: 8192,
        batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
        time_scale: 1.0,
        cache_cap: 2048,
        coalesce,
        ..Default::default()
    };
    let fleet = Fleet::start(reg, cfg).unwrap();
    let dim = tinyml_codesign::data::feature_dim("ad");
    let t0 = Instant::now();
    let threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let h = fleet.handle();
            std::thread::spawn(move || {
                let mut rxs = Vec::with_capacity(per_client);
                let mut x = vec![0.2f32; dim];
                for i in 0..per_client {
                    x[0] = if i % 4 == 3 {
                        // Fresh input, distinct per client and iteration.
                        (1_000_000 * (c + 1) + i) as f32
                    } else {
                        (i % FLASH_HOT) as f32 // the crowd's hot set
                    };
                    rxs.push(
                        h.submit("ad", x.clone()).expect("flash-crowd submit refused"),
                    );
                }
                for rx in rxs {
                    rx.recv()
                        .expect("fleet dropped a flash-crowd reply")
                        .expect("flash-crowd request failed");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let submitted = (CLIENTS * per_client) as u64;
    let summary = fleet.shutdown();
    let snap = &summary.snapshot;
    let shed: u64 = snap.classes.iter().map(|c| c.shed).sum();
    assert_eq!(shed, 0, "flash-crowd trace must not shed");
    let followers = snap.coalesce.as_ref().map_or(0, |co| co.followers);
    // Conservation: every submitted request resolved exactly one way —
    // executed on a board, answered by the cache, or fanned to it as a
    // coalesced follower.
    assert_eq!(
        snap.served + snap.cache.hits + followers,
        submitted,
        "served + hits + followers must cover the whole flash crowd"
    );
    if coalesce {
        let co = snap.coalesce.as_ref().expect("coalesce stats missing");
        assert_eq!(co.fanned_err, 0, "healthy fleet must not fan errors");
        assert_eq!(
            co.fanned_ok, co.followers,
            "every follower must be fanned exactly once"
        );
    }
    FlashStats {
        submitted,
        served: snap.served,
        cache_hits: snap.cache.hits,
        followers,
        wall_s,
        throughput_rps: submitted as f64 / wall_s,
    }
}

fn main() {
    let quick = quick();
    let per_client = if quick { 2_500 } else { 12_000 };
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Lock contention needs hardware parallelism: with fewer than 4
    // threads the A/B measures timeslicing, not locking, so the ratio
    // floors are informational only (and bench-gate skips this file).
    let contended = cores >= 4;
    if !contended {
        eprintln!(
            "[bench] WARNING: only {cores} hardware threads — contention floors \
             skipped, emitting parallelism_limited"
        );
    }

    println!(
        "[bench] hot path: {CLIENTS} closed-loop clients x {per_client} requests \
         over {BOARDS} zero-latency ad boards ({cores} hw threads{})",
        if quick { ", quick mode" } else { "" }
    );

    println!("[bench] part 1: cache off — telemetry + reply path A/B");
    let off_global = run_saturation(true, 0, per_client, 0);
    let off_sharded = run_saturation(false, 0, per_client, 0);
    let off_ratio = off_sharded.throughput_rps / off_global.throughput_rps.max(1e-9);
    for (tag, r) in [("global ", &off_global), ("sharded", &off_sharded)] {
        println!(
            "[bench]   {tag}: {:>9.0} req/s  {:>7.0} ns/req  ({} served)",
            r.throughput_rps, r.ns_per_request, r.served
        );
    }
    println!("[bench]   sharded/global (cache off) = {off_ratio:.3}x");

    println!(
        "[bench] part 2: cache on — {HOT_SET}-input hot set, 75% repeats / 25% \
         fresh, cap 2048 (16 stripes sharded vs 1 global)"
    );
    let on_global = run_saturation(true, 2048, per_client, 0);
    let on_sharded = run_saturation(false, 2048, per_client, 0);
    let headline = on_sharded.throughput_rps / on_global.throughput_rps.max(1e-9);
    for (tag, r) in [("global ", &on_global), ("sharded", &on_sharded)] {
        println!(
            "[bench]   {tag}: {:>9.0} req/s  {:>7.0} ns/req  ({} hits / {} executed)",
            r.throughput_rps, r.ns_per_request, r.cache_hits, r.served
        );
    }
    println!(
        "[bench]   sharded/global (cache on) = {headline:.3}x  (headline; floor 1.3)"
    );

    let eq_batches = telemetry_equivalence(if quick { 1_000 } else { 2_000 });
    println!(
        "[bench] part 3: telemetry merge equivalence OK — {eq_batches} batches, \
         per-class served/shed/p50/p99 and tenants exact"
    );

    const TRACE_EVERY: usize = 16;
    println!(
        "[bench] part 4: tracing — sampled 1-in-{TRACE_EVERY} vs the untraced \
         sharded cache-off leg"
    );
    let traced = run_saturation(false, 0, per_client, TRACE_EVERY);
    let trace_ratio = traced.throughput_rps / off_sharded.throughput_rps.max(1e-9);
    // The sampler is one fleet-wide counter and every submit consults it
    // exactly once, so a shed-free closed loop folds exactly 1-in-N.
    assert_eq!(
        traced.stage_spans,
        traced.submitted / TRACE_EVERY as u64,
        "sampled spans must be exactly 1-in-{TRACE_EVERY} of submits"
    );
    assert!(traced.drift_batches > 0, "tracing must accumulate exec drift");
    println!(
        "[bench]   traced : {:>9.0} req/s  {:>7.0} ns/req  ({} spans, {} drift \
         batches)",
        traced.throughput_rps, traced.ns_per_request, traced.stage_spans,
        traced.drift_batches
    );
    println!(
        "[bench]   traced/untraced (cache off) = {trace_ratio:.3}x  (headline; \
         floor 0.9)"
    );

    let flash_per_client = if quick { 600 } else { 2_400 };
    println!(
        "[bench] part 5: flash crowd — open-loop burst, 75% duplicates of a \
         {FLASH_HOT}-input hot set, {CLIENTS} clients x {flash_per_client}, \
         coalescing off vs on (cache on in both)"
    );
    let uncoalesced = run_flash_crowd(false, flash_per_client);
    let coalesced = run_flash_crowd(true, flash_per_client);
    let flash_ratio =
        coalesced.throughput_rps / uncoalesced.throughput_rps.max(1e-9);
    for (tag, r) in [("uncoalesced", &uncoalesced), ("coalesced  ", &coalesced)] {
        println!(
            "[bench]   {tag}: {:>9.0} req/s  ({} executed / {} hits / {} followers)",
            r.throughput_rps, r.served, r.cache_hits, r.followers
        );
    }
    // The crowd must actually coalesce: with 6x more duplicates than hot
    // inputs in flight, a zero-follower run means the layer is wired
    // wrong, not that the workload was easy.
    assert!(
        coalesced.followers > 0,
        "duplicate-heavy open-loop burst produced no coalesced followers"
    );
    assert_eq!(uncoalesced.followers, 0, "coalescing-off leg must not coalesce");
    println!(
        "[bench]   coalesced/uncoalesced = {flash_ratio:.3}x  (headline; floor 1.2)"
    );

    let mut fields = vec![
        ("bench", s("hotpath")),
        ("quick", Value::Bool(quick)),
        ("clients", num(CLIENTS as f64)),
        ("boards", num(BOARDS as f64)),
        ("parallelism", num(cores as f64)),
        (
            "cache_off",
            obj(vec![
                ("global", off_global.to_json()),
                ("sharded", off_sharded.to_json()),
                ("sharded_over_global", num(off_ratio)),
            ]),
        ),
        (
            "cache_on",
            obj(vec![
                ("hot_set", num(HOT_SET as f64)),
                ("fresh_fraction", num(0.25)),
                ("global", on_global.to_json()),
                ("sharded", on_sharded.to_json()),
                ("sharded_over_global", num(headline)),
            ]),
        ),
        ("sharded_over_global_throughput", num(headline)),
        (
            "tracing",
            obj(vec![
                ("sample_every", num(TRACE_EVERY as f64)),
                ("untraced", off_sharded.to_json()),
                ("traced", traced.to_json()),
                ("traced_spans", num(traced.stage_spans as f64)),
                ("drift_batches", num(traced.drift_batches as f64)),
                ("traced_over_untraced", num(trace_ratio)),
            ]),
        ),
        ("traced_over_untraced_throughput", num(trace_ratio)),
        (
            "flash_crowd",
            obj(vec![
                ("hot_set", num(FLASH_HOT as f64)),
                ("duplicate_fraction", num(0.75)),
                ("per_client", num(flash_per_client as f64)),
                ("uncoalesced", uncoalesced.to_json()),
                ("coalesced", coalesced.to_json()),
                ("coalesced_over_uncoalesced", num(flash_ratio)),
            ]),
        ),
        ("coalesced_over_uncoalesced_throughput", num(flash_ratio)),
        (
            "telemetry_merge",
            obj(vec![
                ("checked_batches", num(eq_batches as f64)),
                ("exact", Value::Bool(true)),
            ]),
        ),
    ];
    if !contended {
        fields.insert(5, ("parallelism_limited", Value::Bool(true)));
    }
    let doc = obj(fields);
    std::fs::write("BENCH_hotpath.json", doc.to_json()).expect("write BENCH_hotpath.json");
    println!("[bench] wrote BENCH_hotpath.json");

    // Self-checks.  The deterministic trace must account identically in
    // both modes — the live-fleet restatement of part 3 (the telemetry
    // refactor loses no events under real concurrency either).
    assert_eq!(
        off_global.class_served, off_sharded.class_served,
        "cache-off per-class served must match across modes"
    );
    assert_eq!(
        on_global.class_served, on_sharded.class_served,
        "cache-on per-class served must match across modes"
    );
    assert_eq!(
        on_global.cache_hits, on_sharded.cache_hits,
        "hit accounting must match across modes"
    );
    if contended {
        // The headline: sharded telemetry + striped cache + pooled
        // replies must buy >= 1.3x submit-saturation throughput over
        // the global-lock plane.
        assert!(
            headline >= 1.3,
            "sharded plane must beat the global-lock plane >= 1.3x at saturation \
             (got {headline:.3}x: {:.0} vs {:.0} req/s)",
            on_sharded.throughput_rps,
            on_global.throughput_rps
        );
        // Sanity floor for the uncached path: removing locks must not
        // cost throughput (generous margin for scheduler noise).
        assert!(
            off_ratio >= 0.8,
            "cache-off sharded path regressed vs global: {off_ratio:.3}x"
        );
        // The tracing headline: sampling 1-in-16 must keep >= 0.9x the
        // untraced throughput (the unsampled path is one branch).
        assert!(
            trace_ratio >= 0.9,
            "sampled tracing costs too much: {trace_ratio:.3}x ({:.0} vs {:.0} \
             req/s untraced)",
            traced.throughput_rps,
            off_sharded.throughput_rps
        );
        // The coalescing headline: merging duplicate in-flight work must
        // buy >= 1.2x flash-crowd throughput (expected ~3x — duplicates
        // never reach a board at all).
        assert!(
            flash_ratio >= 1.2,
            "coalescing must beat the uncoalesced flash crowd >= 1.2x \
             (got {flash_ratio:.3}x: {:.0} vs {:.0} req/s)",
            coalesced.throughput_rps,
            uncoalesced.throughput_rps
        );
        println!(
            "[bench] OK: cache-on sharded/global {headline:.3}x >= 1.3, cache-off \
             {off_ratio:.3}x >= 0.8, traced/untraced {trace_ratio:.3}x >= 0.9, \
             coalesced/uncoalesced {flash_ratio:.3}x >= 1.2, merge exact"
        );
    } else {
        println!(
            "[bench] OK (parallelism-limited): merge exact; ratios reported, floors \
             skipped"
        );
    }
}
