//! Bench: packed quantized kernel core vs the naive f32 hot path it
//! replaced (`data::template_logits` Vec-of-Vec dots with a fresh
//! allocation per request; O(n·window) moving average), plus the SIMD
//! dispatch (AVX2 / SSE2 / NEON) vs the scalar bit-exactness oracle on
//! the batched GEMM.
//!
//! Self-checking: asserts the packed path is no slower than the naive
//! baseline on every shape, ≥ 2x on the batched KWS shape (the serving
//! plane's dominant traffic), that packed argmax agrees with the f32
//! reference on realistic samples, that the dispatched GEMM is
//! **bit-identical** to the scalar oracle, and — on CPUs with a wide
//! SIMD path (AVX2 or NEON) — that `simd_over_scalar_speedup` clears
//! **1.2x** on the batched KWS shape (0.9x everywhere else, noise
//! guard).  On CPUs without a wide path (or under
//! `TINYML_FORCE_SCALAR=1`) the JSON carries `simd_unavailable: true`
//! and the floor is skipped — the `parallelism_limited` precedent —
//! so the scalar-oracle CI rerun stays honest.  Writes
//! `BENCH_kernels.json` (ns/sample, samples/sec, speedups) so later
//! PRs have a recorded trajectory to beat.
//!
//! `BENCH_QUICK=1` (used by ci.sh) cuts the iteration counts ~10x but
//! keeps every assertion.

use tinyml_codesign::data;
use tinyml_codesign::kernels::{simd, PackedLinear, ScratchArena, SmoothKernel};
use tinyml_codesign::report::json::{num, obj, s, Value};
use tinyml_codesign::runtime::argmax;

#[path = "util.rs"]
mod util;
use util::{best_ns, quick};

const BATCH: usize = 64;

struct GemmResult {
    task: &'static str,
    rows: usize,
    cols: usize,
    naive_ns: f64,
    packed1_ns: f64,
    packed_batch_ns: f64,
    scalar_batch_ns: f64,
    agreement: f64,
}

/// One classification shape: naive per-sample vs packed single vs packed
/// batched (dispatched SIMD level) vs the scalar-oracle batched path,
/// all over the same `BATCH` realistic samples.
fn bench_gemm(task: &'static str, n_out: usize, iters: usize) -> GemmResult {
    let templates = data::class_templates_f32(task, n_out);
    let cols = templates[0].len();
    let packed = PackedLinear::pack(&templates, 1.0 / cols as f32);
    let ts = data::test_set(task, BATCH, 0xBE2C);
    let mut xbatch = Vec::with_capacity(BATCH * cols);
    for s in &ts.samples {
        xbatch.extend_from_slice(&s.x);
    }
    let mut scratch = ScratchArena::new();
    let mut out1 = vec![0.0f32; n_out];
    let mut outb = vec![0.0f32; BATCH * n_out];
    let mut outb_scalar = vec![0.0f32; BATCH * n_out];

    // Equivalence self-checks before timing: packed argmax must track
    // the f32 reference on template-derived samples, and the dispatched
    // SIMD path must be bit-identical to the scalar oracle.
    let mut agree = 0usize;
    for s in &ts.samples {
        let reference = data::template_logits(&s.x, &templates);
        packed.gemv(&s.x, &mut out1, &mut scratch);
        if argmax(&reference) == argmax(&out1) {
            agree += 1;
        }
    }
    let agreement = agree as f64 / BATCH as f64;
    packed.gemm_batch(&xbatch, &mut outb, &mut scratch);
    packed.gemm_batch_scalar(&xbatch, &mut outb_scalar, &mut scratch);
    assert_eq!(
        outb, outb_scalar,
        "{task}: {} GEMM diverged from the scalar oracle",
        simd::active_level().name()
    );

    // Naive baseline: the seed's exact hot path — one Vec-of-Vec f32 dot
    // pass plus a fresh allocation per request.
    let naive_ns = best_ns(3, || {
        for _ in 0..iters {
            for smp in &ts.samples {
                std::hint::black_box(data::template_logits(&smp.x, &templates));
            }
        }
    }) / (iters * BATCH) as f64;

    // Packed, one sample at a time (the EEMBC batch-1 path).
    let packed1_ns = best_ns(3, || {
        for _ in 0..iters {
            for smp in &ts.samples {
                packed.gemv(&smp.x, &mut out1, &mut scratch);
                std::hint::black_box(out1[0]);
            }
        }
    }) / (iters * BATCH) as f64;

    // Packed, whole batch per call (the serve-loop path): one tiled,
    // column-blocked walk over the weight matrix per batch at the
    // dispatched SIMD level.
    let packed_batch_ns = best_ns(3, || {
        for _ in 0..iters {
            packed.gemm_batch(&xbatch, &mut outb, &mut scratch);
            std::hint::black_box(outb[0]);
        }
    }) / (iters * BATCH) as f64;

    // Identical blocking pinned to the scalar inner loop — the
    // denominator of `simd_over_scalar_speedup`.
    let scalar_batch_ns = best_ns(3, || {
        for _ in 0..iters {
            packed.gemm_batch_scalar(&xbatch, &mut outb_scalar, &mut scratch);
            std::hint::black_box(outb_scalar[0]);
        }
    }) / (iters * BATCH) as f64;

    GemmResult {
        task,
        rows: n_out,
        cols,
        naive_ns,
        packed1_ns,
        packed_batch_ns,
        scalar_batch_ns,
        agreement,
    }
}

struct SmoothResult {
    n: usize,
    naive_ns: f64,
    packed_ns: f64,
}

/// AD shape: O(n·window) naive moving average vs O(n) prefix-sum pass.
fn bench_smooth(iters: usize) -> SmoothResult {
    let ts = data::test_set("ad", BATCH, 0xBE2D);
    let kernel = SmoothKernel::new(data::AD_SMOOTH_WINDOW);
    let mut scratch = ScratchArena::new();
    let mut out = vec![0.0f32; data::AD_DIM];
    let naive_ns = best_ns(3, || {
        for _ in 0..iters {
            for s in &ts.samples {
                std::hint::black_box(data::moving_average_f32(
                    &s.x,
                    data::AD_SMOOTH_WINDOW,
                ));
            }
        }
    }) / (iters * BATCH) as f64;
    let packed_ns = best_ns(3, || {
        for _ in 0..iters {
            for s in &ts.samples {
                kernel.smooth_into(&s.x, &mut out, &mut scratch);
                std::hint::black_box(out[0]);
            }
        }
    }) / (iters * BATCH) as f64;
    SmoothResult { n: data::AD_DIM, naive_ns, packed_ns }
}

fn main() {
    let quick = quick();
    let iters = if quick { 10 } else { 100 };
    let level = simd::active_level();
    // "Wide" = a lane-parallel path expected to clear the 1.2x floor
    // (AVX2/NEON).  Scalar (kill switch, exotic arch) and the SSE2
    // fallback run the A/B but skip the floor, flagged in the JSON the
    // way benches/hotpath.rs flags parallelism-limited machines.
    let simd_unavailable = !level.is_wide();
    println!(
        "[bench] packed kernel core vs naive f32 hot path ({BATCH}-sample sets, \
         {iters} iters, simd={}{}{})",
        level.name(),
        if simd_unavailable { " [no wide path — simd floor skipped]" } else { "" },
        if quick { ", quick mode" } else { "" }
    );

    let gemms = [
        bench_gemm("kws", data::KWS_CLASSES, iters),
        bench_gemm("ic", data::IC_CLASSES, iters),
    ];
    let smooth = bench_smooth(iters);

    let mut shapes_json = Vec::new();
    for g in &gemms {
        let s1 = g.naive_ns / g.packed1_ns;
        let sb = g.naive_ns / g.packed_batch_ns;
        let simd_speedup = g.scalar_batch_ns / g.packed_batch_ns;
        println!(
            "[bench] {:<3} {:>3}x{:<5} naive {:>8.1} ns/smp | packed-1 {:>8.1} ({s1:>5.2}x) | \
             packed-batch {:>8.1} ({sb:>5.2}x) | scalar-batch {:>8.1} (simd {simd_speedup:>5.2}x) | \
             argmax agreement {:.2}",
            g.task,
            g.rows,
            g.cols,
            g.naive_ns,
            g.packed1_ns,
            g.packed_batch_ns,
            g.scalar_batch_ns,
            g.agreement
        );
        shapes_json.push(obj(vec![
            ("task", s(g.task)),
            ("rows", num(g.rows as f64)),
            ("cols", num(g.cols as f64)),
            ("batch", num(BATCH as f64)),
            ("naive_ns_per_sample", num(g.naive_ns)),
            ("packed_single_ns_per_sample", num(g.packed1_ns)),
            ("packed_batch_ns_per_sample", num(g.packed_batch_ns)),
            ("scalar_batch_ns_per_sample", num(g.scalar_batch_ns)),
            ("packed_single_speedup", num(s1)),
            ("packed_batch_speedup", num(sb)),
            ("simd_over_scalar_speedup", num(simd_speedup)),
            ("samples_per_sec_packed_batch", num(1e9 / g.packed_batch_ns)),
            ("argmax_agreement", num(g.agreement)),
        ]));
    }
    let smooth_speedup = smooth.naive_ns / smooth.packed_ns;
    println!(
        "[bench] ad  smooth({:>3})  naive {:>8.1} ns/smp | prefix-sum {:>8.1} ({smooth_speedup:>5.2}x)",
        smooth.n, smooth.naive_ns, smooth.packed_ns
    );

    let doc = obj(vec![
        ("bench", s("kernels")),
        ("quick", Value::Bool(quick)),
        ("simd_level", s(level.name())),
        ("simd_unavailable", Value::Bool(simd_unavailable)),
        ("shapes", Value::Arr(shapes_json)),
        (
            "smooth",
            obj(vec![
                ("task", s("ad")),
                ("n", num(smooth.n as f64)),
                ("window", num(data::AD_SMOOTH_WINDOW as f64)),
                ("naive_ns_per_sample", num(smooth.naive_ns)),
                ("packed_ns_per_sample", num(smooth.packed_ns)),
                ("samples_per_sec_packed", num(1e9 / smooth.packed_ns)),
                ("speedup", num(smooth_speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_kernels.json", doc.to_json()).expect("write BENCH_kernels.json");
    println!("[bench] wrote BENCH_kernels.json");

    // Self-checks: equivalence first, then the perf floors.
    for g in &gemms {
        assert!(
            g.agreement >= 0.9,
            "{}: packed argmax agreement {:.2} < 0.90",
            g.task,
            g.agreement
        );
        assert!(
            g.naive_ns / g.packed1_ns >= 0.9,
            "{}: packed single-sample path slower than naive ({:.1} vs {:.1} ns)",
            g.task,
            g.packed1_ns,
            g.naive_ns
        );
        assert!(
            g.naive_ns / g.packed_batch_ns >= 1.0,
            "{}: packed batched path slower than naive ({:.1} vs {:.1} ns)",
            g.task,
            g.packed_batch_ns,
            g.naive_ns
        );
    }
    let kws = &gemms[0];
    let kws_speedup = kws.naive_ns / kws.packed_batch_ns;
    assert!(
        kws_speedup >= 2.0,
        "KWS packed batched speedup {kws_speedup:.2}x < 2x floor"
    );
    if simd_unavailable {
        println!(
            "[bench] WARN: no wide SIMD path (level {}) — simd_over_scalar floor \
             skipped, JSON flagged simd_unavailable",
            level.name()
        );
    } else {
        for g in &gemms {
            let simd_speedup = g.scalar_batch_ns / g.packed_batch_ns;
            assert!(
                simd_speedup >= 0.9,
                "{}: {} GEMM slower than the scalar oracle ({:.1} vs {:.1} ns)",
                g.task,
                level.name(),
                g.packed_batch_ns,
                g.scalar_batch_ns
            );
        }
        let kws_simd = kws.scalar_batch_ns / kws.packed_batch_ns;
        assert!(
            kws_simd >= 1.2,
            "KWS {} simd_over_scalar_speedup {kws_simd:.2}x < 1.2x floor",
            level.name()
        );
    }
    assert!(
        smooth_speedup >= 1.0,
        "prefix-sum smoothing slower than naive ({:.1} vs {:.1} ns)",
        smooth.packed_ns,
        smooth.naive_ns
    );
    println!(
        "[bench] OK: packed >= naive everywhere, KWS batched {kws_speedup:.2}x (>= 2x floor), \
         simd={} {}",
        level.name(),
        if simd_unavailable {
            "(floor skipped)".to_string()
        } else {
            format!(
                "KWS {:.2}x over scalar (>= 1.2x floor)",
                kws.scalar_batch_ns / kws.packed_batch_ns
            )
        }
    );
}
