//! Bench: regenerate Fig. 2 (BO NAS scans, 100 models x 3 stack counts)
//! and time the GP-BO loop.
use std::time::Instant;
use tinyml_codesign::dse;

fn main() {
    let t0 = Instant::now();
    println!("{}", tinyml_codesign::report::tables::fig2(100, 0xF16));
    let dt = t0.elapsed().as_secs_f64();
    println!("[bench] 3 x 100-model BO scans in {dt:.2} s ({:.1} ms/model)", dt * 1e3 / 300.0);
    // Shape assertions (the Fig. 2 story).
    for stacks in 1..=3 {
        let pts = dse::run_ic_bo_scan(stacks, 100, 0xF16 + stacks as u64);
        let best = pts.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
        assert!(best > 65.0, "{stacks}-stack best {best}");
    }
}
