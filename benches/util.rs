//! Shared helpers for the self-checking benches (`kernels`, `fleet`,
//! `hotpath`, `scenarios`).  Each bench target pulls this file in with
//! `#[path = "util.rs"] mod util;` — bench targets cannot depend on one
//! another, and these helpers are measurement plumbing, not library
//! surface, so they live beside the benches instead of in the crate.
//!
//! Not every bench uses every helper (only `kernels` needs best-of
//! timing), hence the item-level `dead_code` allowances.

use std::time::Instant;

/// `BENCH_QUICK=1` (set by ci.sh) cuts iteration/trace counts ~10x but
/// keeps every assertion.
#[allow(dead_code)]
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Best-of-`reps` wall time of `f` (ns), de-noising scheduler jitter.
#[allow(dead_code)]
pub fn best_ns<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_nanos() as f64);
    }
    best
}
