//! Bench: regenerate Table 3 (IC/hls4ml optimization ablation) and assert
//! its shape: FIFO opt cuts BRAM, ReLU merge cuts LUTs.
use std::time::Instant;
use tinyml_codesign::report::tables;

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    let t0 = Instant::now();
    let text = tables::table3(&art).unwrap();
    println!("{text}");
    println!("[bench] table3 (4 full flows) in {:.2} s", t0.elapsed().as_secs_f64());
}
