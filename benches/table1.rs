//! Bench: regenerate Table 1 (model summary) and time topology loading +
//! metric computation.  Accuracies come from `train_and_submit`
//! (EXPERIMENTS.md records the measured run).
use std::time::Instant;

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    let t0 = Instant::now();
    let text = tinyml_codesign::report::tables::table1(&art, &[]).unwrap();
    let dt = t0.elapsed();
    println!("{text}");
    println!("[bench] table1 generated in {:.2} ms", dt.as_secs_f64() * 1e3);
    // Throughput: topology load + validate (the compiler front-end).
    let t0 = Instant::now();
    let iters = 200;
    for _ in 0..iters {
        let g = tinyml_codesign::ir::Graph::load(&art.join("kws_mlp_w3a3_topology.json")).unwrap();
        std::hint::black_box(g.total_macs());
    }
    println!(
        "[bench] topology load+validate: {:.1} us/iter",
        t0.elapsed().as_secs_f64() * 1e6 / iters as f64
    );
}
