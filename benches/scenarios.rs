//! Bench: seeded resilience scenarios — the chaos/health/recovery plane
//! under three scripted failures.
//!
//! **Scenario 1 — kill the fastest replica mid-burst.**  Two
//! heterogeneous KWS replicas; `kill=fastest@3` makes the fast one fail
//! every batch from its 3rd on.  The health controller must eject it
//! (consecutive-failure signal) while the retry pump re-routes the
//! failed batches to the survivor.  Self-checking: **every** admitted
//! request resolves with a reply — zero lost, zero typed failures — and
//! exactly one ejection fires.  Headlines: `kill_resolved_fraction`
//! (1.0) and `kill_ejected` (1.0).
//!
//! **Scenario 2 — slow brownout.**  Same fleet, run twice: a healthy
//! control and a degraded run where `slow=4x0` stretches replica 0's
//! device hold 4x (the board still answers — only the drift signal can
//! see it).  The drift-vs-flow accumulator trips, the brownout replica
//! is ejected, and the tail is bounded: p99(degraded) / p99(healthy)
//! stays under a generous ceiling because work stealing and then
//! ejection keep requests off the sick board.  Headline:
//! `p99_under_failure_ratio` (lower is better, ceiling 8.0).
//!
//! **Scenario 3 — flash crowd on a degraded fleet.**  A replica is dead
//! on arrival (`kill=0@1`); a trickle gets it ejected, then a flash
//! crowd bursts onto the survivor.  Self-checking: the degraded fleet
//! serves the whole burst.  Headline: `recovery_served_fraction`
//! (floor 0.95; expected 1.0).  `time_to_recover_ms` (trickle start to
//! ejection) is emitted for trend-watching but not gated — it is an
//! absolute timing, and the gate holds only dimensionless ratios.
//!
//! **Scenario 4 — hedging under an undetectable brownout.**  Two
//! *equal* KWS replicas; `slow=4x0` stretches replica 0's device hold
//! 4x, and health is held inert (all three signals disabled) so the
//! sick board stays in the fleet for the whole run — tail-latency
//! hedging is the only relief.  Closed-loop requests are timed
//! client-side, once with hedging off and once with `hedge_p99` armed:
//! the drift-corrected estimate on the browned-out board crosses the
//! class-p99 threshold, a duplicate leg lands on the healthy sibling,
//! and the first terminal outcome wins while the loser is discarded at
//! its next stage boundary.  Every request also carries a generous
//! deadline so the whole deadline plane runs; `executed_expired` must
//! stay 0 (a board must never burn a window on a request nobody can
//! use).  Headline: `hedged_p99_over_unhedged` (lower is better,
//! ceiling 0.6).
//!
//! Writes `BENCH_scenarios.json` the way `benches/fleet.rs` writes
//! `BENCH_fleet.json`; the bench-gate holds the headline ratios as a CI
//! floor.  Every fault is seeded (`ChaosSpec` + SplitMix64 per replica)
//! so runs replay.  `BENCH_QUICK=1` cuts trace sizes but keeps every
//! assertion.

use std::time::{Duration, Instant};
use tinyml_codesign::fleet::worker::precise_sleep;
use tinyml_codesign::fleet::{
    BoardInstance, ChaosSpec, Fleet, FleetConfig, HealthConfig, Policy, Registry,
    ReplyReceiver, RouteError,
};
use tinyml_codesign::report::json::{num, obj, s, Value};

const TIME_SCALE: f64 = 20.0;
/// A reply that takes this long is a lost request, not a slow one.
const RECV_TIMEOUT: Duration = Duration::from_secs(10);
/// Scenario 4's per-request deadline: generous (closed-loop latencies
/// sit well under it, so nothing sheds or expires) but live — every
/// request runs the full stamp/triage path and the bench asserts no
/// expired request ever reached a board.
const DEADLINE_US: u64 = 150_000;

#[path = "util.rs"]
mod util;
use util::quick;

/// Two KWS replicas: id 0 the slower workhorse, id 1 the fast one
/// (`kill=fastest` resolves to id 1).  Batched service rates at
/// `TIME_SCALE` 20: ~417 req/s (id 0) and ~833 req/s (id 1).
fn two_replica_registry() -> Registry {
    Registry {
        instances: vec![
            BoardInstance::synthetic(0, "kws", 400.0, 80.0, 1.5),
            BoardInstance::synthetic(1, "kws", 200.0, 40.0, 1.2),
        ],
    }
}

/// Health knobs tuned for time-scaled simulation: sample fast, eject on
/// a 2-failure streak or a 2x drift ratio over >= 4 batches.
fn scenario_health() -> HealthConfig {
    HealthConfig {
        interval: Duration::from_millis(1),
        max_consecutive_failures: 2,
        max_drift_ratio: 2.0,
        min_drift_batches: 4,
        ..Default::default()
    }
}

fn scenario_config(chaos: Option<ChaosSpec>) -> FleetConfig {
    FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 1024,
        time_scale: TIME_SCALE,
        chaos,
        health: Some(scenario_health()),
        // Generous: before ejection lands, a dead replica can steal a
        // request back and fail it again; the budget must outlast that
        // window (each failed attempt is cheap — an immediate error).
        retry_budget: 50,
        ..Default::default()
    }
}

/// Submit `n` requests paced `gap` apart, retrying on backpressure.
fn submit_paced(
    fleet: &Fleet,
    n: usize,
    gap: Duration,
) -> Vec<ReplyReceiver> {
    let handle = fleet.handle();
    let x = vec![0.2f32; tinyml_codesign::data::feature_dim("kws")];
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        loop {
            match handle.submit("kws", x.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(e) => panic!("unexpected rejection: {e:?}"),
            }
        }
        precise_sleep(gap);
    }
    pending
}

/// Drain every receiver: `(ok, failed, lost)`.  `lost` > 0 means a
/// reply sender was dropped without an outcome — the exact bug the
/// retry pump exists to make impossible.
fn drain(pending: Vec<ReplyReceiver>) -> (usize, usize, usize) {
    let (mut ok, mut failed, mut lost) = (0usize, 0usize, 0usize);
    for rx in pending {
        match rx.recv_timeout(RECV_TIMEOUT) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => lost += 1,
        }
    }
    (ok, failed, lost)
}

/// Block until the fleet reports at least one ejection (panics after
/// `deadline` — a sick replica the health controller never catches is a
/// scenario failure, not a timeout).
fn await_ejection(fleet: &Fleet, deadline: Duration, what: &str) -> Duration {
    let t0 = Instant::now();
    while fleet.ejections() == 0 {
        assert!(
            t0.elapsed() < deadline,
            "{what}: no ejection within {deadline:?}"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    t0.elapsed()
}

// ---------------------------------------------------------------------------
// Scenario 1: kill the fastest replica mid-burst.
// ---------------------------------------------------------------------------

struct KillResult {
    submitted: usize,
    ok: usize,
    failed: usize,
    lost: usize,
    ejections: u64,
    exec_failures: u64,
    time_to_eject_ms: f64,
    eject_reason: String,
}

fn run_kill(requests: usize) -> KillResult {
    let spec = ChaosSpec::parse("kill=fastest@3", 0xC4A05).unwrap();
    let fleet = Fleet::start(two_replica_registry(), scenario_config(Some(spec))).unwrap();
    let t0 = Instant::now();
    // ~333 req/s: above half the fleet's healthy rate, under what the
    // surviving workhorse alone can absorb after the ejection.
    let pending = submit_paced(&fleet, requests, Duration::from_micros(3000));
    let (ok, failed, lost) = drain(pending);
    await_ejection(&fleet, Duration::from_secs(5), "kill scenario");
    let time_to_eject_ms = t0.elapsed().as_secs_f64() * 1e3;
    let summary = fleet.shutdown();
    let eject_reason = summary
        .snapshot
        .scale_events
        .iter()
        .find(|e| e.reason.starts_with("ejected:"))
        .map(|e| e.reason.clone())
        .unwrap_or_default();
    KillResult {
        submitted: requests,
        ok,
        failed,
        lost,
        ejections: summary.snapshot.ejections,
        exec_failures: summary.snapshot.per_board.iter().map(|b| b.exec_failures).sum(),
        time_to_eject_ms,
        eject_reason,
    }
}

// ---------------------------------------------------------------------------
// Scenario 2: slow brownout, healthy control vs degraded run.
// ---------------------------------------------------------------------------

struct BrownoutLeg {
    p99_us: f64,
    served: u64,
    ejections: u64,
    lost: usize,
}

fn run_brownout_leg(requests: usize, degraded: bool) -> BrownoutLeg {
    let chaos = degraded.then(|| ChaosSpec::parse("slow=4x0", 0xB10).unwrap());
    let fleet = Fleet::start(two_replica_registry(), scenario_config(chaos)).unwrap();
    let pending = submit_paced(&fleet, requests, Duration::from_micros(3000));
    let (ok, failed, lost) = drain(pending);
    assert_eq!(failed, 0, "a slowdown must not fail requests");
    if degraded {
        // The brownout board still answers; only drift can convict it.
        await_ejection(&fleet, Duration::from_secs(5), "brownout scenario");
    }
    let summary = fleet.shutdown();
    assert_eq!(ok + lost, requests);
    BrownoutLeg {
        p99_us: summary.snapshot.p99_us,
        served: summary.snapshot.served,
        ejections: summary.snapshot.ejections,
        lost,
    }
}

// ---------------------------------------------------------------------------
// Scenario 3: flash crowd on a degraded fleet.
// ---------------------------------------------------------------------------

struct FlashCrowdResult {
    burst: usize,
    ok: usize,
    failed: usize,
    lost: usize,
    time_to_recover_ms: f64,
    served_fraction: f64,
}

fn run_flash_crowd(trickle: usize, burst: usize) -> FlashCrowdResult {
    // Replica 0 is dead on arrival; the fast replica survives.
    let spec = ChaosSpec::parse("kill=0@1", 0xF1A5).unwrap();
    let fleet = Fleet::start(two_replica_registry(), scenario_config(Some(spec))).unwrap();
    let t0 = Instant::now();
    // Trickle phase: enough traffic to trip the failure streak.
    let warm = submit_paced(&fleet, trickle, Duration::from_micros(2000));
    await_ejection(&fleet, Duration::from_secs(5), "flash-crowd scenario");
    let time_to_recover_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (warm_ok, warm_failed, warm_lost) = drain(warm);
    assert_eq!(warm_lost, 0, "trickle lost requests");
    assert_eq!(warm_failed, 0, "trickle exhausted a retry budget");
    assert_eq!(warm_ok, trickle);
    // Flash crowd: open the floodgates on the surviving replica.
    let pending = submit_paced(&fleet, burst, Duration::ZERO);
    let (ok, failed, lost) = drain(pending);
    fleet.shutdown();
    FlashCrowdResult {
        burst,
        ok,
        failed,
        lost,
        time_to_recover_ms,
        served_fraction: ok as f64 / burst as f64,
    }
}

// ---------------------------------------------------------------------------
// Scenario 4: hedging under an undetectable brownout.
// ---------------------------------------------------------------------------

/// Two *equal* KWS replicas (200/40 µs model): the router's flow model
/// cannot tell them apart — and with health inert, only drift-corrected
/// hedging can route a caller around the browned-out one.
fn equal_replica_registry() -> Registry {
    Registry {
        instances: vec![
            BoardInstance::synthetic(0, "kws", 200.0, 40.0, 1.2),
            BoardInstance::synthetic(1, "kws", 200.0, 40.0, 1.2),
        ],
    }
}

struct HedgeLeg {
    p50_us: f64,
    p99_us: f64,
    hedged: u64,
    wins: u64,
    cancelled: u64,
    shed_submit: u64,
    executed_expired: u64,
}

/// Nearest-rank percentile over client-side latencies.
fn percentile_us(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// One closed-loop leg: `warmup` untimed requests seed the hedge
/// controller's span histogram and per-board drift EWMA (they all land
/// on the browned-out board — equal flow models tie-break to id 0),
/// then `requests` timed ones.  `hedge_p99 == 0.0` is the unhedged
/// control; the fleet config is otherwise identical.
fn run_hedge_leg(warmup: usize, requests: usize, hedge_p99: f64) -> HedgeLeg {
    let chaos = ChaosSpec::parse("slow=4x0", 0x4ED6E).unwrap();
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 1024,
        time_scale: TIME_SCALE,
        chaos: Some(chaos),
        // Inert health — chaos would otherwise enable it with defaults
        // and the drift signal would eject the browned-out board.  This
        // scenario holds the fleet degraded on purpose: ejection is the
        // *permanent* remedy benched by scenario 2; hedging is the
        // reversible one and must carry the tail alone here.
        health: Some(HealthConfig {
            max_consecutive_failures: 0,
            max_drift_ratio: 0.0,
            stall_timeout: Duration::ZERO,
            ..Default::default()
        }),
        trace_sample: 1,
        deadline_us: DEADLINE_US,
        hedge_p99,
        ..Default::default()
    };
    let fleet = Fleet::start(equal_replica_registry(), cfg).unwrap();
    let handle = fleet.handle();
    let x = vec![0.2f32; tinyml_codesign::data::feature_dim("kws")];
    let mut latencies = Vec::with_capacity(requests);
    for i in 0..warmup + requests {
        let t0 = Instant::now();
        let rx = handle.submit("kws", x.clone()).expect("closed-loop submit refused");
        let reply = rx.recv_timeout(RECV_TIMEOUT).expect("closed-loop reply lost");
        reply.expect("closed-loop request resolved to a typed failure");
        if i >= warmup {
            latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let summary = fleet.shutdown();
    latencies.sort_by(|a, b| a.total_cmp(b));
    let h = summary.snapshot.hedge.unwrap_or_default();
    let d = summary.snapshot.deadline;
    HedgeLeg {
        p50_us: percentile_us(&latencies, 0.50),
        p99_us: percentile_us(&latencies, 0.99),
        hedged: h.hedged,
        wins: h.wins,
        cancelled: h.cancelled,
        shed_submit: d.shed_submit,
        executed_expired: d.executed_expired,
    }
}

fn main() {
    let quick = quick();
    let kill_requests = if quick { 80 } else { 160 };
    let brownout_requests = if quick { 80 } else { 160 };
    let (trickle, burst) = if quick { (30, 120) } else { (40, 240) };
    // Warmup must exceed the hedge controller's seed floor (8 spans)
    // with room for the drift EWMA to converge on the 4x slowdown.
    let (hedge_warmup, hedge_requests) = if quick { (16, 60) } else { (16, 120) };

    println!(
        "[bench] scenario 1: kill=fastest@3 over {kill_requests} requests \
         (2 kws replicas, time_scale {TIME_SCALE}{})",
        if quick { ", quick mode" } else { "" }
    );
    let kill = run_kill(kill_requests);
    println!(
        "[bench] kill      : {}/{} resolved ok ({} typed-failed, {} lost), \
         {} ejection(s) in {:.0} ms ({}), {} exec failures absorbed",
        kill.ok,
        kill.submitted,
        kill.failed,
        kill.lost,
        kill.ejections,
        kill.time_to_eject_ms,
        kill.eject_reason,
        kill.exec_failures
    );

    println!(
        "\n[bench] scenario 2: slow=4x0 brownout vs healthy control \
         ({brownout_requests} requests each)"
    );
    let healthy = run_brownout_leg(brownout_requests, false);
    let degraded = run_brownout_leg(brownout_requests, true);
    let p99_ratio = degraded.p99_us / healthy.p99_us.max(1e-9);
    println!(
        "[bench] healthy   : p99 {:>9.1} us ({} served, {} ejections)",
        healthy.p99_us, healthy.served, healthy.ejections
    );
    println!(
        "[bench] degraded  : p99 {:>9.1} us ({} served, {} ejections) -> \
         ratio {p99_ratio:.2} (ceiling 8.0)",
        degraded.p99_us, degraded.served, degraded.ejections
    );

    println!(
        "\n[bench] scenario 3: flash crowd of {burst} on a fleet with a \
         dead-on-arrival replica ({trickle}-request trickle first)"
    );
    let crowd = run_flash_crowd(trickle, burst);
    println!(
        "[bench] flash     : {}/{} served ({} typed-failed, {} lost), \
         recovered in {:.0} ms",
        crowd.ok, crowd.burst, crowd.failed, crowd.lost, crowd.time_to_recover_ms
    );

    println!(
        "\n[bench] scenario 4: slow=4x0 with health inert, hedged vs unhedged \
         ({hedge_requests} closed-loop requests each, {hedge_warmup} warmup, \
         deadline {DEADLINE_US} us)"
    );
    let unhedged = run_hedge_leg(hedge_warmup, hedge_requests, 0.0);
    let hedged = run_hedge_leg(hedge_warmup, hedge_requests, 0.7);
    let hedge_ratio = hedged.p99_us / unhedged.p99_us.max(1e-9);
    println!(
        "[bench] unhedged  : p50 {:>9.1} us, p99 {:>9.1} us",
        unhedged.p50_us, unhedged.p99_us
    );
    println!(
        "[bench] hedged    : p50 {:>9.1} us, p99 {:>9.1} us ({} legs, {} wins, \
         {} losers cancelled) -> ratio {hedge_ratio:.2} (ceiling 0.6)",
        hedged.p50_us, hedged.p99_us, hedged.hedged, hedged.wins, hedged.cancelled
    );

    let kill_resolved_fraction = kill.ok as f64 / kill.submitted as f64;
    let kill_ejected = if kill.ejections >= 1 { 1.0 } else { 0.0 };
    let doc = obj(vec![
        ("bench", s("scenarios")),
        ("quick", Value::Bool(quick)),
        (
            "kill",
            obj(vec![
                ("submitted", num(kill.submitted as f64)),
                ("ok", num(kill.ok as f64)),
                ("failed", num(kill.failed as f64)),
                ("lost", num(kill.lost as f64)),
                ("ejections", num(kill.ejections as f64)),
                ("exec_failures", num(kill.exec_failures as f64)),
                ("time_to_eject_ms", num(kill.time_to_eject_ms)),
                ("eject_reason", s(&kill.eject_reason)),
                ("resolved_fraction", num(kill_resolved_fraction)),
                ("ejected", num(kill_ejected)),
            ]),
        ),
        (
            "brownout",
            obj(vec![
                ("requests", num(brownout_requests as f64)),
                ("healthy_p99_us", num(healthy.p99_us)),
                ("degraded_p99_us", num(degraded.p99_us)),
                ("degraded_ejections", num(degraded.ejections as f64)),
                ("p99_under_failure_ratio", num(p99_ratio)),
            ]),
        ),
        (
            "flash_crowd",
            obj(vec![
                ("trickle", num(trickle as f64)),
                ("burst", num(crowd.burst as f64)),
                ("ok", num(crowd.ok as f64)),
                ("failed", num(crowd.failed as f64)),
                ("lost", num(crowd.lost as f64)),
                ("time_to_recover_ms", num(crowd.time_to_recover_ms)),
                ("recovery_served_fraction", num(crowd.served_fraction)),
            ]),
        ),
        (
            "hedge",
            obj(vec![
                ("requests", num(hedge_requests as f64)),
                ("warmup", num(hedge_warmup as f64)),
                ("deadline_us", num(DEADLINE_US as f64)),
                ("unhedged_p50_us", num(unhedged.p50_us)),
                ("unhedged_p99_us", num(unhedged.p99_us)),
                ("hedged_p50_us", num(hedged.p50_us)),
                ("hedged_p99_us", num(hedged.p99_us)),
                ("hedged_legs", num(hedged.hedged as f64)),
                ("hedge_wins", num(hedged.wins as f64)),
                ("hedge_cancelled", num(hedged.cancelled as f64)),
                ("shed_submit", num((unhedged.shed_submit + hedged.shed_submit) as f64)),
                (
                    "executed_expired",
                    num((unhedged.executed_expired + hedged.executed_expired) as f64),
                ),
                ("hedged_p99_over_unhedged", num(hedge_ratio)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_scenarios.json", doc.to_json())
        .expect("write BENCH_scenarios.json");
    println!("[bench] wrote BENCH_scenarios.json");

    // Self-checks.  Scenario 1: conservation under failure — the whole
    // point of the retry pump.
    assert_eq!(kill.lost, 0, "kill scenario lost {} requests", kill.lost);
    assert_eq!(
        kill.failed, 0,
        "kill scenario exhausted {} retry budgets",
        kill.failed
    );
    assert_eq!(kill.ok, kill.submitted, "every admitted request must resolve ok");
    assert_eq!(kill.ejections, 1, "exactly the kill victim gets ejected");
    assert!(
        kill.eject_reason.starts_with("ejected:failures:"),
        "a dead board is convicted by its failure streak, got '{}'",
        kill.eject_reason
    );
    assert!(kill.exec_failures > 0, "the kill must actually fail batches");
    // Scenario 2: the brownout is detected by drift alone and the tail
    // stays bounded.
    assert_eq!(healthy.ejections, 0, "the healthy control must not eject");
    assert_eq!(healthy.lost + degraded.lost, 0, "brownout legs lost requests");
    assert_eq!(degraded.ejections, 1, "drift must convict the browned-out board");
    assert!(
        p99_ratio <= 8.0,
        "p99 under brownout {:.1} us must stay within 8x healthy {:.1} us \
         (ratio {p99_ratio:.2})",
        degraded.p99_us,
        healthy.p99_us
    );
    // Scenario 3: a freshly degraded fleet still serves the flash crowd.
    assert_eq!(crowd.lost, 0, "flash crowd lost {} requests", crowd.lost);
    assert!(
        crowd.served_fraction >= 0.95,
        "degraded fleet served only {:.3} of the flash crowd",
        crowd.served_fraction
    );
    // Scenario 4: hedging must actually fire, the duplicate leg must
    // actually win, and the deadline plane must never let an expired
    // request burn a board window — in either leg.
    assert_eq!(unhedged.hedged, 0, "the control leg must not hedge");
    assert!(hedged.hedged > 0, "the armed leg never hedged a request");
    assert!(hedged.wins > 0, "no duplicate leg ever won its race");
    assert_eq!(
        unhedged.executed_expired + hedged.executed_expired,
        0,
        "a board executed a request that was already past its deadline"
    );
    assert!(
        hedge_ratio <= 0.6,
        "hedged p99 {:.1} us must stay within 0.6x unhedged {:.1} us \
         (ratio {hedge_ratio:.2})",
        hedged.p99_us,
        unhedged.p99_us
    );
    println!(
        "[bench] OK: kill resolved {}/{} with {} ejection(s); brownout p99 ratio \
         {p99_ratio:.2} <= 8.0 with a drift ejection; flash crowd served \
         {:.3} >= 0.95; hedged p99 ratio {hedge_ratio:.2} <= 0.6 with \
         {} wins and 0 executed-expired",
        kill.ok, kill.submitted, kill.ejections, crowd.served_fraction, hedged.wins
    );
}
