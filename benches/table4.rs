//! Bench: regenerate Table 4 (AD optimization ablation, RF 144).
use std::time::Instant;
use tinyml_codesign::report::tables;

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    let t0 = Instant::now();
    let text = tables::table4(&art, None).unwrap();
    println!("{text}");
    println!("[bench] table4 (4 AD variants) in {:.2} s", t0.elapsed().as_secs_f64());
}
