//! Bench: Fig. 4 (KWS quantization exploration) — REAL QAT training of the
//! W1A1/W3A3/FP32 variants through the PJRT runtime (the full 6-variant
//! sweep is examples/kws_quant_scan.rs; this bench keeps 3 for time) and
//! the BOPs x-axis for all six.
use std::time::Instant;
use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    println!("variant,bops,wm_bits  (all six variants)");
    for (v, bops, wm) in tables::fig4_costs(&art).unwrap() {
        println!("{v},{bops:.3e},{wm:.0}");
    }
    let rt = Runtime::cpu().unwrap();
    let steps = 150;
    let mut accs = Vec::new();
    for v in ["w1a1", "w3a3", "fp32"] {
        let mut m = LoadedModel::load(&art, &format!("kws_mlp_{v}")).unwrap();
        let cfg = TrainConfig { steps, lr: 0.08, final_lr_frac: 0.15, log_every: steps, seed: 4 };
        let t0 = Instant::now();
        coordinator::train(&rt, &mut m, &cfg).unwrap();
        let train_s = t0.elapsed().as_secs_f64();
        let acc = coordinator::evaluate(&rt, &mut m, 300, 0xE7A1).unwrap();
        println!("[bench] {v}: {steps} steps in {train_s:.1} s ({:.1} ms/step) -> acc {acc:.3}",
            train_s * 1e3 / steps as f64);
        accs.push((v, acc));
    }
    // The Fig. 4 cliff: W1A1 must be clearly below W3A3; W3A3 ~ FP32.
    let get = |name: &str| accs.iter().find(|a| a.0 == name).unwrap().1;
    assert!(get("w3a3") - get("w1a1") > 0.05, "no quantization cliff: {accs:?}");
    assert!((get("fp32") - get("w3a3")).abs() < 0.08, "w3a3 should track fp32: {accs:?}");
    println!("cliff OK: w1a1 {:.3} << w3a3 {:.3} ~= fp32 {:.3}", get("w1a1"), get("w3a3"), get("fp32"));
}
