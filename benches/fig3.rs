//! Bench: regenerate Fig. 3 (ASHA over CNV variants) and time the scan.
use std::time::Instant;
use tinyml_codesign::dse;

fn main() {
    let t0 = Instant::now();
    println!("{}", tinyml_codesign::report::tables::fig3(128, 0xF17));
    println!("[bench] 128-config adaptive ASHA in {:.2} s", t0.elapsed().as_secs_f64());
    let pts = dse::run_cnv_asha_scan(128, 0xF17);
    let evals = pts.len();
    let top = pts.iter().filter(|p| p.rung == 3).count();
    println!("[bench] {evals} evaluations, {top} reached the top rung (eta=4 halving)");
    assert!(top >= 1 && evals < 128 * 2);
}
