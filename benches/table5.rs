//! Bench: regenerate Table 5 (resources/latency/energy, 4 models x 2
//! boards) — the paper's headline table — and check the headline claims.
use std::time::Instant;
use tinyml_codesign::board::pynq_z2;
use tinyml_codesign::report::tables;

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    let t0 = Instant::now();
    let text = tables::table5(&art).unwrap();
    println!("{text}");
    println!("[bench] table5 (8 full flows) in {:.2} s", t0.elapsed().as_secs_f64());
    println!("{}", tables::ic_comparison(&art).unwrap());
    // Headline claims: latency as low as ~20 us, energy as low as ~30 uJ.
    let b = pynq_z2();
    let kws = tables::flow_for(&art, "kws_mlp_w3a3", &b).unwrap();
    let ad = tables::flow_for(&art, "ad_autoencoder", &b).unwrap();
    let min_lat = kws.latency_s.min(ad.latency_s) * 1e6;
    let min_e = kws.energy_per_inference_uj.min(ad.energy_per_inference_uj);
    println!("headline: min latency {min_lat:.1} us (paper ~20 us), min energy {min_e:.1} uJ (paper ~30 uJ)");
    assert!(min_lat < 60.0, "latency headline off: {min_lat}");
    assert!(min_e < 120.0, "energy headline off: {min_e}");
}
