//! Bench: regenerate Table 2 (FIFO sizes) and time the FIFO-depth
//! optimization pass (sizing simulation) per model.
use std::time::Instant;
use tinyml_codesign::board::pynq_z2;
use tinyml_codesign::report::tables;

fn main() {
    let art = tinyml_codesign::artifacts_dir();
    let t0 = Instant::now();
    println!("{}", tables::table2(&art).unwrap());
    println!("[bench] table2 generated in {:.2} s", t0.elapsed().as_secs_f64());
    for (label, name) in tables::SUBMITTED {
        let t0 = Instant::now();
        let r = tables::flow_for(&art, name, &pynq_z2()).unwrap();
        println!(
            "[bench] {label:<14} FIFO sizing sim: {:>9} cycles simulated in {:.1} ms",
            r.fifo.sizing_run.simulated_cycles,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
}
