//! Bench: fleet routing policies under skewed load, plus elastic
//! (autoscaled) vs fixed capacity under a bursty trace.
//!
//! **Part 1 — routing policies.**  90% of the traffic is KWS, served by
//! two *heterogeneous* replicas: a full-budget Pynq-Z2 deployment and an
//! Arty A7-100T folded to 1/8th of the multiplier budget (~10x slower
//! after the clock difference).  Round-robin splits KWS traffic evenly
//! and ends up waiting on the slow replica; least-loaded observes the
//! queue imbalance and shifts traffic to the fast one.  Work stealing is
//! disabled so the routing policy is the only balancing mechanism being
//! measured.  Self-checking: least-loaded throughput >= round-robin.
//!
//! **Part 2 — autoscaling.**  Three task-phased bursts (KWS, then AD,
//! then IC), each paced *above* what two replicas of the task can serve
//! and *below* what four can, with idle gaps in between.  The fixed
//! fleet pins 2 replicas per task (6 boards, always on).  The elastic
//! fleet starts at 1 replica per task (3 boards) and lets the
//! telemetry-driven controller grow the hot task to 4 and shrink back
//! when the burst passes — capacity follows the traffic instead of being
//! provisioned for the union of peaks.  Self-checking: the elastic fleet
//! serves every request with p99 <= the fixed 6-board fleet while
//! spending fewer board-seconds.
//!
//! **Part 3 — multi-tenant priority scheduling.**  One board buried
//! under a 70/20/10 Batch/Standard/Interactive open-loop overload, once
//! with the single-FIFO control (`fifo_queues`) and once with the
//! class-aware queue plane.  FIFO parks interactive requests behind the
//! batch flood and tail-drops every class uniformly; priority
//! scheduling serves Interactive first and sheds only Batch.
//! Self-checking: priority-scheduled Interactive p99 <= 0.5x the FIFO
//! Interactive p99, with **zero** Interactive sheds (Batch absorbs all
//! of the overload), and per-class stats present in the JSON.
//!
//! Writes `BENCH_fleet.json` (per-policy p50/p99/throughput/µJ plus the
//! autoscale-vs-fixed comparison and the priority A/B) the way
//! `benches/kernels.rs` writes `BENCH_kernels.json`, so later PRs have a
//! recorded trajectory to beat — `tools/bench_gate.sh` holds the
//! headline ratios as a CI floor.  `BENCH_QUICK=1` (used by ci.sh) cuts
//! the trace sizes but keeps every assertion.

use std::time::{Duration, Instant};
use tinyml_codesign::board::{arty_a7_100t, pynq_z2};
use tinyml_codesign::data::prng::SplitMix64;
use tinyml_codesign::dataflow::schedule::ScheduleConfig;
use tinyml_codesign::fleet::worker::precise_sleep;
use tinyml_codesign::fleet::{
    AutoscaleConfig, BoardInstance, ClassSnapshot, Fleet, FleetConfig, FleetSnapshot,
    Policy, Priority, Registry, RequestTag, RouteError, ScaleAction,
};
use tinyml_codesign::report::json::{num, obj, s, Value};

#[path = "util.rs"]
mod util;
use util::quick;

const TIME_SCALE: f64 = 50.0;

// ---------------------------------------------------------------------------
// Part 1: routing policies under skewed load.
// ---------------------------------------------------------------------------

fn skewed_registry() -> Registry {
    let mut reg = Registry::new();
    let fast = ScheduleConfig::default();
    let slow = ScheduleConfig { finn_mult_budget: fast.finn_mult_budget / 8, ..fast.clone() };
    reg.add_with(pynq_z2(), "kws_mlp_w3a3", &fast).unwrap();
    reg.add_with(arty_a7_100t(), "kws_mlp_w3a3", &slow).unwrap();
    reg.add(pynq_z2(), "ad_autoencoder").unwrap();
    reg.add(pynq_z2(), "ic_cnv_w1a1").unwrap();
    reg
}

fn skewed_workload(n: usize) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = SplitMix64::new(0xBE7C);
    (0..n)
        .map(|_| {
            let task = match rng.next_below(20) {
                0 => "ad",
                1 => "ic",
                _ => "kws", // 90%
            };
            let dim = tinyml_codesign::data::feature_dim(task);
            (task, vec![0.2f32; dim])
        })
        .collect()
}

struct PolicyResult {
    policy: &'static str,
    throughput_rps: f64,
    p50_us: f64,
    p99_us: f64,
    uj_per_inf: f64,
}

/// Run one policy over the skewed trace (closed submission loop with
/// backpressure retries).
fn run_policy(policy: Policy, name: &'static str, requests: usize) -> PolicyResult {
    let cfg = FleetConfig {
        policy,
        queue_cap: 64,
        time_scale: TIME_SCALE,
        work_stealing: false,
        ..Default::default()
    };
    let fleet = Fleet::start(skewed_registry(), cfg).unwrap();
    let handle = fleet.handle();
    let reqs = skewed_workload(requests);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (task, x) in reqs {
        loop {
            match handle.submit(task, x.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(e) => panic!("unexpected rejection: {e:?}"),
            }
        }
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let summary = fleet.shutdown();
    assert_eq!(summary.snapshot.served as usize, requests);
    PolicyResult {
        policy: name,
        throughput_rps: requests as f64 / wall,
        p50_us: summary.snapshot.p50_us,
        p99_us: summary.snapshot.p99_us,
        uj_per_inf: summary.snapshot.energy_per_inference_uj,
    }
}

// ---------------------------------------------------------------------------
// Part 2: autoscale-on vs fixed fleet under a bursty trace.
// ---------------------------------------------------------------------------

/// Identical synthetic replica envelope per task so elastic clones match
/// fixed replicas exactly: latency 400 us, II 80 us.
fn bursty_instance(id: usize, task: &str) -> BoardInstance {
    let power = match task {
        "kws" => 1.5,
        "ad" => 1.2,
        _ => 1.8,
    };
    BoardInstance::synthetic(id, task, 400.0, 80.0, power)
}

const BURST_TASKS: [&str; 3] = ["kws", "ad", "ic"];

struct BurstyResult {
    snapshot: FleetSnapshot,
    wall_s: f64,
    max_boards_alive: usize,
    /// Max per-board peak queue depth in each burst phase
    /// (`Fleet::snapshot_phase` rolls the peaks over at the boundary, so
    /// these are per-phase numbers, not since-start stickiness).
    phase_peak_depths: Vec<usize>,
}

/// Run the phased bursty trace.  `elastic == false`: 2 replicas per task,
/// fixed.  `elastic == true`: 1 replica per task + the autoscale
/// controller (max 4 per task).
fn run_bursty(elastic: bool, per_burst: usize) -> BurstyResult {
    let replicas = if elastic { 1 } else { 2 };
    let mut instances = Vec::new();
    for task in BURST_TASKS {
        for _ in 0..replicas {
            instances.push(bursty_instance(instances.len(), task));
        }
    }
    let autoscale = elastic.then_some(AutoscaleConfig {
        interval: Duration::from_millis(2),
        high_queue: 2.0,
        slo_p99_us: 0.0,
        low_util: 0.25,
        min_replicas: 1,
        max_replicas: 4,
        cooldown: Duration::from_millis(8),
    });
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 1024,
        time_scale: 20.0,
        autoscale,
        ..Default::default()
    };
    let fleet = Fleet::start(Registry { instances }, cfg).unwrap();
    let handle = fleet.handle();
    // Arrival pacing: one request per 700 us => ~1430 req/s, between the
    // 2-replica (~830/s) and 4-replica (~1670/s) batched service rates.
    let arrival = Duration::from_micros(700);
    let gap = Duration::from_millis(100);
    let t0 = Instant::now();
    let mut max_boards_alive = 0usize;
    let mut phase_peak_depths = Vec::new();
    for task in BURST_TASKS {
        let dim = tinyml_codesign::data::feature_dim(task);
        let x = vec![0.2f32; dim];
        let mut pending = Vec::with_capacity(per_burst);
        for _ in 0..per_burst {
            match handle.submit(task, x.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("bursty trace rejected (queue_cap too small?): {e:?}"),
            }
            precise_sleep(arrival);
        }
        for rx in pending {
            rx.recv().expect("request dropped").expect("request failed");
        }
        let alive: usize =
            BURST_TASKS.iter().map(|t| fleet.active_replicas(t)).sum();
        max_boards_alive = max_boards_alive.max(alive);
        // Phase boundary: snapshot + roll the peak-depth high-water
        // marks over so the next phase reports its own peak.
        let phase = fleet.snapshot_phase();
        phase_peak_depths
            .push(phase.per_board.iter().map(|b| b.depth_peak).max().unwrap_or(0));
        // Idle gap: the elastic fleet shrinks back toward the floor here.
        precise_sleep(gap);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let summary = fleet.shutdown();
    BurstyResult {
        snapshot: summary.snapshot,
        wall_s,
        max_boards_alive,
        phase_peak_depths,
    }
}

// ---------------------------------------------------------------------------
// Part 3: multi-tenant priority scheduling vs the single-FIFO control.
// ---------------------------------------------------------------------------

struct ContendedResult {
    snapshot: FleetSnapshot,
    submitted: usize,
}

impl ContendedResult {
    fn class(&self, p: Priority) -> &ClassSnapshot {
        &self.snapshot.classes[p.idx()]
    }
}

/// One board, open-loop 70/20/10 Batch/Standard/Interactive overload:
/// arrivals are paced at roughly twice the board's batched service rate,
/// rejections are sheds (no retries).  The urgent classes (30% of
/// arrivals) fit comfortably under the service rate, so with priority
/// scheduling every shed should land on Batch.
fn run_contended(fifo: bool, requests: usize) -> ContendedResult {
    let reg = Registry {
        instances: vec![BoardInstance::synthetic(0, "kws", 400.0, 80.0, 1.5)],
    };
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 64,
        time_scale: 20.0,
        work_stealing: false,
        fifo_queues: fifo,
        ..Default::default()
    };
    let fleet = Fleet::start(reg, cfg).unwrap();
    let handle = fleet.handle();
    let mut rng = SplitMix64::new(0x9917_0001);
    let dim = tinyml_codesign::data::feature_dim("kws");
    let x = vec![0.2f32; dim];
    // Batched service rate ~= 8 / ((400 + 7*80) us * 20) ~= 420 req/s;
    // one arrival per 1.2 ms ~= 830 req/s = ~2x overload.  The
    // interactive (10%) + standard (20%) slice is ~250 req/s — well
    // within capacity, so only Batch *needs* to be shed.
    let arrival = Duration::from_micros(1200);
    let mut pending = Vec::new();
    for i in 0..requests {
        let priority = match rng.next_below(10) {
            0 => Priority::Interactive,
            1 | 2 => Priority::Standard,
            _ => Priority::Batch, // 70%
        };
        let tag = RequestTag::new(i as u32 % 4, priority);
        if let Ok(rx) = handle.submit_tagged("kws", x.clone(), tag) {
            pending.push(rx);
        }
        precise_sleep(arrival);
    }
    for rx in pending {
        rx.recv().expect("admitted request dropped").expect("request failed");
    }
    let summary = fleet.shutdown();
    ContendedResult { snapshot: summary.snapshot, submitted: requests }
}

fn contended_json(tag: &str, r: &ContendedResult) -> Value {
    let shed_total: u64 = r.snapshot.classes.iter().map(|c| c.shed).sum();
    obj(vec![
        ("mode", s(tag)),
        ("submitted", num(r.submitted as f64)),
        ("served", num(r.snapshot.served as f64)),
        ("shed_total", num(shed_total as f64)),
        ("tenants", num(r.snapshot.tenants.len() as f64)),
        (
            "classes",
            Value::Arr(r.snapshot.classes.iter().map(|c| c.to_json()).collect()),
        ),
    ])
}

fn bursty_json(tag: &str, r: &BurstyResult, served_want: usize) -> Value {
    obj(vec![
        ("mode", s(tag)),
        ("served", num(r.snapshot.served as f64)),
        ("expected", num(served_want as f64)),
        ("wall_s", num(r.wall_s)),
        ("p50_us", num(r.snapshot.p50_us)),
        ("p99_us", num(r.snapshot.p99_us)),
        ("uj_per_inf", num(r.snapshot.energy_per_inference_uj)),
        ("board_seconds", num(r.snapshot.board_seconds)),
        ("scale_events", num(r.snapshot.scale_events.len() as f64)),
        ("max_boards_alive", num(r.max_boards_alive as f64)),
        (
            "phase_peak_depths",
            Value::Arr(r.phase_peak_depths.iter().map(|&p| num(p as f64)).collect()),
        ),
    ])
}

fn main() {
    let quick = quick();
    let policy_requests = if quick { 200 } else { 400 };
    let per_burst = if quick { 120 } else { 240 };

    println!(
        "[bench] part 1: fleet routing under skewed load ({policy_requests} requests, \
         90% kws, heterogeneous kws replicas, time_scale {TIME_SCALE}, no stealing{})",
        if quick { ", quick mode" } else { "" }
    );
    let results = [
        run_policy(Policy::RoundRobin, "round-robin", policy_requests),
        run_policy(Policy::LeastLoaded, "least-loaded", policy_requests),
        run_policy(Policy::EnergyAware, "energy-aware", policy_requests),
    ];
    for r in &results {
        println!(
            "[bench] {:<12}: {:>8.0} req/s  p50 {:>9.1} us  p99 {:>9.1} us  {:>6.2} uJ/inf",
            r.policy, r.throughput_rps, r.p50_us, r.p99_us, r.uj_per_inf
        );
    }
    let (rr, ll) = (&results[0], &results[1]);
    println!(
        "[bench] least-loaded / round-robin throughput = {:.2}x",
        ll.throughput_rps / rr.throughput_rps
    );

    println!(
        "\n[bench] part 2: autoscale vs fixed fleet over 3 task-phased bursts of \
         {per_burst} requests (1 / 700 us pacing, 100 ms gaps)"
    );
    let fixed = run_bursty(false, per_burst);
    let elastic = run_bursty(true, per_burst);
    let served_want = 3 * per_burst;
    println!(
        "[bench] fixed-6   : p50 {:>9.1} us  p99 {:>9.1} us  {:>7.3} board-s  ({} served)",
        fixed.snapshot.p50_us,
        fixed.snapshot.p99_us,
        fixed.snapshot.board_seconds,
        fixed.snapshot.served
    );
    println!(
        "[bench] autoscale : p50 {:>9.1} us  p99 {:>9.1} us  {:>7.3} board-s  \
         ({} served, {} scale events)",
        elastic.snapshot.p50_us,
        elastic.snapshot.p99_us,
        elastic.snapshot.board_seconds,
        elastic.snapshot.served,
        elastic.snapshot.scale_events.len()
    );
    for e in &elastic.snapshot.scale_events {
        println!("[bench]   {e}");
    }

    let contended_requests = if quick { 320 } else { 640 };
    println!(
        "\n[bench] part 3: priority scheduling vs FIFO under a 70/20/10 \
         batch/standard/interactive overload ({contended_requests} requests, \
         1 / 1.2 ms open-loop pacing, ~2x capacity)"
    );
    let fifo = run_contended(true, contended_requests);
    let classful = run_contended(false, contended_requests);
    for (tag, r) in [("fifo      ", &fifo), ("classful  ", &classful)] {
        let i = r.class(Priority::Interactive);
        let b = r.class(Priority::Batch);
        println!(
            "[bench] {tag}: interactive p99 {:>9.1} us ({} served, {} shed) | \
             batch p99 {:>9.1} us ({} served, {} shed)",
            i.p99_us, i.served, i.shed, b.p99_us, b.served, b.shed
        );
    }
    let interactive_p99_ratio =
        classful.class(Priority::Interactive).p99_us
            / fifo.class(Priority::Interactive).p99_us.max(1e-9);
    println!(
        "[bench] interactive p99 classful / fifo = {interactive_p99_ratio:.3} \
         (floor: <= 0.5)"
    );

    let doc = obj(vec![
        ("bench", s("fleet")),
        ("quick", Value::Bool(quick)),
        (
            "policies",
            Value::Arr(
                results
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("policy", s(r.policy)),
                            ("requests", num(policy_requests as f64)),
                            ("throughput_rps", num(r.throughput_rps)),
                            ("p50_us", num(r.p50_us)),
                            ("p99_us", num(r.p99_us)),
                            ("uj_per_inf", num(r.uj_per_inf)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "autoscale",
            obj(vec![
                ("per_burst", num(per_burst as f64)),
                ("fixed", bursty_json("fixed-6", &fixed, served_want)),
                ("elastic", bursty_json("autoscale", &elastic, served_want)),
                (
                    "p99_ratio_elastic_over_fixed",
                    num(elastic.snapshot.p99_us / fixed.snapshot.p99_us.max(1e-9)),
                ),
                (
                    "board_seconds_ratio_elastic_over_fixed",
                    num(elastic.snapshot.board_seconds
                        / fixed.snapshot.board_seconds.max(1e-9)),
                ),
            ]),
        ),
        (
            "priority",
            obj(vec![
                ("requests", num(contended_requests as f64)),
                (
                    "mix",
                    obj(vec![
                        ("interactive", num(0.1)),
                        ("standard", num(0.2)),
                        ("batch", num(0.7)),
                    ]),
                ),
                ("fifo", contended_json("fifo", &fifo)),
                ("classful", contended_json("classful", &classful)),
                (
                    "interactive_p99_ratio_classful_over_fifo",
                    num(interactive_p99_ratio),
                ),
            ]),
        ),
    ]);
    std::fs::write("BENCH_fleet.json", doc.to_json()).expect("write BENCH_fleet.json");
    println!("[bench] wrote BENCH_fleet.json");

    // Self-checks.  Part 1: the load-aware policy must beat blind
    // rotation under skew.
    assert!(
        ll.throughput_rps >= rr.throughput_rps,
        "least-loaded must beat round-robin under skewed load: {:.0} < {:.0}",
        ll.throughput_rps,
        rr.throughput_rps
    );
    // Part 2: conservation first — scaling must not drop anything.
    assert_eq!(fixed.snapshot.served as usize, served_want, "fixed fleet dropped");
    assert_eq!(elastic.snapshot.served as usize, served_want, "elastic fleet dropped");
    let ups = elastic
        .snapshot
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Up)
        .count();
    let downs = elastic
        .snapshot
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Down)
        .count();
    assert!(ups >= 1, "bursts never tripped a scale-up");
    assert!(downs >= 1, "idle gaps never tripped a scale-down");
    // The headline: elastic capacity follows the hot task, so the tail
    // is no worse than the always-on 6-board fleet...
    assert!(
        elastic.snapshot.p99_us <= fixed.snapshot.p99_us,
        "autoscale p99 {:.1} us must be <= fixed-fleet p99 {:.1} us",
        elastic.snapshot.p99_us,
        fixed.snapshot.p99_us
    );
    // ...while paying for strictly fewer board-seconds.
    assert!(
        elastic.snapshot.board_seconds < fixed.snapshot.board_seconds,
        "autoscale board-seconds {:.3} must be < fixed {:.3}",
        elastic.snapshot.board_seconds,
        fixed.snapshot.board_seconds
    );
    // Part 3: conservation in both modes (every submitted request was
    // either served or recorded as a shed of its class)...
    for (tag, r) in [("fifo", &fifo), ("classful", &classful)] {
        let shed_total: u64 = r.snapshot.classes.iter().map(|c| c.shed).sum();
        assert_eq!(
            r.snapshot.served as usize + shed_total as usize,
            r.submitted,
            "{tag}: served + shed must cover the whole trace"
        );
    }
    // ...the priority plane never sheds Interactive (Batch absorbs the
    // entire overload) while FIFO's uniform tail-drop does...
    assert_eq!(
        classful.class(Priority::Interactive).shed,
        0,
        "priority scheduling must not shed interactive requests"
    );
    assert_eq!(
        classful.class(Priority::Standard).shed,
        0,
        "the standard slice fits under its admission bound"
    );
    assert!(
        classful.class(Priority::Batch).shed > 0,
        "a 2x overload must shed batch traffic"
    );
    // ...and the headline: strict-priority pickup + batch-first shedding
    // must at least halve the interactive tail vs the FIFO control.
    assert!(
        classful.class(Priority::Interactive).served > 0
            && fifo.class(Priority::Interactive).served > 0,
        "both modes must serve interactive traffic for the ratio to mean anything"
    );
    assert!(
        interactive_p99_ratio <= 0.5,
        "priority interactive p99 {:.1} us must be <= 0.5x fifo {:.1} us (ratio {:.3})",
        classful.class(Priority::Interactive).p99_us,
        fifo.class(Priority::Interactive).p99_us,
        interactive_p99_ratio
    );
    println!(
        "[bench] OK: least-loaded >= round-robin; autoscale p99 {:.1} <= fixed {:.1} us \
         with {:.3} vs {:.3} board-seconds; interactive p99 ratio {:.3} <= 0.5 with \
         zero interactive sheds",
        elastic.snapshot.p99_us,
        fixed.snapshot.p99_us,
        elastic.snapshot.board_seconds,
        fixed.snapshot.board_seconds,
        interactive_p99_ratio
    );
}
