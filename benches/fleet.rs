//! Bench: fleet routing policies under skewed load, plus elastic
//! (autoscaled) vs fixed capacity under a bursty trace.
//!
//! **Part 1 — routing policies.**  90% of the traffic is KWS, served by
//! two *heterogeneous* replicas: a full-budget Pynq-Z2 deployment and an
//! Arty A7-100T folded to 1/8th of the multiplier budget (~10x slower
//! after the clock difference).  Round-robin splits KWS traffic evenly
//! and ends up waiting on the slow replica; least-loaded observes the
//! queue imbalance and shifts traffic to the fast one.  Work stealing is
//! disabled so the routing policy is the only balancing mechanism being
//! measured.  Self-checking: least-loaded throughput >= round-robin.
//!
//! **Part 2 — autoscaling.**  Three task-phased bursts (KWS, then AD,
//! then IC), each paced *above* what two replicas of the task can serve
//! and *below* what four can, with idle gaps in between.  The fixed
//! fleet pins 2 replicas per task (6 boards, always on).  The elastic
//! fleet starts at 1 replica per task (3 boards) and lets the
//! telemetry-driven controller grow the hot task to 4 and shrink back
//! when the burst passes — capacity follows the traffic instead of being
//! provisioned for the union of peaks.  Self-checking: the elastic fleet
//! serves every request with p99 <= the fixed 6-board fleet while
//! spending fewer board-seconds.
//!
//! Writes `BENCH_fleet.json` (per-policy p50/p99/throughput/µJ plus the
//! autoscale-vs-fixed comparison) the way `benches/kernels.rs` writes
//! `BENCH_kernels.json`, so later PRs have a recorded trajectory to
//! beat.  `BENCH_QUICK=1` (used by ci.sh) cuts the trace sizes but keeps
//! every assertion.

use std::time::{Duration, Instant};
use tinyml_codesign::board::{arty_a7_100t, pynq_z2};
use tinyml_codesign::data::prng::SplitMix64;
use tinyml_codesign::dataflow::schedule::ScheduleConfig;
use tinyml_codesign::fleet::worker::precise_sleep;
use tinyml_codesign::fleet::{
    AutoscaleConfig, BoardInstance, Fleet, FleetConfig, FleetSnapshot, Policy, Registry,
    RouteError, ScaleAction,
};
use tinyml_codesign::report::json::{num, obj, s, Value};

const TIME_SCALE: f64 = 50.0;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

// ---------------------------------------------------------------------------
// Part 1: routing policies under skewed load.
// ---------------------------------------------------------------------------

fn skewed_registry() -> Registry {
    let mut reg = Registry::new();
    let fast = ScheduleConfig::default();
    let slow = ScheduleConfig { finn_mult_budget: fast.finn_mult_budget / 8, ..fast.clone() };
    reg.add_with(pynq_z2(), "kws_mlp_w3a3", &fast).unwrap();
    reg.add_with(arty_a7_100t(), "kws_mlp_w3a3", &slow).unwrap();
    reg.add(pynq_z2(), "ad_autoencoder").unwrap();
    reg.add(pynq_z2(), "ic_cnv_w1a1").unwrap();
    reg
}

fn skewed_workload(n: usize) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = SplitMix64::new(0xBE7C);
    (0..n)
        .map(|_| {
            let task = match rng.next_below(20) {
                0 => "ad",
                1 => "ic",
                _ => "kws", // 90%
            };
            let dim = tinyml_codesign::data::feature_dim(task);
            (task, vec![0.2f32; dim])
        })
        .collect()
}

struct PolicyResult {
    policy: &'static str,
    throughput_rps: f64,
    p50_us: f64,
    p99_us: f64,
    uj_per_inf: f64,
}

/// Run one policy over the skewed trace (closed submission loop with
/// backpressure retries).
fn run_policy(policy: Policy, name: &'static str, requests: usize) -> PolicyResult {
    let cfg = FleetConfig {
        policy,
        queue_cap: 64,
        time_scale: TIME_SCALE,
        work_stealing: false,
        ..Default::default()
    };
    let fleet = Fleet::start(skewed_registry(), cfg).unwrap();
    let handle = fleet.handle();
    let reqs = skewed_workload(requests);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (task, x) in reqs {
        loop {
            match handle.submit(task, x.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(e) => panic!("unexpected rejection: {e:?}"),
            }
        }
    }
    for rx in pending {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let summary = fleet.shutdown();
    assert_eq!(summary.snapshot.served as usize, requests);
    PolicyResult {
        policy: name,
        throughput_rps: requests as f64 / wall,
        p50_us: summary.snapshot.p50_us,
        p99_us: summary.snapshot.p99_us,
        uj_per_inf: summary.snapshot.energy_per_inference_uj,
    }
}

// ---------------------------------------------------------------------------
// Part 2: autoscale-on vs fixed fleet under a bursty trace.
// ---------------------------------------------------------------------------

/// Identical synthetic replica envelope per task so elastic clones match
/// fixed replicas exactly: latency 400 us, II 80 us.
fn bursty_instance(id: usize, task: &str) -> BoardInstance {
    let power = match task {
        "kws" => 1.5,
        "ad" => 1.2,
        _ => 1.8,
    };
    BoardInstance::synthetic(id, task, 400.0, 80.0, power)
}

const BURST_TASKS: [&str; 3] = ["kws", "ad", "ic"];

struct BurstyResult {
    snapshot: FleetSnapshot,
    wall_s: f64,
    max_boards_alive: usize,
    /// Max per-board peak queue depth in each burst phase
    /// (`Fleet::snapshot_phase` rolls the peaks over at the boundary, so
    /// these are per-phase numbers, not since-start stickiness).
    phase_peak_depths: Vec<usize>,
}

/// Run the phased bursty trace.  `elastic == false`: 2 replicas per task,
/// fixed.  `elastic == true`: 1 replica per task + the autoscale
/// controller (max 4 per task).
fn run_bursty(elastic: bool, per_burst: usize) -> BurstyResult {
    let replicas = if elastic { 1 } else { 2 };
    let mut instances = Vec::new();
    for task in BURST_TASKS {
        for _ in 0..replicas {
            instances.push(bursty_instance(instances.len(), task));
        }
    }
    let autoscale = elastic.then_some(AutoscaleConfig {
        interval: Duration::from_millis(2),
        high_queue: 2.0,
        slo_p99_us: 0.0,
        low_util: 0.25,
        min_replicas: 1,
        max_replicas: 4,
        cooldown: Duration::from_millis(8),
    });
    let cfg = FleetConfig {
        policy: Policy::LeastLoaded,
        queue_cap: 1024,
        time_scale: 20.0,
        autoscale,
        ..Default::default()
    };
    let fleet = Fleet::start(Registry { instances }, cfg).unwrap();
    let handle = fleet.handle();
    // Arrival pacing: one request per 700 us => ~1430 req/s, between the
    // 2-replica (~830/s) and 4-replica (~1670/s) batched service rates.
    let arrival = Duration::from_micros(700);
    let gap = Duration::from_millis(100);
    let t0 = Instant::now();
    let mut max_boards_alive = 0usize;
    let mut phase_peak_depths = Vec::new();
    for task in BURST_TASKS {
        let dim = tinyml_codesign::data::feature_dim(task);
        let x = vec![0.2f32; dim];
        let mut pending = Vec::with_capacity(per_burst);
        for _ in 0..per_burst {
            match handle.submit(task, x.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("bursty trace rejected (queue_cap too small?): {e:?}"),
            }
            precise_sleep(arrival);
        }
        for rx in pending {
            rx.recv().expect("request dropped");
        }
        let alive: usize =
            BURST_TASKS.iter().map(|t| fleet.active_replicas(t)).sum();
        max_boards_alive = max_boards_alive.max(alive);
        // Phase boundary: snapshot + roll the peak-depth high-water
        // marks over so the next phase reports its own peak.
        let phase = fleet.snapshot_phase();
        phase_peak_depths
            .push(phase.per_board.iter().map(|b| b.depth_peak).max().unwrap_or(0));
        // Idle gap: the elastic fleet shrinks back toward the floor here.
        precise_sleep(gap);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let summary = fleet.shutdown();
    BurstyResult {
        snapshot: summary.snapshot,
        wall_s,
        max_boards_alive,
        phase_peak_depths,
    }
}

fn bursty_json(tag: &str, r: &BurstyResult, served_want: usize) -> Value {
    obj(vec![
        ("mode", s(tag)),
        ("served", num(r.snapshot.served as f64)),
        ("expected", num(served_want as f64)),
        ("wall_s", num(r.wall_s)),
        ("p50_us", num(r.snapshot.p50_us)),
        ("p99_us", num(r.snapshot.p99_us)),
        ("uj_per_inf", num(r.snapshot.energy_per_inference_uj)),
        ("board_seconds", num(r.snapshot.board_seconds)),
        ("scale_events", num(r.snapshot.scale_events.len() as f64)),
        ("max_boards_alive", num(r.max_boards_alive as f64)),
        (
            "phase_peak_depths",
            Value::Arr(r.phase_peak_depths.iter().map(|&p| num(p as f64)).collect()),
        ),
    ])
}

fn main() {
    let quick = quick();
    let policy_requests = if quick { 200 } else { 400 };
    let per_burst = if quick { 120 } else { 240 };

    println!(
        "[bench] part 1: fleet routing under skewed load ({policy_requests} requests, \
         90% kws, heterogeneous kws replicas, time_scale {TIME_SCALE}, no stealing{})",
        if quick { ", quick mode" } else { "" }
    );
    let results = [
        run_policy(Policy::RoundRobin, "round-robin", policy_requests),
        run_policy(Policy::LeastLoaded, "least-loaded", policy_requests),
        run_policy(Policy::EnergyAware, "energy-aware", policy_requests),
    ];
    for r in &results {
        println!(
            "[bench] {:<12}: {:>8.0} req/s  p50 {:>9.1} us  p99 {:>9.1} us  {:>6.2} uJ/inf",
            r.policy, r.throughput_rps, r.p50_us, r.p99_us, r.uj_per_inf
        );
    }
    let (rr, ll) = (&results[0], &results[1]);
    println!(
        "[bench] least-loaded / round-robin throughput = {:.2}x",
        ll.throughput_rps / rr.throughput_rps
    );

    println!(
        "\n[bench] part 2: autoscale vs fixed fleet over 3 task-phased bursts of \
         {per_burst} requests (1 / 700 us pacing, 100 ms gaps)"
    );
    let fixed = run_bursty(false, per_burst);
    let elastic = run_bursty(true, per_burst);
    let served_want = 3 * per_burst;
    println!(
        "[bench] fixed-6   : p50 {:>9.1} us  p99 {:>9.1} us  {:>7.3} board-s  ({} served)",
        fixed.snapshot.p50_us,
        fixed.snapshot.p99_us,
        fixed.snapshot.board_seconds,
        fixed.snapshot.served
    );
    println!(
        "[bench] autoscale : p50 {:>9.1} us  p99 {:>9.1} us  {:>7.3} board-s  \
         ({} served, {} scale events)",
        elastic.snapshot.p50_us,
        elastic.snapshot.p99_us,
        elastic.snapshot.board_seconds,
        elastic.snapshot.served,
        elastic.snapshot.scale_events.len()
    );
    for e in &elastic.snapshot.scale_events {
        println!("[bench]   {e}");
    }

    let doc = obj(vec![
        ("bench", s("fleet")),
        ("quick", Value::Bool(quick)),
        (
            "policies",
            Value::Arr(
                results
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("policy", s(r.policy)),
                            ("requests", num(policy_requests as f64)),
                            ("throughput_rps", num(r.throughput_rps)),
                            ("p50_us", num(r.p50_us)),
                            ("p99_us", num(r.p99_us)),
                            ("uj_per_inf", num(r.uj_per_inf)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "autoscale",
            obj(vec![
                ("per_burst", num(per_burst as f64)),
                ("fixed", bursty_json("fixed-6", &fixed, served_want)),
                ("elastic", bursty_json("autoscale", &elastic, served_want)),
                (
                    "p99_ratio_elastic_over_fixed",
                    num(elastic.snapshot.p99_us / fixed.snapshot.p99_us.max(1e-9)),
                ),
                (
                    "board_seconds_ratio_elastic_over_fixed",
                    num(elastic.snapshot.board_seconds
                        / fixed.snapshot.board_seconds.max(1e-9)),
                ),
            ]),
        ),
    ]);
    std::fs::write("BENCH_fleet.json", doc.to_json()).expect("write BENCH_fleet.json");
    println!("[bench] wrote BENCH_fleet.json");

    // Self-checks.  Part 1: the load-aware policy must beat blind
    // rotation under skew.
    assert!(
        ll.throughput_rps >= rr.throughput_rps,
        "least-loaded must beat round-robin under skewed load: {:.0} < {:.0}",
        ll.throughput_rps,
        rr.throughput_rps
    );
    // Part 2: conservation first — scaling must not drop anything.
    assert_eq!(fixed.snapshot.served as usize, served_want, "fixed fleet dropped");
    assert_eq!(elastic.snapshot.served as usize, served_want, "elastic fleet dropped");
    let ups = elastic
        .snapshot
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Up)
        .count();
    let downs = elastic
        .snapshot
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Down)
        .count();
    assert!(ups >= 1, "bursts never tripped a scale-up");
    assert!(downs >= 1, "idle gaps never tripped a scale-down");
    // The headline: elastic capacity follows the hot task, so the tail
    // is no worse than the always-on 6-board fleet...
    assert!(
        elastic.snapshot.p99_us <= fixed.snapshot.p99_us,
        "autoscale p99 {:.1} us must be <= fixed-fleet p99 {:.1} us",
        elastic.snapshot.p99_us,
        fixed.snapshot.p99_us
    );
    // ...while paying for strictly fewer board-seconds.
    assert!(
        elastic.snapshot.board_seconds < fixed.snapshot.board_seconds,
        "autoscale board-seconds {:.3} must be < fixed {:.3}",
        elastic.snapshot.board_seconds,
        fixed.snapshot.board_seconds
    );
    println!(
        "[bench] OK: least-loaded >= round-robin; autoscale p99 {:.1} <= fixed {:.1} us \
         with {:.3} vs {:.3} board-seconds",
        elastic.snapshot.p99_us,
        fixed.snapshot.p99_us,
        elastic.snapshot.board_seconds,
        fixed.snapshot.board_seconds
    );
}
