//! Bench: fleet routing policies under skewed load.
//!
//! 90% of the traffic is KWS, served by two *heterogeneous* replicas: a
//! full-budget Pynq-Z2 deployment and an Arty A7-100T folded to 1/8th of
//! the multiplier budget (~10x slower after the clock difference).
//! Round-robin splits KWS traffic evenly and ends up waiting on the slow
//! replica; least-loaded observes the queue imbalance and shifts traffic
//! to the fast one.  Work stealing is disabled so the routing policy is
//! the only balancing mechanism being measured.
//!
//! Self-checking: asserts least-loaded throughput >= round-robin.

use std::time::{Duration, Instant};
use tinyml_codesign::board::{arty_a7_100t, pynq_z2};
use tinyml_codesign::data::prng::SplitMix64;
use tinyml_codesign::dataflow::schedule::ScheduleConfig;
use tinyml_codesign::fleet::{Fleet, FleetConfig, Policy, Registry, RouteError};

const REQUESTS: usize = 400;
const TIME_SCALE: f64 = 50.0;

fn skewed_registry() -> Registry {
    let mut reg = Registry::new();
    let fast = ScheduleConfig::default();
    let slow = ScheduleConfig { finn_mult_budget: fast.finn_mult_budget / 8, ..fast.clone() };
    reg.add_with(pynq_z2(), "kws_mlp_w3a3", &fast).unwrap();
    reg.add_with(arty_a7_100t(), "kws_mlp_w3a3", &slow).unwrap();
    reg.add(pynq_z2(), "ad_autoencoder").unwrap();
    reg.add(pynq_z2(), "ic_cnv_w1a1").unwrap();
    reg
}

fn workload(n: usize) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = SplitMix64::new(0xBE7C);
    (0..n)
        .map(|_| {
            let task = match rng.next_below(20) {
                0 => "ad",
                1 => "ic",
                _ => "kws", // 90%
            };
            let dim = tinyml_codesign::data::feature_dim(task);
            (task, vec![0.2f32; dim])
        })
        .collect()
}

/// Run one policy; returns (throughput req/s, p99 us, uJ/inf).
fn run_policy(policy: Policy) -> (f64, f64, f64) {
    let cfg = FleetConfig {
        policy,
        queue_cap: 64,
        time_scale: TIME_SCALE,
        work_stealing: false,
        ..Default::default()
    };
    let fleet = Fleet::start(skewed_registry(), cfg).unwrap();
    let handle = fleet.handle();
    let reqs = workload(REQUESTS);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (task, x) in reqs {
        loop {
            match handle.submit(task, x.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(e) => panic!("unexpected rejection: {e:?}"),
            }
        }
    }
    for rx in pending {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let summary = fleet.shutdown();
    assert_eq!(summary.snapshot.served as usize, REQUESTS);
    (REQUESTS as f64 / wall, summary.snapshot.p99_us, summary.snapshot.energy_per_inference_uj)
}

fn main() {
    println!(
        "[bench] fleet routing under skewed load ({REQUESTS} requests, 90% kws, \
         heterogeneous kws replicas, time_scale {TIME_SCALE}, no stealing)"
    );
    let (rr_tput, rr_p99, rr_uj) = run_policy(Policy::RoundRobin);
    let (ll_tput, ll_p99, ll_uj) = run_policy(Policy::LeastLoaded);
    let (ea_tput, ea_p99, ea_uj) = run_policy(Policy::EnergyAware);
    println!(
        "[bench] round-robin : {rr_tput:>8.0} req/s  p99 {rr_p99:>9.1} us  {rr_uj:>6.2} uJ/inf"
    );
    println!(
        "[bench] least-loaded: {ll_tput:>8.0} req/s  p99 {ll_p99:>9.1} us  {ll_uj:>6.2} uJ/inf"
    );
    println!(
        "[bench] energy-aware: {ea_tput:>8.0} req/s  p99 {ea_p99:>9.1} us  {ea_uj:>6.2} uJ/inf"
    );
    println!(
        "[bench] least-loaded / round-robin throughput = {:.2}x",
        ll_tput / rr_tput
    );
    assert!(
        ll_tput >= rr_tput,
        "least-loaded must beat round-robin under skewed load: {ll_tput:.0} < {rr_tput:.0}"
    );
    println!("[bench] OK: least-loaded >= round-robin under skewed load");
}
