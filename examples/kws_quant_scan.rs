//! Fig. 4 — KWS quantization exploration with REAL training.
//!
//! For every WnAm variant (W1A1 .. W8A8, FP32) this trains the actual
//! AOT-exported model through the Rust PJRT runtime, evaluates validation
//! accuracy, and prints accuracy vs BOPs — the paper's Fig. 4 axes.  The
//! expected shape: accuracy flat from FP32 down to 3-bit, with a sudden
//! drop below 3 bits (which is why the submission chose W3A3, §3.4).
//!
//! ```sh
//! cargo run --release --example kws_quant_scan [steps] [eval_n]
//! ```

use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() -> tinyml_codesign::error::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(250);
    let eval_n: usize = std::env::args().nth(2).and_then(|v| v.parse().ok()).unwrap_or(400);
    let art = tinyml_codesign::artifacts_dir();
    let rt = Runtime::cpu()?;
    let costs = tables::fig4_costs(&art)?;

    println!("Fig. 4 — KWS quantization exploration ({steps} steps each, REAL QAT)");
    println!("variant,bops,wm_bits,accuracy");
    let mut results = Vec::new();
    for (variant, bops, wm) in &costs {
        let name = format!("kws_mlp_{variant}");
        let mut m = LoadedModel::load(&art, &name)?;
        let cfg = TrainConfig {
            steps,
            lr: 0.08,
            final_lr_frac: 0.15,
            log_every: steps,
            seed: 0xF164,
        };
        let t0 = std::time::Instant::now();
        coordinator::train(&rt, &mut m, &cfg)?;
        let acc = coordinator::evaluate(&rt, &mut m, eval_n, 0xE7A1)?;
        eprintln!(
            "[{variant}] trained in {:.1} s -> acc {acc:.3}",
            t0.elapsed().as_secs_f64()
        );
        println!("{variant},{bops:.3e},{wm:.0},{acc:.4}");
        results.push((variant.clone(), acc));
    }

    // The paper's observation: the cliff is below 3 bits.
    let get = |v: &str| results.iter().find(|r| r.0 == v).map(|r| r.1).unwrap_or(0.0);
    println!("# w3a3 - w1a1 accuracy gap: {:.3} (paper: sudden decrease below 3 bits)",
        get("w3a3") - get("w1a1"));
    println!("# fp32 - w3a3 accuracy gap: {:.3} (paper: ~none, so W3A3 was submitted)",
        get("fp32") - get("w3a3"));
    Ok(())
}
