//! Full MLPerf Tiny v0.7-style report: Tables 1, 2, 3, 4, 5 + the §4.2.3
//! IC comparison, all regenerated from the artifacts (no training — run
//! `train_and_submit` first for measured accuracies, or pass `--train`
//! for a quick training pass here).

use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() -> tinyml_codesign::error::Result<()> {
    let art = tinyml_codesign::artifacts_dir();
    let do_train = std::env::args().any(|a| a == "--train");

    let mut measured: Vec<(String, String)> = Vec::new();
    let mut ad_auc = None;
    if do_train {
        let rt = Runtime::cpu()?;
        for (name, steps, lr, n) in [
            ("ad_autoencoder", 300usize, 0.05f32, 250usize),
            ("kws_mlp_w3a3", 300, 0.08, 400),
            ("ic_hls4ml", 80, 0.05, 150),
            ("ic_finn", 40, 0.02, 150),
        ] {
            eprintln!("[train] {name} ({steps} steps)...");
            let mut m = LoadedModel::load(&art, name)?;
            let cfg = TrainConfig { steps, lr, final_lr_frac: 0.15, log_every: steps, seed: 1 };
            coordinator::train(&rt, &mut m, &cfg)?;
            let v = coordinator::evaluate(&rt, &mut m, n, 0xE7A1)?;
            if name == "ad_autoencoder" {
                ad_auc = Some(v);
                measured.push((name.into(), format!("{v:.3} AUC")));
            } else {
                measured.push((name.into(), format!("{:.1}%", 100.0 * v)));
            }
        }
    }

    println!("{}", tables::table1(&art, &measured)?);
    println!("{}", tables::table2(&art)?);
    println!("{}", tables::table3(&art)?);
    println!("{}", tables::table4(&art, ad_auc)?);
    println!("{}", tables::table5(&art)?);
    println!("{}", tables::ic_comparison(&art)?);
    Ok(())
}
