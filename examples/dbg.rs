use tinyml_codesign::runtime::{LoadedModel, Runtime};
fn main() -> tinyml_codesign::error::Result<()> {
    let art = tinyml_codesign::artifacts_dir();
    let rt = Runtime::cpu()?;
    let mut m = LoadedModel::load(&art, "kws_mlp_w3a3")?;
    let x = vec![0.25f32; 490];
    let out = m.infer1(&rt, &x)?;
    println!("rs logits: {:?}", &out[..6]);
    let xb = vec![0.25f32; 490*32];
    let yb: Vec<i32> = (0..32).map(|i| i % 12).collect();
    let loss = m.train_step(&rt, &xb, &yb, 0.05)?;
    println!("rs loss: {loss}");
    Ok(())
}
