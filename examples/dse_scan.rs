//! Fig. 2 + Fig. 3 design-space exploration scans.
//!
//! ```sh
//! cargo run --release --example dse_scan [models_per_scan] [asha_configs]
//! ```
//!
//! Prints the Fig. 2 BO scans (accuracy vs MFLOPs for 1-, 2-, 3-stack IC
//! NAS; 100 models per scan like the paper) and the Fig. 3 ASHA scan
//! (accuracy vs inference cost C of eq. 2), both as CSV suitable for
//! plotting, plus summary lines comparing against the paper's anchors.

use tinyml_codesign::dse;

fn main() {
    let models: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(100);
    let configs: usize = std::env::args().nth(2).and_then(|v| v.parse().ok()).unwrap_or(128);

    println!("{}", tinyml_codesign::report::tables::fig2(models, 0xF16));

    // Fig. 2 summary: best model per scan (what §3.1.1 extracted).
    for stacks in 1..=3 {
        let pts = dse::run_ic_bo_scan(stacks, models, 0xF16 + stacks as u64);
        let best = pts
            .iter()
            .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy))
            .unwrap();
        // Pareto knee: best accuracy under 5 MFLOPs.
        let knee = pts
            .iter()
            .filter(|p| p.mflops < 5.0)
            .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy));
        println!(
            "# {stacks}-stack: best {:.1}% @ {:.1} MFLOPs; <5MF knee {:?}",
            best.accuracy,
            best.mflops,
            knee.map(|p| (p.accuracy.round(), p.mflops))
        );
    }

    println!();
    println!("{}", tinyml_codesign::report::tables::fig3(configs, 0xF17));
    let pts = dse::run_cnv_asha_scan(configs, 0xF17);
    let top = pts
        .iter()
        .filter(|p| p.rung >= 2)
        .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy));
    println!("# ASHA winner (rung>=2): {top:?}");
    println!("# paper: CNV-W1A1 (C=1.0) performs near-optimally at 84.5%");
}
