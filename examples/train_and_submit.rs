//! End-to-end validation driver (DESIGN.md §End-to-end validation).
//!
//! Trains the AD autoencoder and the KWS W3A3 MLP for a few hundred SGD
//! steps on synthetic data entirely from Rust via PJRT (loss curves
//! logged), gives the IC models a shorter budget, evaluates
//! accuracy/AUC over the EEMBC-style batch-1 path, then pushes every
//! design through the full codesign flow and the simulated EnergyRunner
//! and prints MLPerf-submission-style rows.  Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example train_and_submit [steps_scale]
//! ```

use tinyml_codesign::board::pynq_z2;
use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::data;
use tinyml_codesign::eembc::{DesignPerf, Dut, Runner};
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() -> tinyml_codesign::error::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(1.0);
    let art = tinyml_codesign::artifacts_dir();
    let rt = Runtime::cpu()?;
    let board = pynq_z2();
    let runner = Runner { min_window_s: 1.0, ..Default::default() };

    // (model, flow-estimation topology, train steps, lr, eval n)
    let plan: [(&str, &str, usize, f32, usize); 4] = [
        ("ad_autoencoder", "ad_autoencoder", (400.0 * scale) as usize, 0.05, 250),
        ("kws_mlp_w3a3", "kws_mlp_w3a3", (400.0 * scale) as usize, 0.08, 500),
        ("ic_hls4ml", "ic_hls4ml", (120.0 * scale) as usize, 0.05, 200),
        ("ic_finn", "ic_finn_full", (60.0 * scale) as usize, 0.02, 200),
    ];

    let mut rows = Vec::new();
    for (name, flow_name, steps, lr, eval_n) in plan {
        println!("==== {name}: training {steps} steps ====");
        let mut m = LoadedModel::load(&art, name)?;
        let cfg = TrainConfig {
            steps,
            lr,
            final_lr_frac: 0.15,
            log_every: (steps / 8).max(1),
            seed: 0x7121,
        };
        let t0 = std::time::Instant::now();
        let curve = coordinator::train(&rt, &mut m, &cfg)?;
        let dt = t0.elapsed().as_secs_f64();
        for p in &curve {
            println!("  step {:>5}  loss {:.4}  lr {:.4}", p.step, p.loss, p.lr);
        }
        println!("  ({dt:.1} s total, {:.1} ms/step)", dt * 1e3 / steps.max(1) as f64);

        // Codesign flow -> simulated platform performance.
        let fr = tables::flow_for(&art, flow_name, &board)?;
        let perf = DesignPerf { latency_s: fr.latency_s, power_w: fr.power_w };
        let task = m.manifest.task.clone();
        let test = data::test_set(&task, eval_n, 0xE7A1);
        let mut dut = Dut::new(&mut m, perf);
        let acc = runner.accuracy_mode(&rt, &mut dut, &test.samples)?;
        let p = runner.performance_mode(&rt, &mut dut, &test.samples)?;
        let e = runner.energy_mode(&rt, &mut dut, &test.samples)?;
        println!(
            "  EEMBC: {} = {:.3} | median latency {:.3} ms | {:.1} uJ/inf",
            acc.metric,
            acc.value,
            p.median_latency_s * 1e3,
            e.median_energy_uj
        );
        rows.push((name, acc.metric.clone(), acc.value, p.median_latency_s, e.median_energy_uj, fr.fits));
    }

    println!("\n==== MLPerf Tiny v0.7-style submission (Pynq-Z2, simulated) ====");
    println!(
        "{:<16} {:>8} {:>10} {:>12} {:>12} {:>6}",
        "model", "metric", "value", "latency[ms]", "E/inf[uJ]", "fits"
    );
    for (name, metric, value, lat, e, fits) in &rows {
        println!(
            "{name:<16} {metric:>8} {value:>10.3} {:>12.3} {e:>12.1} {fits:>6}",
            lat * 1e3
        );
    }
    println!("\npaper rows: IC/hls4ml 83.5% 27.3ms 44330uJ | IC/FINN 84.5% 1.5ms 2535uJ");
    println!("            AD 0.83AUC 19us 30.1uJ | KWS 82.5% 17us 30.9uJ");
    Ok(())
}
