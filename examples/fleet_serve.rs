//! Fleet serving end-to-end: a mixed KWS + AD + IC workload over the
//! standard 6-instance heterogeneous fleet (every task on both a Pynq-Z2
//! and a folded-down Arty A7-100T), once per routing policy.
//!
//! ```sh
//! cargo run --release --example fleet_serve
//! ```
//!
//! For each policy this prints fleet p50/p99 latency, aggregate
//! throughput, energy per inference, and the per-board breakdown
//! (including how much work idle replicas stole), plus one JSON line for
//! dashboards.  Two finales follow: a KWS burst against the autoscaler,
//! and a 70/20/10 batch/standard/interactive overload comparing the
//! single-FIFO control against the class-aware priority queue plane
//! (per-class p50/p99 and shed counts in the rendered tables).  Device
//! time is stretched by `TIME_SCALE` so the µs-class accelerator
//! latencies dominate thread scheduling noise; energy numbers are
//! computed from unscaled device time and are scale-invariant.

use tinyml_codesign::data::prng::SplitMix64;
use tinyml_codesign::error::Result;
use tinyml_codesign::fleet::worker::precise_sleep;
use tinyml_codesign::fleet::{
    AutoscaleConfig, BoardInstance, Fleet, FleetConfig, Policy, Priority, Registry,
    RequestTag, RouteError, Stage,
};

const TIME_SCALE: f64 = 20.0;
const REQUESTS: usize = 900;

fn workload(seed: u64, n: usize) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            // 50% KWS, 25% AD, 25% IC.
            let task = match rng.next_below(4) {
                0 | 1 => "kws",
                2 => "ad",
                _ => "ic",
            };
            let dim = tinyml_codesign::data::feature_dim(task);
            let x = (0..dim).map(|_| rng.next_f64() as f32).collect();
            (task, x)
        })
        .collect()
}

fn main() -> Result<()> {
    let policies = [
        Policy::RoundRobin,
        Policy::LeastLoaded,
        Policy::EnergyAware,
        Policy::LatencySlo { slo_us: 3_000.0 },
    ];

    println!(
        "== fleet_serve: {REQUESTS} mixed requests (50% kws / 25% ad / 25% ic), \
         time_scale {TIME_SCALE} =="
    );
    let reg = Registry::standard_fleet()?;
    println!("boards:");
    for i in &reg.instances {
        println!(
            "  [{}] {:<28} lat {:>8.1} us  ii {:>7.2} us  {:>6.2} uJ/inf  {:.2} W",
            i.id,
            i.label,
            i.latency_s * 1e6,
            i.ii_s * 1e6,
            i.energy_per_inference_uj,
            i.power_w
        );
    }

    for policy in policies {
        let cfg = FleetConfig {
            policy,
            queue_cap: 128,
            time_scale: TIME_SCALE,
            ..Default::default()
        };
        let fleet = Fleet::start(Registry::standard_fleet()?, cfg)?;
        let handle = fleet.handle();
        let mut pending = Vec::new();
        let mut rejected = 0usize;
        for (task, x) in workload(0xF1EE7, REQUESTS) {
            loop {
                match handle.submit(task, x.clone()) {
                    Ok(rx) => {
                        pending.push(rx);
                        break;
                    }
                    Err(RouteError::Overloaded) => {
                        // Backpressure: wait for queues to drain a bit.
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    Err(_) => {
                        // SLO admission control: this request is shed.
                        rejected += 1;
                        break;
                    }
                }
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let summary = fleet.shutdown();
        println!("\n-- policy: {policy} ({rejected} rejected) --");
        print!("{}", summary.render());
        println!("json: {}", summary.snapshot.to_json().to_json());
    }

    // Elastic finale: one replica per task plus the telemetry-driven
    // autoscaler, hit with a KWS burst.  Watch the controller grow the
    // KWS replica set while the burst is hot and shrink it back once the
    // queue drains — the scale history prints with the summary.
    println!("\n-- autoscale demo: kws burst over a 3-board floor --");
    let mut reg = Registry::new();
    reg.add(tinyml_codesign::board::pynq_z2(), "kws_mlp_w3a3")?;
    reg.add(tinyml_codesign::board::pynq_z2(), "ad_autoencoder")?;
    reg.add(tinyml_codesign::board::pynq_z2(), "ic_cnv_w1a1")?;
    let cfg = FleetConfig {
        queue_cap: 1024,
        time_scale: TIME_SCALE,
        autoscale: Some(AutoscaleConfig {
            interval: std::time::Duration::from_millis(2),
            cooldown: std::time::Duration::from_millis(10),
            max_replicas: 4,
            ..Default::default()
        }),
        ..Default::default()
    };
    let fleet = Fleet::start(reg, cfg)?;
    let handle = fleet.handle();
    let dim = tinyml_codesign::data::feature_dim("kws");
    let mut pending = Vec::new();
    for _ in 0..300 {
        loop {
            match handle.submit("kws", vec![0.2f32; dim]) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => {
                    println!("rejected: {e}");
                    break;
                }
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    // Idle long enough for the controller to notice and shrink.
    precise_sleep(std::time::Duration::from_millis(120));
    let summary = fleet.shutdown();
    print!("{}", summary.render());
    println!("json: {}", summary.snapshot.to_json().to_json());

    // Priority finale: one kws board under an open-loop overload that is
    // 70% Batch / 20% Standard / 10% Interactive, once with the
    // single-FIFO control and once with the class-aware queue plane.
    // Watch the interactive tail: FIFO parks interactive requests behind
    // the batch flood (and tail-drops them with everyone else), while
    // priority scheduling serves them first and sheds only Batch.
    println!("\n-- priority demo: 70/20/10 batch/standard/interactive overload --");
    for fifo in [true, false] {
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 400.0, 80.0, 1.5)],
        };
        let cfg = FleetConfig {
            queue_cap: 64,
            time_scale: TIME_SCALE,
            work_stealing: false,
            fifo_queues: fifo,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg)?;
        let handle = fleet.handle();
        let mut rng = SplitMix64::new(0x9917);
        let dim = tinyml_codesign::data::feature_dim("kws");
        let mut pending = Vec::new();
        for i in 0..400u32 {
            let priority = match rng.next_below(10) {
                0 => Priority::Interactive,
                1 | 2 => Priority::Standard,
                _ => Priority::Batch,
            };
            // Open loop: a rejection is a shed, not a retry.
            if let Ok(rx) =
                handle.submit_tagged("kws", vec![0.2f32; dim], RequestTag::new(i % 4, priority))
            {
                pending.push(rx);
            }
            precise_sleep(std::time::Duration::from_micros(600));
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let summary = fleet.shutdown();
        println!(
            "\n-- queues: {} --",
            if fifo { "fifo (control)" } else { "class-aware" }
        );
        print!("{}", summary.render());
    }

    // Tracing finale: the mixed workload again with 1-in-4 lifecycle
    // sampling.  The per-stage breakdown separates where time goes
    // (queue wait vs batch-window wait vs device hold vs reply copy),
    // the drift table ranks boards by how far measured device time sits
    // from the registry's flow prediction (`ratio` ~ 1.0 means the
    // analytical model the router/autoscaler act on is honest), and the
    // first few event-ring entries show the JSONL shape `fleet
    // --trace-dump` emits.
    println!("\n-- trace demo: 1-in-4 sampled lifecycle tracing --");
    let cfg = FleetConfig {
        queue_cap: 128,
        time_scale: TIME_SCALE,
        trace_sample: 4,
        ..Default::default()
    };
    let fleet = Fleet::start(Registry::standard_fleet()?, cfg)?;
    let handle = fleet.handle();
    let mut pending = Vec::new();
    for (task, x) in workload(0x7ACE, 600) {
        loop {
            match handle.submit(task, x.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(RouteError::Overloaded) => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => {
                    println!("rejected: {e}");
                    break;
                }
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let summary = fleet.shutdown();
    let snap = &summary.snapshot;
    println!("stage latency over sampled requests (p50/p99 us, log2-bucket edges):");
    println!(
        "  {:<12} {:>7} {:>20} {:>20} {:>20} {:>20}",
        "class", "spans", "queue_wait", "window_wait", "exec", "reply"
    );
    for c in &snap.classes {
        if let Some(set) = &c.stages {
            print!("  {:<12} {:>7}", c.class.to_string(), set[0].count);
            for st in Stage::ALL {
                let h = &set[st.idx()];
                print!(
                    " {:>9.0}/{:<10.0}",
                    h.percentile_us(0.50),
                    h.percentile_us(0.99)
                );
            }
            println!();
        }
    }
    println!("flow-vs-measured exec drift (worst boards first):");
    let mut drifted: Vec<&tinyml_codesign::fleet::BoardSnapshot> =
        snap.per_board.iter().filter(|b| b.drift.is_some()).collect();
    drifted.sort_by(|a, b| {
        let ka = (a.drift.unwrap().ratio - 1.0).abs();
        let kb = (b.drift.unwrap().ratio - 1.0).abs();
        kb.partial_cmp(&ka).unwrap()
    });
    for b in drifted.iter().take(4) {
        let d = b.drift.unwrap();
        println!(
            "  {:<28} {:>4} batches  predicted {:>10.0} us  observed {:>10.0} us  \
             ratio {:.3}",
            b.label, d.batches, d.predicted_exec_us, d.observed_exec_us, d.ratio
        );
    }
    println!("event ring (first 5 of {} retained events, JSONL):", summary.trace_events.len());
    for e in summary.trace_events.iter().take(5) {
        println!("  {}", e.to_json().to_json());
    }
    Ok(())
}
