//! Quickstart: the full codesign flow on one model in ~a minute.
//!
//! ```sh
//! make artifacts                      # once (Python, build time)
//! cargo run --release --example quickstart
//! ```
//!
//! Loads the KWS W3A3 topology, runs the compiler passes + FIFO
//! optimization + resource/latency/energy estimation for both boards,
//! then trains the model for a few SGD steps from Rust via PJRT and
//! reports accuracy — Python is never touched.

use tinyml_codesign::board::all_boards;
use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::report::tables;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn main() -> tinyml_codesign::error::Result<()> {
    let art = tinyml_codesign::artifacts_dir();
    let model = "kws_mlp_w3a3";

    println!("== 1. codesign flow: {model} ==");
    for board in all_boards() {
        let r = tables::flow_for(&art, model, &board)?;
        let t = &r.resources.total;
        println!(
            "  {:<14} {:>6.0} LUT  {:>5.1} BRAM36  {:>4.0} DSP | {:>8.1} us  {:>7.1} uJ/inf  fits: {}",
            board.name,
            t.luts,
            t.bram36,
            t.dsps,
            r.latency_s * 1e6,
            r.energy_per_inference_uj,
            r.fits
        );
        println!(
            "     FIFO depths (optimized, {}): {:?}",
            if r.optimized.flow == "finn" { "pow2" } else { "exact" },
            r.fifo.depths
        );
    }

    println!("== 2. Rust-driven QAT training (PJRT, no Python) ==");
    let rt = Runtime::cpu()?;
    let mut m = LoadedModel::load(&art, model)?;
    let cfg = TrainConfig { steps: 120, log_every: 30, ..Default::default() };
    let curve = coordinator::train(&rt, &mut m, &cfg)?;
    for p in &curve {
        println!("  step {:>4}  loss {:.4}", p.step, p.loss);
    }

    println!("== 3. accuracy over a fresh synthetic test set ==");
    let acc = coordinator::evaluate(&rt, &mut m, 300, 0xACC)?;
    println!("  top-1 = {acc:.3} (paper submission: 0.825 on Speech Commands v2)");
    Ok(())
}
