//! FPGA resource estimation (Tables 3, 4, 5).
//!
//! Substitute for Vivado HLS synthesis reports (DESIGN.md
//! §Hardware-Adaptation): an analytic per-stage cost model over the
//! scheduled design.  The *structure* is first-principles — multiplier
//! counts come from the folding schedule, weight storage from param-bits,
//! FIFO costs from the optimized depths, line buffers from window shapes —
//! while the per-unit coefficients in [`CostModel`] are calibrated so the
//! four submitted models land near the paper's reported utilizations
//! (e.g. AD at RF 144 ⇒ 208 DSP-mapped multipliers vs the paper's 205).
//!
//! What the model must reproduce (and the benches assert):
//! * FIFO-depth optimization cuts BRAM massively (Table 3: 477 → 278).
//! * ReLU merging cuts LUTs (Table 3: 66.8 k → 55.3 k).
//! * BN folding + downsampling + width reduction takes AD from
//!   unsynthesizable to 58.5% LUT (Table 4).
//! * hls4ml-IC uses far fewer BRAMs than FINN-IC (Table 5: 42 vs 100).

use crate::board::{soft_system_overhead, Board};
use crate::dataflow::schedule::{ScheduledDesign, StageImpl};


/// Multiplier implementation choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MultImpl {
    Dsp,
    Lut,
}

/// Calibrated per-unit coefficients.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// LUTs per DSP-mapped multiplier (routing + pre-adders).
    pub lut_per_dsp_mult: f64,
    /// FFs per DSP-mapped multiplier (pipeline + accumulator).
    pub ff_per_dsp_mult: f64,
    /// LUTs per XNOR-popcount binary MAC (incl. its popcount-tree share).
    pub lut_per_binary_mult: f64,
    /// LUTs per (wbits x abits) LUT-mapped multiplier, per bit-product.
    pub lut_per_bitproduct: f64,
    /// FFs per LUT-mapped multiplier, per accumulator bit.
    pub ff_per_acc_bit: f64,
    /// Fixed control/stream logic per dataflow stage.
    pub ctrl_lut_per_stage: f64,
    pub ctrl_ff_per_stage: f64,
    /// Extra stream glue per stage (AXI-stream handshakes, counters).
    pub stream_lut_per_stage: f64,
    /// MultiThreshold comparators: LUTs per channel·level·bit.
    pub lut_per_threshold_bit: f64,
    /// Standalone (unfolded) BatchNorm: LUTs/FFs per channel (fixed-point
    /// mult-add at full width — this is what folding eliminates, §3.3.1).
    pub bn_lut_per_channel: f64,
    pub bn_ff_per_channel: f64,
    /// Standalone ReLU stage: LUTs per channel (what merging eliminates).
    pub relu_lut_per_channel: f64,
    /// BRAM packing efficiency for partitioned weight memories (FINN slices
    /// weights per PE, wasting part of each block).
    pub weight_bram_efficiency: f64,
    /// FIFO impl threshold: depth*width (bits) at or below this go to
    /// LUTRAM (SRL), above to BRAM.
    pub fifo_lutram_threshold_bits: u64,
    /// DSP-mapping rule: hls4ml designs with reuse-factor >= this map
    /// multipliers to DSP slices (resource strategy); below, to LUTs.
    pub dsp_reuse_threshold: u32,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            lut_per_dsp_mult: 105.0,
            ff_per_dsp_mult: 190.0,
            lut_per_binary_mult: 8.0,
            lut_per_bitproduct: 1.0,
            ff_per_acc_bit: 2.4,
            ctrl_lut_per_stage: 180.0,
            ctrl_ff_per_stage: 420.0,
            stream_lut_per_stage: 300.0,
            lut_per_threshold_bit: 0.55,
            bn_lut_per_channel: 160.0,
            bn_ff_per_channel: 96.0,
            relu_lut_per_channel: 48.0,
            weight_bram_efficiency: 0.45,
            fifo_lutram_threshold_bits: 9_216, // half an 18-kb BRAM
            dsp_reuse_threshold: 16,
        }
    }
}

/// Resource totals (Table 5 columns).
#[derive(Clone, Copy, Debug, Default)]
pub struct Resources {
    pub luts: f64,
    pub lutram: f64,
    pub ffs: f64,
    pub bram36: f64,
    pub dsps: f64,
}

impl Resources {
    pub fn add(&mut self, o: &Resources) {
        self.luts += o.luts;
        self.lutram += o.lutram;
        self.ffs += o.ffs;
        self.bram36 += o.bram36;
        self.dsps += o.dsps;
    }

    pub fn utilization(&self, b: &Board) -> Utilization {
        Utilization {
            lut_pct: 100.0 * self.luts / b.luts as f64,
            lutram_pct: 100.0 * self.lutram / b.lutram as f64,
            ff_pct: 100.0 * self.ffs / b.ffs as f64,
            bram_pct: 100.0 * self.bram36 / b.bram36,
            dsp_pct: 100.0 * self.dsps / b.dsps as f64,
        }
    }

    pub fn fits(&self, b: &Board) -> bool {
        let u = self.utilization(b);
        u.lut_pct <= 100.0 && u.ff_pct <= 100.0 && u.bram_pct <= 100.0 && u.dsp_pct <= 100.0
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub lut_pct: f64,
    pub lutram_pct: f64,
    pub ff_pct: f64,
    pub bram_pct: f64,
    pub dsp_pct: f64,
}

/// Per-stage breakdown + totals.
#[derive(Clone, Debug)]
pub struct ResourceReport {
    pub model: String,
    pub per_stage: Vec<(String, Resources)>,
    pub fifo: Resources,
    pub accelerator: Resources,
    /// Accelerator + platform system (AXI/DMA or MicroBlaze+MIG).
    pub total: Resources,
}

/// Estimate one compute stage.
fn compute_stage(s: &StageImpl, flow: &str, reuse_factor: u32, cm: &CostModel) -> Resources {
    let mut r = Resources::default();
    let n = s.n_mult as f64;
    let mult_impl = if flow == "hls4ml" && reuse_factor >= cm.dsp_reuse_threshold {
        MultImpl::Dsp
    } else {
        MultImpl::Lut
    };
    match mult_impl {
        MultImpl::Dsp => {
            r.dsps += n;
            r.luts += n * cm.lut_per_dsp_mult;
            r.ffs += n * cm.ff_per_dsp_mult;
        }
        MultImpl::Lut => {
            if s.wbits <= 1 {
                r.luts += n * cm.lut_per_binary_mult;
            } else {
                let bitprod = (s.wbits.max(1) * s.in_bits.max(1)) as f64;
                r.luts += n * bitprod * cm.lut_per_bitproduct;
            }
            r.ffs += n * s.acc_bits.max(8) as f64 * cm.ff_per_acc_bit;
        }
    }
    // Weight storage: BRAM above LUTRAM threshold, partition-inefficient.
    let wbits = s.weight_store_bits as f64;
    if s.weight_store_bits > cm.fifo_lutram_threshold_bits {
        r.bram36 += (wbits / 36_864.0 / cm.weight_bram_efficiency).ceil().max(1.0);
    } else {
        r.lutram += wbits / 64.0;
        r.luts += wbits / 64.0;
    }
    r
}

/// Window line buffers for conv/pool stages ((kernel-1) rows on chip).
fn line_buffer(s: &StageImpl, cm: &CostModel) -> Resources {
    let mut r = Resources::default();
    if let crate::dataflow::Prereq::Window { in_w, kernel, .. } = s.spec.prereq {
        if kernel > 1 {
            let bits = ((kernel - 1) * in_w * s.token_elems) as f64 * s.in_bits.max(1) as f64;
            if bits > cm.fifo_lutram_threshold_bits as f64 {
                r.bram36 += (bits / 36_864.0).ceil();
            } else {
                r.lutram += bits / 64.0;
                r.luts += bits / 64.0;
            }
        }
    }
    r
}

/// FIFO cost for one channel of `depth` tokens of `width_bits` each.
pub fn fifo_cost(depth: usize, width_bits: u64, cm: &CostModel) -> Resources {
    let mut r = Resources::default();
    let bits = depth as u64 * width_bits;
    if bits == 0 {
        return r;
    }
    if bits <= cm.fifo_lutram_threshold_bits {
        // SRL-based: one LUT shifts 32 bits deep per bit of width.
        let lut = (width_bits as f64) * (depth as f64 / 32.0).ceil();
        r.lutram += lut;
        r.luts += lut + 12.0; // + handshake
        r.ffs += width_bits as f64 + 10.0;
    } else {
        r.bram36 += (bits as f64 / 36_864.0).ceil().max(0.5);
        r.luts += 40.0;
        r.ffs += width_bits as f64 + 16.0;
    }
    r
}

/// Full design estimate: scheduled stages + FIFO depths (one per channel,
/// `depths.len() == stages + 1`).
pub fn estimate(
    design: &ScheduledDesign,
    reuse_factor: u32,
    depths: &[usize],
    board: &Board,
    cm: &CostModel,
) -> ResourceReport {
    let mut per_stage = Vec::new();
    let mut accel = Resources::default();

    for s in &design.stages {
        let mut r = Resources::default();
        match s.op {
            "Conv2D" | "Dense" => {
                r.add(&compute_stage(s, &design.flow, reuse_factor, cm));
                r.add(&line_buffer(s, cm));
            }
            "BatchNorm" => {
                r.luts += s.token_elems as f64 * cm.bn_lut_per_channel;
                r.ffs += s.token_elems as f64 * cm.bn_ff_per_channel;
            }
            "ReLU" => {
                r.luts += s.token_elems as f64 * cm.relu_lut_per_channel;
                r.ffs += s.token_elems as f64 * 4.0;
            }
            "MultiThreshold" => {
                // channels * levels comparators at in_bits width.
                let levels = s.spec.n_out.max(1); // not meaningful; use params
                let _ = levels;
                let bits = s.token_elems as f64 * s.out_bits.max(1) as f64;
                r.luts += bits * 12.0 * cm.lut_per_threshold_bit;
                r.ffs += s.token_elems as f64 * 6.0;
            }
            "BipolarAct" => {
                r.luts += s.token_elems as f64 * 2.0;
            }
            "MaxPool" => {
                r.add(&line_buffer(s, cm));
                r.luts += s.token_elems as f64 * 6.0;
                r.ffs += s.token_elems as f64 * 8.0;
            }
            "Softmax" => {
                // exp LUT tables + divider — expensive, which is why it is
                // removed (§3.1.1).
                r.luts += 3_000.0;
                r.ffs += 2_400.0;
                r.bram36 += 2.0;
            }
            "TopK" => {
                r.luts += s.token_elems as f64 * 14.0;
                r.ffs += s.token_elems as f64 * 8.0;
            }
            _ => {}
        }
        // Every stage pays dataflow control + stream glue.
        r.luts += cm.ctrl_lut_per_stage + cm.stream_lut_per_stage;
        r.ffs += cm.ctrl_ff_per_stage;
        accel.add(&r);
        per_stage.push((s.name.clone(), r));
    }

    // FIFOs between stages (width = token elems * activation bits).
    let mut fifo_total = Resources::default();
    for (i, &d) in depths.iter().enumerate() {
        let width_bits = if i == 0 {
            // input FIFO carries input-precision tokens
            design.stages.first().map(|s| s.token_elems as u64 * 8).unwrap_or(8)
        } else {
            let s = &design.stages[i - 1];
            (s.token_elems as u64 * s.out_bits.max(1) as u64).max(8)
        };
        fifo_total.add(&fifo_cost(d, width_bits, cm));
    }
    accel.add(&fifo_total);

    let sys = soft_system_overhead(board);
    let mut total = accel;
    total.luts += sys.luts as f64;
    total.ffs += sys.ffs as f64;
    total.bram36 += sys.bram36;
    total.dsps += sys.dsps as f64;

    ResourceReport {
        model: design.model.clone(),
        per_stage,
        fifo: fifo_total,
        accelerator: accel,
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::pynq_z2;
    use crate::dataflow::schedule::{schedule, ScheduleConfig};
    use crate::ir::Graph;
    use crate::passes::PassManager;

    fn ad_like(rf: u32, width: usize, input: usize) -> Graph {
        let mut nodes = Vec::new();
        let mut total = 0u64;
        let dims = [input, width, width, 8, width, width, input];
        for (i, w) in dims.windows(2).enumerate() {
            let params = (w[0] * w[1] + w[1]) as u64;
            total += params;
            nodes.push(format!(
                r#"{{"op":"Dense","name":"fc{i}","in_features":{},"out_features":{},"weight_bits":6,"has_bias":true,"params":{params}}}"#,
                w[0], w[1]
            ));
            if i < dims.len() - 2 {
                nodes.push(format!(
                    r#"{{"op":"ReLU","name":"r{i}","channels":{},"act_bits":8,"params":0}}"#,
                    w[1]
                ));
            }
        }
        let json = format!(
            r#"{{"name":"ad_like","task":"ad","flow":"hls4ml","input_shape":[{input}],"input_bits":8,"reuse_factor":{rf},"nodes":[{}],"total_params":{total}}}"#,
            nodes.join(",")
        );
        Graph::from_json_str(&json).unwrap()
    }

    fn estimate_for(g: &Graph) -> (ResourceReport, ScheduledDesign) {
        let mut pm = PassManager::for_flow(&g.flow);
        let g2 = pm.run(g);
        let d = schedule(&g2, &ScheduleConfig::default());
        let sim = crate::dataflow::Simulator::new(d.stage_specs());
        let opt = crate::fifo::optimize_fifos(&sim, crate::fifo::DepthPolicy::Exact);
        let r = estimate(&d, g2.reuse_factor, &opt.depths, &pynq_z2(), &CostModel::default());
        (r, d)
    }

    #[test]
    fn ad_at_rf144_maps_to_dsps_near_205() {
        let (r, d) = estimate_for(&ad_like(144, 72, 128));
        // Paper: 205 DSPs at RF 144 (Table 5).  Structural: sum of
        // ceil(macs_l / 144).
        assert!(
            (150.0..260.0).contains(&r.total.dsps),
            "dsps={} mults={}",
            r.total.dsps,
            d.total_mults()
        );
    }

    #[test]
    fn higher_reuse_factor_fewer_multipliers() {
        let (r144, _) = estimate_for(&ad_like(144, 72, 128));
        let (r36, _) = estimate_for(&ad_like(36, 72, 128));
        assert!(r36.total.dsps > r144.total.dsps);
    }

    #[test]
    fn fp32_reference_is_unsynthesizable() {
        let mut g = ad_like(144, 128, 640);
        for n in &mut g.nodes {
            if let crate::ir::Node::Dense { weight_bits, .. } = n {
                *weight_bits = 32;
            }
        }
        // fp32 "quantization": LUT-mapped float mults at RF below the DSP
        // threshold explode.
        g.reuse_factor = 4;
        let (r, _) = estimate_for(&g);
        assert!(!r.total.fits(&pynq_z2()), "{:?}", r.total);
    }

    #[test]
    fn fifo_cost_lutram_vs_bram() {
        let cm = CostModel::default();
        let small = fifo_cost(16, 64, &cm);
        assert!(small.bram36 == 0.0 && small.lutram > 0.0);
        let big = fifo_cost(2048, 64, &cm);
        assert!(big.bram36 >= 1.0);
    }

    #[test]
    fn report_structure() {
        let (r, d) = estimate_for(&ad_like(144, 72, 128));
        assert_eq!(r.per_stage.len(), d.stages.len());
        assert!(r.total.luts > r.accelerator.luts - 1.0);
        assert!(r.total.fits(&pynq_z2()), "{:?}", r.total.utilization(&pynq_z2()));
    }
}
