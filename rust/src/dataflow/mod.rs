//! Spatial dataflow architecture simulator.
//!
//! Both hls4ml and FINN emit *dataflow* accelerators (§4.2.1): one stage
//! per layer, stages connected by FIFOs, activations streaming on-chip.
//! This module is the substitute for Vivado's RTL simulation (DESIGN.md
//! §Hardware-Adaptation): it executes the stage network cycle by cycle with
//! bounded FIFOs and backpressure, and reports
//!
//! * end-to-end latency (first input token -> last output token),
//! * steady-state initiation interval (throughput),
//! * per-FIFO maximum occupancy — the signal the FIFO-depth optimization
//!   of §3.1.2/§3.5 is built on.
//!
//! Token model: one token is one spatial position's channel vector for 2-D
//! layers, one element-group for 1-D layers.  Window stages (conv/pool)
//! track the sliding-window dependency (output (r,c) needs input rows up to
//! `r*stride + kernel - 1`), dense stages need the full input but *pop*
//! incrementally (the MVAU accumulates as tokens stream in, which is why
//! the paper's AD design works with FIFO depth 1).

pub mod schedule;



/// Input-dependency shape of a stage.
#[derive(Clone, Debug, PartialEq)]
pub enum Prereq {
    /// Output j requires all `n_in` input tokens (dense/global layers).
    All,
    /// Sliding window over a 2-D raster: output (r, c) requires the input
    /// raster up to row `r*stride + kernel - 1 - pad` (VALID: pad = 0).
    Window { in_w: usize, kernel: usize, stride: usize, pad: usize },
    /// Output j requires inputs 0..=j (elementwise stages).
    Elementwise,
}

/// One dataflow stage (≈ one layer IP block / HLS dataflow process).
#[derive(Clone, Debug)]
pub struct StageSpec {
    pub name: String,
    /// Input tokens per inference.
    pub n_in: usize,
    /// Output tokens per inference.
    pub n_out: usize,
    /// Cycles between successive output tokens once inputs are available.
    pub ii_out: u64,
    /// Cycles between successive input-token pops (stream-in pace).
    pub ii_in: u64,
    /// Dependency shape.
    pub prereq: Prereq,
}

impl StageSpec {
    /// Input tokens required before output token `j` can be produced.
    fn required(&self, j: usize) -> usize {
        match &self.prereq {
            Prereq::All => self.n_in,
            Prereq::Elementwise => (j + 1).min(self.n_in),
            Prereq::Window { in_w, kernel, stride, pad } => {
                let out_w = if *in_w + pad >= *kernel {
                    (*in_w + 2 * pad - *kernel) / *stride + 1
                } else {
                    1
                };
                let r = j / out_w.max(1);
                let c = j % out_w.max(1);
                let last_row = (r * stride + kernel - 1).saturating_sub(*pad);
                let last_col = (c * stride + kernel - 1).saturating_sub(*pad);
                let need = last_row * in_w + last_col.min(in_w - 1) + 1;
                need.min(self.n_in)
            }
        }
    }
}

/// Result of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// First-input to last-output, in cycles.
    pub latency_cycles: u64,
    /// Steady-state cycles/inference (gap between last outputs of two
    /// back-to-back inferences); equals the slowest stage's total cycles.
    pub ii_cycles: u64,
    /// Max occupancy observed per FIFO (FIFO i sits in front of stage i).
    pub fifo_max_occupancy: Vec<usize>,
    /// Total simulated cycles.
    pub simulated_cycles: u64,
    /// True if the run hit the cycle limit without finishing (deadlock or
    /// under-sized FIFOs in a cyclic stall).
    pub deadlocked: bool,
}

struct FifoState {
    occupancy: usize,
    depth: usize,
    max_seen: usize,
}

struct StageState {
    popped: usize,
    produced: usize,
    in_timer: u64,
    out_timer: u64,
}

/// Cycle-level simulator over a chain of stages.
pub struct Simulator {
    pub stages: Vec<StageSpec>,
    /// Tokens/cycle the input interface can sustain (m_axi burst pace).
    pub source_ii: u64,
    pub cycle_limit: u64,
}

pub const UNBOUNDED_DEPTH: usize = 1 << 24;

impl Simulator {
    pub fn new(stages: Vec<StageSpec>) -> Self {
        Self { stages, source_ii: 1, cycle_limit: 2_000_000_000 }
    }

    /// Run `inferences` back-to-back inferences with the given FIFO depths
    /// (`depths[i]` feeds stage i; length = stages + 1, the last entry is
    /// the output FIFO).  Use [`UNBOUNDED_DEPTH`] for the sizing run.
    pub fn run(&self, depths: &[usize], inferences: usize) -> SimResult {
        assert_eq!(depths.len(), self.stages.len() + 1);
        let n = self.stages.len();
        let mut fifos: Vec<FifoState> = depths
            .iter()
            .map(|&d| FifoState { occupancy: 0, depth: d.max(1), max_seen: 0 })
            .collect();
        // out_timer starts at ii_out: a stage's first output token costs
        // one initiation interval of compute after its inputs arrive
        // (otherwise stages with n_out == 1 — dense layers — would appear
        // free and pipeline latency would collapse to stream-in time).
        let mut st: Vec<StageState> = self
            .stages
            .iter()
            .map(|s| StageState { popped: 0, produced: 0, in_timer: 0, out_timer: s.ii_out })
            .collect();

        let total_in = self.stages[0].n_in * inferences;
        let total_out = self.stages[n - 1].n_out * inferences;
        let mut src_sent = 0usize;
        let mut src_timer = 0u64;
        let mut sink_got = 0usize;
        let mut first_out_cycle = 0u64;
        let mut finish_cycles: Vec<u64> = Vec::with_capacity(inferences);

        let mut cycle: u64 = 0;
        while sink_got < total_out && cycle < self.cycle_limit {
            // Source: feed the first FIFO.
            if src_sent < total_in && src_timer == 0 && fifos[0].occupancy < fifos[0].depth {
                fifos[0].occupancy += 1;
                fifos[0].max_seen = fifos[0].max_seen.max(fifos[0].occupancy);
                src_sent += 1;
                src_timer = self.source_ii;
            }
            src_timer = src_timer.saturating_sub(1);

            // Stages, downstream first so a pop this cycle frees space for
            // the upstream push next cycle (RTL-ish, order-independent-ish).
            for i in (0..n).rev() {
                let spec = &self.stages[i];
                let s = &mut st[i];
                // Wrap per-inference counters.
                let infer_idx = s.produced / spec.n_out.max(1);
                let local_produced = s.produced % spec.n_out.max(1);
                let local_popped = s.popped.saturating_sub(infer_idx * spec.n_in);

                // Pop side.
                if s.in_timer == 0
                    && fifos[i].occupancy > 0
                    && local_popped < spec.n_in
                {
                    fifos[i].occupancy -= 1;
                    s.popped += 1;
                    s.in_timer = spec.ii_in;
                }
                s.in_timer = s.in_timer.saturating_sub(1);

                // Push side.  The compute timer only runs once the stage
                // has started (popped its first input): a stage cannot
                // pipeline-fill before data exists, so its first output
                // costs a full initiation interval after data arrival.
                let started = s.popped > 0;
                if started && s.out_timer == 0 && s.produced < spec.n_out * inferences {
                    let local_popped2 = s.popped.saturating_sub(infer_idx * spec.n_in);
                    let need = spec.required(local_produced);
                    if local_popped2 >= need
                        && fifos[i + 1].occupancy < fifos[i + 1].depth
                    {
                        fifos[i + 1].occupancy += 1;
                        fifos[i + 1].max_seen =
                            fifos[i + 1].max_seen.max(fifos[i + 1].occupancy);
                        s.produced += 1;
                        s.out_timer = spec.ii_out;
                    }
                }
                if started {
                    s.out_timer = s.out_timer.saturating_sub(1);
                }
            }

            // Sink drains the last FIFO freely.
            if fifos[n].occupancy > 0 {
                fifos[n].occupancy -= 1;
                sink_got += 1;
                if sink_got == self.stages[n - 1].n_out {
                    first_out_cycle = cycle;
                }
                if sink_got % self.stages[n - 1].n_out == 0 {
                    finish_cycles.push(cycle);
                }
            }
            cycle += 1;
        }

        let deadlocked = sink_got < total_out;
        let latency = if finish_cycles.is_empty() { cycle } else { finish_cycles[0] + 1 };
        let ii = if finish_cycles.len() >= 2 {
            let l = finish_cycles.len();
            finish_cycles[l - 1] - finish_cycles[l - 2]
        } else {
            latency
        };
        let _ = first_out_cycle;
        SimResult {
            latency_cycles: latency,
            ii_cycles: ii,
            fifo_max_occupancy: fifos.iter().map(|f| f.max_seen).collect(),
            simulated_cycles: cycle,
            deadlocked,
        }
    }

    /// Convenience: single inference with unbounded FIFOs (the §3.1.2
    /// "large FIFO" RTL-simulation configuration).
    pub fn run_unbounded(&self) -> SimResult {
        let depths = vec![UNBOUNDED_DEPTH; self.stages.len() + 1];
        self.run(&depths, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn elementwise(name: &str, n: usize, ii: u64) -> StageSpec {
        StageSpec {
            name: name.into(),
            n_in: n,
            n_out: n,
            ii_out: ii,
            ii_in: ii,
            prereq: Prereq::Elementwise,
        }
    }

    #[test]
    fn single_stage_latency_matches_ii() {
        let sim = Simulator::new(vec![elementwise("a", 10, 3)]);
        let r = sim.run_unbounded();
        assert!(!r.deadlocked);
        // 10 tokens at II=3, plus pipeline entry: latency ≈ 30 ± small.
        assert!((28..=35).contains(&r.latency_cycles), "{r:?}");
    }

    #[test]
    fn chain_latency_dominated_by_slowest() {
        let sim = Simulator::new(vec![
            elementwise("fast", 100, 1),
            elementwise("slow", 100, 5),
            elementwise("fast2", 100, 1),
        ]);
        let r = sim.run_unbounded();
        assert!(!r.deadlocked);
        assert!((480..560).contains(&r.latency_cycles), "{r:?}");
    }

    #[test]
    fn dense_stage_waits_for_all_inputs() {
        let dense = StageSpec {
            name: "fc".into(),
            n_in: 16,
            n_out: 4,
            ii_out: 2,
            ii_in: 1,
            prereq: Prereq::All,
        };
        let sim = Simulator::new(vec![dense]);
        let r = sim.run_unbounded();
        assert!(!r.deadlocked);
        // 16 pops at ii_in=1 (≈16 cycles, paced with source), then 4 outputs
        // at II=2 (≈8 cycles).
        assert!(r.latency_cycles >= 22, "{r:?}");
    }

    #[test]
    fn window_stage_streams_before_end() {
        // 8x8 raster, 3x3 window, stride 1: first output after ~2 rows + 3.
        let conv = StageSpec {
            name: "conv".into(),
            n_in: 64,
            n_out: 36,
            ii_out: 1,
            ii_in: 1,
            prereq: Prereq::Window { in_w: 8, kernel: 3, stride: 1, pad: 0 },
        };
        assert_eq!(conv.required(0), 2 * 8 + 3);
        assert_eq!(conv.required(1), 2 * 8 + 4);
        assert_eq!(conv.required(35), 64);
        let sim = Simulator::new(vec![conv]);
        let r = sim.run_unbounded();
        assert!(!r.deadlocked);
        assert!(r.latency_cycles < 64 + 40, "{r:?}");
    }

    #[test]
    fn bounded_fifos_preserve_results() {
        let stages = vec![
            elementwise("a", 50, 1),
            StageSpec {
                name: "fc".into(),
                n_in: 50,
                n_out: 10,
                ii_out: 4,
                ii_in: 1,
                prereq: Prereq::All,
            },
        ];
        let sim = Simulator::new(stages);
        let unbounded = sim.run_unbounded();
        let sized: Vec<usize> =
            unbounded.fifo_max_occupancy.iter().map(|&m| m + 1).collect();
        let bounded = sim.run(&sized, 1);
        assert!(!bounded.deadlocked);
        assert_eq!(bounded.latency_cycles, unbounded.latency_cycles);
    }

    #[test]
    fn tiny_fifos_slow_but_do_not_deadlock_chain() {
        let stages = vec![elementwise("a", 40, 1), elementwise("b", 40, 3)];
        let sim = Simulator::new(stages);
        let r = sim.run(&[1, 1, 1], 1);
        assert!(!r.deadlocked);
        let fast = sim.run(&[64, 64, 64], 1);
        assert!(r.latency_cycles >= fast.latency_cycles);
    }

    #[test]
    fn steady_state_ii_from_multiple_inferences() {
        let sim = Simulator::new(vec![elementwise("a", 20, 2)]);
        let depths = vec![UNBOUNDED_DEPTH; 2];
        let r = sim.run(&depths, 3);
        assert!(!r.deadlocked);
        assert!((38..=44).contains(&r.ii_cycles), "{r:?}");
    }

    #[test]
    fn backpressure_bounds_occupancy() {
        let stages = vec![elementwise("fast", 100, 1), elementwise("slow", 100, 10)];
        let sim = Simulator::new(stages);
        let r = sim.run(&[4, 4, 4], 1);
        assert!(!r.deadlocked);
        assert!(r.fifo_max_occupancy.iter().all(|&m| m <= 4));
    }
}
