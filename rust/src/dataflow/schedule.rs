//! Folding/parallelism scheduler: Graph -> dataflow stage network.
//!
//! This encodes the paper's resource-latency tradeoffs (§4.2.3):
//!
//! * **hls4ml, RF-driven** (AD, §3.3.2): every layer gets
//!   `n_mult = ceil(macs / RF)` multipliers — RF ("reuse factor") is how
//!   many MACs share one multiplier.  Layer cycles ≈ RF.
//! * **hls4ml, sequential kernel engine** (IC, §4.2.3): the streaming conv
//!   iterates the full input raster and performs the kernel multiplications
//!   sequentially, `out_ch` MACs in parallel — the paper's own description
//!   of why worst-case latency scales as `32·32·16384` cycles (and why
//!   their IC latency is 18.2x FINN's).
//! * **FINN, rate-balanced** (IC/KWS, §3.2): a total multiplier budget is
//!   spread so each layer's total cycles ≈ T = total_macs / budget, the
//!   PE×SIMD folding FINN computes automatically.
//!
//! The resulting [`StageImpl`]s carry both the timing view (for the
//! simulator) and the implementation view (`n_mult`, weights, precisions —
//! for the resource estimator).

use super::{Prereq, StageSpec};
use crate::ir::{Graph, Node};

/// Scheduler configuration knobs.
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    /// FINN total multiplier budget (PE*SIMD summed over layers).
    pub finn_mult_budget: u64,
    /// SIMD group width for streaming 1-D tensors between FINN stages.
    pub stream_group: usize,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        Self { finn_mult_budget: 1024, stream_group: 8 }
    }
}

/// Implementation record for one stage.
#[derive(Clone, Debug)]
pub struct StageImpl {
    pub node_idx: usize,
    pub name: String,
    pub op: &'static str,
    /// Parallel MAC units (0 for non-compute stages).
    pub n_mult: u64,
    /// Total busy cycles per inference.
    pub total_cycles: u64,
    /// Weight bits stored on-chip for this stage.
    pub weight_store_bits: u64,
    /// Weight precision / input precision / accumulator width.
    pub wbits: u32,
    pub in_bits: u32,
    pub acc_bits: u32,
    /// Output channels (width of one token in elements).
    pub token_elems: usize,
    /// Output activation bits (FIFO word width driver).
    pub out_bits: u32,
    pub spec: StageSpec,
}

/// A fully-scheduled design.
#[derive(Clone, Debug)]
pub struct ScheduledDesign {
    pub model: String,
    pub flow: String,
    pub stages: Vec<StageImpl>,
    /// Tokens the input interface pushes per inference.
    pub input_tokens: usize,
}

pub fn schedule(g: &Graph, cfg: &ScheduleConfig) -> ScheduledDesign {
    let total_macs = g.total_macs().max(1);
    // FINN rate-balance target: every layer finishes in ~T cycles.
    let max_out_tokens = g
        .nodes
        .iter()
        .map(|n| n.out_tokens())
        .max()
        .unwrap_or(1) as u64;
    let t_finn = (total_macs / cfg.finn_mult_budget).max(max_out_tokens);

    let is_2d_input = g.input_shape.len() == 3;
    let input_elems: usize = g.input_shape.iter().product();
    let mut prev_tokens = if is_2d_input {
        g.input_shape[0] * g.input_shape[1]
    } else {
        (input_elems / cfg.stream_group).max(1)
    };
    let mut cur_bits = g.input_bits;

    let mut stages = Vec::new();
    let input_tokens = prev_tokens;

    for (idx, node) in g.nodes.iter().enumerate() {
        if matches!(node, Node::Flatten { .. }) {
            continue; // free reshape — removed by fold_flatten anyway
        }
        let (spec, n_mult, total_cycles, wbits, acc_bits, token_elems, out_bits) = match node {
            Node::Conv2D {
                name, in_hw, out_hw, in_ch, out_ch, kernel, stride, padding,
                weight_bits, acc_bits, fused_relu: _, ..
            } => {
                let macs = node.macs().max(1);
                let n_out = out_hw * out_hw;
                let pad = if padding == "SAME" { (kernel - 1) / 2 } else { 0 };
                let (n_mult, cycles) = if g.flow == "hls4ml" {
                    if g.reuse_factor > 1 {
                        let nm = macs.div_ceil(g.reuse_factor as u64).max(1);
                        (nm, macs.div_ceil(nm))
                    } else {
                        // Sequential kernel engine (§4.2.3): iterate the
                        // input raster; up to 16 MACs in parallel (the
                        // paper: "up to 16384 multiplications performed
                        // sequentially, resulting in 32 outputs" — the
                        // engine is mostly serial, which is why hls4ml IC
                        // latency is 18.2x FINN's).
                        let nm = (*out_ch as u64).clamp(1, 16);
                        let cycles = (in_hw * in_hw) as u64
                            * (kernel * kernel * in_ch * out_ch) as u64
                            / nm;
                        (nm, cycles.max(1))
                    }
                } else {
                    let nm = macs.div_ceil(t_finn).clamp(1, macs);
                    (nm, macs.div_ceil(nm))
                };
                let ii_out = (cycles / n_out as u64).max(1);
                let ii_in = (cycles / (in_hw * in_hw) as u64).max(1);
                (
                    StageSpec {
                        name: name.clone(),
                        n_in: prev_tokens,
                        n_out,
                        ii_out,
                        ii_in,
                        prereq: Prereq::Window {
                            in_w: *in_hw,
                            kernel: *kernel,
                            stride: *stride,
                            pad,
                        },
                    },
                    n_mult,
                    cycles,
                    *weight_bits,
                    if *acc_bits == 0 { 32 } else { *acc_bits },
                    *out_ch,
                    cur_bits,
                )
            }
            Node::Dense {
                name, in_features: _, out_features, weight_bits, acc_bits, ..
            } => {
                let macs = node.macs().max(1);
                let (n_mult, cycles) = if g.flow == "hls4ml" {
                    if g.reuse_factor > 1 {
                        let nm = macs.div_ceil(g.reuse_factor as u64).max(1);
                        (nm, macs.div_ceil(nm))
                    } else {
                        // Sequential engine: one MAC lane per output neuron.
                        let nm = (*out_features as u64).clamp(1, 32);
                        (nm, macs.div_ceil(nm))
                    }
                } else {
                    let nm = macs.div_ceil(t_finn).clamp(1, macs);
                    (nm, macs.div_ceil(nm))
                };
                // FINN streams the output vector in PE-wide groups.
                let n_out = if g.flow == "finn" {
                    out_features.div_ceil(cfg.stream_group).max(1)
                } else {
                    1
                };
                let ii_out = (cycles / n_out as u64).max(1);
                let ii_in = (cycles / prev_tokens.max(1) as u64).max(1);
                (
                    StageSpec {
                        name: name.clone(),
                        n_in: prev_tokens,
                        n_out,
                        ii_out,
                        ii_in,
                        prereq: Prereq::All,
                    },
                    n_mult,
                    cycles,
                    *weight_bits,
                    if *acc_bits == 0 { 32 } else { *acc_bits },
                    *out_features / n_out.max(1),
                    cur_bits,
                )
            }
            Node::MaxPool { name, in_hw, out_hw, channels, size, .. } => {
                let n_out = out_hw * out_hw;
                let cycles = (n_out * size * size) as u64;
                (
                    StageSpec {
                        name: name.clone(),
                        n_in: prev_tokens,
                        n_out,
                        ii_out: (size * size) as u64,
                        ii_in: 1,
                        prereq: Prereq::Window {
                            in_w: *in_hw,
                            kernel: *size,
                            stride: *size,
                            pad: 0,
                        },
                    },
                    0,
                    cycles,
                    0,
                    0,
                    *channels,
                    cur_bits,
                )
            }
            Node::BatchNorm { name, channels, .. }
            | Node::ReLU { name, channels, .. }
            | Node::BipolarAct { name, channels, .. }
            | Node::MultiThreshold { name, channels, .. } => {
                let n = prev_tokens;
                (
                    StageSpec {
                        name: name.clone(),
                        n_in: n,
                        n_out: n,
                        ii_out: 1,
                        ii_in: 1,
                        prereq: Prereq::Elementwise,
                    },
                    0,
                    n as u64,
                    0,
                    0,
                    *channels,
                    cur_bits,
                )
            }
            Node::Softmax { name, channels, .. } | Node::TopK { name, channels, .. } => {
                (
                    StageSpec {
                        name: name.clone(),
                        n_in: prev_tokens,
                        n_out: 1,
                        ii_out: *channels as u64,
                        ii_in: 1,
                        prereq: Prereq::All,
                    },
                    0,
                    *channels as u64,
                    0,
                    0,
                    *channels,
                    cur_bits,
                )
            }
            Node::Flatten { .. } => unreachable!(),
        };

        // Track activation precision through the chain.
        match node {
            Node::ReLU { act_bits, .. } => cur_bits = *act_bits,
            Node::BipolarAct { .. } => cur_bits = 1,
            Node::MultiThreshold { levels, .. } => {
                cur_bits = (32 - levels.leading_zeros()).max(1)
            }
            Node::Conv2D { fused_relu, in_bits, .. }
            | Node::Dense { fused_relu, in_bits, .. } => {
                let _ = in_bits;
                if *fused_relu {
                    // fused activation keeps the layer's output precision
                    // (hls4ml fixed-point stays at weight precision + head).
                }
            }
            _ => {}
        }

        let weight_store_bits = node.params() * wbits.max(1) as u64 * node.is_compute() as u64;
        prev_tokens = spec.n_out;
        stages.push(StageImpl {
            node_idx: idx,
            name: node.name().to_string(),
            op: node.op(),
            n_mult,
            total_cycles,
            weight_store_bits,
            wbits,
            in_bits: out_bits,
            acc_bits,
            token_elems,
            out_bits: cur_bits,
            spec,
        });
    }

    ScheduledDesign {
        model: g.name.clone(),
        flow: g.flow.clone(),
        stages,
        input_tokens,
    }
}

impl ScheduledDesign {
    pub fn stage_specs(&self) -> Vec<StageSpec> {
        self.stages.iter().map(|s| s.spec.clone()).collect()
    }

    /// Lower bound on cycles/inference: the slowest stage.
    pub fn bottleneck_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.total_cycles).max().unwrap_or(0)
    }

    pub fn total_mults(&self) -> u64 {
        self.stages.iter().map(|s| s.n_mult).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::PassManager;

    fn mlp_graph(flow: &str, rf: u32) -> Graph {
        let json = format!(
            r#"{{
            "name":"m","task":"kws","flow":"{flow}","input_shape":[64],
            "input_bits":8,"reuse_factor":{rf},"nodes":[
              {{"op":"Dense","name":"fc1","in_features":64,"out_features":32,
               "weight_bits":3,"params":2048}},
              {{"op":"BatchNorm","name":"bn1","channels":32,"params":128}},
              {{"op":"ReLU","name":"r1","channels":32,"act_bits":3,"params":0}},
              {{"op":"Dense","name":"fc2","in_features":32,"out_features":10,
               "weight_bits":3,"params":320}}
            ],"total_params":2496}}"#
        );
        Graph::from_json_str(&json).unwrap()
    }

    #[test]
    fn hls4ml_rf_controls_mult_count() {
        let g = mlp_graph("hls4ml", 16);
        let d = schedule(&g, &ScheduleConfig::default());
        let fc1 = &d.stages[0];
        assert_eq!(fc1.n_mult, 2048 / 16);
        assert!((15..=17).contains(&fc1.total_cycles), "{}", fc1.total_cycles);
    }

    #[test]
    fn finn_budget_rate_balances() {
        let g = mlp_graph("finn", 1);
        let cfg = ScheduleConfig { finn_mult_budget: 64, stream_group: 8 };
        let d = schedule(&g, &cfg);
        // total_macs = 2368; T = max(2368/64, 8) = 37.
        let fc1 = &d.stages[0];
        let fc2 = &d.stages[3];
        assert!(fc1.total_cycles.abs_diff(fc2.total_cycles) <= fc1.total_cycles,);
        assert!(fc1.n_mult >= fc2.n_mult); // bigger layer, more mults
    }

    #[test]
    fn token_counts_chain() {
        let g = mlp_graph("finn", 1);
        let d = schedule(&g, &ScheduleConfig::default());
        for w in d.stages.windows(2) {
            assert_eq!(w[0].spec.n_out, w[1].spec.n_in, "{} -> {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn schedules_simulate_clean(){
        for flow in ["finn", "hls4ml"] {
            let g = mlp_graph(flow, 8);
            let mut pm = PassManager::for_flow(flow);
            let g = pm.run(&g);
            let d = schedule(&g, &ScheduleConfig::default());
            let sim = crate::dataflow::Simulator::new(d.stage_specs());
            let r = sim.run_unbounded();
            assert!(!r.deadlocked, "{flow}: {r:?}");
            assert!(r.latency_cycles > 0);
        }
    }
}
