//! Pooled reply buffers: the last per-request heap allocation on the
//! serve loop, closed.
//!
//! The PR 2 scratch-arena work made every *staging* buffer on the hot
//! path reusable (kernels, batch gather, engine x/out), but the reply
//! vectors still allocated — they cross a channel to the caller, so the
//! serve loop could not own them (ROADMAP "Kernel core": "only
//! per-request reply vectors still allocate").  [`ReplyPool`] closes
//! that gap: replies ride in a [`PooledVec`] — a guard around a plain
//! `Vec<f32>` that *returns the buffer to its pool when the caller drops
//! the reply*.  Steady state, every reply reuses a buffer some earlier
//! reply finished with; the allocator is only touched while the pool
//! warms up (or past its bound).
//!
//! Two properties the tests pin down (`rust/tests/proptests.rs`):
//!
//! * **Bit identity** — a reply through the pool is bit-identical to the
//!   unpooled path ([`ReplyPool::take_copy`] clears and overwrites the
//!   whole buffer, never resizes around stale data).
//! * **No data leaks** — a recycled buffer can never leak a previous
//!   request's data: the pool fills every buffer it shelves with
//!   [`poison`] (a recognizable quiet NaN, [`POISON_BITS`]) — buffers
//!   dropped past the bound go straight back to the allocator instead —
//!   and the take path's full overwrite is asserted against that
//!   poison.
//!
//! The pool itself is **striped** ([`N_STRIPES`] independent locks, a
//! rotating cursor for takes, returns go to the stripe the buffer came
//! from) — a single pool mutex shared by every client thread would just
//! recreate the global-lock contention the sharded telemetry and striped
//! result cache remove from the same hot path.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Bit pattern of the quiet NaN written over every buffer returned to
/// the pool, so stale request data is unmistakable if a take path ever
/// fails to overwrite fully (compare with [`f32::to_bits`]; `NaN !=
/// NaN` hides it otherwise).
pub const POISON_BITS: u32 = 0x7FC0_5EED;

/// The poison value itself (`from_bits` is not const on older
/// toolchains, so this is a fn rather than a const).
#[inline]
pub fn poison() -> f32 {
    f32::from_bits(POISON_BITS)
}

/// Independent lock stripes per pool.  Takes rotate over the stripes and
/// each buffer returns to its origin stripe, so the put/take traffic of
/// many client threads spreads instead of serializing.
pub const N_STRIPES: usize = 8;

struct PoolInner {
    stripes: Vec<Mutex<Vec<Vec<f32>>>>,
    /// Rotating take cursor (relaxed; distribution is all that matters).
    next: AtomicUsize,
    cap_per_stripe: usize,
    recycled: AtomicU64,
    allocated: AtomicU64,
}

/// A bounded, striped pool of recycled `Vec<f32>` reply buffers.
/// Cloning the handle is an Arc clone — every clone (and every
/// outstanding [`PooledVec`]) shares the same shelves.
#[derive(Clone)]
pub struct ReplyPool {
    inner: Arc<PoolInner>,
}

impl ReplyPool {
    /// Pool bounded at ~`cap` shelved buffers (split over the stripes).
    pub fn new(cap: usize) -> ReplyPool {
        ReplyPool {
            inner: Arc::new(PoolInner {
                stripes: (0..N_STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
                next: AtomicUsize::new(0),
                cap_per_stripe: (cap / N_STRIPES).max(1),
                recycled: AtomicU64::new(0),
                allocated: AtomicU64::new(0),
            }),
        }
    }

    /// Take an **empty** buffer (recycled if available).  The caller
    /// fills it (e.g. [`crate::fleet::cache::ResultCache::get_copy`]);
    /// dropping the guard returns the buffer here.
    pub fn take(&self) -> PooledVec {
        let stripe = self.inner.next.fetch_add(1, Ordering::Relaxed) % N_STRIPES;
        let buf = self.inner.stripes[stripe].lock().unwrap().pop();
        let mut buf = match buf {
            Some(b) => {
                self.inner.recycled.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.inner.allocated.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        // Recycled buffers arrive poison-filled; the guard hands out an
        // empty vec so every element the caller reads was written by the
        // caller.
        buf.clear();
        PooledVec { buf, home: Some((self.clone(), stripe)) }
    }

    /// Take a buffer holding a copy of `src` — the pooled replacement
    /// for `src.to_vec()` on the reply path.  Clear + extend: every
    /// element is freshly written, so a recycled buffer cannot leak.
    pub fn take_copy(&self, src: &[f32]) -> PooledVec {
        let mut v = self.take();
        v.buf.extend_from_slice(src);
        v
    }

    /// Return a buffer; full stripes drop it back to the allocator so
    /// the pool stays bounded.  Only buffers actually shelved are
    /// poison-filled — an overflow drop pays nothing (the allocator
    /// reclaims it; nothing can read it again through the safe API).
    fn put(&self, stripe: usize, mut buf: Vec<f32>) {
        let mut shelf = self.inner.stripes[stripe].lock().unwrap();
        if shelf.len() < self.inner.cap_per_stripe {
            let poison = poison();
            buf.iter_mut().for_each(|v| *v = poison);
            shelf.push(buf);
        }
    }

    /// Takes served from a recycled buffer (observability / tests).
    pub fn recycled(&self) -> u64 {
        self.inner.recycled.load(Ordering::Relaxed)
    }

    /// Takes that had to touch the allocator.
    pub fn allocated(&self) -> u64 {
        self.inner.allocated.load(Ordering::Relaxed)
    }

    /// Buffers currently shelved across all stripes.
    pub fn shelved(&self) -> usize {
        self.inner.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

/// A reply buffer that knows its way home: derefs as `[f32]`, and on
/// drop returns the underlying `Vec` to the [`ReplyPool`] it came from
/// (detached instances are plain vectors and just deallocate).
///
/// Cloning detaches: the copy is an ordinary allocation that does *not*
/// return to the pool — a clone outliving the pool must not resurrect
/// it, and replies are cloned only off the hot path.
#[derive(Default)]
pub struct PooledVec {
    buf: Vec<f32>,
    home: Option<(ReplyPool, usize)>,
}

impl PooledVec {
    /// Wrap a plain vector; dropping it frees normally (the unpooled
    /// path, and what `Clone` produces).
    pub fn detached(buf: Vec<f32>) -> Self {
        PooledVec { buf, home: None }
    }

    /// Whether this buffer returns to a pool on drop.
    pub fn is_pooled(&self) -> bool {
        self.home.is_some()
    }

    /// Mutable access to the underlying vector (fill-in-place paths like
    /// the result cache's `get_copy`).
    pub fn vec_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }

    /// Detached copy of the contents.
    pub fn to_vec(&self) -> Vec<f32> {
        self.buf.clone()
    }
}

impl Drop for PooledVec {
    fn drop(&mut self) {
        if let Some((pool, stripe)) = self.home.take() {
            pool.put(stripe, std::mem::take(&mut self.buf));
        }
    }
}

impl std::ops::Deref for PooledVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Clone for PooledVec {
    fn clone(&self) -> Self {
        PooledVec::detached(self.buf.clone())
    }
}

impl From<Vec<f32>> for PooledVec {
    fn from(buf: Vec<f32>) -> Self {
        PooledVec::detached(buf)
    }
}

impl std::fmt::Debug for PooledVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.buf.fmt(f)
    }
}

impl PartialEq for PooledVec {
    fn eq(&self, other: &Self) -> bool {
        self.buf == other.buf
    }
}

impl PartialEq<Vec<f32>> for PooledVec {
    fn eq(&self, other: &Vec<f32>) -> bool {
        &self.buf == other
    }
}

impl PartialEq<[f32]> for PooledVec {
    fn eq(&self, other: &[f32]) -> bool {
        self.buf == other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_copy_round_trips_and_recycles() {
        let pool = ReplyPool::new(32);
        let data = vec![1.0f32, -2.5, 3.25];
        let ptr = {
            let v = pool.take_copy(&data);
            assert_eq!(v, data);
            assert!(v.is_pooled());
            assert_eq!(pool.allocated(), 1);
            v.as_ptr()
        };
        // Dropped: shelved (poisoned), and the next take on the same
        // stripe reuses the same storage.
        assert_eq!(pool.shelved(), 1);
        for _ in 0..N_STRIPES {
            let v = pool.take_copy(&[9.0]);
            if v.as_ptr() == ptr {
                assert_eq!(v, vec![9.0f32], "recycled buffer must hold only new data");
                assert!(pool.recycled() >= 1);
                return;
            }
        }
        panic!("rotating cursor never revisited the stripe holding the buffer");
    }

    #[test]
    fn returned_buffers_are_poisoned() {
        let pool = ReplyPool::new(8);
        drop(pool.take_copy(&[1.0, 2.0]));
        // Reach into the stripe the cursor used (take 0 used stripe 0).
        let shelf = pool.inner.stripes[0].lock().unwrap();
        let buf = shelf.first().expect("buffer shelved");
        assert!(
            buf.iter().all(|v| v.to_bits() == POISON_BITS),
            "shelved buffer must be fully poison-filled: {buf:?}"
        );
    }

    #[test]
    fn pool_is_bounded_and_detached_vecs_stay_plain() {
        let pool = ReplyPool::new(N_STRIPES); // one buffer per stripe
        let taken: Vec<PooledVec> =
            (0..4 * N_STRIPES).map(|_| pool.take_copy(&[0.5])).collect();
        drop(taken);
        assert!(pool.shelved() <= N_STRIPES, "cap must bound shelved buffers");
        let d = PooledVec::detached(vec![1.0]);
        assert!(!d.is_pooled());
        let c = pool.take_copy(&[2.0]).clone();
        assert!(!c.is_pooled(), "clones must detach from the pool");
    }

    #[test]
    fn equality_and_deref_match_plain_vectors() {
        let pool = ReplyPool::new(8);
        let v = pool.take_copy(&[1.0, 2.0]);
        assert_eq!(v, vec![1.0f32, 2.0]);
        assert_eq!(&v[..], &[1.0f32, 2.0][..]);
        assert_eq!(v.len(), 2);
        assert_eq!(v.to_vec(), vec![1.0f32, 2.0]);
        let w: PooledVec = vec![1.0f32, 2.0].into();
        assert_eq!(v, w);
    }
}
