//! The end-to-end codesign flow (the paper's §5 "common methodology"):
//!
//! topology → optimization passes → folding schedule → FIFO-depth
//! optimization → resource estimate → board-fit check → dataflow latency →
//! power/energy — one call, one [`FlowReport`] per (model, board).
//!
//! This is the Rust-side equivalent of `hls4ml convert + vivado_hls csynth`
//! / `FINN build_dataflow`, driven entirely from the AOT topology JSON.

use crate::board::Board;
use crate::dataflow::schedule::{schedule, ScheduleConfig, ScheduledDesign};
use crate::dataflow::Simulator;
use crate::fifo::{depth_range, optimize_fifos, DepthPolicy, FifoOptResult};
use crate::ir::Graph;
use crate::passes::PassManager;
use crate::power::PowerModel;
use crate::resources::{estimate, CostModel, ResourceReport};
use crate::error::Result;

/// Which optimizations to run — the Table 3/4 ablation axes.
#[derive(Clone, Copy, Debug)]
pub struct FlowOptions {
    pub run_passes: bool,
    pub fifo_opt: bool,
    /// Only meaningful for hls4ml (Table 3): merge ReLU stages.
    pub relu_merge: bool,
    /// Only meaningful for hls4ml (Table 4): fold BN into FC.
    pub bn_fold: bool,
}

impl Default for FlowOptions {
    fn default() -> Self {
        Self { run_passes: true, fifo_opt: true, relu_merge: true, bn_fold: true }
    }
}

impl FlowOptions {
    pub fn none() -> Self {
        Self { run_passes: false, fifo_opt: false, relu_merge: false, bn_fold: false }
    }
}

/// Everything the flow produces for one (model, board) pair.
#[derive(Clone, Debug)]
pub struct FlowReport {
    pub model: String,
    pub board: &'static str,
    pub optimized: Graph,
    pub fifo: FifoOptResult,
    pub fifo_range: (usize, usize),
    pub resources: ResourceReport,
    pub fits: bool,
    pub latency_cycles: u64,
    pub latency_s: f64,
    /// Steady-state initiation interval: cycles between back-to-back
    /// inferences once the pipeline is full (= the bottleneck stage).
    /// This is what the fleet batches against: a batch of n costs
    /// `latency + (n-1) * ii`.
    pub ii_cycles: u64,
    pub power_w: f64,
    pub energy_per_inference_uj: f64,
    pub pass_log: Vec<String>,
}

/// Run the full flow.
pub fn run_flow(
    g: &Graph,
    board: &Board,
    opts: &FlowOptions,
    schedule_cfg: &ScheduleConfig,
) -> Result<FlowReport> {
    // 1. Compiler passes.
    let mut pm = if opts.run_passes {
        let mut pm = PassManager::for_flow(&g.flow);
        if g.flow == "hls4ml" {
            if !opts.relu_merge {
                pm.passes.retain(|(n, _)| *n != "merge_relu");
            }
            if !opts.bn_fold {
                pm.passes.retain(|(n, _)| *n != "fold_bn_into_linear");
            }
        }
        pm
    } else {
        PassManager::baseline()
    };
    let optimized = pm.run(g);
    optimized.validate()?;

    // 2. Folding schedule + dataflow network.
    let design: ScheduledDesign = schedule(&optimized, schedule_cfg);
    let sim = Simulator::new(design.stage_specs());

    // 3. FIFO sizing (§3.1.2): optimized or naive depths.
    let policy = DepthPolicy::for_flow(&g.flow);
    let fifo = if opts.fifo_opt {
        optimize_fifos(&sim, policy)
    } else {
        let depths = crate::fifo::naive_depths(&sim);
        let run = sim.run(&depths, 1);
        crate::fifo::FifoOptResult {
            unoptimized_latency: run.latency_cycles,
            optimized_latency: run.latency_cycles,
            sizing_run: run,
            depths,
        }
    };
    // Interior FIFO range (skip the I/O FIFOs for Table 2 reporting).
    let interior = if fifo.depths.len() > 2 {
        &fifo.depths[1..fifo.depths.len() - 1]
    } else {
        &fifo.depths[..]
    };
    let fifo_range = depth_range(interior);

    // 4. Resources + fit.
    let resources = estimate(
        &design,
        optimized.reuse_factor,
        &fifo.depths,
        board,
        &CostModel::default(),
    );
    let fits = resources.total.fits(board);

    // 5. Latency + power + energy.
    let latency_cycles = fifo.optimized_latency;
    let latency_s = latency_cycles as f64 / board.clock_hz;
    let pm_power = PowerModel::default();
    let power = pm_power.power(&resources.total, board);
    let energy = pm_power.energy_per_inference_uj(&resources.total, board, latency_s);

    Ok(FlowReport {
        model: g.name.clone(),
        board: board.name,
        optimized,
        fifo_range,
        fifo,
        resources,
        fits,
        latency_cycles,
        latency_s,
        ii_cycles: design.bottleneck_cycles().max(1),
        power_w: power.total_w,
        energy_per_inference_uj: energy,
        pass_log: pm.log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::pynq_z2;
    use crate::ir::Graph;

    fn kws_graph() -> Graph {
        Graph::from_json_str(
            r#"{
            "name":"kws_small","task":"kws","flow":"finn","input_shape":[64],
            "input_bits":8,"nodes":[
              {"op":"Dense","name":"fc1","in_features":64,"out_features":32,
               "weight_bits":3,"params":2048},
              {"op":"BatchNorm","name":"bn1","channels":32,"params":128},
              {"op":"ReLU","name":"r1","channels":32,"act_bits":3,"params":0},
              {"op":"Dense","name":"fc2","in_features":32,"out_features":12,
               "weight_bits":3,"params":384},
              {"op":"BatchNorm","name":"bn2","channels":12,"params":48}
            ],"total_params":2608}"#,
        )
        .unwrap()
    }

    #[test]
    fn flow_runs_end_to_end() {
        let r = run_flow(
            &kws_graph(),
            &pynq_z2(),
            &FlowOptions::default(),
            &ScheduleConfig::default(),
        )
        .unwrap();
        assert!(r.fits, "{:?}", r.resources.total);
        assert!(r.latency_s > 0.0 && r.latency_s < 1.0);
        assert!(r.ii_cycles > 0 && r.ii_cycles <= r.latency_cycles, "{r:?}");
        assert!(r.energy_per_inference_uj > 0.0);
        assert!(r.optimized.nodes.iter().any(|n| n.op() == "MultiThreshold"));
    }

    #[test]
    fn fifo_opt_does_not_change_latency() {
        let with = run_flow(
            &kws_graph(),
            &pynq_z2(),
            &FlowOptions::default(),
            &ScheduleConfig::default(),
        )
        .unwrap();
        assert_eq!(with.fifo.unoptimized_latency, with.fifo.optimized_latency);
    }

    #[test]
    fn finn_depths_are_powers_of_two() {
        let r = run_flow(
            &kws_graph(),
            &pynq_z2(),
            &FlowOptions::default(),
            &ScheduleConfig::default(),
        )
        .unwrap();
        for &d in &r.fifo.depths {
            assert!(d.is_power_of_two(), "{d}");
        }
    }

    #[test]
    fn unoptimized_flow_uses_more_resources() {
        let opt = run_flow(
            &kws_graph(),
            &pynq_z2(),
            &FlowOptions::default(),
            &ScheduleConfig::default(),
        )
        .unwrap();
        let raw = run_flow(
            &kws_graph(),
            &pynq_z2(),
            &FlowOptions::none(),
            &ScheduleConfig::default(),
        )
        .unwrap();
        assert!(
            raw.resources.total.luts > opt.resources.total.luts,
            "raw={:?} opt={:?}",
            raw.resources.total,
            opt.resources.total
        );
    }
}
