//! Async batching inference engine (std-threads; the image's vendored
//! crate set has no tokio, so the event loop is a plain channel-driven
//! worker — same architecture as a vLLM-style router: request queue →
//! dynamic batcher → device executor).
//!
//! Requests are coalesced into device batches of up to the AOT batch size
//! within a bounded batching window; the worker owns the executor (PJRT
//! executables are not Sync) and replies over per-request channels.
//!
//! The device side is abstracted behind [`BatchExecutor`]
//! ([`ModelExecutor`] wraps a [`LoadedModel`];
//! [`crate::fleet::worker::SimBoardExecutor`] wraps a simulated board
//! with its dataflow-predicted device occupancy).  [`serve_with`] and the
//! fleet's [`crate::fleet::worker::run_worker`] both drive the trait —
//! the fleet loop adds work stealing and per-batch telemetry but contains
//! no execute path of its own — and both batch through [`fill_window`],
//! so every serving path batches *and executes* identically.

use super::pool::{PooledVec, ReplyPool};
use crate::error::{anyhow, Result};
use crate::runtime::{argmax, LoadedModel, Runtime};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Per-sample outputs.  Rides in a [`PooledVec`]: serve loops copy
    /// into a recycled buffer instead of allocating, and dropping the
    /// reply returns the buffer to its pool — the serve loop's last
    /// per-request heap allocation, closed (see [`super::pool`]).
    pub output: PooledVec,
    pub top1: usize,
    /// Device batch this request rode in (observability).
    pub batch_size: usize,
    /// Time spent queued + batching before execution started.
    pub queue_us: u128,
    /// Device execution time of the batch this request rode in, so
    /// downstream telemetry (fleet) doesn't re-measure.
    pub exec_us: u128,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// What the batching loop needs from a device: capacity, shapes, and a
/// padded-batch execute.  This is the *only* execute abstraction in the
/// crate — the single-model engine, the fleet board workers, and the
/// pjrt-feature fleet workers all drive it, so device semantics (padding,
/// occupancy-dependent timing) live behind the trait and nowhere else.
pub trait BatchExecutor {
    /// Device batch capacity; batches are padded to exactly this size.
    fn device_batch(&mut self) -> Result<usize>;
    /// Flattened input elements per sample.
    fn input_elems(&self) -> usize;
    /// Output elements per sample.
    fn num_outputs(&self) -> usize;
    /// Execute one padded batch: `x` holds `device_batch * input_elems`
    /// values of which the first `n` samples are live (`1 <= n <=
    /// device_batch`, tail zero-padded); results land in the first
    /// `n * num_outputs` values of `out` (sized `device_batch *
    /// num_outputs`).  Executors compiled for a fixed batch (the AOT PJRT
    /// path) run the whole padded batch and may ignore `n` for compute;
    /// executors whose device time depends on occupancy (the simulated
    /// boards' `latency + (n-1)*ii` dataflow hold) use `n` for timing.
    /// The serve loops reuse both buffers across batches, so the steady
    /// state allocates nothing on the device path.
    fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()>;
}

/// [`BatchExecutor`] over the runtime's [`LoadedModel`].
pub struct ModelExecutor<'a> {
    pub rt: &'a Runtime,
    pub model: &'a mut LoadedModel,
}

impl BatchExecutor for ModelExecutor<'_> {
    fn device_batch(&mut self) -> Result<usize> {
        self.model.ensure_fwd_batch(self.rt)
    }

    fn input_elems(&self) -> usize {
        self.model.manifest.input_elems()
    }

    fn num_outputs(&self) -> usize {
        self.model.manifest.num_outputs
    }

    fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
        self.model.infer_prefix_into(self.rt, x, n, out)
    }
}

/// Handle for submitting requests; clone freely across threads.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<(Request, Instant)>,
}

impl EngineHandle {
    pub fn infer(&self, x: Vec<f32>) -> Result<Reply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send((Request { x, reply: reply_tx }, Instant::now()))
            .map_err(|_| anyhow!("engine stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }
}

/// Grow a batch around `first`: keep pulling items until `max_batch` is
/// reached or the `max_wait` window closes.  `next` is handed the window
/// deadline and returns the next item, or `None` when the source is dry
/// for this window.  Shared by the engine loop and the fleet workers so
/// every serving path batches identically.
///
/// Lifecycle-trace boundary: the instant this function returns is the
/// "batch-window close" edge.  The fleet worker stamps it into every
/// traced rider's [`crate::fleet::TraceCtx`] right after the call (and
/// stamps dequeue inside its `next` closures), so `window_wait` measures
/// exactly the time spent inside this window — the engine loop itself
/// carries no per-request tracing.
pub fn fill_window<T>(
    first: T,
    policy: &BatchPolicy,
    mut next: impl FnMut(Instant) -> Option<T>,
) -> Vec<T> {
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch && Instant::now() < deadline {
        match next(deadline) {
            Some(item) => batch.push(item),
            None => break,
        }
    }
    batch
}

/// Run the batching loop over any executor until `rx` hangs up; returns
/// total requests served.
pub fn serve_with<E: BatchExecutor>(
    exec: &mut E,
    policy: BatchPolicy,
    rx: mpsc::Receiver<(Request, Instant)>,
) -> Result<u64> {
    let device_batch = exec.device_batch()?;
    let window = BatchPolicy {
        max_batch: policy.max_batch.min(device_batch),
        max_wait: policy.max_wait,
    };
    let feat = exec.input_elems();
    let n_out = exec.num_outputs();
    let mut served = 0u64;
    // Batch staging buffers, allocated once and reused for every batch;
    // replies copy into pooled buffers that return on drop — together
    // with the executor-side scratch arena the steady-state serve loop
    // allocates nothing per request (the per-request reply channel is
    // the submitter's).
    let mut x = vec![0.0f32; device_batch * feat];
    let mut out = vec![0.0f32; device_batch * n_out];
    let pool = ReplyPool::new(4 * device_batch.max(16));

    loop {
        // Block for the first request of a batch.
        let Ok(first) = rx.recv() else {
            return Ok(served);
        };
        let batch = fill_window(first, &window, |deadline| {
            let now = Instant::now();
            rx.recv_timeout(deadline.saturating_duration_since(now)).ok()
        });

        // Pad to the device batch and execute once.  Only the tail needs
        // zeroing — the head is overwritten by this batch's requests.
        for (i, (req, _)) in batch.iter().enumerate() {
            x[i * feat..(i + 1) * feat].copy_from_slice(&req.x);
        }
        x[batch.len() * feat..].fill(0.0);
        let exec_start = Instant::now();
        exec.execute(&x, batch.len(), &mut out)?;
        let exec_us = exec_start.elapsed().as_micros();
        for (i, (req, t0)) in batch.iter().enumerate() {
            let slice = pool.take_copy(&out[i * n_out..(i + 1) * n_out]);
            let top1 = argmax(&slice);
            let _ = req.reply.send(Reply {
                output: slice,
                top1,
                batch_size: batch.len(),
                queue_us: exec_start.duration_since(*t0).as_micros(),
                exec_us,
            });
            served += 1;
        }
    }
}

/// Run the engine on the current thread until the handle side hangs up.
/// Call from a dedicated `std::thread`; returns total requests served.
pub fn serve(
    rt: &Runtime,
    model: &mut LoadedModel,
    policy: BatchPolicy,
    rx: mpsc::Receiver<(Request, Instant)>,
) -> Result<u64> {
    serve_with(&mut ModelExecutor { rt, model }, policy, rx)
}

/// Spawn the engine on a background thread, returning a handle.  PJRT
/// handles are not `Send`, so the runtime and model are constructed
/// *inside* the worker thread from the artifact directory.
pub fn spawn(
    art_dir: std::path::PathBuf,
    model_name: String,
    policy: BatchPolicy,
) -> (EngineHandle, std::thread::JoinHandle<Result<u64>>) {
    let (tx, rx) = mpsc::channel();
    let join = std::thread::spawn(move || {
        let rt = Runtime::cpu()?;
        let mut model = LoadedModel::load(&art_dir, &model_name)?;
        serve(&rt, &mut model, policy, rx)
    });
    (EngineHandle { tx }, join)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_policy_defaults() {
        let p = BatchPolicy::default();
        assert_eq!(p.max_batch, 64);
        assert!(p.max_wait >= Duration::from_millis(1));
    }

    /// Executor that doubles every input element (batch 4).
    struct Doubler;

    impl BatchExecutor for Doubler {
        fn device_batch(&mut self) -> Result<usize> {
            Ok(4)
        }

        fn input_elems(&self) -> usize {
            2
        }

        fn num_outputs(&self) -> usize {
            2
        }

        fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
            assert!((1..=4).contains(&n), "live count out of range: {n}");
            for (o, v) in out.iter_mut().zip(x) {
                *o = v * 2.0;
            }
            Ok(())
        }
    }

    #[test]
    fn serve_with_batches_and_reports_timing() {
        let (tx, rx) = mpsc::channel();
        let worker = std::thread::spawn(move || {
            let mut exec = Doubler;
            let policy =
                BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) };
            serve_with(&mut exec, policy, rx).unwrap()
        });
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (rtx, rrx) = mpsc::channel();
            tx.send((Request { x: vec![i as f32, 1.0], reply: rtx }, Instant::now()))
                .unwrap();
            rxs.push((i, rrx));
        }
        drop(tx);
        for (i, rrx) in rxs {
            let r = rrx.recv().unwrap();
            assert_eq!(r.output, vec![i as f32 * 2.0, 2.0]);
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
            // exec_us is per-batch device time; queue_us covers the wait.
            assert!(r.queue_us < 5_000_000);
        }
        let served = worker.join().unwrap();
        assert_eq!(served, 8);
    }

    #[test]
    fn fill_window_respects_max_batch() {
        let mut pool = (1..10).collect::<Vec<i32>>();
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) };
        let batch = fill_window(0, &policy, |_| pool.pop());
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn fill_window_stops_when_source_dry() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) };
        let batch = fill_window(7, &policy, |_| None);
        assert_eq!(batch, vec![7]);
    }
}
