//! Async batching inference engine (std-threads; the image's vendored
//! crate set has no tokio, so the event loop is a plain channel-driven
//! worker — same architecture as a vLLM-style router: request queue →
//! dynamic batcher → device executor).
//!
//! Requests are coalesced into device batches of up to the AOT batch size
//! within a bounded batching window; the worker owns the `LoadedModel`
//! (PJRT executables are not Sync) and replies over per-request channels.

use crate::runtime::{argmax, LoadedModel, Runtime};
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct Reply {
    pub output: Vec<f32>,
    pub top1: usize,
    /// Device batch this request rode in (observability).
    pub batch_size: usize,
    pub queue_us: u128,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Handle for submitting requests; clone freely across threads.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<(Request, Instant)>,
}

impl EngineHandle {
    pub fn infer(&self, x: Vec<f32>) -> Result<Reply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send((Request { x, reply: reply_tx }, Instant::now()))
            .map_err(|_| anyhow!("engine stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }
}

/// Run the engine on the current thread until the handle side hangs up.
/// Call from a dedicated `std::thread`; returns total requests served.
pub fn serve(
    rt: &Runtime,
    model: &mut LoadedModel,
    policy: BatchPolicy,
    rx: mpsc::Receiver<(Request, Instant)>,
) -> Result<u64> {
    let device_batch = model.ensure_fwd_batch(rt)?;
    let max_batch = policy.max_batch.min(device_batch);
    let feat = model.manifest.input_elems();
    let n_out = model.manifest.num_outputs;
    let mut served = 0u64;

    loop {
        // Block for the first request of a batch.
        let Ok(first) = rx.recv() else {
            return Ok(served);
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(_) => break,
            }
        }

        // Pad to the device batch and execute once.
        let mut x = vec![0.0f32; device_batch * feat];
        for (i, (req, _)) in batch.iter().enumerate() {
            x[i * feat..(i + 1) * feat].copy_from_slice(&req.x);
        }
        let out = model.infer_batch(rt, &x)?;
        for (i, (req, t0)) in batch.iter().enumerate() {
            let slice = out[i * n_out..(i + 1) * n_out].to_vec();
            let top1 = argmax(&slice);
            let _ = req.reply.send(Reply {
                output: slice,
                top1,
                batch_size: batch.len(),
                queue_us: t0.elapsed().as_micros(),
            });
            served += 1;
        }
    }
}

/// Spawn the engine on a background thread, returning a handle.  PJRT
/// handles are not `Send`, so the runtime and model are constructed
/// *inside* the worker thread from the artifact directory.
pub fn spawn(
    art_dir: std::path::PathBuf,
    model_name: String,
    policy: BatchPolicy,
) -> (EngineHandle, std::thread::JoinHandle<Result<u64>>) {
    let (tx, rx) = mpsc::channel();
    let join = std::thread::spawn(move || {
        let rt = Runtime::cpu()?;
        let mut model = LoadedModel::load(&art_dir, &model_name)?;
        serve(&rt, &mut model, policy, rx)
    });
    (EngineHandle { tx }, join)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need PJRT + artifacts live in rust/tests/.
    #[test]
    fn batch_policy_defaults() {
        let p = BatchPolicy::default();
        assert_eq!(p.max_batch, 64);
        assert!(p.max_wait >= Duration::from_millis(1));
    }
}
