//! L3 coordinator: the codesign flow driver + the inference engine + the
//! Rust-driven SGD training loop (the paper's workflow, owned end-to-end
//! by Rust with Python only at AOT time).

pub mod engine;
pub mod flow;
pub mod pool;

use crate::data::{self, prng::SplitMix64};
use crate::runtime::{LoadedModel, Runtime};
use crate::error::Result;

/// Training-loop configuration for the e2e driver.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Decay the LR by this factor over the run (cosine-free simple decay).
    pub final_lr_frac: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 0.08, final_lr_frac: 0.1, log_every: 25, seed: 0x7121 }
    }
}

/// One logged point of the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
}

/// Train a loaded model on synthetic data via the AOT train-step
/// executable.  Returns the loss curve (recorded every `log_every` steps,
/// plus first and last).
pub fn train(
    rt: &Runtime,
    model: &mut LoadedModel,
    cfg: &TrainConfig,
) -> Result<Vec<LossPoint>> {
    let batch = model.ensure_train(rt)?;
    let task = model.manifest.task.clone();
    let mut rng = SplitMix64::new(cfg.seed);
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let (x, y) = data::train_batch(&task, &mut rng, batch);
        let t = step as f32 / cfg.steps.max(1) as f32;
        let lr = cfg.lr * (1.0 - t + t * cfg.final_lr_frac);
        let loss = model.train_step(rt, &x, &y, lr)?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            curve.push(LossPoint { step, loss, lr });
        }
    }
    Ok(curve)
}

/// Evaluate accuracy (classification) or AUC (AD) over a fresh synthetic
/// test set of `n` samples, batch-1 through the EEMBC-style path.
pub fn evaluate(rt: &Runtime, model: &mut LoadedModel, n: usize, seed: u64) -> Result<f64> {
    let task = model.manifest.task.clone();
    let ts = data::test_set(&task, n, seed);
    if task == "ad" {
        let mut scores = Vec::with_capacity(n);
        for s in &ts.samples {
            scores.push((model.anomaly_score1(rt, &s.x)?, s.label == 1));
        }
        Ok(data::roc_auc(&scores))
    } else {
        let mut correct = 0usize;
        for s in &ts.samples {
            if model.classify1(rt, &s.x)? == s.label as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / n as f64)
    }
}
