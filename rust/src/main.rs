//! tinyml-codesign CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; the offline vendored crate set has
//! no clap):
//!
//! ```text
//! tinyml-codesign flow <model> [--board pynq|arty]   codesign flow report
//! tinyml-codesign train <model> [--steps N] [--lr F] Rust-driven SGD
//! tinyml-codesign eval <model> [--n N]               accuracy / AUC
//! tinyml-codesign eembc <model> [--mode perf|energy|accuracy]
//! tinyml-codesign table <1|2|3|4|5>                  paper tables
//! tinyml-codesign fig <2|3>                          DSE scan CSVs
//! tinyml-codesign serve <model> [--requests N]       batching engine demo
//! tinyml-codesign fleet [--policy rr|ll|energy|slo] [--requests N] [--cache N]
//!                       [--cache-ttl-us N] [--cache-task-cap N] [--coalesce]
//!                       [--autoscale] [--min-replicas N] [--max-replicas N]
//!                       [--scale-interval-us N] [--json]
//!                       [--tenants N] [--priority-mix i:s:b] [--fifo] [--global-hotpath]
//!                       [--trace-sample N] [--trace-dump]
//!                       [--chaos SPEC] [--chaos-seed N]
//!                       [--deadline-us N] [--hedge-p99 F] [--breaker]
//! tinyml-codesign bench-gate [--baseline-dir D] [--bench-dir D] [--tol F]
//!                       [--update] [--self-test]    BENCH_* regression gate
//! tinyml-codesign list                               available models
//! ```
//!
//! `--priority-mix i:s:b` weights the interactive:standard:batch classes
//! of the generated fleet workload (default `0:1:0`, all standard);
//! `--tenants N` spreads requests over N tenant ids; `--fifo` disables
//! priority scheduling (single-FIFO control); `--global-hotpath`
//! restores the pre-sharding global-lock telemetry/cache/allocating
//! reply path (the A/B control `benches/hotpath.rs` measures against).
//! `--trace-sample N` samples one request in N through the lifecycle
//! tracing layer (`tinyml_codesign::fleet::trace`) — stage-latency
//! histograms and flow-vs-measured drift land in the report/JSON —
//! and `--trace-dump` prints the fleet event ring as JSONL (one event
//! per line) instead of the report.
//!
//! `--chaos SPEC` injects seeded faults into the fleet (grammar:
//! `exec=P,kill=ID@B,slow=FxID,stall=US@EVERY,panic=ID@B` — see
//! `tinyml_codesign::fleet::chaos`); the health controller then detects
//! and ejects misbehaving replicas while the retry pump re-routes their
//! failed batches. `--chaos-seed N` re-seeds the fault PRNG (default 42).
//! With chaos on, the report is prefixed by a machine-parseable
//! `chaos: ejections=.. served=.. failed=.. lost=..` line.
//!
//! `--cache-ttl-us N` expires result-cache entries after N µs (expired
//! probes count as misses, never stale hits); `--cache-task-cap N`
//! bounds any one task's share of the cache (see
//! `tinyml_codesign::fleet::cache` for the full v5 admission rules).
//! `--coalesce` turns on single-flight request coalescing
//! (`tinyml_codesign::fleet::coalesce`): duplicate in-flight requests
//! ride one leader's board execution and the report is prefixed by a
//! machine-parseable `coalesce: leaders=.. followers=.. fanned_ok=..
//! fanned_err=..` line.
//!
//! `--deadline-us N` stamps every generated request with an N µs
//! deadline: submit refuses requests whose flow-predicted completion
//! already misses it, and workers discard expired requests at every
//! later stage boundary (see `tinyml_codesign::fleet::hedge`), so the
//! report is prefixed by a machine-parseable `deadline:` line whose
//! `executed_expired` field must stay 0. `--hedge-p99 F` arms
//! tail-latency hedging: when a request's drift-corrected estimate on
//! its assigned replica exceeds F x its class's observed p99, a
//! duplicate leg is queued on a sibling replica and the first terminal
//! outcome wins (`hedge:` line). `--breaker` puts a per-replica
//! circuit breaker in front of routing (trip on failure-rate window,
//! half-open probes) as the reversible complement to health ejection
//! (`breaker:` line).

use tinyml_codesign::board::{arty_a7_100t, pynq_z2, Board};
use tinyml_codesign::coordinator::engine::{spawn, BatchPolicy};
use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::data;
use tinyml_codesign::eembc::{DesignPerf, Dut, Runner};
use tinyml_codesign::error::{anyhow, bail, Result};
use tinyml_codesign::fleet::{
    AutoscaleConfig, BreakerConfig, ChaosSpec, Fleet, FleetConfig, Policy, Priority, Registry,
    RequestTag,
};
use tinyml_codesign::report::{gate, tables};
use tinyml_codesign::runtime::{LoadedModel, Runtime};

struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Self {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = it.peek().filter(|v| !v.starts_with("--")).cloned();
                if let Some(v) = val {
                    it.next();
                    flags.push((name.to_string(), v));
                } else {
                    flags.push((name.to_string(), "true".to_string()));
                }
            } else {
                positional.push(a);
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Parse `--priority-mix i:s:b` into per-class weights
/// (interactive:standard:batch).
fn parse_priority_mix(text: &str) -> Result<[f64; 3]> {
    let parts: Vec<&str> = text.split(':').collect();
    if parts.len() != 3 {
        bail!("--priority-mix wants i:s:b (e.g. 10:20:70), got '{text}'");
    }
    let mut mix = [0.0f64; 3];
    for (slot, part) in mix.iter_mut().zip(&parts) {
        *slot = part
            .parse::<f64>()
            .map_err(|_| anyhow!("bad priority-mix component '{part}'"))?;
        if *slot < 0.0 {
            bail!("priority-mix components must be >= 0, got '{part}'");
        }
    }
    if mix.iter().sum::<f64>() <= 0.0 {
        bail!("priority-mix must have at least one positive component");
    }
    Ok(mix)
}

/// Sample a class from the mix weights.
fn sample_priority(mix: &[f64; 3], u: f64) -> Priority {
    let total: f64 = mix.iter().sum();
    let mut acc = 0.0;
    for (i, &w) in mix.iter().enumerate() {
        acc += w;
        if u * total < acc {
            return Priority::ALL[i];
        }
    }
    Priority::Batch
}

/// Usage text for `help` / unknown subcommands.  A plain `const` — the
/// old `include_str!("main.rs").lines().skip(2).take(19)` slice of the
/// module doc silently truncated whenever the doc comment grew.
const HELP: &str = "\
tinyml-codesign flow <model> [--board pynq|arty]   codesign flow report
tinyml-codesign train <model> [--steps N] [--lr F] Rust-driven SGD
tinyml-codesign eval <model> [--n N]               accuracy / AUC
tinyml-codesign eembc <model> [--mode perf|energy|accuracy]
tinyml-codesign table <1|2|3|4|5>                  paper tables
tinyml-codesign fig <2|3>                          DSE scan CSVs
tinyml-codesign serve <model> [--requests N]       batching engine demo
tinyml-codesign fleet [--policy rr|ll|energy|slo] [--requests N] [--cache N]
                      [--cache-ttl-us N] [--cache-task-cap N] [--coalesce]
                      [--autoscale] [--min-replicas N] [--max-replicas N]
                      [--scale-interval-us N] [--json]
                      [--tenants N] [--priority-mix i:s:b] [--fifo] [--global-hotpath]
                      [--trace-sample N] [--trace-dump]
                      [--chaos SPEC] [--chaos-seed N]
                      [--deadline-us N] [--hedge-p99 F] [--breaker]
tinyml-codesign bench-gate [--baseline-dir D] [--bench-dir D] [--tol F]
                      [--update] [--self-test]    BENCH_* regression gate
tinyml-codesign list                               available models";

fn board_from(args: &Args) -> Board {
    match args.flag("board").unwrap_or("pynq") {
        "arty" => arty_a7_100t(),
        _ => pynq_z2(),
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let art = tinyml_codesign::artifacts_dir();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "list" => {
            let idx = std::fs::read_to_string(art.join("index.json"))?;
            println!("{idx}");
        }
        "flow" => {
            let model = args.positional.get(1).ok_or_else(|| anyhow!("flow <model>"))?;
            let board = board_from(&args);
            let r = tables::flow_for(&art, model, &board)?;
            println!("== codesign flow: {} on {} ==", r.model, r.board);
            for l in &r.pass_log {
                println!("  pass {l}");
            }
            println!("  FIFO depths: {:?}", r.fifo.depths);
            let t = &r.resources.total;
            let u = t.utilization(&board);
            println!(
                "  resources: {:.0} LUT ({:.1}%), {:.0} FF ({:.1}%), {:.1} BRAM36 ({:.1}%), {:.0} DSP ({:.1}%) -> fits: {}",
                t.luts, u.lut_pct, t.ffs, u.ff_pct, t.bram36, u.bram_pct, t.dsps, u.dsp_pct, r.fits
            );
            println!(
                "  latency: {} cycles = {:.3} ms @ {:.0} MHz | power {:.2} W | energy/inf {:.1} uJ",
                r.latency_cycles,
                r.latency_s * 1e3,
                board.clock_hz / 1e6,
                r.power_w,
                r.energy_per_inference_uj
            );
        }
        "train" => {
            let model = args.positional.get(1).ok_or_else(|| anyhow!("train <model>"))?;
            let rt = Runtime::cpu()?;
            let mut m = LoadedModel::load(&art, model)?;
            let cfg = TrainConfig {
                steps: args.usize_flag("steps", 300),
                lr: args.flag("lr").and_then(|v| v.parse().ok()).unwrap_or(0.08),
                ..Default::default()
            };
            println!("training {model} for {} steps (batch from manifest)...", cfg.steps);
            let curve = coordinator::train(&rt, &mut m, &cfg)?;
            for p in &curve {
                println!("  step {:>5}  loss {:.4}  lr {:.4}", p.step, p.loss, p.lr);
            }
            let metric = coordinator::evaluate(&rt, &mut m, 200, 0xE7A1)?;
            println!("eval ({}) = {:.4}", if m.manifest.task == "ad" { "AUC" } else { "top-1" }, metric);
        }
        "eval" => {
            let model = args.positional.get(1).ok_or_else(|| anyhow!("eval <model>"))?;
            let rt = Runtime::cpu()?;
            let mut m = LoadedModel::load(&art, model)?;
            let n = args.usize_flag("n", 200);
            let metric = coordinator::evaluate(&rt, &mut m, n, 0xE7A1)?;
            println!("{model}: {:.4} over {n} samples", metric);
        }
        "eembc" => {
            let model = args.positional.get(1).ok_or_else(|| anyhow!("eembc <model>"))?;
            let board = board_from(&args);
            let flow_name = if model == "ic_finn" { "ic_finn_full" } else { model };
            let fr = tables::flow_for(&art, flow_name, &board)?;
            let perf = DesignPerf { latency_s: fr.latency_s, power_w: fr.power_w };
            let rt = Runtime::cpu()?;
            let mut m = LoadedModel::load(&art, model)?;
            let task = m.manifest.task.clone();
            let n_acc = match task.as_str() {
                "ic" => 200,
                "kws" => 1000,
                _ => 250,
            };
            let samples = data::test_set(&task, n_acc, 0xEE4B);
            let mut dut = Dut::new(&mut m, perf);
            let runner = Runner::default();
            match args.flag("mode").unwrap_or("perf") {
                "perf" => {
                    let r = runner.performance_mode(&rt, &mut dut, &samples.samples)?;
                    println!(
                        "performance: median latency {:.3} ms ({:.1} inf/s), {} inferences, serial {:.2} s",
                        r.median_latency_s * 1e3,
                        r.throughput_inf_per_s,
                        r.total_inferences,
                        r.serial_time_s
                    );
                }
                "energy" => {
                    let r = runner.energy_mode(&rt, &mut dut, &samples.samples)?;
                    println!(
                        "energy: median {:.1} uJ/inf at {:.2} W",
                        r.median_energy_uj, r.mean_power_w
                    );
                }
                "accuracy" => {
                    let r = runner.accuracy_mode(&rt, &mut dut, &samples.samples)?;
                    println!("accuracy: {} = {:.4} over {} samples", r.metric, r.value, r.n_samples);
                }
                other => bail!("unknown mode {other}"),
            }
        }
        "table" => {
            let which = args.positional.get(1).map(String::as_str).unwrap_or("5");
            let text = match which {
                "1" => tables::table1(&art, &[])?,
                "2" => tables::table2(&art)?,
                "3" => tables::table3(&art)?,
                "4" => tables::table4(&art, None)?,
                "5" => tables::table5(&art)?,
                other => bail!("unknown table {other}"),
            };
            println!("{text}");
        }
        "fig" => {
            let which = args.positional.get(1).map(String::as_str).unwrap_or("2");
            match which {
                "2" => println!("{}", tables::fig2(args.usize_flag("models", 100), 0xF16)),
                "3" => println!("{}", tables::fig3(args.usize_flag("configs", 128), 0xF17)),
                other => bail!("unknown fig {other} (fig 4 = examples/kws_quant_scan)"),
            }
        }
        "serve" => {
            let model = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "kws_mlp_w3a3".to_string());
            let n = args.usize_flag("requests", 256);
            let (handle, join) = spawn(art.clone(), model.clone(), BatchPolicy::default());
            let task = LoadedModel::load(&art, &model)?.manifest.task.clone();
            let ts = data::test_set(&task, n, 0x5E12);
            let t0 = std::time::Instant::now();
            let mut correct = 0usize;
            let mut batch_sizes = Vec::new();
            for s in &ts.samples {
                let reply = handle.infer(s.x.clone())?;
                if reply.top1 == s.label as usize {
                    correct += 1;
                }
                batch_sizes.push(reply.batch_size);
            }
            let dt = t0.elapsed().as_secs_f64();
            drop(handle);
            let served = join.join().unwrap()?;
            println!(
                "served {served} requests in {dt:.2} s ({:.1} req/s), top-1 {:.3}, mean batch {:.2}",
                n as f64 / dt,
                correct as f64 / n as f64,
                batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len() as f64
            );
        }
        "fleet" => {
            let policy = match args.flag("policy").unwrap_or("ll") {
                "rr" => Policy::RoundRobin,
                "energy" => Policy::EnergyAware,
                "slo" => Policy::LatencySlo {
                    slo_us: args.usize_flag("slo-us", 3000) as f64,
                },
                _ => Policy::LeastLoaded,
            };
            let n = args.usize_flag("requests", 600);
            // --autoscale: let the telemetry controller grow each task
            // past the standard fleet's 2 replicas under load and shrink
            // idle tasks down to the floor.
            let autoscale = args.flag("autoscale").map(|_| AutoscaleConfig {
                interval: std::time::Duration::from_micros(
                    args.usize_flag("scale-interval-us", 5000) as u64,
                ),
                min_replicas: args.usize_flag("min-replicas", 1),
                max_replicas: args.usize_flag("max-replicas", 4),
                ..Default::default()
            });
            let tenants = args.usize_flag("tenants", 1).max(1) as u32;
            let mix = parse_priority_mix(args.flag("priority-mix").unwrap_or("0:1:0"))?;
            // --chaos: seeded fault injection; the health controller
            // defaults on whenever chaos is requested (Fleet::start).
            let chaos = match args.flag("chaos") {
                Some(spec) => {
                    Some(ChaosSpec::parse(spec, args.usize_flag("chaos-seed", 42) as u64)?)
                }
                None => None,
            };
            let cfg = FleetConfig {
                policy,
                time_scale: 20.0,
                cache_cap: args.usize_flag("cache", 0),
                cache_ttl_us: args.usize_flag("cache-ttl-us", 0) as u64,
                cache_task_cap: args.usize_flag("cache-task-cap", 0),
                coalesce: args.flag("coalesce").is_some(),
                autoscale,
                fifo_queues: args.flag("fifo").is_some(),
                global_hotpath: args.flag("global-hotpath").is_some(),
                trace_sample: args.usize_flag("trace-sample", 0),
                chaos,
                deadline_us: args.usize_flag("deadline-us", 0) as u64,
                hedge_p99: args.f64_flag("hedge-p99", 0.0),
                breaker: args.flag("breaker").map(|_| BreakerConfig::default()),
                ..Default::default()
            };
            let fleet = Fleet::start(Registry::standard_fleet()?, cfg)?;
            let handle = fleet.handle();
            let mut rng = data::prng::SplitMix64::new(0xF1EE7);
            let mut pending = Vec::new();
            let mut rejected = 0usize;
            for i in 0..n {
                let task = match rng.next_below(4) {
                    0 | 1 => "kws",
                    2 => "ad",
                    _ => "ic",
                };
                let tag = RequestTag::new(
                    i as u32 % tenants,
                    sample_priority(&mix, rng.next_f64()),
                );
                let x = vec![0.2f32; data::feature_dim(task)];
                match handle.submit_tagged(task, x, tag) {
                    Ok(rx) => pending.push(rx),
                    Err(_) => rejected += 1,
                }
            }
            let (mut ok, mut failed, mut lost) = (0usize, 0usize, 0usize);
            for rx in pending {
                match rx.recv() {
                    Ok(Ok(_)) => ok += 1,
                    Ok(Err(_)) => failed += 1,
                    Err(_) => lost += 1,
                }
            }
            let summary = fleet.shutdown();
            if args.flag("trace-dump").is_some() {
                // JSONL only (one event per line, machine-consumable) —
                // the human banner/report would corrupt the stream.
                // Each line is self-checked against the strict parser
                // before printing: an unparseable dump is a bug, not a
                // consumer's problem.
                for e in &summary.trace_events {
                    let line = e.to_json().to_json();
                    tinyml_codesign::report::json::Value::parse(&line)
                        .map_err(|err| anyhow!("trace-dump line not valid JSON: {err}"))?;
                    println!("{line}");
                }
                return Ok(());
            }
            println!(
                "policy {policy}{}, {n} mixed requests over {tenants} tenant(s), \
                 {rejected} rejected",
                if cfg.fifo_queues { " (fifo queues)" } else { "" }
            );
            if cfg.chaos.is_some() {
                // Machine-parseable resilience line for the CI chaos
                // smoke: ejections must be nonzero, lost must be zero.
                println!(
                    "chaos: ejections={} served={ok} failed={failed} lost={lost}",
                    summary.snapshot.ejections
                );
            }
            if cfg.coalesce {
                // Machine-parseable coalescing line for the CI smoke:
                // a duplicate-heavy workload must show followers > 0.
                let co = summary.snapshot.coalesce.clone().unwrap_or_default();
                println!(
                    "coalesce: leaders={} followers={} fanned_ok={} fanned_err={}",
                    co.leaders, co.followers, co.fanned_ok, co.fanned_err
                );
            }
            if cfg.deadline_us > 0 || summary.snapshot.deadline.any() {
                // Machine-parseable deadline line for the CI smoke:
                // executed_expired must be zero — a nonzero value means
                // a board burned cycles on a request nobody could use.
                let d = summary.snapshot.deadline;
                println!(
                    "deadline: shed_submit={} expired_dequeue={} expired_window={} \
                     expired_retry={} executed_expired={}",
                    d.shed_submit,
                    d.expired_dequeue,
                    d.expired_window,
                    d.expired_retry,
                    d.executed_expired
                );
            }
            if cfg.hedge_p99 > 0.0 {
                let h = summary.snapshot.hedge.unwrap_or_default();
                println!(
                    "hedge: hedged={} wins={} cancelled={}",
                    h.hedged, h.wins, h.cancelled
                );
            }
            if cfg.breaker.is_some() {
                println!(
                    "breaker: trips={}",
                    summary.snapshot.breaker_trips.unwrap_or(0)
                );
            }
            if args.flag("json").is_some() {
                println!("{}", summary.snapshot.to_json().to_json());
            } else {
                print!("{}", summary.render());
            }
        }
        "bench-gate" => {
            let bench_dir = std::path::PathBuf::from(args.flag("bench-dir").unwrap_or("."));
            let baseline_dir =
                std::path::PathBuf::from(args.flag("baseline-dir").unwrap_or("baselines"));
            let tol = args.f64_flag("tol", gate::DEFAULT_TOLERANCE);
            if args.flag("self-test").is_some() {
                println!("{}", gate::self_test(&baseline_dir, tol)?);
            } else if args.flag("update").is_some() {
                println!("{}", gate::update_baselines(&bench_dir, &baseline_dir)?);
            } else {
                println!("{}", gate::run_gate(&bench_dir, &baseline_dir, tol)?);
            }
        }
        _ => {
            println!("{HELP}");
        }
    }
    Ok(())
}
