//! FIFO buffer depth optimization (paper §3.1.2 / §3.5).
//!
//! The algorithm is exactly the paper's: simulate the dataflow design with
//! effectively-unbounded FIFOs (their RTL simulation with "large FIFO
//! buffers"), record the maximum occupancy of every FIFO, then size each
//! FIFO to that maximum plus one.  hls4ml allows arbitrary integer depths;
//! FINN rounds up to the next power of two (Table 2).  The optimized
//! depths must not change latency — asserted both in tests here and by the
//! Table 2/3 benches.

use crate::dataflow::{SimResult, Simulator};
#[cfg(test)]
use crate::dataflow::UNBOUNDED_DEPTH;


/// Depth-rounding policy per flow.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DepthPolicy {
    /// hls4ml: arbitrary integer depths (max occupancy + 1).
    Exact,
    /// FINN: next power of two of (max occupancy + 1), minimum 2.
    PowerOfTwo,
}

impl DepthPolicy {
    pub fn for_flow(flow: &str) -> Self {
        if flow == "finn" { DepthPolicy::PowerOfTwo } else { DepthPolicy::Exact }
    }

    pub fn round(&self, max_occupancy: usize) -> usize {
        let need = max_occupancy + 1;
        match self {
            DepthPolicy::Exact => need,
            DepthPolicy::PowerOfTwo => need.max(2).next_power_of_two(),
        }
    }
}

/// Result of the optimization pass.
#[derive(Clone, Debug)]
pub struct FifoOptResult {
    pub depths: Vec<usize>,
    pub unoptimized_latency: u64,
    pub optimized_latency: u64,
    /// The simulation run used for sizing.
    pub sizing_run: SimResult,
}

/// Run the sizing simulation and return per-FIFO depths.
pub fn optimize_fifos(sim: &Simulator, policy: DepthPolicy) -> FifoOptResult {
    let sizing = sim.run_unbounded();
    assert!(!sizing.deadlocked, "sizing run must complete");
    let depths: Vec<usize> =
        sizing.fifo_max_occupancy.iter().map(|&m| policy.round(m)).collect();
    let optimized = sim.run(&depths, 1);
    FifoOptResult {
        depths,
        unoptimized_latency: sizing.latency_cycles,
        optimized_latency: optimized.latency_cycles,
        sizing_run: sizing,
    }
}

/// Default (unoptimized) depths a naive synthesis would use: every FIFO as
/// deep as the producing stage's full output — the "Without opt." Table 3
/// configuration.
pub fn naive_depths(sim: &Simulator) -> Vec<usize> {
    let mut depths = Vec::with_capacity(sim.stages.len() + 1);
    depths.push(sim.stages[0].n_in.max(1));
    for s in &sim.stages {
        depths.push(s.n_out.max(1));
    }
    depths
}

/// Summary of a depth vector for Table 2 reporting.
pub fn depth_range(depths: &[usize]) -> (usize, usize) {
    let lo = depths.iter().copied().min().unwrap_or(0);
    let hi = depths.iter().copied().max().unwrap_or(0);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Prereq, StageSpec};

    fn chain() -> Simulator {
        Simulator::new(vec![
            StageSpec {
                name: "producer".into(),
                n_in: 64,
                n_out: 64,
                ii_out: 1,
                ii_in: 1,
                prereq: Prereq::Elementwise,
            },
            StageSpec {
                name: "dense".into(),
                n_in: 64,
                n_out: 8,
                ii_out: 4,
                ii_in: 2,
                prereq: Prereq::All,
            },
        ])
    }

    #[test]
    fn optimized_depths_preserve_latency() {
        let sim = chain();
        let r = optimize_fifos(&sim, DepthPolicy::Exact);
        assert_eq!(r.unoptimized_latency, r.optimized_latency);
        assert!(r.depths.iter().all(|&d| d < UNBOUNDED_DEPTH));
    }

    #[test]
    fn pow2_policy_rounds_up() {
        assert_eq!(DepthPolicy::PowerOfTwo.round(0), 2);
        assert_eq!(DepthPolicy::PowerOfTwo.round(2), 4);
        assert_eq!(DepthPolicy::PowerOfTwo.round(3), 4);
        assert_eq!(DepthPolicy::PowerOfTwo.round(4), 8);
        assert_eq!(DepthPolicy::Exact.round(41), 42);
    }

    #[test]
    fn optimized_no_deeper_than_naive_total() {
        let sim = chain();
        let naive: usize = naive_depths(&sim).iter().sum();
        let opt: usize = optimize_fifos(&sim, DepthPolicy::Exact).depths.iter().sum();
        assert!(opt <= naive * 2, "opt={opt} naive={naive}");
    }

    #[test]
    fn depth_range_reporting() {
        assert_eq!(depth_range(&[1, 5, 3]), (1, 5));
    }
}
