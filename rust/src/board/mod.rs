//! Target platform descriptors (§4.2.3).
//!
//! * **TUL Pynq-Z2** — Zynq-7020 SoC (xc7z020-1clg400c): ARM Cortex-A9 PS +
//!   PL with 53 200 LUTs / 106 400 FFs / 140 36-kb BRAMs / 220 DSPs.
//! * **Digilent Arty A7-100T** — pure FPGA (xc7a100t-1csg324) with a soft
//!   MicroBlaze + MIG DDR controller: 63 400 LUTs / 126 800 FFs / 135
//!   36-kb BRAMs / 240 DSPs.
//!
//! Static power coefficients are calibrated against the paper's Table 5
//! energies (total board power ≈ 1.6-1.8 W on Pynq-Z2 — dominated by the
//! ARM PS — and ≈ 1.6-2.2 W on the Arty where the soft MIG keeps the
//! fabric busy).



#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoardKind {
    PynqZ2,
    ArtyA7100T,
}

#[derive(Clone, Debug)]
pub struct Board {
    pub kind: BoardKind,
    pub name: &'static str,
    pub part: &'static str,
    /// Available fabric resources.
    pub luts: u64,
    pub lutram: u64,
    pub ffs: u64,
    /// 36-kb BRAM blocks (Table 5 counts in 36 kb units).
    pub bram36: f64,
    pub dsps: u64,
    /// Fabric clock for the accelerator (Hz).
    pub clock_hz: f64,
    /// Idle platform power (W): PS / soft-core + memory controller.
    pub static_power_w: f64,
    /// Host processing: SoC uses hard ARM + off-chip DDR; pure FPGA uses a
    /// soft MicroBlaze + soft MIG (§4.2.2).
    pub soft_processor: bool,
}

pub fn pynq_z2() -> Board {
    Board {
        kind: BoardKind::PynqZ2,
        name: "Pynq-Z2",
        part: "xc7z020-1clg400c",
        luts: 53_200,
        lutram: 17_400,
        ffs: 106_400,
        bram36: 140.0,
        dsps: 220,
        clock_hz: 100e6,
        static_power_w: 1.45,
        soft_processor: false,
    }
}

pub fn arty_a7_100t() -> Board {
    Board {
        kind: BoardKind::ArtyA7100T,
        name: "Arty A7-100T",
        part: "xc7a100t-1csg324",
        luts: 63_400,
        lutram: 19_000,
        ffs: 126_800,
        bram36: 135.0,
        dsps: 240,
        // Slightly slower achievable fabric clock on the -1 Artix part with
        // the soft MIG sharing the fabric (paper latencies are ~1.2-2.4x
        // Pynq's for the same designs).
        clock_hz: 83e6,
        static_power_w: 1.9,
        soft_processor: true,
    }
}

pub fn all_boards() -> Vec<Board> {
    vec![pynq_z2(), arty_a7_100t()]
}

/// MicroBlaze soft-processor overhead on pure-FPGA targets (§4.2.2):
/// instruction/data caches (1-16 kB) + OCM (32-128 kB) in BRAM, plus MIG.
#[derive(Clone, Debug)]
pub struct SoftSystemOverhead {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
}

pub fn soft_system_overhead(board: &Board) -> SoftSystemOverhead {
    if board.soft_processor {
        SoftSystemOverhead {
            luts: 4_800,  // MicroBlaze + MIG + UART Lite + AXI interconnect
            ffs: 5_600,
            bram36: 18.0, // caches + 64 kB OCM
            dsps: 2,
        }
    } else {
        SoftSystemOverhead { luts: 1_800, ffs: 2_400, bram36: 2.0, dsps: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_available_rows() {
        let p = pynq_z2();
        assert_eq!(p.luts, 53_200);
        assert_eq!(p.ffs, 106_400);
        assert_eq!(p.bram36 as u64, 140);
        assert_eq!(p.dsps, 220);
        let a = arty_a7_100t();
        assert_eq!(a.luts, 63_400);
        assert_eq!(a.dsps, 240);
    }

    #[test]
    fn arty_carries_soft_system() {
        let a = arty_a7_100t();
        let o = soft_system_overhead(&a);
        assert!(a.soft_processor && o.bram36 > 10.0);
        let p = pynq_z2();
        assert!(soft_system_overhead(&p).luts < o.luts);
    }
}
