//! Model execution runtime behind a stable `Runtime` / `LoadedModel`
//! facade with two interchangeable backends:
//!
//! * **`pjrt`** (`--features pjrt`, needs the vendored `xla` crate) — the
//!   real thing: loads the HLO **text** artifacts that `make artifacts`
//!   leaves in `artifacts/`, compiles once per executable on the PJRT CPU
//!   client, and serves inference and SGD train steps from Rust with
//!   Python out of the loop entirely.  (Text, not serialized protos:
//!   jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects — see /opt/xla-example/README.md.)
//! * **`sim`** (default) — a deterministic surrogate with the same API:
//!   template-matching classifiers and a smoothing autoencoder driven by
//!   the shared splitmix64 streams.  It exists so the offline build image
//!   (no xla, no artifacts) still exercises every layer above the
//!   executor: the batching engine, the fleet serving plane, the EEMBC
//!   harness, and the CLI.
//!
//! The manifest format and [`argmax`] are backend-independent and live
//! here.

use crate::error::{Context, Result};
use crate::report::json::Value;
use std::path::Path;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod sim;
#[cfg(not(feature = "pjrt"))]
pub use sim::{LoadedModel, Runtime};

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

/// Parsed `<model>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub task: String,
    pub flow: String,
    pub input_shape: Vec<usize>,
    pub num_outputs: usize,
    pub loss_kind: String,
    pub weight_bits: String,
    pub params: Vec<ParamSpec>,
    /// (tag, hlo file, batch)
    pub artifacts: Vec<(String, String, usize)>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = Value::parse(&text)?;
        let params = v
            .req("params")?
            .as_arr()
            .context("params not array")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.str_of("name")?,
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    file: p.str_of("file")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = Vec::new();
        if let Some(Value::Obj(m)) = v.get("artifacts") {
            for (tag, a) in m {
                artifacts.push((tag.clone(), a.str_of("file")?, a.usize_of("batch")?));
            }
        }
        Ok(Manifest {
            name: v.str_of("name")?,
            task: v.str_of("task")?,
            flow: v.str_of("flow")?,
            input_shape: v
                .req("input_shape")?
                .as_arr()
                .context("input_shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            num_outputs: v.usize_of("num_outputs")?,
            loss_kind: v.str_of("loss_kind")?,
            weight_bits: v.str_of("weight_bits")?,
            params,
            artifacts,
        })
    }

    pub fn artifact(&self, tag: &str) -> Option<(&str, usize)> {
        self.artifacts
            .iter()
            .find(|(t, _, _)| t == tag)
            .map(|(_, f, b)| (f.as_str(), *b))
    }

    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[0.5, 1.0, 1.0]), 1); // first max wins on tie
        assert_eq!(argmax(&[]), 0);
    }

    // PJRT-backend tests live in rust/tests/ (they need artifacts/ built
    // and a compiled client, which is slow per test); surrogate-backend
    // tests live in sim.rs.
}
