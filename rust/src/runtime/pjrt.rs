//! PJRT backend: compiles the AOT HLO artifacts on the PJRT CPU client
//! and owns all request-path compute.  Requires the vendored `xla` crate
//! (`--features pjrt`).

use super::{argmax, Manifest};
use crate::error::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// The PJRT client wrapper (one per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// A loaded model: parameters + lazily-compiled executables.
pub struct LoadedModel {
    pub manifest: Manifest,
    pub params: Vec<xla::Literal>,
    /// Device-resident copies of `params` for the inference hot path
    /// (§Perf L3: avoids re-uploading every weight on every request).
    /// Invalidated by `train_step`.
    param_bufs: Option<Vec<xla::PjRtBuffer>>,
    art_dir: PathBuf,
    fwd1: Option<xla::PjRtLoadedExecutable>,
    fwd_batch: Option<(usize, xla::PjRtLoadedExecutable)>,
    train: Option<(usize, xla::PjRtLoadedExecutable)>,
}

/// Which forward executable to run.
#[derive(Clone, Copy)]
enum Fwd {
    One,
    Batch,
}

fn literal_from_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

impl LoadedModel {
    /// Load manifest + initial params; compiles executables lazily.
    pub fn load(art_dir: &Path, name: &str) -> Result<Self> {
        let manifest = Manifest::load(&art_dir.join(format!("{name}_manifest.json")))?;
        let mut params = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let bytes = std::fs::read(art_dir.join(&p.file))
                .with_context(|| format!("param file {}", p.file))?;
            let n: usize = p.shape.iter().product::<usize>().max(1);
            if bytes.len() != 4 * n {
                bail!("{}: expected {} bytes, got {}", p.file, 4 * n, bytes.len());
            }
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(literal_from_f32(&data, &p.shape)?);
        }
        Ok(Self {
            manifest,
            params,
            param_bufs: None,
            art_dir: art_dir.to_path_buf(),
            fwd1: None,
            fwd_batch: None,
            train: None,
        })
    }

    fn compile(&self, rt: &Runtime, tag: &str) -> Result<(usize, xla::PjRtLoadedExecutable)> {
        let (file, batch) = self
            .manifest
            .artifact(tag)
            .ok_or_else(|| anyhow!("{}: no artifact '{tag}'", self.manifest.name))?;
        let exe = rt.compile_hlo_text(&self.art_dir.join(file))?;
        Ok((batch, exe))
    }

    pub fn ensure_fwd1(&mut self, rt: &Runtime) -> Result<()> {
        if self.fwd1.is_none() {
            self.fwd1 = Some(self.compile(rt, "fwd1")?.1);
        }
        Ok(())
    }

    pub fn ensure_fwd_batch(&mut self, rt: &Runtime) -> Result<usize> {
        if self.fwd_batch.is_none() {
            let tag = self
                .manifest
                .artifacts
                .iter()
                .find(|(t, _, _)| t.starts_with("fwd") && t != "fwd1")
                .map(|(t, _, _)| t.clone())
                .ok_or_else(|| anyhow!("no batch fwd artifact"))?;
            self.fwd_batch = Some(self.compile(rt, &tag)?);
        }
        Ok(self.fwd_batch.as_ref().unwrap().0)
    }

    pub fn ensure_train(&mut self, rt: &Runtime) -> Result<usize> {
        if self.train.is_none() {
            self.train = Some(self.compile(rt, "train")?);
        }
        Ok(self.train.as_ref().unwrap().0)
    }

    /// Upload parameters to the device once (inference hot path).
    fn ensure_param_bufs(&mut self, rt: &Runtime) -> Result<()> {
        if self.param_bufs.is_none() {
            let mut bufs = Vec::with_capacity(self.params.len());
            for (lit, spec) in self.params.iter().zip(&self.manifest.params) {
                let data = lit.to_vec::<f32>()?;
                let dims = if spec.shape.is_empty() { vec![1] } else { spec.shape.clone() };
                // Rank-0 params round-trip as [1]; none exist today but
                // keep the path total.
                let buf = if spec.shape.is_empty() {
                    rt.client.buffer_from_host_buffer::<f32>(&data, &[], None)?
                } else {
                    rt.client.buffer_from_host_buffer::<f32>(&data, &dims, None)?
                };
                bufs.push(buf);
            }
            self.param_bufs = Some(bufs);
        }
        Ok(())
    }

    fn run_fwd(
        &mut self,
        rt: &Runtime,
        which: Fwd,
        x: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        let feat = self.manifest.input_elems();
        if x.len() != feat * batch {
            bail!("input len {} != batch {} * {}", x.len(), batch, feat);
        }
        self.ensure_param_bufs(rt)?;
        let mut shape = vec![batch];
        shape.extend_from_slice(&self.manifest.input_shape);
        let xbuf = rt.client.buffer_from_host_buffer::<f32>(x, &shape, None)?;
        let exe = match which {
            Fwd::One => self.fwd1.as_ref().unwrap(),
            Fwd::Batch => &self.fwd_batch.as_ref().unwrap().1,
        };
        let mut args: Vec<&xla::PjRtBuffer> =
            self.param_bufs.as_ref().unwrap().iter().collect();
        args.push(&xbuf);
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let tup = result.to_tuple()?;
        let out = tup
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty output tuple"))?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Batch-1 inference (the EEMBC path): returns the output vector.
    pub fn infer1(&mut self, rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        self.ensure_fwd1(rt)?;
        self.run_fwd(rt, Fwd::One, x, 1)
    }

    /// Batched inference; `x` must hold exactly `batch_size` samples (pad
    /// the tail batch with zeros and slice the result).
    pub fn infer_batch(&mut self, rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        let batch = self.ensure_fwd_batch(rt)?;
        self.run_fwd(rt, Fwd::Batch, x, batch)
    }

    /// Batched inference into a caller-owned buffer (same contract as the
    /// surrogate backend).  PJRT materializes its own host literal, so
    /// this copies once; it exists so `BatchExecutor` drives both
    /// backends identically.
    pub fn infer_batch_into(
        &mut self,
        rt: &Runtime,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let y = self.infer_batch(rt, x)?;
        if y.len() != out.len() {
            bail!("output len {} != expected {}", out.len(), y.len());
        }
        out.copy_from_slice(&y);
        Ok(())
    }

    /// Prefix-aware batched inference (same contract as the surrogate
    /// backend's `infer_prefix_into`, the `BatchExecutor::execute` entry
    /// point).  The AOT executable always runs the full compiled batch,
    /// so `n` cannot skip compute here — it only bounds how much of the
    /// result is copied back into `out`'s live prefix.
    pub fn infer_prefix_into(
        &mut self,
        rt: &Runtime,
        x: &[f32],
        n: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let batch = self.ensure_fwd_batch(rt)?;
        if n == 0 || n > batch {
            bail!("live count {n} outside 1..={batch}");
        }
        let n_out = self.manifest.num_outputs;
        if out.len() != batch * n_out {
            bail!("output len {} != batch {} * {}", out.len(), batch, n_out);
        }
        let y = self.infer_batch(rt, x)?;
        if y.len() != out.len() {
            bail!("device returned {} values, expected {}", y.len(), out.len());
        }
        out[..n * n_out].copy_from_slice(&y[..n * n_out]);
        Ok(())
    }

    /// One SGD step; parameters round-trip through the runtime.  Returns
    /// the loss.
    pub fn train_step(&mut self, rt: &Runtime, x: &[f32], y: &[i32], lr: f32) -> Result<f32> {
        let batch = self.ensure_train(rt)?;
        let feat = self.manifest.input_elems();
        if x.len() != feat * batch || y.len() != batch {
            bail!(
                "train batch mismatch: x {} (want {}), y {} (want {})",
                x.len(),
                feat * batch,
                y.len(),
                batch
            );
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(&self.manifest.input_shape);
        let xlit = literal_from_f32(x, &shape)?;
        let ylit = xla::Literal::vec1(y);
        let lrlit = xla::Literal::from(lr);
        let exe = &self.train.as_ref().unwrap().1;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&xlit);
        args.push(&ylit);
        args.push(&lrlit);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut tup = result.to_tuple()?;
        if tup.len() != self.params.len() + 1 {
            bail!("train output arity {} != params {} + 1", tup.len(), self.params.len());
        }
        let loss_lit = tup.pop().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0];
        self.params = tup;
        self.param_bufs = None; // device copies are stale after the update
        Ok(loss)
    }

    /// Argmax over the batch-1 output (classification).
    pub fn classify1(&mut self, rt: &Runtime, x: &[f32]) -> Result<usize> {
        let out = self.infer1(rt, x)?;
        Ok(argmax(&out))
    }

    /// AD anomaly score: mean squared reconstruction error (§2.2).
    pub fn anomaly_score1(&mut self, rt: &Runtime, x: &[f32]) -> Result<f32> {
        let out = self.infer1(rt, x)?;
        let mse = out
            .iter()
            .zip(x.iter())
            .map(|(r, t)| (r - t) * (r - t))
            .sum::<f32>()
            / x.len() as f32;
        Ok(mse)
    }
}
