//! Surrogate backend (default build): a deterministic stand-in for the
//! PJRT runtime with the same `Runtime` / `LoadedModel` API.
//!
//! Classification tasks score inputs against the shared splitmix64 class
//! templates (the same streams `data::test_set` draws from), blended with
//! a small pseudo-random projection whose weight shrinks as "training"
//! progresses; the AD autoencoder reconstructs the 9-tap moving average
//! of its input (the spectral profile minus noise), plus a residual that
//! decays with training.  Losses decay deterministically, so train/eval
//! driver code behaves as it does on the real backend.
//!
//! The forward pass runs on the packed quantized kernel core
//! ([`crate::kernels`]): templates *and* projections are quantized once
//! at load into a single fused i8 matrix (`2 * n_out` rows, per-row
//! scales — the software mirror of the paper's 4–8-bit MVAU weight
//! memories), every batch makes one tiled pass over it with i32
//! accumulation, and the AD smoothing is an O(n) prefix-sum pass.  All
//! intermediates live in a model-owned [`ScratchArena`], so the
//! steady-state serve loop allocates nothing inside the forward.  The
//! GEMM inner loop runs at the process-wide [`crate::kernels::simd`]
//! dispatch level (AVX2 / SSE2 / NEON, scalar under
//! `TINYML_FORCE_SCALAR=1`), so SIMD wins land here with no code in
//! this module — and bit-exactly, so surrogate outputs do not depend
//! on the host CPU.
//!
//! If `<model>_manifest.json` exists it is honored; otherwise a manifest
//! is synthesized from the model name so the engine, fleet, EEMBC, and
//! CLI layers run on a fresh checkout with no artifacts at all.

use super::{argmax, Manifest};
use crate::data::prng::SplitMix64;
use crate::error::{bail, Result};
use crate::kernels::{PackedLinear, ScratchArena, SmoothKernel};
use std::path::Path;

/// Stand-in for the PJRT client (one per process; nothing to hold).
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime)
    }
}

const DEFAULT_BATCH: usize = 64;

/// A loaded surrogate model.
pub struct LoadedModel {
    pub manifest: Manifest,
    /// Fused packed weights for classification: rows `0..n_out` are the
    /// class templates, rows `n_out..2*n_out` the pseudo-random
    /// projections, both with the `1/dim` template-logits scale folded
    /// into the per-row dequantization scales.  `None` for AD.
    packed: Option<PackedLinear>,
    /// O(n) prefix-sum smoothing for the AD reconstruction.
    smooth: SmoothKernel,
    /// Deterministic per-element residual for the AD reconstruction.
    residual: Vec<f32>,
    /// Kernel scratch (quantized activations, prefix sums) — grows to
    /// the high-water mark once, then the forward is allocation-free.
    scratch: ScratchArena,
    /// Reused staging for the fused GEMM output (`2 * n_out` per sample).
    gemm_out: Vec<f32>,
    /// SGD steps taken (drives loss decay and blend sharpening).
    steps: u32,
}

fn fnv64(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn synth_manifest(name: &str) -> Result<Manifest> {
    let (task, flow, input_shape, num_outputs, loss_kind) = if name.contains("kws") {
        ("kws", "finn", vec![490], 12, "xent")
    } else if name.contains("ad") {
        ("ad", "hls4ml", vec![128], 128, "mse")
    } else if name.contains("ic") {
        ("ic", "finn", vec![32, 32, 3], 10, "xent")
    } else {
        bail!("sim backend: cannot infer task from model name '{name}'");
    };
    Ok(Manifest {
        name: name.to_string(),
        task: task.to_string(),
        flow: flow.to_string(),
        input_shape,
        num_outputs,
        loss_kind: loss_kind.to_string(),
        weight_bits: "sim".to_string(),
        params: Vec::new(),
        artifacts: vec![
            ("fwd1".into(), "<sim>".into(), 1),
            (format!("fwd{DEFAULT_BATCH}"), "<sim>".into(), DEFAULT_BATCH),
            ("train".into(), "<sim>".into(), DEFAULT_BATCH),
        ],
    })
}

impl LoadedModel {
    /// Load the manifest if present, else synthesize one from the name.
    /// Classification weights (templates + projections) are packed to i8
    /// here, once, and never touched again on the request path.
    pub fn load(art_dir: &Path, name: &str) -> Result<Self> {
        let path = art_dir.join(format!("{name}_manifest.json"));
        let manifest =
            if path.exists() { Manifest::load(&path)? } else { synth_manifest(name)? };
        let feat = manifest.input_elems();
        let n_out = manifest.num_outputs;
        let seed = fnv64(name);
        let packed = if manifest.task == "ad" {
            None
        } else {
            let mut rows = crate::data::class_templates_f32(&manifest.task, n_out);
            for row in &mut rows {
                // A user manifest may declare an input width other than
                // the built-in template width; reproduce the seed's
                // zip-truncation semantics (elements past the shorter
                // operand contribute 0) instead of panicking in pack.
                row.resize(feat, 0.0);
            }
            for c in 0..n_out {
                let mut rng = SplitMix64::new(seed ^ (0x9E37 + c as u64));
                rows.push((0..feat).map(|_| rng.next_gaussian() as f32).collect());
            }
            Some(PackedLinear::pack(&rows, 1.0 / feat.max(1) as f32))
        };
        let mut rng = SplitMix64::new(seed ^ 0xAD0FF5E7);
        let residual = (0..feat).map(|_| rng.next_gaussian() as f32).collect();
        Ok(Self {
            manifest,
            packed,
            smooth: SmoothKernel::new(crate::data::AD_SMOOTH_WINDOW),
            residual,
            scratch: ScratchArena::new(),
            gemm_out: Vec::new(),
            steps: 0,
        })
    }

    /// Blend weight of the template/profile component: grows with steps.
    fn fidelity(&self) -> f32 {
        1.0 - 0.4 * (-(self.steps as f32) / 50.0).exp()
    }

    /// Forward `n` contiguous samples through the packed kernel core into
    /// `out` (`n * num_outputs` values).  One tiled pass over the fused
    /// weight matrix per call; zero allocations in steady state.
    fn forward_batch_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) {
        let feat = self.manifest.input_elems();
        let n_out = self.manifest.num_outputs;
        debug_assert_eq!(x.len(), n * feat);
        debug_assert_eq!(out.len(), n * n_out);
        if let Some(packed) = &self.packed {
            // Fused GEMM: template part and projection part in one pass.
            if self.gemm_out.len() < n * 2 * n_out {
                self.gemm_out.resize(n * 2 * n_out, 0.0);
            }
            let y = &mut self.gemm_out[..n * 2 * n_out];
            packed.gemm_batch(x, y, &mut self.scratch);
            // Training-decayed blend: rescale the projection part from
            // /dim to 0.05/sqrt(dim), exactly as the seed did.
            let beta = self.fidelity();
            let wscale = 0.05 * (feat as f32).sqrt();
            for s in 0..n {
                let y_s = &y[s * 2 * n_out..(s + 1) * 2 * n_out];
                let out_s = &mut out[s * n_out..(s + 1) * n_out];
                for c in 0..n_out {
                    out_s[c] = beta * y_s[c] + (1.0 - beta) * y_s[n_out + c] * wscale;
                }
            }
        } else {
            // Reconstruction: smoothed input + a training-decayed residual.
            let delta = 0.5 * (1.0 - self.fidelity());
            for s in 0..n {
                let x_s = &x[s * feat..(s + 1) * feat];
                let out_s = &mut out[s * n_out..(s + 1) * n_out];
                self.smooth.smooth_into(x_s, out_s, &mut self.scratch);
                for (o, &r) in out_s.iter_mut().zip(&self.residual) {
                    *o += delta * r;
                }
            }
        }
    }

    pub fn ensure_fwd1(&mut self, _rt: &Runtime) -> Result<()> {
        Ok(())
    }

    pub fn ensure_fwd_batch(&mut self, _rt: &Runtime) -> Result<usize> {
        Ok(self
            .manifest
            .artifacts
            .iter()
            .find(|(t, _, _)| t.starts_with("fwd") && t != "fwd1")
            .map(|(_, _, b)| *b)
            .unwrap_or(DEFAULT_BATCH))
    }

    pub fn ensure_train(&mut self, _rt: &Runtime) -> Result<usize> {
        Ok(self
            .manifest
            .artifact("train")
            .map(|(_, b)| b)
            .unwrap_or(DEFAULT_BATCH))
    }

    /// Batch-1 inference (the EEMBC path): returns the output vector.
    pub fn infer1(&mut self, _rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        let feat = self.manifest.input_elems();
        if x.len() != feat {
            bail!("input len {} != {}", x.len(), feat);
        }
        let mut out = vec![0.0f32; self.manifest.num_outputs];
        self.forward_batch_into(x, 1, &mut out);
        Ok(out)
    }

    /// Batched inference; `x` must hold exactly the device batch (pad the
    /// tail batch with zeros and slice the result).
    pub fn infer_batch(&mut self, rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        let batch = self.ensure_fwd_batch(rt)?;
        let mut out = vec![0.0f32; batch * self.manifest.num_outputs];
        self.infer_batch_into(rt, x, &mut out)?;
        Ok(out)
    }

    /// Batched inference into a caller-owned buffer — the zero-allocation
    /// serving entry point ([`crate::coordinator::engine::BatchExecutor`]
    /// drives this with buffers it reuses across batches).  Because the
    /// kernel accumulates in exact integer arithmetic, outputs are
    /// bit-identical to `infer1` on the same sample.
    pub fn infer_batch_into(
        &mut self,
        rt: &Runtime,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let batch = self.ensure_fwd_batch(rt)?;
        self.infer_prefix_into(rt, x, batch, out)
    }

    /// Like [`Self::infer_batch_into`], but only the first `n` samples of
    /// the padded batch are live: the surrogate skips the zero-padded
    /// tail entirely (the AOT PJRT backend cannot — its executable runs
    /// the full compiled batch — so there `n` is advisory).  This is the
    /// [`crate::coordinator::engine::BatchExecutor::execute`] entry
    /// point; outputs past `n * num_outputs` are left untouched.
    pub fn infer_prefix_into(
        &mut self,
        rt: &Runtime,
        x: &[f32],
        n: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let batch = self.ensure_fwd_batch(rt)?;
        let feat = self.manifest.input_elems();
        if n == 0 || n > batch {
            bail!("live count {n} outside 1..={batch}");
        }
        if x.len() != feat * batch {
            bail!("input len {} != batch {} * {}", x.len(), batch, feat);
        }
        if out.len() != batch * self.manifest.num_outputs {
            bail!(
                "output len {} != batch {} * {}",
                out.len(),
                batch,
                self.manifest.num_outputs
            );
        }
        self.forward_batch_into(
            &x[..n * feat],
            n,
            &mut out[..n * self.manifest.num_outputs],
        );
        Ok(())
    }

    /// One surrogate SGD step: advances the fidelity schedule and returns
    /// a deterministically decaying loss.  Accepts any batch whose `x`
    /// and `y` lengths agree (the real backend is stricter — it must
    /// match the AOT-compiled train batch).
    pub fn train_step(&mut self, _rt: &Runtime, x: &[f32], y: &[i32], _lr: f32) -> Result<f32> {
        let feat = self.manifest.input_elems();
        if y.is_empty() || x.len() != feat * y.len() {
            bail!("train batch mismatch: x {} vs y {} * {}", x.len(), y.len(), feat);
        }
        self.steps += 1;
        let base = if self.manifest.loss_kind == "mse" {
            0.35f32 * 0.35
        } else {
            (self.manifest.num_outputs as f32).ln()
        };
        Ok(base * (0.12 + 0.88 * (-(self.steps as f32) / 35.0).exp()))
    }

    /// Argmax over the batch-1 output (classification).
    pub fn classify1(&mut self, rt: &Runtime, x: &[f32]) -> Result<usize> {
        let out = self.infer1(rt, x)?;
        Ok(argmax(&out))
    }

    /// AD anomaly score: mean squared reconstruction error (§2.2).
    pub fn anomaly_score1(&mut self, rt: &Runtime, x: &[f32]) -> Result<f32> {
        let out = self.infer1(rt, x)?;
        let mse = out
            .iter()
            .zip(x.iter())
            .map(|(r, t)| (r - t) * (r - t))
            .sum::<f32>()
            / x.len() as f32;
        Ok(mse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn model(name: &str) -> LoadedModel {
        // Point at a directory with no manifests: synthesis path.
        LoadedModel::load(Path::new("/nonexistent"), name).unwrap()
    }

    #[test]
    fn deterministic_and_input_sensitive() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("kws_mlp_w3a3");
        let ts = data::test_set("kws", 3, 1);
        let a = m.infer1(&rt, &ts.samples[0].x).unwrap();
        let b = m.infer1(&rt, &ts.samples[0].x).unwrap();
        assert_eq!(a.len(), 12);
        assert_eq!(a, b);
        let c = m.infer1(&rt, &ts.samples[1].x).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn batch_matches_single() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("kws_mlp_w3a3");
        let batch = m.ensure_fwd_batch(&rt).unwrap();
        let feat = m.manifest.input_elems();
        let ts = data::test_set("kws", 2, 5);
        let mut x = vec![0.0f32; batch * feat];
        x[..feat].copy_from_slice(&ts.samples[0].x);
        let out = m.infer_batch(&rt, &x).unwrap();
        let single = m.infer1(&rt, &ts.samples[0].x).unwrap();
        // Integer accumulation makes batch vs single *bit*-identical.
        assert_eq!(&out[..12], &single[..]);
    }

    #[test]
    fn infer_batch_into_matches_infer_batch() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("ad_autoencoder");
        let batch = m.ensure_fwd_batch(&rt).unwrap();
        let feat = m.manifest.input_elems();
        let ts = data::test_set("ad", 4, 3);
        let mut x = vec![0.0f32; batch * feat];
        for (i, s) in ts.samples.iter().enumerate() {
            x[i * feat..(i + 1) * feat].copy_from_slice(&s.x);
        }
        let owned = m.infer_batch(&rt, &x).unwrap();
        let mut buf = vec![0.0f32; batch * m.manifest.num_outputs];
        m.infer_batch_into(&rt, &x, &mut buf).unwrap();
        assert_eq!(owned, buf);
        // Wrong buffer size is an error, not a panic.
        let mut short = vec![0.0f32; 3];
        assert!(m.infer_batch_into(&rt, &x, &mut short).is_err());
    }

    #[test]
    fn infer_prefix_skips_padded_tail() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("kws_mlp_w3a3");
        let batch = m.ensure_fwd_batch(&rt).unwrap();
        let feat = m.manifest.input_elems();
        let n_out = m.manifest.num_outputs;
        let ts = data::test_set("kws", 3, 0x11);
        let mut x = vec![0.0f32; batch * feat];
        for (i, s) in ts.samples.iter().enumerate() {
            x[i * feat..(i + 1) * feat].copy_from_slice(&s.x);
        }
        let full = m.infer_batch(&rt, &x).unwrap();
        let mut buf = vec![f32::NAN; batch * n_out];
        m.infer_prefix_into(&rt, &x, 3, &mut buf).unwrap();
        // Live prefix matches the full-batch result bit-for-bit...
        assert_eq!(&buf[..3 * n_out], &full[..3 * n_out]);
        // ...and the padded tail was never touched.
        assert!(buf[3 * n_out..].iter().all(|v| v.is_nan()));
        // n outside 1..=batch is an error, not a panic.
        assert!(m.infer_prefix_into(&rt, &x, 0, &mut buf).is_err());
        assert!(m.infer_prefix_into(&rt, &x, batch + 1, &mut buf).is_err());
    }

    #[test]
    fn training_loss_decreases() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("kws_mlp_w3a3");
        let mut rng = SplitMix64::new(7);
        let (x, y) = data::train_batch("kws", &mut rng, 8);
        let first = m.train_step(&rt, &x, &y, 0.05).unwrap();
        let mut last = first;
        for _ in 0..5 {
            last = m.train_step(&rt, &x, &y, 0.05).unwrap();
        }
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn classification_beats_chance() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("kws_mlp_w3a3");
        let ts = data::test_set("kws", 60, 0xACC);
        let mut correct = 0;
        for s in &ts.samples {
            if m.classify1(&rt, &s.x).unwrap() == s.label as usize {
                correct += 1;
            }
        }
        assert!(correct > 30, "top-1 {correct}/60");
    }

    #[test]
    fn anomaly_scores_separate() {
        let rt = Runtime::cpu().unwrap();
        let mut m = model("ad_autoencoder");
        // Train a little so the residual decays.
        let mut rng = SplitMix64::new(3);
        for _ in 0..60 {
            let (x, y) = data::train_batch("ad", &mut rng, 8);
            m.train_step(&rt, &x, &y, 0.05).unwrap();
        }
        let ts = data::test_set("ad", 60, 11);
        let mut scores = Vec::new();
        for s in &ts.samples {
            scores.push((m.anomaly_score1(&rt, &s.x).unwrap(), s.label == 1));
        }
        let auc = data::roc_auc(&scores);
        assert!(auc > 0.6, "AUC {auc}");
    }
}
