//! Single-flight request coalescing: collapse identical **in-flight**
//! misses into one board execution.
//!
//! The result cache ([`super::cache`]) answers repeats of *completed*
//! executions; this module handles the window the cache cannot cover —
//! the flash crowd that arrives while the first identical request is
//! still queued or executing.  Without it, N identical submits that all
//! miss the (not-yet-populated) cache each queue for a board and each
//! burn `latency + ii` device time; with it, one **leader** executes
//! and N−1 **followers** ride its reply.
//!
//! **Identity.**  Two requests coalesce when they share the same
//! 64-bit [`super::cache::ResultCache::key`] digest — task name plus
//! the input quantized to the 1/256 grid — i.e. exactly when a cache
//! hit would have served one from the other.
//!
//! **Admission state machine.**  The submit path calls
//! [`Coalescer::attach_or_lead`] *after* the cache probe misses:
//!
//! * no open flight for the key → a fresh [`Flight`] is registered and
//!   the caller becomes its **leader** ([`Attach::Lead`]); the flight
//!   rides the leader's [`super::FleetRequest`] through routing,
//!   queueing, retries, and execution.
//! * an open flight whose leader's class is the same or more urgent →
//!   the caller's reply sender is enrolled as a **follower**
//!   ([`Attach::Follow`]) and the submit returns immediately — the
//!   request never touches the router.
//! * an open flight led by a *less* urgent class → [`Attach::Solo`]:
//!   an Interactive request must not wait behind a Batch leader's
//!   queue position, so it proceeds uncoalesced (and simply does not
//!   coalesce with anyone — the key is occupied).
//!
//! **Leader/follower lifecycle and failure semantics.**  Exactly one
//! terminal event finishes a flight, and whoever triggers it calls
//! [`Coalescer::finish`] (directly or via [`Coalescer::fan_err`]):
//!
//! * the worker completes the leader's batch → it fans a bit-identical
//!   copy of the leader's output to every follower through its
//!   [`crate::coordinator::pool::ReplyPool`];
//! * the leader's retry budget runs out (worker fail path, or the
//!   retry pump finding no queue) → the same typed
//!   [`super::FleetError`] the leader gets is fanned to every
//!   follower;
//! * the leader is refused admission after registering (every routing
//!   retry bounced) → the submit path fans
//!   `FleetError::Exhausted { attempts: 0 }` — zero attempts marks "the
//!   leader never executed" — and surfaces its own `RouteError`.
//!
//! `finish` removes the map entry *first* (new arrivals for the key
//! start a fresh flight — re-election by succession rather than
//! follower promotion) and then snapshots the follower list exactly
//! once, flipping the flight to `Done`.  Enrolment and finishing take
//! the same stripe lock, so no follower can enrol after the snapshot:
//! the **exactly-one-outcome invariant** extends to followers — every
//! enrolled sender receives exactly one `Ok`/`Err`, pinned by the
//! chaos proptests in `rust/tests/proptests.rs`.  A `finish` racing a
//! stale `Arc<Flight>` (the key already re-led by a successor) is
//! guarded by pointer identity and leaves the successor untouched.
//!
//! Like the cache, the flight map is lock-striped by the low key bits;
//! a coalesce-off fleet never constructs a [`Coalescer`], so the
//! steady-state submit path pays nothing.

use super::queue::Priority;
use super::FleetError;
use crate::coordinator::engine::Reply;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Reply-channel sender type shared with [`super::FleetRequest`].
pub type ReplySender = mpsc::Sender<std::result::Result<Reply, FleetError>>;

/// Lock stripes over the in-flight map (power of two; the map holds one
/// entry per *distinct in-flight key*, so it stays small — striping is
/// about lock traffic under flash-crowd submit storms, not capacity).
const STRIPES: usize = 16;

/// Counters for telemetry (`coalesce` block in the snapshot JSON).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CoalesceStats {
    /// Flights opened (one per leader, followers or not).
    pub leaders: u64,
    /// Requests that attached to an open flight instead of routing.
    pub followers: u64,
    /// Follower replies fanned out from completed leader batches.
    pub fanned_ok: u64,
    /// Follower errors fanned out from failed/aborted leaders.
    pub fanned_err: u64,
}

enum FlightState {
    Open { followers: Vec<ReplySender>, leader_class: Priority },
    Done,
}

/// One in-flight execution a herd can ride.  Registered under its key
/// in the [`Coalescer`] map while open; carried by the leader's
/// `FleetRequest` until a terminal outcome finishes it.
pub struct Flight {
    key: u64,
    state: Mutex<FlightState>,
}

impl FlightState {
    fn open(leader_class: Priority) -> FlightState {
        FlightState::Open { followers: Vec::new(), leader_class }
    }
}

impl Flight {
    /// Snapshot the follower list exactly once, flipping to `Done`.
    /// Later calls (a double-finish race) get an empty list.
    fn take_followers(&self) -> Vec<ReplySender> {
        let mut st = self.state.lock().unwrap();
        match std::mem::replace(&mut *st, FlightState::Done) {
            FlightState::Open { followers, .. } => followers,
            FlightState::Done => Vec::new(),
        }
    }
}

/// What [`Coalescer::attach_or_lead`] decided for one submitted miss.
pub enum Attach {
    /// Caller leads a fresh flight: route normally, carry the flight on
    /// the request, fan followers at the terminal outcome.
    Lead(Arc<Flight>),
    /// Caller's sender was enrolled on an open flight; its receiver
    /// resolves when the leader's outcome fans out.  Do not route.
    Follow,
    /// An open flight exists but its leader's class is less urgent than
    /// the caller: proceed uncoalesced.
    Solo,
}

/// Striped map of in-flight executions, keyed by the cache digest.
pub struct Coalescer {
    stripes: Vec<Mutex<HashMap<u64, Arc<Flight>>>>,
    leaders: AtomicU64,
    followers: AtomicU64,
    fanned_ok: AtomicU64,
    fanned_err: AtomicU64,
}

impl Default for Coalescer {
    fn default() -> Self {
        Self::new()
    }
}

impl Coalescer {
    pub fn new() -> Self {
        Coalescer {
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            leaders: AtomicU64::new(0),
            followers: AtomicU64::new(0),
            fanned_ok: AtomicU64::new(0),
            fanned_err: AtomicU64::new(0),
        }
    }

    #[inline]
    fn stripe(&self, key: u64) -> &Mutex<HashMap<u64, Arc<Flight>>> {
        &self.stripes[key as usize & (STRIPES - 1)]
    }

    /// Decide what a cache-missing submit does for `key` — see the
    /// module docs for the state machine.  Compatibility is "the
    /// leader's class is the same or more urgent than `class`": a
    /// follower never waits behind a lazier leader's queue position.
    pub fn attach_or_lead(&self, key: u64, class: Priority, tx: &ReplySender) -> Attach {
        let mut map = self.stripe(key).lock().unwrap();
        if let Some(f) = map.get(&key) {
            let mut st = f.state.lock().unwrap();
            match &mut *st {
                FlightState::Open { followers, leader_class } => {
                    if leader_class.idx() <= class.idx() {
                        followers.push(tx.clone());
                        self.followers.fetch_add(1, Ordering::Relaxed);
                        return Attach::Follow;
                    }
                    return Attach::Solo;
                }
                // Done but not yet (or never) deregistered: stale —
                // fall through and lead a successor flight.
                FlightState::Done => {}
            }
        }
        let f = Arc::new(Flight { key, state: Mutex::new(FlightState::open(class)) });
        map.insert(key, f.clone());
        self.leaders.fetch_add(1, Ordering::Relaxed);
        Attach::Lead(f)
    }

    /// Terminally resolve `flight`: deregister it (pointer-identity
    /// guarded, so finishing a stale flight cannot evict a successor
    /// already leading the same key) and hand back the follower list —
    /// exactly once; a second finish gets an empty list.  The caller
    /// owes every returned sender exactly one outcome.
    pub fn finish(&self, flight: &Arc<Flight>) -> Vec<ReplySender> {
        {
            let mut map = self.stripe(flight.key).lock().unwrap();
            if map.get(&flight.key).map_or(false, |cur| Arc::ptr_eq(cur, flight)) {
                map.remove(&flight.key);
            }
        }
        flight.take_followers()
    }

    /// [`Self::finish`] + fan `err` to every follower (the leader's own
    /// reply channel is the caller's to resolve).
    pub fn fan_err(&self, flight: &Arc<Flight>, err: &FleetError) {
        let followers = self.finish(flight);
        if followers.is_empty() {
            return;
        }
        self.fanned_err.fetch_add(followers.len() as u64, Ordering::Relaxed);
        for tx in followers {
            let _ = tx.send(Err(err.clone()));
        }
    }

    /// Count `n` follower replies fanned from a completed batch (the
    /// worker copies and sends them itself — it owns the reply pool).
    pub fn note_fanned_ok(&self, n: u64) {
        self.fanned_ok.fetch_add(n, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CoalesceStats {
        CoalesceStats {
            leaders: self.leaders.load(Ordering::Relaxed),
            followers: self.followers.load(Ordering::Relaxed),
            fanned_ok: self.fanned_ok.load(Ordering::Relaxed),
            fanned_err: self.fanned_err.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::PooledVec;

    fn chan() -> (ReplySender, mpsc::Receiver<std::result::Result<Reply, FleetError>>) {
        mpsc::channel()
    }

    fn reply(v: Vec<f32>) -> Reply {
        Reply {
            output: PooledVec::detached(v),
            top1: 0,
            batch_size: 1,
            queue_us: 0,
            exec_us: 0,
        }
    }

    #[test]
    fn first_leads_duplicates_follow_and_fan_out_resolves_them() {
        let co = Coalescer::new();
        let key = 0xAB;
        let (ltx, _lrx) = chan();
        let flight = match co.attach_or_lead(key, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("first request must lead"),
        };
        let (f1tx, f1rx) = chan();
        let (f2tx, f2rx) = chan();
        assert!(matches!(co.attach_or_lead(key, Priority::Standard, &f1tx), Attach::Follow));
        assert!(matches!(co.attach_or_lead(key, Priority::Batch, &f2tx), Attach::Follow));
        // A different key is independent.
        let (otx, _orx) = chan();
        assert!(matches!(co.attach_or_lead(0xCD, Priority::Standard, &otx), Attach::Lead(_)));
        // The "worker" finishes the flight and fans copies.
        let followers = co.finish(&flight);
        assert_eq!(followers.len(), 2);
        co.note_fanned_ok(followers.len() as u64);
        for tx in followers {
            let _ = tx.send(Ok(reply(vec![1.0, 2.0])));
        }
        for rx in [f1rx, f2rx] {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got.output[..], &[1.0, 2.0]);
        }
        let s = co.stats();
        assert_eq!(s, CoalesceStats { leaders: 2, followers: 2, fanned_ok: 2, fanned_err: 0 });
        // The key is free again: the next identical request leads.
        let (ntx, _nrx) = chan();
        assert!(matches!(co.attach_or_lead(key, Priority::Standard, &ntx), Attach::Lead(_)));
    }

    #[test]
    fn more_urgent_request_goes_solo_instead_of_waiting_on_a_lazy_leader() {
        let co = Coalescer::new();
        let (btx, _brx) = chan();
        let _flight = match co.attach_or_lead(7, Priority::Batch, &btx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        // Interactive behind a Batch leader: solo, never enrolled.
        let (itx, irx) = chan();
        assert!(matches!(co.attach_or_lead(7, Priority::Interactive, &itx), Attach::Solo));
        assert!(irx.try_recv().is_err());
        // The reverse composition coalesces: Interactive leader,
        // Standard/Batch followers.
        let (ltx, _lrx) = chan();
        assert!(matches!(co.attach_or_lead(9, Priority::Interactive, &ltx), Attach::Lead(_)));
        let (stx, _srx) = chan();
        assert!(matches!(co.attach_or_lead(9, Priority::Batch, &stx), Attach::Follow));
        assert_eq!(co.stats().followers, 1);
    }

    #[test]
    fn fan_err_resolves_followers_with_the_typed_error() {
        let co = Coalescer::new();
        let (ltx, _lrx) = chan();
        let flight = match co.attach_or_lead(1, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        let (ftx, frx) = chan();
        assert!(matches!(co.attach_or_lead(1, Priority::Standard, &ftx), Attach::Follow));
        co.fan_err(&flight, &FleetError::Exhausted { attempts: 4 });
        match frx.recv().unwrap() {
            Err(FleetError::Exhausted { attempts }) => assert_eq!(attempts, 4),
            Ok(_) => panic!("follower got a reply from a failed leader"),
        }
        assert!(frx.try_recv().is_err(), "exactly one outcome");
        assert_eq!(co.stats().fanned_err, 1);
    }

    #[test]
    fn finish_is_once_only_and_a_stale_finish_cannot_evict_a_successor() {
        let co = Coalescer::new();
        let (tx, _rx) = chan();
        let f1 = match co.attach_or_lead(5, Priority::Standard, &tx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        let (ftx, _frx) = chan();
        assert!(matches!(co.attach_or_lead(5, Priority::Standard, &ftx), Attach::Follow));
        assert_eq!(co.finish(&f1).len(), 1);
        assert_eq!(co.finish(&f1).len(), 0, "followers snapshot exactly once");
        // A successor takes over the key; finishing the stale flight
        // again must not deregister it.
        let f2 = match co.attach_or_lead(5, Priority::Standard, &tx) {
            Attach::Lead(f) => f,
            _ => panic!("successor must lead"),
        };
        assert_eq!(co.finish(&f1).len(), 0);
        let (gtx, _grx) = chan();
        assert!(
            matches!(co.attach_or_lead(5, Priority::Standard, &gtx), Attach::Follow),
            "successor flight must survive the stale finish"
        );
        assert_eq!(co.finish(&f2).len(), 1);
    }
}
