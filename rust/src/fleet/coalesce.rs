//! Single-flight request coalescing: collapse identical **in-flight**
//! misses into one board execution.
//!
//! The result cache ([`super::cache`]) answers repeats of *completed*
//! executions; this module handles the window the cache cannot cover —
//! the flash crowd that arrives while the first identical request is
//! still queued or executing.  Without it, N identical submits that all
//! miss the (not-yet-populated) cache each queue for a board and each
//! burn `latency + ii` device time; with it, one **leader** executes
//! and N−1 **followers** ride its reply.
//!
//! **Identity.**  Two requests coalesce when they share the same
//! 64-bit [`super::cache::ResultCache::key`] digest — task name plus
//! the input quantized to the 1/256 grid — i.e. exactly when a cache
//! hit would have served one from the other.
//!
//! **Admission state machine.**  The submit path calls
//! [`Coalescer::attach_or_lead`] *after* the cache probe misses:
//!
//! * no open flight for the key → a fresh [`Flight`] is registered and
//!   the caller becomes its **leader** ([`Attach::Lead`]); the flight
//!   rides the leader's [`super::FleetRequest`] through routing,
//!   queueing, retries, and execution.
//! * an open flight whose leader's class is the same or more urgent →
//!   the caller's reply sender is enrolled as a **follower**
//!   ([`Attach::Follow`]) and the submit returns immediately — the
//!   request never touches the router.
//! * an open flight led by a *less* urgent class → the caller is still
//!   enrolled, but the flight is **upgraded** to the caller's class
//!   ([`Attach::FollowUpgraded`]): the submit path re-tags the queued
//!   leader in place ([`super::queue::BoardQueue::promote_flight`]), so
//!   an Interactive duplicate lifts a Batch leader to the interactive
//!   pickup plane instead of burning a second execution.
//! * an open flight already carrying [`MAX_FOLLOWERS_PER_FLIGHT`]
//!   followers → [`Attach::Solo`]: the overflow request falls through
//!   to a normal queued submit, bounding the fan loop one worker runs
//!   at the terminal outcome.
//!
//! **Leader/follower lifecycle and failure semantics.**  Exactly one
//! terminal event finishes a flight, and whoever triggers it calls
//! [`Coalescer::finish`] (directly or via [`Coalescer::fan_err`]):
//!
//! * the worker completes the leader's batch → it fans a bit-identical
//!   copy of the leader's output to every follower through its
//!   [`crate::coordinator::pool::ReplyPool`];
//! * the leader's retry budget runs out (worker fail path, or the
//!   retry pump finding no queue) → the same typed
//!   [`super::FleetError`] the leader gets is fanned to every
//!   follower;
//! * the leader is refused admission after registering (every routing
//!   retry bounced) → the submit path fans
//!   `FleetError::Exhausted { attempts: 0 }` — zero attempts marks "the
//!   leader never executed" — and surfaces its own `RouteError`.
//!
//! `finish` removes the map entry *first* (new arrivals for the key
//! start a fresh flight — re-election by succession rather than
//! follower promotion) and then snapshots the follower list exactly
//! once, flipping the flight to `Done`.  Enrolment and finishing take
//! the same stripe lock, so no follower can enrol after the snapshot:
//! the **exactly-one-outcome invariant** extends to followers — every
//! enrolled sender receives exactly one `Ok`/`Err`, pinned by the
//! chaos proptests in `rust/tests/proptests.rs`.  A `finish` racing a
//! stale `Arc<Flight>` (the key already re-led by a successor) is
//! guarded by pointer identity and leaves the successor untouched.
//!
//! Like the cache, the flight map is lock-striped by the low key bits;
//! a coalesce-off fleet never constructs a [`Coalescer`], so the
//! steady-state submit path pays nothing.

use super::queue::Priority;
use super::FleetError;
use crate::coordinator::engine::Reply;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Reply-channel sender type shared with [`super::FleetRequest`].
pub type ReplySender = mpsc::Sender<std::result::Result<Reply, FleetError>>;

/// Lock stripes over the in-flight map (power of two; the map holds one
/// entry per *distinct in-flight key*, so it stays small — striping is
/// about lock traffic under flash-crowd submit storms, not capacity).
const STRIPES: usize = 16;

/// Follower bound per flight.  One mega-flight fanning thousands of
/// copies would serialize the winning worker on the fan loop; past this
/// many followers, further duplicates go [`Attach::Solo`] and queue
/// normally.
pub const MAX_FOLLOWERS_PER_FLIGHT: usize = 64;

/// Counters for telemetry (`coalesce` block in the snapshot JSON).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CoalesceStats {
    /// Flights opened (one per leader, followers or not).
    pub leaders: u64,
    /// Requests that attached to an open flight instead of routing.
    pub followers: u64,
    /// Follower replies fanned out from completed leader batches.
    pub fanned_ok: u64,
    /// Follower errors fanned out from failed/aborted leaders.
    pub fanned_err: u64,
    /// Duplicates forced solo because their flight was already at
    /// [`MAX_FOLLOWERS_PER_FLIGHT`].
    pub overflow: u64,
    /// Flights lifted to a stronger class by a more urgent duplicate.
    pub upgrades: u64,
}

enum FlightState {
    Open { followers: Vec<ReplySender>, leader_class: Priority },
    Done,
}

/// One in-flight execution a herd can ride.  Registered under its key
/// in the [`Coalescer`] map while open; carried by the leader's
/// `FleetRequest` until a terminal outcome finishes it.
pub struct Flight {
    key: u64,
    state: Mutex<FlightState>,
    /// Slot id of the queue the leader leg was pushed to
    /// ([`NO_BOARD`] until known) — lets a class upgrade find and
    /// promote the queued leader without a fleet-wide scan.
    board: AtomicUsize,
}

/// Sentinel for [`Flight::board`]: the leader has not been pushed yet
/// (or never will be — a refused or standalone flight).
const NO_BOARD: usize = usize::MAX;

impl FlightState {
    fn open(leader_class: Priority) -> FlightState {
        FlightState::Open { followers: Vec::new(), leader_class }
    }
}

impl Flight {
    /// Snapshot the follower list exactly once, flipping to `Done`.
    /// Later calls (a double-finish race) get an empty list.
    fn take_followers(&self) -> Vec<ReplySender> {
        let mut st = self.state.lock().unwrap();
        match std::mem::replace(&mut *st, FlightState::Done) {
            FlightState::Open { followers, .. } => followers,
            FlightState::Done => Vec::new(),
        }
    }

    /// A free-standing flight that is **not** registered in any
    /// coalescer map: the hedged-request racer.  Both hedge legs carry
    /// it; the caller's real sender rides as its only follower, so the
    /// first leg to reach a terminal outcome resolves the caller and
    /// the loser sees `Done` at its next stage boundary.  `finish` /
    /// `fan_err` work unchanged (the pointer-identity deregister guard
    /// simply never fires).
    pub fn standalone(leader_class: Priority) -> Arc<Flight> {
        Arc::new(Flight {
            key: u64::MAX,
            state: Mutex::new(FlightState::open(leader_class)),
            board: AtomicUsize::new(NO_BOARD),
        })
    }

    /// Record which board queue the leader leg landed in (submit path,
    /// right after a successful `try_push`).
    pub fn note_board(&self, instance: usize) {
        self.board.store(instance, Ordering::Relaxed);
    }

    /// The leader's board, if it has been pushed.
    pub fn board(&self) -> Option<usize> {
        match self.board.load(Ordering::Relaxed) {
            NO_BOARD => None,
            i => Some(i),
        }
    }

    /// `true` once a terminal outcome has resolved this flight.  Hedge
    /// losers poll this at stage boundaries (dequeue, window-close) and
    /// discard themselves instead of executing.
    pub fn is_done(&self) -> bool {
        matches!(*self.state.lock().unwrap(), FlightState::Done)
    }

    /// Enrol one more follower sender; `false` (no enrolment) once the
    /// flight is `Done` or full.  The hedging submit path uses this to
    /// park the caller's real sender on the race flight — it bumps the
    /// same fan accounting as a coalesced follower so every enrolled
    /// sender still maps to exactly one fanned outcome.
    fn enroll(&self, tx: &ReplySender) -> bool {
        let mut st = self.state.lock().unwrap();
        match &mut *st {
            FlightState::Open { followers, .. } if followers.len() < MAX_FOLLOWERS_PER_FLIGHT => {
                followers.push(tx.clone());
                true
            }
            _ => false,
        }
    }
}

/// What [`Coalescer::attach_or_lead`] decided for one submitted miss.
pub enum Attach {
    /// Caller leads a fresh flight: route normally, carry the flight on
    /// the request, fan followers at the terminal outcome.
    Lead(Arc<Flight>),
    /// Caller's sender was enrolled on an open flight; its receiver
    /// resolves when the leader's outcome fans out.  Do not route.
    Follow,
    /// Enrolled like [`Attach::Follow`], but the caller's class was
    /// more urgent than the leader's, so the flight was lifted to the
    /// caller's class — the submit path should promote the queued
    /// leader ([`super::queue::BoardQueue::promote_flight`]) on the
    /// board recorded by [`Flight::board`].
    FollowUpgraded(Arc<Flight>),
    /// An open flight exists but is already at
    /// [`MAX_FOLLOWERS_PER_FLIGHT`]: proceed uncoalesced.
    Solo,
}

/// Striped map of in-flight executions, keyed by the cache digest.
pub struct Coalescer {
    stripes: Vec<Mutex<HashMap<u64, Arc<Flight>>>>,
    leaders: AtomicU64,
    followers: AtomicU64,
    fanned_ok: AtomicU64,
    fanned_err: AtomicU64,
    overflow: AtomicU64,
    upgrades: AtomicU64,
}

impl Default for Coalescer {
    fn default() -> Self {
        Self::new()
    }
}

impl Coalescer {
    pub fn new() -> Self {
        Coalescer {
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            leaders: AtomicU64::new(0),
            followers: AtomicU64::new(0),
            fanned_ok: AtomicU64::new(0),
            fanned_err: AtomicU64::new(0),
            overflow: AtomicU64::new(0),
            upgrades: AtomicU64::new(0),
        }
    }

    #[inline]
    fn stripe(&self, key: u64) -> &Mutex<HashMap<u64, Arc<Flight>>> {
        &self.stripes[key as usize & (STRIPES - 1)]
    }

    /// Decide what a cache-missing submit does for `key` — see the
    /// module docs for the state machine.  A follower never waits
    /// behind a lazier leader's queue position: instead of soloing, a
    /// more urgent duplicate lifts the whole flight to its class.
    pub fn attach_or_lead(&self, key: u64, class: Priority, tx: &ReplySender) -> Attach {
        let mut map = self.stripe(key).lock().unwrap();
        if let Some(f) = map.get(&key) {
            let mut st = f.state.lock().unwrap();
            match &mut *st {
                FlightState::Open { followers, leader_class } => {
                    if followers.len() >= MAX_FOLLOWERS_PER_FLIGHT {
                        self.overflow.fetch_add(1, Ordering::Relaxed);
                        return Attach::Solo;
                    }
                    followers.push(tx.clone());
                    self.followers.fetch_add(1, Ordering::Relaxed);
                    if leader_class.idx() <= class.idx() {
                        return Attach::Follow;
                    }
                    // Stronger class behind a lazier leader: enrol and
                    // lift the flight so the queued leader can be
                    // promoted to the caller's pickup plane.
                    *leader_class = class;
                    self.upgrades.fetch_add(1, Ordering::Relaxed);
                    let f = f.clone();
                    drop(st);
                    return Attach::FollowUpgraded(f);
                }
                // Done but not yet (or never) deregistered: stale —
                // fall through and lead a successor flight.
                FlightState::Done => {}
            }
        }
        let f = Arc::new(Flight {
            key,
            state: Mutex::new(FlightState::open(class)),
            board: AtomicUsize::new(NO_BOARD),
        });
        map.insert(key, f.clone());
        self.leaders.fetch_add(1, Ordering::Relaxed);
        Attach::Lead(f)
    }

    /// Enrol `tx` as one more follower on `flight`, with the same fan
    /// accounting as [`Self::attach_or_lead`]'s `Follow` path.  `false`
    /// when the flight is already `Done` or full — the caller must then
    /// resolve `tx` through some other path.  The hedging submit uses
    /// this to park the caller's real sender on the race flight.
    pub fn enroll_follower(&self, flight: &Arc<Flight>, tx: &ReplySender) -> bool {
        let enrolled = flight.enroll(tx);
        if enrolled {
            self.followers.fetch_add(1, Ordering::Relaxed);
        }
        enrolled
    }

    /// Terminally resolve `flight`: deregister it (pointer-identity
    /// guarded, so finishing a stale flight cannot evict a successor
    /// already leading the same key) and hand back the follower list —
    /// exactly once; a second finish gets an empty list.  The caller
    /// owes every returned sender exactly one outcome.
    pub fn finish(&self, flight: &Arc<Flight>) -> Vec<ReplySender> {
        {
            let mut map = self.stripe(flight.key).lock().unwrap();
            if map.get(&flight.key).map_or(false, |cur| Arc::ptr_eq(cur, flight)) {
                map.remove(&flight.key);
            }
        }
        flight.take_followers()
    }

    /// [`Self::finish`] + fan `err` to every follower (the leader's own
    /// reply channel is the caller's to resolve).
    pub fn fan_err(&self, flight: &Arc<Flight>, err: &FleetError) {
        let followers = self.finish(flight);
        if followers.is_empty() {
            return;
        }
        self.fanned_err.fetch_add(followers.len() as u64, Ordering::Relaxed);
        for tx in followers {
            let _ = tx.send(Err(err.clone()));
        }
    }

    /// Count `n` follower replies fanned from a completed batch (the
    /// worker copies and sends them itself — it owns the reply pool).
    pub fn note_fanned_ok(&self, n: u64) {
        self.fanned_ok.fetch_add(n, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CoalesceStats {
        CoalesceStats {
            leaders: self.leaders.load(Ordering::Relaxed),
            followers: self.followers.load(Ordering::Relaxed),
            fanned_ok: self.fanned_ok.load(Ordering::Relaxed),
            fanned_err: self.fanned_err.load(Ordering::Relaxed),
            overflow: self.overflow.load(Ordering::Relaxed),
            upgrades: self.upgrades.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::PooledVec;

    fn chan() -> (ReplySender, mpsc::Receiver<std::result::Result<Reply, FleetError>>) {
        mpsc::channel()
    }

    fn reply(v: Vec<f32>) -> Reply {
        Reply {
            output: PooledVec::detached(v),
            top1: 0,
            batch_size: 1,
            queue_us: 0,
            exec_us: 0,
        }
    }

    #[test]
    fn first_leads_duplicates_follow_and_fan_out_resolves_them() {
        let co = Coalescer::new();
        let key = 0xAB;
        let (ltx, _lrx) = chan();
        let flight = match co.attach_or_lead(key, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("first request must lead"),
        };
        let (f1tx, f1rx) = chan();
        let (f2tx, f2rx) = chan();
        assert!(matches!(co.attach_or_lead(key, Priority::Standard, &f1tx), Attach::Follow));
        assert!(matches!(co.attach_or_lead(key, Priority::Batch, &f2tx), Attach::Follow));
        // A different key is independent.
        let (otx, _orx) = chan();
        assert!(matches!(co.attach_or_lead(0xCD, Priority::Standard, &otx), Attach::Lead(_)));
        // The "worker" finishes the flight and fans copies.
        let followers = co.finish(&flight);
        assert_eq!(followers.len(), 2);
        co.note_fanned_ok(followers.len() as u64);
        for tx in followers {
            let _ = tx.send(Ok(reply(vec![1.0, 2.0])));
        }
        for rx in [f1rx, f2rx] {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got.output[..], &[1.0, 2.0]);
        }
        let s = co.stats();
        assert_eq!(
            s,
            CoalesceStats {
                leaders: 2,
                followers: 2,
                fanned_ok: 2,
                fanned_err: 0,
                ..CoalesceStats::default()
            }
        );
        // The key is free again: the next identical request leads.
        let (ntx, _nrx) = chan();
        assert!(matches!(co.attach_or_lead(key, Priority::Standard, &ntx), Attach::Lead(_)));
    }

    #[test]
    fn more_urgent_duplicate_upgrades_the_flight_instead_of_soloing() {
        let co = Coalescer::new();
        let (btx, _brx) = chan();
        let flight = match co.attach_or_lead(7, Priority::Batch, &btx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        // Interactive behind a Batch leader: enrolled, flight lifted.
        let (itx, irx) = chan();
        match co.attach_or_lead(7, Priority::Interactive, &itx) {
            Attach::FollowUpgraded(f) => assert!(Arc::ptr_eq(&f, &flight)),
            _ => panic!("urgent duplicate must upgrade-and-follow"),
        }
        assert_eq!(co.stats().upgrades, 1);
        // The flight now reads as Interactive-led: a later Interactive
        // duplicate follows plainly, no second upgrade.
        let (i2tx, _i2rx) = chan();
        assert!(matches!(co.attach_or_lead(7, Priority::Interactive, &i2tx), Attach::Follow));
        assert_eq!(co.stats().upgrades, 1);
        // Both enrolled senders resolve on the fan like any follower.
        co.fan_err(&flight, &FleetError::Exhausted { attempts: 1 });
        assert!(matches!(irx.recv().unwrap(), Err(FleetError::Exhausted { .. })));
        assert_eq!(co.stats().fanned_err, 2);
        // The same-or-more-urgent leader composition still coalesces
        // without an upgrade: Interactive leader, Batch follower.
        let (ltx, _lrx) = chan();
        assert!(matches!(co.attach_or_lead(9, Priority::Interactive, &ltx), Attach::Lead(_)));
        let (stx, _srx) = chan();
        assert!(matches!(co.attach_or_lead(9, Priority::Batch, &stx), Attach::Follow));
    }

    #[test]
    fn full_flight_overflows_to_solo() {
        let co = Coalescer::new();
        let (ltx, _lrx) = chan();
        let flight = match co.attach_or_lead(3, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        let mut rxs = Vec::new();
        for _ in 0..MAX_FOLLOWERS_PER_FLIGHT {
            let (tx, rx) = chan();
            assert!(matches!(co.attach_or_lead(3, Priority::Standard, &tx), Attach::Follow));
            rxs.push(rx);
        }
        // One past the cap: solo, not enrolled — and an Interactive
        // duplicate cannot upgrade a full flight either.
        let (otx, orx) = chan();
        assert!(matches!(co.attach_or_lead(3, Priority::Standard, &otx), Attach::Solo));
        assert!(matches!(co.attach_or_lead(3, Priority::Interactive, &otx), Attach::Solo));
        assert_eq!(co.stats().overflow, 2);
        assert_eq!(co.stats().followers, MAX_FOLLOWERS_PER_FLIGHT as u64);
        co.fan_err(&flight, &FleetError::Exhausted { attempts: 0 });
        for rx in &rxs {
            assert!(rx.recv().unwrap().is_err());
        }
        assert!(orx.try_recv().is_err(), "overflow sender was never enrolled");
    }

    #[test]
    fn standalone_flight_races_first_terminal_outcome_wins() {
        // The hedge shape: a free-standing flight, the caller's sender
        // as its only follower, two legs racing to finish it.
        let co = Coalescer::new();
        let flight = Flight::standalone(Priority::Standard);
        assert!(flight.board().is_none());
        flight.note_board(1);
        assert_eq!(flight.board(), Some(1));
        let (caller_tx, caller_rx) = chan();
        assert!(co.enroll_follower(&flight, &caller_tx));
        assert!(!flight.is_done());
        // Winner leg finishes and fans.
        let followers = co.finish(&flight);
        assert_eq!(followers.len(), 1);
        co.note_fanned_ok(followers.len() as u64);
        for tx in followers {
            let _ = tx.send(Ok(reply(vec![3.0])));
        }
        assert_eq!(&caller_rx.recv().unwrap().unwrap().output[..], &[3.0]);
        // Loser leg sees Done, gets nothing to fan, cannot re-enrol.
        assert!(flight.is_done());
        assert_eq!(co.finish(&flight).len(), 0);
        let (late_tx, _late_rx) = chan();
        assert!(!co.enroll_follower(&flight, &late_tx));
        assert!(caller_rx.try_recv().is_err(), "exactly one outcome for the caller");
    }

    #[test]
    fn fan_err_resolves_followers_with_the_typed_error() {
        let co = Coalescer::new();
        let (ltx, _lrx) = chan();
        let flight = match co.attach_or_lead(1, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        let (ftx, frx) = chan();
        assert!(matches!(co.attach_or_lead(1, Priority::Standard, &ftx), Attach::Follow));
        co.fan_err(&flight, &FleetError::Exhausted { attempts: 4 });
        match frx.recv().unwrap() {
            Err(FleetError::Exhausted { attempts }) => assert_eq!(attempts, 4),
            Ok(_) => panic!("follower got a reply from a failed leader"),
        }
        assert!(frx.try_recv().is_err(), "exactly one outcome");
        assert_eq!(co.stats().fanned_err, 1);
    }

    #[test]
    fn finish_is_once_only_and_a_stale_finish_cannot_evict_a_successor() {
        let co = Coalescer::new();
        let (tx, _rx) = chan();
        let f1 = match co.attach_or_lead(5, Priority::Standard, &tx) {
            Attach::Lead(f) => f,
            _ => panic!("must lead"),
        };
        let (ftx, _frx) = chan();
        assert!(matches!(co.attach_or_lead(5, Priority::Standard, &ftx), Attach::Follow));
        assert_eq!(co.finish(&f1).len(), 1);
        assert_eq!(co.finish(&f1).len(), 0, "followers snapshot exactly once");
        // A successor takes over the key; finishing the stale flight
        // again must not deregister it.
        let f2 = match co.attach_or_lead(5, Priority::Standard, &tx) {
            Attach::Lead(f) => f,
            _ => panic!("successor must lead"),
        };
        assert_eq!(co.finish(&f1).len(), 0);
        let (gtx, _grx) = chan();
        assert!(
            matches!(co.attach_or_lead(5, Priority::Standard, &gtx), Attach::Follow),
            "successor flight must survive the stale finish"
        );
        assert_eq!(co.finish(&f2).len(), 1);
    }
}
