//! Seeded, replayable fault injection for the serving plane.
//!
//! A [`ChaosSpec`] is a compact, `Copy` description of what should go
//! wrong (parsed from the CLI `fleet --chaos <spec> --chaos-seed N`);
//! [`FaultPlan::materialize`] resolves it against a concrete
//! [`Registry`](super::registry::Registry) into per-replica
//! [`ReplicaFaults`] schedules — picking the *fastest* replica as the
//! kill victim when asked, but never a task's last replica, so a plan
//! can always be survived.  Each faulted replica's executor is wrapped
//! in a [`ChaosExecutor`], which perturbs the batch boundary only:
//!
//! - **transient exec errors** — each batch fails with probability
//!   `exec=P` (seeded per replica, so runs replay exactly);
//! - **permanent death** — `kill=ID@B` / `kill=fastest@B` makes every
//!   batch from the victim's `B`-th on return an error, forever (the
//!   worker keeps draining; health ejects it);
//! - **latency inflation** — `slow=FxID` stretches the victim's device
//!   hold by `F` (a brownout the drift accumulator can see);
//! - **queue-stall windows** — `stall=US@EVERY` freezes every replica
//!   for `US` µs on every `EVERY`-th batch;
//! - **worker panic** — `panic=ID@B` panics *inside* `execute` on the
//!   victim's `B`-th batch (the worker's `catch_unwind` converts it
//!   into a failed batch — the thread itself must survive).
//!
//! Injection happens entirely behind [`BatchExecutor`], so the worker
//! loop, the router, and the recovery machinery are exercised exactly
//! as a real device failure would exercise them.

use super::registry::Registry;
use super::worker::{precise_sleep, DataflowTiming};
use crate::coordinator::engine::BatchExecutor;
use crate::data::prng::SplitMix64;
use crate::error::{bail, Result};
use std::time::Duration;

/// Which replica a targeted fault hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Victim {
    /// The registry's fastest eligible replica (smallest `ii_s` whose
    /// task keeps at least one other replica) — resolved at
    /// [`FaultPlan::materialize`] time.
    Fastest,
    /// An explicit instance id.
    Replica(usize),
}

/// Compact, `Copy` fault description (rides inside
/// [`FleetConfig`](super::FleetConfig), which stays `Copy`).
/// Parse one from the CLI grammar with [`ChaosSpec::parse`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosSpec {
    /// Per-batch transient execute-failure probability on every replica
    /// (`exec=P`, 0.0 = off).
    pub exec_fail_p: f64,
    /// Permanently kill this victim after it has executed `kill_after`
    /// batches (`kill=ID@B` / `kill=fastest@B`).
    pub kill: Option<Victim>,
    /// Batch index (1-based) from which the kill victim fails forever.
    pub kill_after: u64,
    /// Inflate this victim's device hold by `slow_factor`
    /// (`slow=FxID`).
    pub slow: Option<usize>,
    /// Device-hold inflation factor for the slow victim (> 1.0).
    pub slow_factor: f64,
    /// Extra µs of stall injected on stall batches (`stall=US@EVERY`).
    pub stall_us: u64,
    /// Every `stall_every`-th batch on every replica stalls (0 = off).
    pub stall_every: u64,
    /// Panic inside `execute` on this victim's `panic_after`-th batch
    /// (`panic=ID@B`); repeats every batch after, like a kill that
    /// unwinds instead of erroring.
    pub panic_on: Option<usize>,
    /// Batch index (1-based) from which the panic victim unwinds.
    pub panic_after: u64,
    /// Root seed; each replica derives its own stream, so runs replay.
    pub seed: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            exec_fail_p: 0.0,
            kill: None,
            kill_after: 1,
            slow: None,
            slow_factor: 1.0,
            stall_us: 0,
            stall_every: 0,
            panic_on: None,
            panic_after: 1,
            seed: 0,
        }
    }
}

impl ChaosSpec {
    /// Parse the CLI grammar: comma-separated clauses out of
    /// `exec=P`, `kill=ID@B`, `kill=fastest@B`, `slow=FxID`,
    /// `stall=US@EVERY`, `panic=ID@B`.  Example:
    /// `exec=0.05,kill=fastest@40,stall=500@16`.
    pub fn parse(spec: &str, seed: u64) -> Result<ChaosSpec> {
        let mut out = ChaosSpec { seed, ..ChaosSpec::default() };
        for clause in spec.split(',').filter(|c| !c.is_empty()) {
            let Some((key, val)) = clause.split_once('=') else {
                bail!("chaos clause '{clause}' is not key=value");
            };
            match key {
                "exec" => {
                    let p: f64 = val
                        .parse()
                        .map_err(|_| crate::error::anyhow!("bad exec prob '{val}'"))?;
                    if !(0.0..=1.0).contains(&p) {
                        bail!("exec probability {p} outside [0, 1]");
                    }
                    out.exec_fail_p = p;
                }
                "kill" => {
                    let (who, at) = split_at_clause(val, clause)?;
                    out.kill = Some(if who == "fastest" {
                        Victim::Fastest
                    } else {
                        Victim::Replica(parse_usize(who, clause)?)
                    });
                    out.kill_after = at.max(1);
                }
                "slow" => {
                    let Some((f, id)) = val.split_once('x') else {
                        bail!("slow clause '{clause}' is not FACTORxID");
                    };
                    let factor: f64 = f
                        .parse()
                        .map_err(|_| crate::error::anyhow!("bad slow factor '{f}'"))?;
                    if factor <= 1.0 {
                        bail!("slow factor {factor} must exceed 1.0");
                    }
                    out.slow = Some(parse_usize(id, clause)?);
                    out.slow_factor = factor;
                }
                "stall" => {
                    let (us, every) = split_at_clause(val, clause)?;
                    out.stall_us = parse_usize(us, clause)? as u64;
                    out.stall_every = every.max(1);
                }
                "panic" => {
                    let (who, at) = split_at_clause(val, clause)?;
                    out.panic_on = Some(parse_usize(who, clause)?);
                    out.panic_after = at.max(1);
                }
                other => bail!("unknown chaos clause '{other}'"),
            }
        }
        Ok(out)
    }

    /// True when the spec injects nothing.
    pub fn is_noop(&self) -> bool {
        self.exec_fail_p == 0.0
            && self.kill.is_none()
            && self.slow.is_none()
            && self.stall_every == 0
            && self.panic_on.is_none()
    }
}

/// `VAL@N` → (`VAL`, `N`); the `@N` part is required.
fn split_at_clause<'a>(val: &'a str, clause: &str) -> Result<(&'a str, u64)> {
    let Some((v, at)) = val.split_once('@') else {
        bail!("chaos clause '{clause}' is missing '@N'");
    };
    let n: u64 = at
        .parse()
        .map_err(|_| crate::error::anyhow!("bad batch count '{at}' in '{clause}'"))?;
    Ok((v, n))
}

fn parse_usize(v: &str, clause: &str) -> Result<usize> {
    v.parse()
        .map_err(|_| crate::error::anyhow!("bad replica id '{v}' in '{clause}'"))
}

/// The schedule one faulted replica's [`ChaosExecutor`] follows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaFaults {
    /// Per-batch transient failure probability.
    pub exec_fail_p: f64,
    /// Fail every batch from this 1-based batch index on (permanent
    /// death).
    pub kill_after: Option<u64>,
    /// Device-hold inflation factor (1.0 = none).
    pub slow_factor: f64,
    /// Extra stall per stall batch, µs.
    pub stall_us: u64,
    /// Every `stall_every`-th batch stalls (0 = off).
    pub stall_every: u64,
    /// Panic on every batch from this 1-based batch index on.
    pub panic_after: Option<u64>,
    /// Per-replica seed (derived from the spec seed + replica id).
    pub seed: u64,
}

impl ReplicaFaults {
    fn is_noop(&self) -> bool {
        self.exec_fail_p == 0.0
            && self.kill_after.is_none()
            && self.slow_factor <= 1.0
            && self.stall_every == 0
            && self.panic_after.is_none()
    }
}

/// A [`ChaosSpec`] resolved against a concrete registry: one optional
/// fault schedule per replica slot.  Replicas added *after*
/// materialization (autoscale, recovery) get no faults — new hardware
/// is healthy.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Option<ReplicaFaults>>,
}

impl FaultPlan {
    /// Resolve `spec` against `reg`.  `kill=fastest` picks the replica
    /// with the smallest `ii_s` whose task keeps at least one other
    /// replica (so the plan is survivable); errors when a targeted
    /// victim id does not exist or when no kill victim is eligible.
    pub fn materialize(spec: &ChaosSpec, reg: &Registry) -> Result<FaultPlan> {
        let kill_victim = match spec.kill {
            None => None,
            Some(Victim::Replica(id)) => {
                if id >= reg.len() {
                    bail!("chaos kill victim {id} does not exist");
                }
                Some(id)
            }
            Some(Victim::Fastest) => {
                let victim = reg
                    .instances
                    .iter()
                    .filter(|i| {
                        reg.instances.iter().any(|j| j.task == i.task && j.id != i.id)
                    })
                    .min_by(|a, b| a.ii_s.total_cmp(&b.ii_s))
                    .map(|i| i.id);
                let Some(v) = victim else {
                    bail!("chaos kill=fastest: no task has a second replica to survive on");
                };
                Some(v)
            }
        };
        for &(who, id) in &[("slow", spec.slow), ("panic", spec.panic_on)] {
            if let Some(id) = id {
                if id >= reg.len() {
                    bail!("chaos {who} victim {id} does not exist");
                }
            }
        }
        let faults = (0..reg.len())
            .map(|id| {
                let f = ReplicaFaults {
                    exec_fail_p: spec.exec_fail_p,
                    kill_after: (kill_victim == Some(id)).then_some(spec.kill_after),
                    slow_factor: if spec.slow == Some(id) {
                        spec.slow_factor
                    } else {
                        1.0
                    },
                    stall_us: spec.stall_us,
                    stall_every: spec.stall_every,
                    panic_after: (spec.panic_on == Some(id)).then_some(spec.panic_after),
                    // SplitMix64 decorrelates consecutive seeds, so
                    // seed + id gives each replica its own stream.
                    seed: spec.seed.wrapping_add(id as u64),
                };
                (!f.is_noop()).then_some(f)
            })
            .collect();
        Ok(FaultPlan { faults })
    }

    /// The fault schedule for replica `id` (`None` = healthy, including
    /// every id past the materialized registry).
    pub fn for_replica(&self, id: usize) -> Option<ReplicaFaults> {
        self.faults.get(id).copied().flatten()
    }

    /// The resolved kill victim, if the plan has one.
    pub fn kill_victim(&self) -> Option<usize> {
        self.faults
            .iter()
            .position(|f| f.map(|f| f.kill_after.is_some()).unwrap_or(false))
    }
}

/// [`BatchExecutor`] wrapper that injects one replica's faults at the
/// batch boundary, then delegates.  Capacity and shape queries pass
/// through untouched, so the worker's staging is identical with chaos
/// on or off.
pub struct ChaosExecutor<E> {
    inner: E,
    faults: ReplicaFaults,
    /// The replica's own timing model — sizes the slowdown hold so
    /// `slow=F` means "F× the flow-predicted device time", matching
    /// what a browned-out board would measure.
    timing: DataflowTiming,
    rng: SplitMix64,
    batches: u64,
}

impl<E> ChaosExecutor<E> {
    pub fn new(inner: E, faults: ReplicaFaults, timing: DataflowTiming) -> Self {
        let rng = SplitMix64::new(faults.seed);
        ChaosExecutor { inner, faults, timing, rng, batches: 0 }
    }

    /// Batches seen so far (tests).
    pub fn batches(&self) -> u64 {
        self.batches
    }
}

impl<E: BatchExecutor> BatchExecutor for ChaosExecutor<E> {
    fn device_batch(&mut self) -> Result<usize> {
        self.inner.device_batch()
    }

    fn input_elems(&self) -> usize {
        self.inner.input_elems()
    }

    fn num_outputs(&self) -> usize {
        self.inner.num_outputs()
    }

    fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
        self.batches += 1;
        let b = self.batches;
        if let Some(at) = self.faults.panic_after {
            if b >= at {
                // The worker's catch_unwind turns this into a failed
                // batch; the executor stays usable for the next one.
                panic!("chaos: injected worker panic at batch {b}");
            }
        }
        if let Some(at) = self.faults.kill_after {
            if b >= at {
                bail!("chaos: replica dead since batch {at}");
            }
        }
        if self.faults.stall_every > 0 && b % self.faults.stall_every == 0 {
            precise_sleep(Duration::from_micros(self.faults.stall_us));
        }
        if self.faults.exec_fail_p > 0.0 && self.rng.next_f64() < self.faults.exec_fail_p
        {
            bail!("chaos: transient execute failure at batch {b}");
        }
        if self.faults.slow_factor > 1.0 {
            // The inner executor holds 1× already; add the excess.
            let extra_s = self.timing.batch_device_s(n)
                * self.timing.time_scale
                * (self.faults.slow_factor - 1.0);
            if extra_s > 0.0 {
                precise_sleep(Duration::from_secs_f64(extra_s));
            }
        }
        self.inner.execute(x, n, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::BoardInstance;
    use crate::fleet::worker::SimBoardExecutor;

    fn two_task_registry() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 300.0, 60.0, 1.8),
                BoardInstance::synthetic(2, "ad", 40.0, 5.0, 1.5),
            ],
        }
    }

    #[test]
    fn parse_full_grammar() {
        let s = ChaosSpec::parse("exec=0.25,kill=fastest@40,slow=2.5x1,stall=500@16,panic=2@10", 7)
            .unwrap();
        assert_eq!(s.exec_fail_p, 0.25);
        assert_eq!(s.kill, Some(Victim::Fastest));
        assert_eq!(s.kill_after, 40);
        assert_eq!(s.slow, Some(1));
        assert_eq!(s.slow_factor, 2.5);
        assert_eq!((s.stall_us, s.stall_every), (500, 16));
        assert_eq!((s.panic_on, s.panic_after), (Some(2), 10));
        assert_eq!(s.seed, 7);
        assert_eq!(
            ChaosSpec::parse("kill=3@12", 0).unwrap().kill,
            Some(Victim::Replica(3))
        );
        for bad in [
            "nope=1",
            "exec=2.0",
            "kill=fastest",
            "slow=0.5x1",
            "slow=2.0",
            "stall=500",
            "exec",
        ] {
            assert!(ChaosSpec::parse(bad, 0).is_err(), "{bad} should fail");
        }
        assert!(ChaosSpec::parse("", 0).unwrap().is_noop());
    }

    #[test]
    fn materialize_targets_fastest_with_surviving_sibling() {
        let reg = two_task_registry();
        // Fastest overall is the lone ad replica (ii 5 µs) — ineligible
        // (its task would not survive); the kws pair's fast half wins.
        let spec = ChaosSpec::parse("kill=fastest@4", 1).unwrap();
        let plan = FaultPlan::materialize(&spec, &reg).unwrap();
        assert_eq!(plan.kill_victim(), Some(0));
        assert_eq!(plan.for_replica(0).unwrap().kill_after, Some(4));
        assert!(plan.for_replica(1).is_none());
        assert!(plan.for_replica(2).is_none());
        assert!(plan.for_replica(99).is_none(), "future replicas are healthy");
        // No second replica anywhere -> no eligible victim.
        let lone = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5)],
        };
        assert!(FaultPlan::materialize(&spec, &lone).is_err());
        // Targeted victims must exist.
        let bad = ChaosSpec::parse("kill=9@1", 0).unwrap();
        assert!(FaultPlan::materialize(&bad, &reg).is_err());
        assert!(FaultPlan::materialize(
            &ChaosSpec::parse("slow=2x9", 0).unwrap(),
            &reg
        )
        .is_err());
    }

    #[test]
    fn chaos_executor_replays_by_seed_and_kills_permanently() {
        let run = |seed: u64| -> Vec<bool> {
            let faults = ReplicaFaults {
                exec_fail_p: 0.4,
                kill_after: None,
                slow_factor: 1.0,
                stall_us: 0,
                stall_every: 0,
                panic_after: None,
                seed,
            };
            let mut e = ChaosExecutor::new(
                SimBoardExecutor::for_task("kws"),
                faults,
                DataflowTiming::OFF,
            );
            let x = vec![0.1f32; e.input_elems()];
            let mut out = vec![0.0f32; e.num_outputs()];
            (0..32).map(|_| e.execute(&x, 1, &mut out).is_ok()).collect()
        };
        assert_eq!(run(7), run(7), "same seed must replay exactly");
        assert_ne!(run(7), run(8), "different seeds must differ");
        assert!(run(7).iter().any(|ok| !ok), "p=0.4 over 32 batches must fail some");
        assert!(run(7).iter().any(|ok| *ok), "p=0.4 over 32 batches must pass some");

        let faults = ReplicaFaults {
            exec_fail_p: 0.0,
            kill_after: Some(3),
            slow_factor: 1.0,
            stall_us: 0,
            stall_every: 0,
            panic_after: None,
            seed: 0,
        };
        let mut e = ChaosExecutor::new(
            SimBoardExecutor::for_task("kws"),
            faults,
            DataflowTiming::OFF,
        );
        let x = vec![0.1f32; e.input_elems()];
        let mut out = vec![0.0f32; e.num_outputs()];
        assert!(e.execute(&x, 1, &mut out).is_ok());
        assert!(e.execute(&x, 1, &mut out).is_ok());
        for _ in 0..4 {
            assert!(e.execute(&x, 1, &mut out).is_err(), "dead stays dead");
        }
    }

    #[test]
    fn injected_panic_is_catchable_and_repeats() {
        let faults = ReplicaFaults {
            exec_fail_p: 0.0,
            kill_after: None,
            slow_factor: 1.0,
            stall_us: 0,
            stall_every: 0,
            panic_after: Some(2),
            seed: 0,
        };
        let mut e = ChaosExecutor::new(
            SimBoardExecutor::for_task("ad"),
            faults,
            DataflowTiming::OFF,
        );
        let x = vec![0.1f32; e.input_elems()];
        let mut out = vec![0.0f32; e.num_outputs()];
        assert!(e.execute(&x, 1, &mut out).is_ok());
        for _ in 0..2 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e.execute(&x, 1, &mut out)
            }));
            assert!(r.is_err(), "batch >= 2 must unwind");
        }
    }
}
