//! Tail-latency hedging and deadline bookkeeping.
//!
//! **Why hedge.**  The paper's boards answer in tens of µs, so fleet
//! tail latency is dominated not by execution but by *where* a request
//! queues: one browned-out replica (chaos `slow=4x`, thermal
//! throttling) puts every request routed to it 4× over budget while
//! its siblings sit idle.  Health ejection (PR 7) eventually removes
//! such a replica, but ejection is permanent and deliberately slow to
//! trip; hedging covers the window before it — and the brownouts that
//! never get bad enough to eject.
//!
//! **Decision rule.**  The [`HedgeController`] keeps one log2 span
//! histogram per request class of *observed* queue-wait + execute time
//! (fed from the same sampled [`super::trace::TraceCtx`] lifecycle
//! spans PR 6 added) and a per-board **drift ratio** (observed /
//! flow-predicted execute time, EWMA).  At submit, after routing picks
//! board `i`, the fleet estimates this request's completion as
//!
//! ```text
//! est_i = drift_i × (latency_us[i] + depth_i × ii_us[i]) × time_scale
//! ```
//!
//! — the rule4ml-style flow estimate the router already uses, corrected
//! by how far board `i`'s reality has drifted from it.  If `est_i`
//! exceeds `hedge_p99 ×` the class's observed p99 span (and a same-task
//! sibling is admittable), the request is **hedged**: a duplicate leg
//! is queued on the best sibling through a standalone coalesce
//! [`Flight`](super::coalesce::Flight) carrying the caller's reply
//! sender as its only follower.  The first leg to reach a terminal
//! outcome fans it to the caller; the loser finds the flight `Done` at
//! its next stage boundary (dequeue or window-close) and discards
//! itself without executing.  Exactly-one-outcome is inherited from the
//! flight machinery: `finish`/`fan_err` resolve each enrolled sender
//! exactly once, and both legs' own channels are throwaways.
//!
//! The observed-p99 threshold is self-stabilizing: hedge losers never
//! execute, so a brownout's slow spans stop polluting the histogram as
//! soon as hedging starts winning, keeping the threshold anchored to
//! healthy-sibling latency rather than chasing the degraded tail.
//!
//! **Deadline counters.**  [`DeadlineStats`] is the fleet-wide ledger
//! of the deadline plane: how many expired requests were refused at
//! submit, discarded at dequeue / window-close / retry, and — the
//! invariant the scenario bench pins at zero — how many expired
//! requests a worker ever *committed to execute*.

use super::queue::{Priority, N_CLASSES};
use super::trace::StageHistogram;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Spans needed in a class histogram before its p99 is trusted for
/// hedge decisions (startup noise makes tiny samples swing wildly).
pub const MIN_SEED_SPANS: u64 = 8;

/// Fixed-point scale for the per-board drift ratio atomics.
const MILLI: f64 = 1000.0;

/// EWMA weight of each new batch's drift observation (1/8: a browned
/// out board crosses the hedge threshold within a handful of batches,
/// one outlier batch does not).
const DRIFT_ALPHA: f64 = 0.125;

/// Per-class observed-span seed and per-board drift state behind the
/// hedge decision, plus the hedge counters.  One instance per fleet,
/// shared by the submit path (decisions) and the workers (feeding
/// spans/drift, counting cancelled losers).
pub struct HedgeController {
    /// `hedge_p99` from the config: hedge when the drift-corrected flow
    /// estimate exceeds this multiple of the class's observed p99.
    factor: f64,
    spans: [Mutex<StageHistogram>; N_CLASSES],
    /// Cached p99 per class (µs), updated on every span record so the
    /// submit path reads one relaxed atomic instead of locking.
    p99_us: [AtomicU64; N_CLASSES],
    /// Per-board drift ratio EWMA, milli fixed-point (1000 = on-model).
    drift_milli: Mutex<HashMap<usize, u64>>,
    hedged: AtomicU64,
    cancelled: AtomicU64,
    wins: AtomicU64,
}

impl HedgeController {
    pub fn new(factor: f64) -> Self {
        HedgeController {
            factor,
            spans: Default::default(),
            p99_us: Default::default(),
            drift_milli: Mutex::new(HashMap::new()),
            hedged: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            wins: AtomicU64::new(0),
        }
    }

    /// Feed one sampled request's observed queue-wait + execute span.
    pub fn note_span(&self, class: Priority, span_us: u64) {
        let mut h = self.spans[class.idx()].lock().unwrap();
        h.record(span_us);
        if h.count >= MIN_SEED_SPANS {
            let p99 = h.percentile_us(0.99) as u64;
            self.p99_us[class.idx()].store(p99.max(1), Ordering::Relaxed);
        }
    }

    /// The class's observed p99 span, once seeded.
    pub fn p99_of(&self, class: Priority) -> Option<u64> {
        match self.p99_us[class.idx()].load(Ordering::Relaxed) {
            0 => None,
            us => Some(us),
        }
    }

    /// Fold one batch's flow-predicted vs observed execute time into
    /// board `i`'s drift ratio (worker, per traced batch).
    pub fn note_drift(&self, board: usize, pred_us: f64, obs_us: u64) {
        if pred_us <= 0.0 {
            return;
        }
        let sample = (obs_us as f64 / pred_us).clamp(0.01, 1000.0);
        let mut map = self.drift_milli.lock().unwrap();
        let cur = *map.get(&board).unwrap_or(&(MILLI as u64)) as f64 / MILLI;
        let next = cur + DRIFT_ALPHA * (sample - cur);
        map.insert(board, (next * MILLI) as u64);
    }

    /// Board `i`'s drift-corrected multiplier (1.0 = on-model).
    pub fn drift_ratio(&self, board: usize) -> f64 {
        let map = self.drift_milli.lock().unwrap();
        *map.get(&board).unwrap_or(&(MILLI as u64)) as f64 / MILLI
    }

    /// The submit-path decision: hedge when the drift-corrected flow
    /// estimate for the assigned board crosses `factor ×` the class's
    /// observed p99.  Always `false` until the class histogram seeds —
    /// an unseeded fleet must not hedge on noise.
    pub fn should_hedge(&self, class: Priority, est_us: f64) -> bool {
        match self.p99_of(class) {
            Some(p99) => est_us > self.factor * p99 as f64,
            None => false,
        }
    }

    /// A duplicate leg was actually queued.
    pub fn note_hedged(&self) {
        self.hedged.fetch_add(1, Ordering::Relaxed);
    }

    /// A losing leg found its flight `Done` at a stage boundary and was
    /// discarded without executing.
    pub fn note_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// A hedge flight was resolved by one of its legs (the caller got
    /// an outcome through the race).
    pub fn note_win(&self) {
        self.wins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> HedgeStats {
        HedgeStats {
            hedged: self.hedged.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            wins: self.wins.load(Ordering::Relaxed),
        }
    }
}

/// Hedge counters for the snapshot JSON / `hedge:` machine line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HedgeStats {
    /// Duplicate legs queued.
    pub hedged: u64,
    /// Losing legs discarded at a stage boundary without executing.
    pub cancelled: u64,
    /// Hedge flights resolved (caller reached through the race).
    pub wins: u64,
}

/// Fleet-wide deadline ledger.  `shed_submit` mirrors the
/// `ShedReason::Deadline` telemetry counter (kept here too so the
/// `deadline:` machine line needs one source); the `expired_*` counters
/// split discards by the stage that caught them; `executed_expired`
/// counts expired requests a worker *committed to execute* at
/// window-close — the deadline plane's correctness headline keeps it at
/// zero.
#[derive(Default)]
pub struct DeadlineStats {
    pub shed_submit: AtomicU64,
    pub expired_dequeue: AtomicU64,
    pub expired_window: AtomicU64,
    pub expired_retry: AtomicU64,
    pub executed_expired: AtomicU64,
}

impl DeadlineStats {
    pub fn snapshot(&self) -> DeadlineSnapshot {
        DeadlineSnapshot {
            shed_submit: self.shed_submit.load(Ordering::Relaxed),
            expired_dequeue: self.expired_dequeue.load(Ordering::Relaxed),
            expired_window: self.expired_window.load(Ordering::Relaxed),
            expired_retry: self.expired_retry.load(Ordering::Relaxed),
            executed_expired: self.executed_expired.load(Ordering::Relaxed),
        }
    }

    /// Total requests resolved `DeadlineExceeded` after admission
    /// (dequeue + window + retry discards).
    pub fn expired_total(&self) -> u64 {
        self.expired_dequeue.load(Ordering::Relaxed)
            + self.expired_window.load(Ordering::Relaxed)
            + self.expired_retry.load(Ordering::Relaxed)
    }
}

/// Point-in-time copy of [`DeadlineStats`] for the snapshot JSON and
/// the `deadline:` machine line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeadlineSnapshot {
    pub shed_submit: u64,
    pub expired_dequeue: u64,
    pub expired_window: u64,
    pub expired_retry: u64,
    pub executed_expired: u64,
}

impl DeadlineSnapshot {
    /// Any deadline-plane activity at all (drives snapshot rendering).
    pub fn any(&self) -> bool {
        *self != DeadlineSnapshot::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hedge_decision_waits_for_seed_then_tracks_p99() {
        let hc = HedgeController::new(2.0);
        assert!(!hc.should_hedge(Priority::Standard, 1e9), "unseeded: never hedge");
        for _ in 0..MIN_SEED_SPANS {
            hc.note_span(Priority::Standard, 100);
        }
        let p99 = hc.p99_of(Priority::Standard).expect("seeded");
        // Log2 buckets round up to the bucket edge; the decision uses
        // whatever edge the histogram reports.
        assert!(p99 >= 100);
        assert!(hc.should_hedge(Priority::Standard, 2.0 * p99 as f64 + 1.0));
        assert!(!hc.should_hedge(Priority::Standard, 2.0 * p99 as f64 - 1.0));
        // Classes are independent: Interactive is still unseeded.
        assert!(!hc.should_hedge(Priority::Interactive, 1e9));
    }

    #[test]
    fn drift_ratio_converges_toward_observations_and_decays_back() {
        let hc = HedgeController::new(2.0);
        assert!((hc.drift_ratio(0) - 1.0).abs() < 1e-9, "unseen board is on-model");
        // A 4x brownout: the EWMA climbs toward 4 within a few batches.
        for _ in 0..32 {
            hc.note_drift(0, 100.0, 400);
        }
        assert!(hc.drift_ratio(0) > 3.5, "ratio {} should approach 4", hc.drift_ratio(0));
        assert!((hc.drift_ratio(1) - 1.0).abs() < 1e-9, "boards are independent");
        // Recovery: back on model, the ratio decays toward 1.
        for _ in 0..48 {
            hc.note_drift(0, 100.0, 100);
        }
        assert!(hc.drift_ratio(0) < 1.2, "ratio {} should recover", hc.drift_ratio(0));
    }

    #[test]
    fn counters_and_deadline_ledger_accumulate() {
        let hc = HedgeController::new(1.5);
        hc.note_hedged();
        hc.note_hedged();
        hc.note_cancelled();
        hc.note_win();
        assert_eq!(hc.stats(), HedgeStats { hedged: 2, cancelled: 1, wins: 1 });
        let d = DeadlineStats::default();
        d.shed_submit.fetch_add(3, Ordering::Relaxed);
        d.expired_dequeue.fetch_add(2, Ordering::Relaxed);
        d.expired_window.fetch_add(1, Ordering::Relaxed);
        d.expired_retry.fetch_add(4, Ordering::Relaxed);
        let snap = d.snapshot();
        assert!(snap.any());
        assert_eq!(
            snap,
            DeadlineSnapshot {
                shed_submit: 3,
                expired_dequeue: 2,
                expired_window: 1,
                expired_retry: 4,
                executed_expired: 0,
            }
        );
        assert_eq!(d.expired_total(), 7);
        assert!(!DeadlineSnapshot::default().any());
    }
}
