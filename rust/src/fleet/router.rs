//! Request routing across board instances.
//!
//! The router is a *pure* decision function over the registry's static
//! cost model plus the instantaneous queue depths: given a task and the
//! current depths it returns a target instance or an admission-control
//! rejection.  Keeping it side-effect free makes the policies directly
//! property-testable (see `rust/tests/proptests.rs`); the fleet wires it
//! to the real [`super::queue::BoardQueue`] depths.
//!
//! Policies:
//! * **RoundRobin** — rotate over the task's replicas, skipping full
//!   queues.
//! * **LeastLoaded** — shortest queue first; ties break toward the
//!   faster replica (smaller steady-state interval).
//! * **EnergyAware** — cheapest µJ/inference replica that still has
//!   queue room (the codesign energy axis as a serving policy).
//! * **LatencySlo** — smallest *predicted* completion latency
//!   (`queue_depth × ii + batch-1 latency`, all from the dataflow
//!   estimates); rejects when even the best replica would blow the SLO.
//!
//! Selection is **class-aware** ([`Router::select_class`]): eligibility
//! uses the class's tiered admission bound ([`super::queue::admit_limit`]
//! — so the router doesn't bounce `Batch` requests off queues that would
//! refuse them anyway), load ordering and the SLO prediction run over the
//! *class-visible* backlog the caller passes (an `Interactive` request
//! jumps queued `Standard`/`Batch` work, so only the interactive backlog
//! is ahead of it), and `Batch` — which has no latency target — is
//! exempt from SLO shedding (depth admission sheds it instead).
//! [`Router::select`] is the untagged wrapper: `Standard` semantics over
//! the raw depths, the pre-priority behavior.

use super::queue::{admit_limit, Priority};
use super::registry::Registry;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    EnergyAware,
    /// Reject requests whose predicted latency exceeds `slo_us`.
    LatencySlo { slo_us: f64 },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::EnergyAware => "energy-aware",
            Policy::LatencySlo { .. } => "latency-slo",
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Policy::LatencySlo { slo_us } => write!(f, "latency-slo({slo_us} us)"),
            p => f.write_str(p.name()),
        }
    }
}

/// Why a request was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteError {
    /// No instance hosts this task's model.
    UnknownTask,
    /// Every eligible queue is at capacity (backpressure).
    Overloaded,
    /// Even the best replica's predicted latency exceeds the SLO.
    SloUnattainable,
    /// The input length does not match the task's feature dimension —
    /// a typed submit-side rejection (the worker keeps only a debug
    /// assertion; it never truncates or pads silently for tasks the
    /// submit path can validate).
    InvalidInput { expected: usize, got: usize },
    /// The flow-predicted completion on the chosen replica already
    /// misses the request's deadline at submit: refusing now is cheaper
    /// than queueing work every later stage would discard.
    DeadlineUnmeetable,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::UnknownTask => f.write_str("no board hosts this task"),
            RouteError::Overloaded => f.write_str("all eligible queues full"),
            RouteError::SloUnattainable => {
                f.write_str("predicted latency exceeds SLO on every replica")
            }
            RouteError::InvalidInput { expected, got } => {
                write!(f, "input length {got} does not match the task's feature dim {expected}")
            }
            RouteError::DeadlineUnmeetable => {
                f.write_str("flow-predicted completion already misses the request deadline")
            }
        }
    }
}

/// Policy-driven instance selector.
pub struct Router {
    policy: Policy,
    queue_cap: usize,
    /// Class-aware admission bounds + per-class SLO semantics; `false`
    /// restores the uniform single-FIFO behavior (every class treated
    /// as `Standard` with the full `queue_cap` bound).
    classful: bool,
    by_task: BTreeMap<String, Vec<usize>>,
    rr: BTreeMap<String, AtomicUsize>,
    latency_us: Vec<f64>,
    ii_us: Vec<f64>,
    energy_uj: Vec<f64>,
}

impl Router {
    pub fn new(reg: &Registry, policy: Policy, queue_cap: usize) -> Self {
        Self::with_active(reg, policy, queue_cap, &vec![true; reg.len()])
    }

    /// Router over the *live* subset of a registry: retired replicas keep
    /// their slots (cost vectors stay index-aligned with queues and
    /// telemetry) but are never candidates.  The fleet rebuilds the
    /// router on every membership change — construction is a handful of
    /// clones, and swapping an `Arc<Router>` atomically re-points every
    /// subsequent submit at the new replica set.
    pub fn with_active(
        reg: &Registry,
        policy: Policy,
        queue_cap: usize,
        active: &[bool],
    ) -> Self {
        Self::with_options(reg, policy, queue_cap, active, true)
    }

    /// [`Self::with_active`] with the queue mode made explicit:
    /// `classful = false` pairs the router with FIFO-compat queues
    /// (`FleetConfig::fifo_queues`) so eligibility matches what the
    /// queues will actually admit.
    pub fn with_options(
        reg: &Registry,
        policy: Policy,
        queue_cap: usize,
        active: &[bool],
        classful: bool,
    ) -> Self {
        let mut by_task: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for inst in &reg.instances {
            if active.get(inst.id).copied().unwrap_or(false) {
                by_task.entry(inst.task.clone()).or_default().push(inst.id);
            }
        }
        let rr = by_task.keys().map(|t| (t.clone(), AtomicUsize::new(0))).collect();
        Router {
            policy,
            queue_cap: queue_cap.max(1),
            classful,
            by_task,
            rr,
            latency_us: reg.instances.iter().map(|i| i.latency_s * 1e6).collect(),
            ii_us: reg.instances.iter().map(|i| i.ii_s * 1e6).collect(),
            energy_uj: reg.instances.iter().map(|i| i.energy_per_inference_uj).collect(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Predicted completion latency if one more request joins instance
    /// `i` behind `depth` queued ones (dataflow estimates, µs).
    pub fn predicted_latency_us(&self, i: usize, depth: usize) -> f64 {
        self.latency_us[i] + depth as f64 * self.ii_us[i]
    }

    /// Untagged pick: `Standard` semantics over the raw depths (the
    /// pre-priority behavior; `ahead == depths`).
    pub fn select(&self, task: &str, depths: &[usize]) -> Result<usize, RouteError> {
        self.select_class(task, depths, depths, Priority::Standard)
    }

    /// Pick a target instance for a `class`-tagged `task` request.
    /// `depths[i]` is the *total* queue depth in front of instance `i`
    /// (capacity signal, checked against the class's admission bound);
    /// `ahead[i]` is the backlog actually ahead of this class on `i`
    /// (load/SLO signal — the fleet passes the interactive depth for
    /// `Interactive`, interactive+standard for `Standard`, total for
    /// `Batch`).  The jump model is an approximation on the optimistic
    /// side: the anti-starvation guard and the DRR weights let a slice
    /// of lower-class work through (1/17 and 1/5 of pickups under
    /// saturation), and the batch currently *executing* is invisible to
    /// any depth signal — so a predicted latency can undershoot by on
    /// the order of one device window.  Pure: admission accounting is
    /// the caller's (the queue push is what commits).
    pub fn select_class(
        &self,
        task: &str,
        depths: &[usize],
        ahead: &[usize],
        class: Priority,
    ) -> Result<usize, RouteError> {
        let Some(cands) = self.by_task.get(task) else {
            return Err(RouteError::UnknownTask);
        };
        let limit = if self.classful {
            admit_limit(self.queue_cap, class)
        } else {
            self.queue_cap
        };
        let open: Vec<usize> =
            cands.iter().copied().filter(|&i| depths[i] < limit).collect();
        if open.is_empty() {
            return Err(RouteError::Overloaded);
        }
        match self.policy {
            Policy::RoundRobin => {
                // Rotate over *all* replicas so the cursor advances evenly,
                // then skip to the next open one.
                let start = self.rr[task].fetch_add(1, Ordering::Relaxed) % cands.len();
                for k in 0..cands.len() {
                    let i = cands[(start + k) % cands.len()];
                    if depths[i] < limit {
                        return Ok(i);
                    }
                }
                unreachable!("open was non-empty");
            }
            Policy::LeastLoaded => Ok(open
                .into_iter()
                .min_by(|&a, &b| {
                    ahead[a]
                        .cmp(&ahead[b])
                        .then(self.ii_us[a].total_cmp(&self.ii_us[b]))
                })
                .unwrap()),
            Policy::EnergyAware => Ok(open
                .into_iter()
                .min_by(|&a, &b| self.energy_uj[a].total_cmp(&self.energy_uj[b]))
                .unwrap()),
            Policy::LatencySlo { slo_us } => {
                let best = open
                    .into_iter()
                    .min_by(|&a, &b| {
                        self.predicted_latency_us(a, ahead[a])
                            .total_cmp(&self.predicted_latency_us(b, ahead[b]))
                    })
                    .unwrap();
                // Batch has no latency target: depth admission sheds it,
                // never the SLO check.
                let slo_exempt = self.classful && class == Priority::Batch;
                if !slo_exempt && self.predicted_latency_us(best, ahead[best]) > slo_us {
                    Err(RouteError::SloUnattainable)
                } else {
                    Ok(best)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::{BoardInstance, Registry};

    fn reg() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 100.0, 10.0, 1.5), // fast, 15 uJ
                BoardInstance::synthetic(1, "kws", 400.0, 80.0, 1.8), // slow, 144 uJ
                BoardInstance::synthetic(2, "ad", 50.0, 5.0, 1.5),
            ],
        }
    }

    #[test]
    fn unknown_task_rejected() {
        let r = Router::new(&reg(), Policy::RoundRobin, 4);
        assert_eq!(r.select("vww", &[0, 0, 0]), Err(RouteError::UnknownTask));
    }

    #[test]
    fn round_robin_alternates_and_skips_full() {
        let r = Router::new(&reg(), Policy::RoundRobin, 2);
        let a = r.select("kws", &[0, 0, 0]).unwrap();
        let b = r.select("kws", &[0, 0, 0]).unwrap();
        assert_ne!(a, b);
        // Board 0 full: everything lands on 1.
        assert_eq!(r.select("kws", &[2, 0, 0]).unwrap(), 1);
        assert_eq!(r.select("kws", &[2, 0, 0]).unwrap(), 1);
        // Both full: backpressure.
        assert_eq!(r.select("kws", &[2, 2, 0]), Err(RouteError::Overloaded));
    }

    #[test]
    fn least_loaded_prefers_short_queue_then_speed() {
        let r = Router::new(&reg(), Policy::LeastLoaded, 8);
        assert_eq!(r.select("kws", &[3, 1, 0]).unwrap(), 1);
        // Tie: the faster replica (smaller ii) wins.
        assert_eq!(r.select("kws", &[2, 2, 0]).unwrap(), 0);
    }

    #[test]
    fn energy_aware_prefers_cheap_board_until_full() {
        let r = Router::new(&reg(), Policy::EnergyAware, 2);
        assert_eq!(r.select("kws", &[0, 0, 0]).unwrap(), 0);
        assert_eq!(r.select("kws", &[1, 0, 0]).unwrap(), 0);
        assert_eq!(r.select("kws", &[2, 0, 0]).unwrap(), 1);
    }

    #[test]
    fn with_active_hides_retired_replicas() {
        // Retire the fast kws board: everything lands on the slow one;
        // retire both and the task is unknown to the router.
        let r = Router::with_active(
            &reg(),
            Policy::LeastLoaded,
            8,
            &[false, true, true],
        );
        assert_eq!(r.select("kws", &[0, 3, 0]).unwrap(), 1);
        assert_eq!(r.select("ad", &[0, 0, 0]).unwrap(), 2);
        let none = Router::with_active(
            &reg(),
            Policy::LeastLoaded,
            8,
            &[false, false, true],
        );
        assert_eq!(none.select("kws", &[0, 0, 0]), Err(RouteError::UnknownTask));
    }

    #[test]
    fn class_aware_admission_bounds_and_slo_exemption() {
        // cap 16: batch bound 8, standard bound 15, interactive 16.
        let r = Router::new(&reg(), Policy::LeastLoaded, 16);
        let depths = [10, 10, 0];
        // Batch bounces off queues past half capacity...
        assert_eq!(
            r.select_class("kws", &depths, &depths, Priority::Batch),
            Err(RouteError::Overloaded)
        );
        // ...while interactive still routes.
        assert!(r.select_class("kws", &depths, &depths, Priority::Interactive).is_ok());
        // Batch is never SLO-shed: depth admission is its only gate.
        let slo = Router::new(&reg(), Policy::LatencySlo { slo_us: 1.0 }, 64);
        let zeros = [0usize, 0, 0];
        assert_eq!(
            slo.select_class("kws", &zeros, &zeros, Priority::Standard),
            Err(RouteError::SloUnattainable)
        );
        assert!(slo.select_class("kws", &zeros, &zeros, Priority::Batch).is_ok());
        // FIFO-compat routers keep the uniform bound for every class.
        let fifo = Router::with_options(
            &reg(),
            Policy::LeastLoaded,
            16,
            &[true, true, true],
            false,
        );
        assert!(fifo.select_class("kws", &depths, &depths, Priority::Batch).is_ok());
    }

    #[test]
    fn interactive_orders_by_class_visible_backlog() {
        // Total depths favor board 1, but board 1 holds the interactive
        // backlog — least-loaded must order by `ahead`, not `depths`.
        let r = Router::new(&reg(), Policy::LeastLoaded, 64);
        let depths = [20, 10, 0]; // board 0 deep in batch work...
        let ahead = [0, 5, 0]; // ...but nothing ahead of an interactive
        assert_eq!(
            r.select_class("kws", &depths, &ahead, Priority::Interactive).unwrap(),
            0
        );
    }

    #[test]
    fn slo_routing_predicts_and_rejects() {
        let r = Router::new(&reg(), Policy::LatencySlo { slo_us: 300.0 }, 64);
        // Empty queues: fast board predicted 100 us — fine.
        assert_eq!(r.select("kws", &[0, 0, 0]).unwrap(), 0);
        // Fast board deep (100 + 30*10 = 400), slow empty (400): both
        // blow the 300 us SLO.
        assert_eq!(
            r.select("kws", &[30, 0, 0]),
            Err(RouteError::SloUnattainable)
        );
        // Deep fast board but generous SLO: prediction picks the smaller.
        let r2 = Router::new(&reg(), Policy::LatencySlo { slo_us: 10_000.0 }, 64);
        assert_eq!(r2.select("kws", &[35, 0, 0]).unwrap(), 1);
    }
}
