//! Multi-board serving plane: one coordinator, many simulated
//! accelerators.
//!
//! The paper deploys each MLPerf Tiny task on a *single* board and
//! measures µs-latency / µJ-energy per inference; this module lifts that
//! codesign envelope to fleet scope.  A [`registry::Registry`] enumerates
//! heterogeneous board instances (Pynq-Z2 / Arty A7-100T × KWS / AD / IC
//! × folding schedule), each carrying the latency, initiation-interval,
//! power, and energy numbers its codesign flow produced.  A
//! [`router::Router`] places every request on an instance under a
//! pluggable policy with admission control; bounded per-board queues give
//! backpressure; per-board worker threads batch through the same dynamic
//! window as the single-model engine, steal work from same-task replicas,
//! and hold the (simulated) accelerator for the dataflow-predicted device
//! time.  [`telemetry::Telemetry`] aggregates the result into fleet-level
//! p50/p99/throughput/energy.  An optional bounded [`cache::ResultCache`]
//! in front of the router memoizes (task, quantized-input) → output so
//! repeated requests skip the boards entirely, with hit/miss counters in
//! the snapshot.
//!
//! ```no_run
//! use tinyml_codesign::fleet::{Fleet, FleetConfig, Registry};
//!
//! let reg = Registry::standard_fleet().unwrap();
//! let fleet = Fleet::start(reg, FleetConfig::default()).unwrap();
//! let handle = fleet.handle();
//! let x = vec![0.0f32; 490];
//! let reply = handle.infer("kws", x).unwrap();
//! println!("top1 {} in {} us", reply.top1, reply.queue_us + reply.exec_us);
//! let summary = fleet.shutdown();
//! println!("{}", summary.render());
//! ```

pub mod cache;
pub mod registry;
pub mod router;
pub mod telemetry;
pub mod worker;

pub use cache::{CacheStats, ResultCache};
pub use registry::{BoardInstance, Registry};
pub use router::{Policy, RouteError, Router};
pub use telemetry::{FleetSnapshot, Telemetry};
pub use worker::{BoardQueue, FleetRequest, WorkerConfig};

use crate::coordinator::engine::{BatchPolicy, Reply};
use crate::error::{anyhow, Result};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Fleet-wide serving knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    pub policy: Policy,
    /// Bounded queue capacity per board (admission control past this).
    pub queue_cap: usize,
    /// Dynamic-batching window shared by every worker.
    pub batch: BatchPolicy,
    /// Wall-seconds per simulated device-second (stretch µs-class
    /// accelerator latencies so policy differences dominate thread
    /// overhead; 1.0 = real time).
    pub time_scale: f64,
    /// Let idle workers steal queued requests from same-task replicas.
    pub work_stealing: bool,
    /// Result-cache capacity in entries (0 = disabled).  When on,
    /// repeated (task, quantized-input) requests are answered in front
    /// of the router without touching a board; cache hits carry
    /// `batch_size == 0` in their [`Reply`].
    pub cache_cap: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            policy: Policy::LeastLoaded,
            queue_cap: 256,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
            time_scale: 1.0,
            work_stealing: true,
            cache_cap: 0,
        }
    }
}

/// A running fleet: workers + router + telemetry.
pub struct Fleet {
    registry: Registry,
    router: Arc<Router>,
    queues: Vec<Arc<BoardQueue>>,
    telemetry: Arc<Telemetry>,
    cache: Option<Arc<ResultCache>>,
    workers: Vec<std::thread::JoinHandle<u64>>,
}

impl Fleet {
    /// Spawn one worker thread per registry instance.
    pub fn start(registry: Registry, config: FleetConfig) -> Result<Fleet> {
        if registry.is_empty() {
            return Err(anyhow!("fleet registry is empty"));
        }
        // Queues, router cost tables, and telemetry are all indexed by
        // instance id; a hand-built registry with ids out of line would
        // route on the wrong board's cost model (or panic).
        for (pos, inst) in registry.instances.iter().enumerate() {
            if inst.id != pos {
                return Err(anyhow!(
                    "registry instance '{}' has id {} at position {pos}",
                    inst.label,
                    inst.id
                ));
            }
        }
        let router = Arc::new(Router::new(&registry, config.policy, config.queue_cap));
        let queues: Vec<Arc<BoardQueue>> = registry
            .instances
            .iter()
            .map(|_| Arc::new(BoardQueue::new(config.queue_cap)))
            .collect();
        let telemetry = Arc::new(Telemetry::new(registry.len()));
        let cache = (config.cache_cap > 0)
            .then(|| Arc::new(ResultCache::new(config.cache_cap)));
        let mut workers = Vec::new();
        for inst in &registry.instances {
            let inst = inst.clone();
            let own = queues[inst.id].clone();
            // Same-task replicas to steal from, skipping self.
            let peers: Vec<Arc<BoardQueue>> = registry
                .eligible(&inst.task)
                .into_iter()
                .filter(|&i| i != inst.id)
                .map(|i| queues[i].clone())
                .collect();
            let telemetry = telemetry.clone();
            let cache = cache.clone();
            let wcfg = WorkerConfig {
                batch: config.batch,
                time_scale: config.time_scale,
                work_stealing: config.work_stealing,
            };
            workers.push(std::thread::spawn(move || {
                worker::run_worker(&inst, &own, &peers, &wcfg, &telemetry, cache.as_deref())
            }));
        }
        Ok(Fleet { registry, router, queues, telemetry, cache, workers })
    }

    /// Cloneable submission handle.
    pub fn handle(&self) -> FleetHandle {
        FleetHandle {
            router: self.router.clone(),
            queues: self.queues.clone(),
            cache: self.cache.clone(),
        }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Current telemetry without stopping the fleet.
    pub fn snapshot(&self) -> FleetSnapshot {
        snapshot_with_cache(&self.telemetry, &self.registry, self.cache.as_deref())
    }

    /// Close every queue, drain, join workers, and return the final
    /// telemetry plus per-worker serve counts.
    pub fn shutdown(self) -> FleetSummary {
        for q in &self.queues {
            q.close();
        }
        let served_per_worker: Vec<u64> =
            self.workers.into_iter().map(|w| w.join().unwrap_or(0)).collect();
        FleetSummary {
            snapshot: snapshot_with_cache(
                &self.telemetry,
                &self.registry,
                self.cache.as_deref(),
            ),
            served_per_worker,
        }
    }
}

/// Telemetry snapshot with the result-cache counters grafted on (the
/// cache lives outside `Telemetry`, which stays per-board).
fn snapshot_with_cache(
    telemetry: &Telemetry,
    registry: &Registry,
    cache: Option<&ResultCache>,
) -> FleetSnapshot {
    let mut snap = telemetry.snapshot(registry);
    if let Some(c) = cache {
        snap.cache = c.stats();
    }
    snap
}

/// What [`Fleet::shutdown`] returns.
pub struct FleetSummary {
    pub snapshot: FleetSnapshot,
    pub served_per_worker: Vec<u64>,
}

impl FleetSummary {
    pub fn render(&self) -> String {
        self.snapshot.render()
    }
}

/// Clone-to-share submission side of a running fleet.
#[derive(Clone)]
pub struct FleetHandle {
    router: Arc<Router>,
    queues: Vec<Arc<BoardQueue>>,
    cache: Option<Arc<ResultCache>>,
}

impl FleetHandle {
    fn depths(&self) -> Vec<usize> {
        self.queues.iter().map(|q| q.depth()).collect()
    }

    /// Route + enqueue; returns the reply channel without blocking on
    /// execution.  Admission control surfaces as `Err(RouteError)`.
    /// With result caching on, a repeated (task, quantized-input) is
    /// answered here — in front of the router — with `batch_size == 0`
    /// marking the cache hit; the boards never see it.
    pub fn submit(
        &self,
        task: &str,
        x: Vec<f32>,
    ) -> Result<mpsc::Receiver<Reply>, RouteError> {
        let mut cache_key = None;
        if let Some(cache) = &self.cache {
            let key = ResultCache::key(task, &x);
            if let Some((output, top1)) = cache.get(key) {
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Reply {
                    output,
                    top1,
                    batch_size: 0,
                    queue_us: 0,
                    exec_us: 0,
                });
                return Ok(rx);
            }
            cache_key = Some(key);
        }
        // select() reads a depth snapshot; the push re-checks the bound
        // under the queue lock, so a racing submit can at worst bounce to
        // the next replica — never overfill.  try_push hands the request
        // back on failure, so the input is never copied.
        let (tx, rx) = mpsc::channel();
        let mut req = FleetRequest {
            x,
            reply: tx,
            enqueued: std::time::Instant::now(),
            cache_key,
        };
        for _ in 0..3 {
            let idx = self.router.select(task, &self.depths())?;
            match self.queues[idx].try_push(req) {
                Ok(()) => return Ok(rx),
                Err(r) => req = r,
            }
        }
        Err(RouteError::Overloaded)
    }

    /// Blocking round trip.
    pub fn infer(&self, task: &str, x: Vec<f32>) -> Result<Reply> {
        let rx = self
            .submit(task, x)
            .map_err(|e| anyhow!("fleet rejected {task} request: {e}"))?;
        rx.recv().map_err(|_| anyhow!("fleet dropped {task} request"))
    }

    /// Instantaneous queue depths (observability).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.depths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_registry() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 300.0, 60.0, 1.8),
                BoardInstance::synthetic(2, "ad", 40.0, 5.0, 1.5),
                BoardInstance::synthetic(3, "ic", 600.0, 100.0, 1.6),
            ],
        }
    }

    fn input_for(task: &str) -> Vec<f32> {
        vec![0.1; crate::data::feature_dim(task)]
    }

    #[test]
    fn mixed_workload_round_trips() {
        let fleet =
            Fleet::start(synthetic_registry(), FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        let tasks = ["kws", "ad", "ic", "kws", "kws", "ad"];
        let mut rxs = Vec::new();
        for &t in tasks.iter().cycle().take(60) {
            rxs.push((t, handle.submit(t, input_for(t)).unwrap()));
        }
        for (t, rx) in rxs {
            let r = rx.recv().unwrap();
            let want = match t {
                "kws" => 12,
                "ad" => 128,
                _ => 10,
            };
            assert_eq!(r.output.len(), want, "{t}");
            assert!(r.batch_size >= 1);
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 60);
        assert_eq!(summary.served_per_worker.iter().sum::<u64>(), 60);
        assert!(summary.snapshot.p99_us >= summary.snapshot.p50_us);
        assert!(summary.snapshot.energy_per_inference_uj > 0.0);
    }

    #[test]
    fn unknown_task_is_rejected_not_dropped() {
        let fleet =
            Fleet::start(synthetic_registry(), FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        assert_eq!(
            handle.submit("vww", vec![0.0; 10]).unwrap_err(),
            RouteError::UnknownTask
        );
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 0);
    }

    #[test]
    fn backpressure_bounds_queues() {
        let cfg = FleetConfig {
            queue_cap: 4,
            work_stealing: false,
            // Slow the boards down so queues actually fill.
            time_scale: 20.0,
            ..Default::default()
        };
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 2000.0, 500.0, 1.5)],
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match handle.submit("kws", input_for("kws")) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(RouteError::Overloaded) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
            assert!(handle.queue_depths()[0] <= 4);
        }
        assert!(rejected > 0, "cap 4 must reject under a 64-burst");
        for rx in rxs {
            rx.recv().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served as usize, accepted);
    }

    #[test]
    fn result_cache_answers_repeats_in_front_of_the_router() {
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5)],
        };
        let cfg = FleetConfig { cache_cap: 64, ..Default::default() };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let x = input_for("kws");
        // First round trip executes on the board and populates the memo.
        let first = handle.infer("kws", x.clone()).unwrap();
        assert!(first.batch_size >= 1, "first request must hit a board");
        // Repeat must be a hit: same output, batch_size 0 (no board).
        let hit = handle.infer("kws", x.clone()).unwrap();
        assert_eq!(hit.output, first.output);
        assert_eq!(hit.top1, first.top1);
        assert_eq!(hit.batch_size, 0, "repeat should be served from cache");
        // A different input misses.
        let mut y = x.clone();
        y[0] += 1.0;
        let other = handle.infer("kws", y).unwrap();
        assert!(other.batch_size >= 1);
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 2, "cache hit must not reach a board");
        assert_eq!(summary.snapshot.cache.hits, 1);
        assert_eq!(summary.snapshot.cache.misses, 2);
        assert!(summary.snapshot.cache.entries >= 1);
        let json = summary.snapshot.to_json().to_json();
        assert!(json.contains("\"cache_hits\""), "{json}");
    }

    #[test]
    fn work_stealing_drains_hot_replica() {
        // Two same-task replicas; all requests land on board 0 (energy-
        // aware always picks the cheaper), but the idle replica steals.
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 500.0, 100.0, 1.0),
                BoardInstance::synthetic(1, "kws", 500.0, 100.0, 2.0),
            ],
        };
        let cfg = FleetConfig {
            policy: Policy::EnergyAware,
            time_scale: 10.0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for _ in 0..120 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 120);
        let stolen: u64 = summary.snapshot.per_board.iter().map(|b| b.stolen).sum();
        assert!(stolen > 0, "idle replica should have stolen work");
    }
}
