//! Multi-board serving plane: one coordinator, many simulated
//! accelerators — with an **elastic** replica set.
//!
//! The paper deploys each MLPerf Tiny task on a *single* board and
//! measures µs-latency / µJ-energy per inference; this module lifts that
//! codesign envelope to fleet scope.  A [`registry::Registry`] enumerates
//! heterogeneous board instances (Pynq-Z2 / Arty A7-100T × KWS / AD / IC
//! × folding schedule), each carrying the latency, initiation-interval,
//! power, and energy numbers its codesign flow produced.  A
//! [`router::Router`] places every request on an instance under a
//! pluggable policy with admission control; bounded per-board queues give
//! backpressure; per-board worker threads batch through the same dynamic
//! window as the single-model engine and execute through the same
//! [`crate::coordinator::engine::BatchExecutor`] trait (the simulated
//! dataflow hold lives inside [`worker::SimBoardExecutor`]).
//! [`telemetry::Telemetry`] aggregates the result into fleet-level
//! p50/p99/throughput/energy.  An optional bounded LRU
//! [`cache::ResultCache`] in front of the router memoizes
//! (task, quantized-input) → output with per-task hit/miss counters,
//! per-entry TTL, per-task capacity splits, and hit-rate-aware
//! admission (caching v5); an optional single-flight [`coalesce`] layer
//! behind it merges identical *in-flight* requests onto one leader's
//! board execution and fans the reply to every follower.
//!
//! The queue plane is **multi-tenant and class-aware** ([`queue`]):
//! every request carries a (tenant, [`Priority`]) tag, each board
//! queue keeps per-class subqueues with strict-priority pickup for
//! `Interactive` (bounded by an anti-starvation guard) and weighted
//! deficit-round-robin between `Standard` and `Batch`, and tiered
//! admission sheds `Batch` first under overload.  Telemetry splits
//! latency percentiles and shed counts per class (and served counts per
//! tenant); `FleetConfig::fifo_queues` restores the single-FIFO control
//! for A/B measurements.
//!
//! The steady-state submit→reply path takes **no fleet-global mutexes**
//! and the serve side **allocates nothing per request**: telemetry is
//! lock-sharded per worker ([`telemetry`] — each worker records into
//! its own shard through a [`TelemetrySink`], merged at snapshot time),
//! the result cache is lock-striped ([`cache`]), reply buffers and
//! worker staging recycle (replies through
//! [`crate::coordinator::pool::ReplyPool`]s), and registry reads are
//! Arc clones.  Two fleet-wide synchronization points remain, both
//! deliberately cheap: queued submits take the read side of the
//! `RwLock<Plane>` (read-mostly — writers only on membership changes;
//! this is what makes live scaling possible), and sheds bump relaxed
//! atomics.  What still allocates per request sits on the caller's side
//! of the submit boundary: the input vector and the one-shot `mpsc`
//! reply channel (the API hand-off).
//! [`FleetConfig::global_hotpath`] restores the pre-PR
//! global-lock/allocating path as the A/B control `benches/hotpath.rs`
//! measures against.
//!
//! Replicas **come and go at runtime**: [`Fleet::add_replica`] clones a
//! task's instance (flow numbers carry over) and spins up its queue +
//! worker; [`Fleet::retire_replica`] closes the queue, lets the worker
//! drain every admitted request, then joins it — never dropping work.
//! Every submit re-reads the live routing plane, so membership changes
//! are visible immediately.  With [`FleetConfig::autoscale`] set, a
//! [`autoscale`] controller thread drives both from telemetry (queue
//! depth, predicted latency vs SLO, utilization), and the scale history
//! rides [`FleetSnapshot`] into `report::json`.
//!
//! The plane **survives failure**.  A batch that fails to execute
//! (device error, or a worker panic caught at the execute boundary) is
//! never dropped: each rider goes back through the router via a retry
//! pump (avoiding the board it failed on while siblings survive), and a
//! request whose [`FleetConfig::retry_budget`] runs out gets a
//! definitive typed [`FleetError::Exhausted`] on its reply channel —
//! every admitted request resolves to **exactly one** outcome, reply or
//! typed error, never a bare `recv` error and never a hang.  A [`health`]
//! controller watches per-replica signals (consecutive execute failures,
//! flow-vs-measured drift, a depth-gated heartbeat) and **ejects** sick
//! replicas through the same drain-then-join retirement as scale-down.
//! The [`chaos`] module injects seeded, replayable faults (transient
//! exec errors, permanent death, slowdowns, stall windows, worker
//! panics) behind [`FleetConfig::chaos`] to exercise all of it —
//! `benches/scenarios.rs` gates the resulting resilience headlines.
//!
//! ```no_run
//! use tinyml_codesign::fleet::{Fleet, FleetConfig, Registry};
//!
//! let reg = Registry::standard_fleet().unwrap();
//! let fleet = Fleet::start(reg, FleetConfig::default()).unwrap();
//! let handle = fleet.handle();
//! let x = vec![0.0f32; 490];
//! let reply = handle.infer("kws", x).unwrap();
//! println!("top1 {} in {} us", reply.top1, reply.queue_us + reply.exec_us);
//! let summary = fleet.shutdown();
//! println!("{}", summary.render());
//! ```

pub mod autoscale;
pub mod cache;
pub mod chaos;
pub mod coalesce;
pub mod health;
pub mod hedge;
pub mod queue;
pub mod registry;
pub mod router;
pub mod telemetry;
pub mod trace;
pub mod worker;

pub use autoscale::{AutoscaleConfig, ScaleAction, ScaleEvent};
pub use cache::{CacheOptions, CacheStats, ResultCache, TaskCacheStats};
pub use chaos::{ChaosExecutor, ChaosSpec, FaultPlan, ReplicaFaults, Victim};
pub use coalesce::{CoalesceStats, Coalescer};
pub use health::{
    BoardHealth, BreakerConfig, BreakerTransition, CircuitBreaker, HealthConfig,
};
pub use hedge::{DeadlineSnapshot, DeadlineStats, HedgeController, HedgeStats};
pub use queue::{admit_limit, BoardQueue, FleetRequest, Priority, RequestTag};
pub use registry::{BoardInstance, Registry};
pub use router::{Policy, RouteError, Router};
pub use telemetry::{
    BoardSnapshot, ClassSnapshot, DriftSnapshot, FleetSnapshot, ReplySample,
    Telemetry, TelemetrySink,
};
pub use trace::{
    EventLog, EventRing, FleetEvent, Sampler, ShedReason, Stage, StageHistogram,
    TraceCtx, TraceEvent,
};
pub use worker::{
    DataflowTiming, PeerList, RetryItem, SimBoardExecutor, WorkerConfig,
    WorkerTraceConfig,
};

use crate::coordinator::engine::{BatchPolicy, Reply};
use crate::coordinator::pool::{PooledVec, ReplyPool};
use crate::error::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Definitive per-request failure, delivered **on the reply channel**.
/// An admitted request resolves to exactly one of `Ok(Reply)` or
/// `Err(FleetError)` — the channel is never just dropped, so a bare
/// `recv()` error now only means the fleet itself went away.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetError {
    /// The request rode `attempts` failed batches and the retry budget
    /// ([`FleetConfig::retry_budget`]) is spent — or no healthy replica
    /// could re-admit it.
    Exhausted { attempts: u32 },
    /// The request's deadline passed before it could execute: caught at
    /// dequeue, window-close, or in the retry pump, and resolved here
    /// instead of burning board time on dead work.  (A deadline the
    /// submit path already predicts unmeetable is refused up front as
    /// [`RouteError::DeadlineUnmeetable`] — the request is never
    /// admitted.)
    DeadlineExceeded,
    /// The fleet itself went away while the request was waiting (its
    /// reply channel disconnected) — the only failure that is not a
    /// per-request outcome.
    Disconnected,
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Exhausted { attempts } => {
                write!(f, "request failed {attempts} attempt(s); retry budget spent")
            }
            FleetError::DeadlineExceeded => {
                f.write_str("deadline exceeded before execution")
            }
            FleetError::Disconnected => f.write_str("fleet shut down mid-request"),
        }
    }
}

/// Reply side of a submitted request: exactly one `Ok(Reply)` or one
/// typed [`FleetError`] arrives per admitted request.
pub type ReplyReceiver = mpsc::Receiver<std::result::Result<Reply, FleetError>>;

/// Fleet-wide serving knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    pub policy: Policy,
    /// Bounded queue capacity per board (admission control past this).
    pub queue_cap: usize,
    /// Dynamic-batching window shared by every worker.
    pub batch: BatchPolicy,
    /// Wall-seconds per simulated device-second (stretch µs-class
    /// accelerator latencies so policy differences dominate thread
    /// overhead; 1.0 = real time).
    pub time_scale: f64,
    /// Let idle workers steal queued requests from same-task replicas.
    pub work_stealing: bool,
    /// Result-cache capacity in entries (0 = disabled).  When on,
    /// repeated (task, quantized-input) requests are answered in front
    /// of the router without touching a board; cache hits carry
    /// `batch_size == 0` in their [`Reply`].
    pub cache_cap: usize,
    /// Per-entry result-cache TTL in µs (0 = entries never expire).  An
    /// expired entry is dropped by the probe that discovers it and the
    /// probe counts as a plain miss — never a stale hit (see
    /// [`cache`]'s v5 notes).
    pub cache_ttl_us: u64,
    /// Cache-wide per-task entry budget (0 = no split): a task at its
    /// split evicts its own oldest entry instead of squeezing its
    /// neighbours' working sets.
    pub cache_task_cap: usize,
    /// Single-flight request coalescing ([`coalesce`]): identical
    /// in-flight requests (same task + 1/256-quantized input, same or
    /// more urgent leader class) attach as followers to one leader's
    /// board execution and ride its reply.  Works with or without the
    /// result cache (the cache remembers *completed* executions; the
    /// coalescer collapses *in-flight* ones).
    pub coalesce: bool,
    /// Telemetry-driven replica autoscaling (`None` = fixed fleet).
    pub autoscale: Option<AutoscaleConfig>,
    /// Run the queues in single-FIFO compat mode: arrival-order pickup
    /// and uniform tail-drop admission, ignoring request priority (the
    /// control `benches/fleet.rs` measures priority scheduling against).
    /// Default `false` = class-aware queue plane ([`queue`]).
    pub fifo_queues: bool,
    /// Run the steady-state serve path with the pre-PR **global locks**:
    /// fleet-wide class/tenant telemetry mutexes on every recorded
    /// batch, a single-mutex result cache, and a freshly allocated
    /// reply vector per request.  Default `false` = lock-sharded
    /// telemetry ([`telemetry`]), striped cache ([`cache`]), and pooled
    /// zero-allocation replies ([`crate::coordinator::pool`]).  Kept as
    /// the A/B control `benches/hotpath.rs` measures the sharded plane
    /// against (`tinyml-codesign fleet --global-hotpath`).
    pub global_hotpath: bool,
    /// Per-request lifecycle tracing: sample one request in
    /// `trace_sample` (0 = off, 1 = every request).  A sampled request
    /// carries a [`TraceCtx`] stamped at submit, dequeue, window close,
    /// and reply; workers fold the completed spans into per-shard
    /// stage-latency histograms and a flow-vs-measured drift
    /// accumulator, and discrete fleet events (scale, shed, steal,
    /// cache-insert-denied) land in a bounded event log ([`trace`]).
    /// An unsampled request pays exactly one branch.
    pub trace_sample: usize,
    /// Seeded fault injection ([`chaos`]): per-replica schedules of
    /// transient execute failures, permanent death, slowdowns, stall
    /// windows, and worker panics, wrapped around the executor at spawn.
    /// `None` = no injection (zero hot-path cost).
    pub chaos: Option<ChaosSpec>,
    /// Board health monitoring ([`health`]): a controller thread that
    /// watches per-replica failure streaks, flow-vs-measured drift, and
    /// heartbeat age, and ejects sick replicas (drain-then-join, same
    /// path as scale-down).  `None` here still turns health on with
    /// defaults when `chaos` is set.
    pub health: Option<HealthConfig>,
    /// How many failed batches one request may ride before it resolves
    /// to a typed [`FleetError::Exhausted`] instead of another retry.
    pub retry_budget: u32,
    /// Default per-request deadline in wall-clock µs applied to
    /// requests whose tag carries none (0 = no default).  A request
    /// whose flow-predicted completion already misses its deadline is
    /// refused at submit ([`RouteError::DeadlineUnmeetable`], shed
    /// reason `deadline`); one that expires after admission is
    /// discarded at its next stage boundary and resolved
    /// [`FleetError::DeadlineExceeded`] — dead work never reaches a
    /// board.
    pub deadline_us: u64,
    /// Tail-latency hedging threshold (0.0 = off): when a request's
    /// drift-corrected flow estimate on its routed board exceeds
    /// `hedge_p99 ×` its class's observed p99 span, a duplicate leg is
    /// queued on a same-task sibling through a standalone coalesce
    /// flight; first terminal outcome wins, the loser is cancelled at
    /// its next stage boundary ([`hedge`]).  Enabling this turns
    /// request tracing on (sample 1) if it was off — the threshold is
    /// seeded from sampled stage spans.
    pub hedge_p99: f64,
    /// Per-replica circuit breakers ([`health::CircuitBreaker`]): trip
    /// on a failure-rate window, mask the replica from routing through
    /// a cooldown, re-admit via half-open probe batches — the
    /// *reversible* complement to health ejection.  `None` = off.
    pub breaker: Option<BreakerConfig>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            policy: Policy::LeastLoaded,
            queue_cap: 256,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
            time_scale: 1.0,
            work_stealing: true,
            cache_cap: 0,
            cache_ttl_us: 0,
            cache_task_cap: 0,
            coalesce: false,
            autoscale: None,
            fifo_queues: false,
            global_hotpath: false,
            trace_sample: 0,
            chaos: None,
            health: None,
            retry_budget: 3,
            deadline_us: 0,
            hedge_p99: 0.0,
            breaker: None,
        }
    }
}

/// The live routing surface: which queues exist and which replicas are
/// candidates.  Swapped under a write lock on every membership change;
/// every submit takes a read lock, so new and retired replicas are
/// visible on the very next request.
pub(crate) struct Plane {
    pub(crate) router: Arc<Router>,
    pub(crate) queues: Vec<Arc<BoardQueue>>,
    /// `active[id]` — retired slots keep their queue (history) but are
    /// never routed to.
    pub(crate) active: Vec<bool>,
}

struct WorkerSlot {
    handle: Option<std::thread::JoinHandle<u64>>,
    /// Final serve count, filled in when the worker is joined.
    served: u64,
}

struct Lifecycle {
    started: Instant,
    stopped: Option<Instant>,
}

/// Everything a running fleet shares between its public handle, its
/// workers, and the autoscale controller.
pub(crate) struct FleetState {
    pub(crate) config: FleetConfig,
    /// Arc-shared: snapshots and scale decisions clone the *handle*
    /// (the seed deep-cloned the whole instance table on every
    /// `snapshot`/`registry()` call); mutations are rare scale events
    /// that clone-and-swap the inner registry under the lock.
    pub(crate) registry: Mutex<Arc<Registry>>,
    pub(crate) plane: RwLock<Plane>,
    pub(crate) telemetry: Arc<Telemetry>,
    cache: Option<Arc<ResultCache>>,
    /// Reply buffers for the cache-hit submit path (hits never reach a
    /// worker, so they recycle through this shared striped pool);
    /// `None` in `global_hotpath` mode — hits allocate, the pre-PR
    /// behavior.
    reply_pool: Option<ReplyPool>,
    /// Single-flight coalescer ([`FleetConfig::coalesce`]): identical
    /// in-flight requests attach to one leader's board execution and
    /// share its reply.  `None` = coalescing off — the submit path pays
    /// one branch.
    coalescer: Option<Arc<Coalescer>>,
    workers: Mutex<Vec<WorkerSlot>>,
    /// task → live same-task queue list shared with the workers (for
    /// stealing); updated in place on membership changes.
    peers: Mutex<BTreeMap<String, PeerList>>,
    /// Per-slot alive interval — the board-seconds ledger.
    lifecycle: Mutex<Vec<Lifecycle>>,
    events: Mutex<Vec<ScaleEvent>>,
    /// Serializes add/retire end to end so slot ids stay aligned across
    /// registry, telemetry, queues, workers, and lifecycle.
    scale_lock: Mutex<()>,
    /// Tracing layer (`trace_sample > 0`): the 1-in-N sampler consulted
    /// on every submit and the fleet event log.  `None` = tracing off —
    /// the submit path pays one branch, the workers one per edge.
    pub(crate) trace: Option<FleetTrace>,
    /// Per-slot health handles (same index space as queues/telemetry;
    /// grows on scale-up, retired slots keep theirs).  `None` = health
    /// plane off — workers skip the per-batch beat entirely.
    pub(crate) health: Option<RwLock<Vec<Arc<BoardHealth>>>>,
    /// Materialized fault schedule (empty when chaos is off).  Fixed at
    /// start: replicas added later are always healthy.
    pub(crate) fault_plan: FaultPlan,
    /// Send side of the retry pump.  Workers clone it at spawn; shutdown
    /// takes and drops it after the workers are joined so the pump's
    /// `recv` loop ends (the pump holds this `FleetState`, so the cycle
    /// is broken through the Sender, not the Arc).
    retry_tx: Mutex<Option<mpsc::Sender<RetryItem>>>,
    /// Replicas ejected by the health controller (a subset of the
    /// scale-down events; also on [`FleetSnapshot::ejections`]).
    pub(crate) ejections: AtomicU64,
    /// Hedging plane ([`FleetConfig::hedge_p99`]): per-class observed
    /// span seed, per-board drift ratios, hedge counters.  `None` =
    /// hedging off — the submit path pays one branch.
    pub(crate) hedge: Option<Arc<HedgeController>>,
    /// Fleet-wide deadline ledger (always present — its counters stay
    /// zero in a deadline-free fleet).
    pub(crate) deadline_stats: Arc<DeadlineStats>,
    /// Per-slot circuit breakers ([`FleetConfig::breaker`]), same index
    /// space as queues; grows on scale-up.  `None` = breakers off.
    pub(crate) breakers: Option<RwLock<Vec<Arc<CircuitBreaker>>>>,
    pub(crate) t0: Instant,
}

/// Shared tracing state: sampler + event log ([`trace`]).
pub(crate) struct FleetTrace {
    pub(crate) sampler: Sampler,
    pub(crate) log: Arc<EventLog>,
}

/// Stop signal for the controller thread (flag + condvar for a prompt
/// wakeup out of its sampling sleep).
pub(crate) type StopSignal = Arc<(Mutex<bool>, Condvar)>;

struct Scaler {
    stop: StopSignal,
    join: std::thread::JoinHandle<()>,
}

/// A running fleet: workers + live router + telemetry (+ autoscaler,
/// health controller, and retry pump when configured).
pub struct Fleet {
    state: Arc<FleetState>,
    scaler: Option<Scaler>,
    /// Health controller thread (same stop-signal shape as the scaler).
    health_ctl: Option<Scaler>,
    /// Retry pump: re-routes requests rescued off failed batches.
    retry_pump: Option<std::thread::JoinHandle<u64>>,
}

/// Spawn the worker thread for one replica slot.  The executor comes
/// from the instance's factory ([`BoardInstance::executor`]) — the
/// worker loop itself is executor-agnostic.
fn spawn_worker(
    state: &Arc<FleetState>,
    inst: BoardInstance,
    own: Arc<BoardQueue>,
    peers: PeerList,
) -> std::thread::JoinHandle<u64> {
    // Resolve the telemetry sink once, outside the serve loop: in the
    // sharded (default) mode the worker holds its own shard and never
    // touches the collector's slot table again.
    let sink = TelemetrySink::resolve(&state.telemetry, inst.id);
    let cache = state.cache.clone();
    let coalesce = state.coalescer.clone();
    let cfg = state.config;
    // Resolve the board's event ring once, like the telemetry sink.
    let trace = state.trace.as_ref().map(|t| WorkerTraceConfig {
        ring: t.log.ring(inst.id),
        time_scale: cfg.time_scale,
    });
    // Resolve the failure-plane handles once too: retry sender, health
    // slot, and this replica's fault schedule (chaos).  All `None`/empty
    // in a plain fleet — the serve loop pays nothing.
    let retry = state.retry_tx.lock().unwrap().clone();
    let health = state
        .health
        .as_ref()
        .map(|h| h.read().unwrap()[inst.id].clone());
    // Deadline/hedge/breaker planes, resolved once like the handles
    // above.  The deadline ledger is unconditional (a request can carry
    // its own deadline even when the fleet default is 0); hedge and
    // breaker are `None`-cheap when off.
    let deadline = state.deadline_stats.clone();
    let hedge = state.hedge.clone();
    let breaker = state
        .breakers
        .as_ref()
        .map(|b| b.read().unwrap()[inst.id].clone());
    // Health's drift signal needs the flow-vs-measured accumulator even
    // when request tracing is off.
    let drift_time_scale =
        (trace.is_some() || health.is_some()).then_some(cfg.time_scale);
    let faults = state.fault_plan.for_replica(inst.id);
    std::thread::spawn(move || {
        let exec = inst.executor(cfg.batch.max_batch, cfg.time_scale);
        let wcfg = WorkerConfig {
            batch: cfg.batch,
            work_stealing: cfg.work_stealing,
            pooled_replies: !cfg.global_hotpath,
            trace,
            retry,
            retry_budget: cfg.retry_budget,
            health,
            drift_time_scale,
            deadline,
            hedge,
            breaker,
        };
        match faults {
            // `ChaosExecutor<SimBoardExecutor>` is a distinct executor
            // type; `run_worker` is generic, so each arm monomorphizes
            // its own loop and the healthy path stays wrapper-free.
            Some(f) => {
                let timing = DataflowTiming::for_instance(&inst, cfg.time_scale);
                let exec = ChaosExecutor::new(exec, f, timing);
                worker::run_worker(
                    &inst,
                    exec,
                    &own,
                    &peers,
                    &wcfg,
                    &sink,
                    cache.as_deref(),
                    coalesce.as_deref(),
                )
            }
            None => worker::run_worker(
                &inst,
                exec,
                &own,
                &peers,
                &wcfg,
                &sink,
                cache.as_deref(),
                coalesce.as_deref(),
            ),
        }
    })
}

/// Grow `task` by one replica (clone of its fastest instance).  Returns
/// the new slot id.  Serialized by the scale lock so concurrent scale
/// operations cannot interleave their slot appends.
pub(crate) fn add_replica_inner(
    state: &Arc<FleetState>,
    task: &str,
    reason: &str,
) -> Result<usize> {
    let _guard = state.scale_lock.lock().unwrap();
    let cfg = state.config;
    let (inst, reg_snapshot) = {
        // Scale events are rare: clone the registry once, mutate, and
        // swap the shared Arc — every reader holding the old Arc keeps a
        // consistent (briefly stale) view, and no hot path ever deep
        // clones.
        let mut guard = state.registry.lock().unwrap();
        let mut reg = (**guard).clone();
        let tmpl = reg
            .instances
            .iter()
            .filter(|i| i.task == task)
            .min_by(|a, b| a.ii_s.total_cmp(&b.ii_s))
            .map(|i| i.id)
            .ok_or_else(|| anyhow!("no instance hosts task '{task}' to replicate"))?;
        let id = reg.add_replica_of(tmpl)?;
        let inst = reg.instances[id].clone();
        *guard = Arc::new(reg);
        (inst, guard.clone())
    };
    let id = inst.id;
    let tid = state.telemetry.add_board();
    debug_assert_eq!(tid, id, "telemetry slot out of line with registry id");
    if let Some(t) = &state.trace {
        let rid = t.log.add_ring();
        debug_assert_eq!(rid, id, "event ring out of line with registry id");
    }
    if let Some(h) = &state.health {
        // Grow the health plane *before* the worker spawns: the worker
        // resolves its slot by id at spawn time.
        let mut hs = h.write().unwrap();
        debug_assert_eq!(hs.len(), id, "health slot out of line with registry id");
        hs.push(Arc::new(BoardHealth::new()));
    }
    if let Some(b) = &state.breakers {
        // Same before-spawn rule as health: the worker resolves its
        // breaker slot by id.  A fresh replica starts Closed.
        let mut bs = b.write().unwrap();
        debug_assert_eq!(bs.len(), id, "breaker slot out of line with registry id");
        bs.push(Arc::new(CircuitBreaker::new(
            cfg.breaker.unwrap_or_default(),
        )));
    }
    let q = Arc::new(BoardQueue::with_mode(cfg.queue_cap, !cfg.fifo_queues));
    state
        .lifecycle
        .lock()
        .unwrap()
        .push(Lifecycle { started: Instant::now(), stopped: None });
    let peers = {
        let mut pm = state.peers.lock().unwrap();
        let entry = pm
            .entry(task.to_string())
            .or_insert_with(|| Arc::new(RwLock::new(Vec::new())))
            .clone();
        entry.write().unwrap().push(q.clone());
        entry
    };
    let handle = spawn_worker(state, inst.clone(), q.clone(), peers);
    state.workers.lock().unwrap().push(WorkerSlot { handle: Some(handle), served: 0 });
    // Last step: publish to the routing plane — from here on submits can
    // land on the new replica.
    let replicas_after = {
        let mut p = state.plane.write().unwrap();
        p.queues.push(q);
        p.active.push(true);
        p.router = Arc::new(Router::with_options(
            &reg_snapshot,
            cfg.policy,
            cfg.queue_cap,
            &p.active,
            !cfg.fifo_queues,
        ));
        reg_snapshot
            .instances
            .iter()
            .filter(|i| i.task == task && p.active[i.id])
            .count()
    };
    state.events.lock().unwrap().push(ScaleEvent {
        t_s: state.t0.elapsed().as_secs_f64(),
        action: ScaleAction::Up,
        task: task.to_string(),
        instance: id,
        label: inst.label.clone(),
        reason: reason.to_string(),
        replicas_after,
    });
    if let Some(t) = &state.trace {
        t.log.record_fleet(FleetEvent::ScaleUp {
            task: task.to_string(),
            instance: id,
            reason: reason.to_string(),
        });
    }
    Ok(id)
}

/// Retire slot `id`: unroute it, close its queue, let the worker drain
/// every admitted request, then join the thread (drain-then-join — a
/// scale-down can never drop work).  Refuses to retire a task's last
/// active replica.  Returns the worker's final serve count.
pub(crate) fn retire_replica_inner(
    state: &Arc<FleetState>,
    id: usize,
    reason: &str,
) -> Result<u64> {
    let _guard = state.scale_lock.lock().unwrap();
    let cfg = state.config;
    // Arc clone — a handle to the shared table, not a deep copy.
    let reg_snapshot: Arc<Registry> = state.registry.lock().unwrap().clone();
    let Some(inst) = reg_snapshot.instances.get(id) else {
        bail!("no instance {id} to retire");
    };
    let task = inst.task.clone();
    let label = inst.label.clone();
    let (queue, replicas_after) = {
        let mut p = state.plane.write().unwrap();
        if !p.active.get(id).copied().unwrap_or(false) {
            bail!("instance {id} ({label}) is already retired");
        }
        let live = reg_snapshot
            .instances
            .iter()
            .filter(|i| i.task == task && p.active[i.id])
            .count();
        if live <= 1 {
            bail!("cannot retire the last active '{task}' replica");
        }
        p.active[id] = false;
        p.router = Arc::new(Router::with_options(
            &reg_snapshot,
            cfg.policy,
            cfg.queue_cap,
            &p.active,
            !cfg.fifo_queues,
        ));
        (p.queues[id].clone(), live - 1)
    };
    // Stop admitting.  A submit racing on the old router bounces off the
    // closed queue and retries on the rebuilt one; anything that won the
    // push race is in the queue and will be drained below.
    queue.close();
    // Unlist from the live peer set so surviving replicas stop scanning
    // it (the owner drains its own backlog).
    if let Some(peers) = state.peers.lock().unwrap().get(&task) {
        peers.write().unwrap().retain(|q| !Arc::ptr_eq(q, &queue));
    }
    // Drain-then-join: bounded by the queued backlog.
    let handle = state.workers.lock().unwrap()[id].handle.take();
    let served = match handle {
        Some(h) => h.join().unwrap_or(0),
        None => 0,
    };
    state.workers.lock().unwrap()[id].served = served;
    state.lifecycle.lock().unwrap()[id].stopped = Some(Instant::now());
    state.events.lock().unwrap().push(ScaleEvent {
        t_s: state.t0.elapsed().as_secs_f64(),
        action: ScaleAction::Down,
        task: task.clone(),
        instance: id,
        label,
        reason: reason.to_string(),
        replicas_after,
    });
    if let Some(t) = &state.trace {
        t.log.record_fleet(FleetEvent::ScaleDown {
            task,
            instance: id,
            reason: reason.to_string(),
        });
    }
    Ok(served)
}

/// Eject slot `id` for cause (the health controller's verdict): the same
/// drain-then-join retirement as a scale-down — a chaos-dead or panicking
/// worker still drains its queue, because every failed batch resolves its
/// riders through the retry path — plus the ejection counter and a
/// [`FleetEvent::ReplicaEjected`] marker.  Inherits retirement's
/// last-replica guard: a task's only replica is never ejected, however
/// sick (degraded service beats no service).
pub(crate) fn eject_replica_inner(
    state: &Arc<FleetState>,
    id: usize,
    reason: &str,
) -> Result<u64> {
    let task = state
        .registry
        .lock()
        .unwrap()
        .instances
        .get(id)
        .map(|i| i.task.clone())
        .ok_or_else(|| anyhow!("no instance {id} to eject"))?;
    let served = retire_replica_inner(state, id, reason)?;
    state.ejections.fetch_add(1, Ordering::Relaxed);
    if let Some(t) = &state.trace {
        t.log.record_fleet(FleetEvent::ReplicaEjected {
            task,
            instance: id,
            reason: reason.to_string(),
        });
    }
    Ok(served)
}

/// The retry pump: re-route requests rescued off failed batches.  Runs
/// until every sender (one per worker + the fleet's own) is gone.
/// Returns how many items it pumped.
fn run_retry_pump(state: &Arc<FleetState>, rx: mpsc::Receiver<RetryItem>) -> u64 {
    let mut pumped = 0u64;
    while let Ok(item) = rx.recv() {
        pumped += 1;
        resubmit(state, item);
    }
    pumped
}

/// Re-admit one rescued request: prefer an active same-task replica
/// *other than* the one it failed on (when siblings survive), shallowest
/// queue first.  If no queue accepts it — everything closed, full, or
/// ejected — the request resolves to a typed [`FleetError::Exhausted`]
/// with its true attempt count; it is never silently dropped.
fn resubmit(state: &Arc<FleetState>, item: RetryItem) {
    let RetryItem { task, mut req } = item;
    // A rescued hedge loser whose flight already resolved owes nobody
    // anything: the winning leg fanned the caller's outcome.  Retrying
    // it would be dead work.
    if req.hedge && req.flight.as_ref().is_some_and(|f| f.is_done()) {
        if let Some(hc) = &state.hedge {
            hc.note_cancelled();
        }
        return;
    }
    // The retry budget never outlives the deadline: an expired rescue
    // resolves typed now instead of re-queueing work every later stage
    // would discard.
    if req.deadline.is_some_and(|dl| Instant::now() >= dl) {
        state.deadline_stats.expired_retry.fetch_add(1, Ordering::Relaxed);
        if let (Some(co), Some(f)) = (&state.coalescer, req.flight.as_ref()) {
            co.fan_err(f, &FleetError::DeadlineExceeded);
        }
        let _ = req.reply.send(Err(FleetError::DeadlineExceeded));
        return;
    }
    let reg: Arc<Registry> = state.registry.lock().unwrap().clone();
    let candidates: Vec<usize> = {
        let p = state.plane.read().unwrap();
        let mut ids: Vec<usize> = reg
            .instances
            .iter()
            .filter(|i| i.task == task && p.active.get(i.id).copied().unwrap_or(false))
            .map(|i| i.id)
            .collect();
        if ids.len() > 1 {
            ids.retain(|&id| id as u32 != req.failed_on);
        }
        // Skip replicas whose breaker is open — the rescue should not
        // land back on the board class that just failed it.
        if let Some(bs) = &state.breakers {
            let bs = bs.read().unwrap();
            let now = Instant::now();
            ids.retain(|&id| bs.get(id).map_or(true, |b| b.allows(now)));
        }
        ids.sort_by_key(|&id| p.queues[id].depth());
        ids
    };
    {
        let p = state.plane.read().unwrap();
        for id in candidates {
            match p.queues[id].try_push(req) {
                Ok(()) => return,
                Err(back) => req = back,
            }
        }
    }
    let attempts = req.attempts;
    if let (Some(co), Some(f)) = (&state.coalescer, req.flight.as_ref()) {
        co.fan_err(f, &FleetError::Exhausted { attempts });
    }
    let _ = req.reply.send(Err(FleetError::Exhausted { attempts }));
}

/// Telemetry snapshot with the fleet-level extras grafted on: cache
/// counters, per-slot active flags, board-seconds, scale history.
fn snapshot_of(state: &FleetState) -> FleetSnapshot {
    // Arc clone, not a deep copy: the seed cloned the full instance
    // table (labels, models, flow numbers) on every snapshot.
    let reg: Arc<Registry> = state.registry.lock().unwrap().clone();
    let mut snap = state.telemetry.snapshot(&reg);
    if let Some(c) = &state.cache {
        snap.cache = c.stats();
    }
    if let Some(co) = &state.coalescer {
        snap.coalesce = Some(co.stats());
    }
    {
        let p = state.plane.read().unwrap();
        for (i, b) in snap.per_board.iter_mut().enumerate() {
            b.active = p.active.get(i).copied().unwrap_or(false);
        }
    }
    let now = Instant::now();
    snap.board_seconds = state
        .lifecycle
        .lock()
        .unwrap()
        .iter()
        .map(|l| (l.stopped.unwrap_or(now) - l.started).as_secs_f64())
        .sum();
    snap.scale_events = state.events.lock().unwrap().clone();
    snap.ejections = state.ejections.load(Ordering::Relaxed);
    snap.hedge = state.hedge.as_ref().map(|h| h.stats());
    snap.deadline = state.deadline_stats.snapshot();
    snap.breaker_trips = state.breakers.as_ref().map(|bs| {
        bs.read().unwrap().iter().map(|b| b.trips()).sum()
    });
    snap
}

impl Fleet {
    /// Spawn one worker thread per registry instance (plus the autoscale
    /// controller when configured).
    pub fn start(registry: Registry, mut config: FleetConfig) -> Result<Fleet> {
        if registry.is_empty() {
            return Err(anyhow!("fleet registry is empty"));
        }
        // Hedging decides off sampled lifecycle spans; a hedging fleet
        // with tracing off would never seed its thresholds.  Same
        // auto-enable precedent as chaos implying a health watchdog.
        if config.hedge_p99 > 0.0 && config.trace_sample == 0 {
            config.trace_sample = 1;
        }
        // Queues, router cost tables, and telemetry are all indexed by
        // instance id; a hand-built registry with ids out of line would
        // route on the wrong board's cost model (or panic).
        for (pos, inst) in registry.instances.iter().enumerate() {
            if inst.id != pos {
                return Err(anyhow!(
                    "registry instance '{}' has id {} at position {pos}",
                    inst.label,
                    inst.id
                ));
            }
        }
        if let Some(a) = &config.autoscale {
            if a.min_replicas < 1 || a.max_replicas < a.min_replicas {
                return Err(anyhow!(
                    "autoscale replica bounds {}..{} invalid",
                    a.min_replicas,
                    a.max_replicas
                ));
            }
        }
        // Materialize the fault schedule against the start-time registry
        // (resolves `kill=fastest`, rejects victims that don't exist).
        let fault_plan = match &config.chaos {
            Some(spec) => FaultPlan::materialize(spec, &registry)?,
            None => FaultPlan::default(),
        };
        // Chaos without an explicit health config still gets the
        // watchdog: injecting faults nobody detects tests nothing.
        let health_cfg = config.health.or(config.chaos.map(|_| HealthConfig::default()));
        let n = registry.len();
        let queues: Vec<Arc<BoardQueue>> = registry
            .instances
            .iter()
            .map(|_| Arc::new(BoardQueue::with_mode(config.queue_cap, !config.fifo_queues)))
            .collect();
        // The A/B flag swaps all three hot-path subsystems at once:
        // global-lock telemetry, single-shard cache, allocating replies.
        let telemetry = Arc::new(if config.global_hotpath {
            Telemetry::with_global_locks(n)
        } else {
            Telemetry::new(n)
        });
        let cache = (config.cache_cap > 0).then(|| {
            let opts = CacheOptions {
                ttl: (config.cache_ttl_us > 0)
                    .then(|| Duration::from_micros(config.cache_ttl_us)),
                task_cap: config.cache_task_cap,
                hitrate_admission: true,
            };
            Arc::new(if config.global_hotpath {
                ResultCache::with_config(config.cache_cap, 1, opts)
            } else {
                ResultCache::with_options(config.cache_cap, opts)
            })
        });
        let reply_pool = (!config.global_hotpath && config.cache_cap > 0)
            .then(|| ReplyPool::new(256));
        // Hedging rides the coalesce flight machinery (the duplicate leg
        // races the primary through a flight), so a hedging fleet gets a
        // coalescer even when request coalescing itself is off.
        let coalescer = (config.coalesce || config.hedge_p99 > 0.0)
            .then(|| Arc::new(Coalescer::new()));
        let router = Arc::new(Router::with_options(
            &registry,
            config.policy,
            config.queue_cap,
            &vec![true; n],
            !config.fifo_queues,
        ));
        let mut peers_map: BTreeMap<String, PeerList> = BTreeMap::new();
        for inst in &registry.instances {
            peers_map
                .entry(inst.task.clone())
                .or_insert_with(|| Arc::new(RwLock::new(Vec::new())))
                .write()
                .unwrap()
                .push(queues[inst.id].clone());
        }
        let now = Instant::now();
        let (retry_tx, retry_rx) = mpsc::channel::<RetryItem>();
        let state = Arc::new(FleetState {
            config,
            registry: Mutex::new(Arc::new(registry.clone())),
            plane: RwLock::new(Plane {
                router,
                queues: queues.clone(),
                active: vec![true; n],
            }),
            telemetry,
            cache,
            reply_pool,
            coalescer,
            workers: Mutex::new(Vec::new()),
            peers: Mutex::new(peers_map),
            lifecycle: Mutex::new(
                (0..n).map(|_| Lifecycle { started: now, stopped: None }).collect(),
            ),
            events: Mutex::new(Vec::new()),
            scale_lock: Mutex::new(()),
            trace: (config.trace_sample > 0).then(|| FleetTrace {
                sampler: Sampler::new(config.trace_sample),
                log: Arc::new(EventLog::new(n)),
            }),
            health: health_cfg.map(|_| {
                RwLock::new((0..n).map(|_| Arc::new(BoardHealth::new())).collect())
            }),
            fault_plan,
            retry_tx: Mutex::new(Some(retry_tx)),
            ejections: AtomicU64::new(0),
            hedge: (config.hedge_p99 > 0.0)
                .then(|| Arc::new(HedgeController::new(config.hedge_p99))),
            deadline_stats: Arc::new(DeadlineStats::default()),
            breakers: config.breaker.map(|cfg| {
                RwLock::new((0..n).map(|_| Arc::new(CircuitBreaker::new(cfg))).collect())
            }),
            t0: now,
        });
        let retry_pump = {
            let pump_state = state.clone();
            Some(std::thread::spawn(move || run_retry_pump(&pump_state, retry_rx)))
        };
        let peer_of: Vec<PeerList> = {
            let pm = state.peers.lock().unwrap();
            registry.instances.iter().map(|i| pm[&i.task].clone()).collect()
        };
        {
            let mut workers = state.workers.lock().unwrap();
            for (inst, peers) in registry.instances.iter().zip(peer_of) {
                let handle = spawn_worker(
                    &state,
                    inst.clone(),
                    queues[inst.id].clone(),
                    peers,
                );
                workers.push(WorkerSlot { handle: Some(handle), served: 0 });
            }
        }
        let scaler = config.autoscale.map(|acfg| {
            let stop: StopSignal = Arc::new((Mutex::new(false), Condvar::new()));
            let thread_stop = stop.clone();
            let thread_state = state.clone();
            let join = std::thread::spawn(move || {
                autoscale::run_controller(thread_state, acfg, thread_stop)
            });
            Scaler { stop, join }
        });
        let health_ctl = health_cfg.map(|hcfg| {
            let stop: StopSignal = Arc::new((Mutex::new(false), Condvar::new()));
            let thread_stop = stop.clone();
            let thread_state = state.clone();
            let join = std::thread::spawn(move || {
                health::run_health(thread_state, hcfg, thread_stop)
            });
            Scaler { stop, join }
        });
        Ok(Fleet { state, scaler, health_ctl, retry_pump })
    }

    /// Cloneable submission handle.
    pub fn handle(&self) -> FleetHandle {
        FleetHandle { state: self.state.clone() }
    }

    /// Current registry (grows as replicas are added; retired instances
    /// keep their slots).  Returns a shared handle — an Arc clone, not
    /// a copy of the instance table.
    pub fn registry(&self) -> Arc<Registry> {
        self.state.registry.lock().unwrap().clone()
    }

    /// Active replica count for `task`.
    pub fn active_replicas(&self, task: &str) -> usize {
        let reg = self.state.registry.lock().unwrap();
        let p = self.state.plane.read().unwrap();
        reg.instances
            .iter()
            .filter(|i| i.task == task && p.active.get(i.id).copied().unwrap_or(false))
            .count()
    }

    /// Manually grow `task` by one replica (what the autoscaler does on
    /// a queue/SLO trip).  Returns the new slot id.
    pub fn add_replica(&self, task: &str) -> Result<usize> {
        add_replica_inner(&self.state, task, "manual")
    }

    /// Manually retire slot `id` (drain-then-join; refuses to retire a
    /// task's last active replica).  Returns the worker's serve count.
    pub fn retire_replica(&self, id: usize) -> Result<u64> {
        retire_replica_inner(&self.state, id, "manual")
    }

    /// Current telemetry without stopping the fleet.
    pub fn snapshot(&self) -> FleetSnapshot {
        snapshot_of(&self.state)
    }

    /// Events currently retained in the fleet event log, merged across
    /// every ring and sorted by sequence number (empty when tracing is
    /// off — `trace_sample == 0`).
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.state.trace.as_ref().map(|t| t.log.dump_sorted()).unwrap_or_default()
    }

    /// Snapshot *and* roll the per-phase high-water marks over (queue
    /// peaks reset to current depth, telemetry depth peaks to zero) —
    /// use at bench phase boundaries so each phase reports its own peak
    /// queue depth instead of the stickiest value since start.
    pub fn snapshot_phase(&self) -> FleetSnapshot {
        let snap = snapshot_of(&self.state);
        for q in self.state.plane.read().unwrap().queues.iter() {
            q.reset_peak();
        }
        self.state.telemetry.reset_depth_peaks();
        snap
    }

    /// Replicas ejected by the health controller so far.
    pub fn ejections(&self) -> u64 {
        self.state.ejections.load(Ordering::Relaxed)
    }

    /// Hedge counters so far (`None` when hedging is off).
    pub fn hedge_stats(&self) -> Option<HedgeStats> {
        self.state.hedge.as_ref().map(|h| h.stats())
    }

    /// Deadline-plane ledger so far (all zeros in a deadline-free
    /// fleet).
    pub fn deadline_stats(&self) -> DeadlineSnapshot {
        self.state.deadline_stats.snapshot()
    }

    /// Per-slot circuit-breaker state names (`None` when breakers are
    /// off) — observability for tests and the CLI.
    pub fn breaker_states(&self) -> Option<Vec<&'static str>> {
        self.state
            .breakers
            .as_ref()
            .map(|bs| bs.read().unwrap().iter().map(|b| b.state_name()).collect())
    }

    /// Stop the controllers, close every queue, drain, join workers, end
    /// the retry pump, and return the final telemetry plus per-worker
    /// serve counts.
    pub fn shutdown(mut self) -> FleetSummary {
        // Controllers first so no scale/eject decision races the drain.
        for ctl in [self.health_ctl.take(), self.scaler.take()].into_iter().flatten() {
            *ctl.stop.0.lock().unwrap() = true;
            ctl.stop.1.notify_all();
            let _ = ctl.join.join();
        }
        let queues: Vec<Arc<BoardQueue>> =
            self.state.plane.read().unwrap().queues.clone();
        for q in &queues {
            q.close();
        }
        let served_per_worker: Vec<u64> = {
            let mut workers = self.state.workers.lock().unwrap();
            workers
                .iter_mut()
                .map(|w| {
                    if let Some(h) = w.handle.take() {
                        w.served = h.join().unwrap_or(0);
                    }
                    w.served
                })
                .collect()
        };
        // All worker senders died with their threads; dropping the
        // fleet's own ends the pump's recv loop after it drains what the
        // failing workers rescued (closed queues turn those re-pushes
        // into typed errors — resolved, not lost).
        drop(self.state.retry_tx.lock().unwrap().take());
        if let Some(p) = self.retry_pump.take() {
            let _ = p.join();
        }
        let now = Instant::now();
        for l in self.state.lifecycle.lock().unwrap().iter_mut() {
            if l.stopped.is_none() {
                l.stopped = Some(now);
            }
        }
        let trace_events = self
            .state
            .trace
            .as_ref()
            .map(|t| t.log.dump_sorted())
            .unwrap_or_default();
        FleetSummary {
            snapshot: snapshot_of(&self.state),
            served_per_worker,
            trace_events,
        }
    }
}

/// What [`Fleet::shutdown`] returns.
pub struct FleetSummary {
    pub snapshot: FleetSnapshot,
    pub served_per_worker: Vec<u64>,
    /// Final event-log contents, sorted by sequence number (empty when
    /// tracing was off).
    pub trace_events: Vec<TraceEvent>,
}

impl FleetSummary {
    pub fn render(&self) -> String {
        self.snapshot.render()
    }
}

/// Clone-to-share submission side of a running fleet.  Reads the live
/// routing plane on every submit, so replicas added or retired after the
/// handle was created are used/avoided automatically.
#[derive(Clone)]
pub struct FleetHandle {
    state: Arc<FleetState>,
}

impl FleetHandle {
    /// Route + enqueue with the default tag (tenant 0, `Standard`) —
    /// the pre-priority behavior.  See [`Self::submit_tagged`].
    pub fn submit(
        &self,
        task: &str,
        x: Vec<f32>,
    ) -> Result<ReplyReceiver, RouteError> {
        self.submit_tagged(task, x, RequestTag::default())
    }

    /// Route + enqueue; returns the reply channel without blocking on
    /// execution.  Admission control surfaces as `Err(RouteError)` —
    /// counted in telemetry as a **shed** of the request's class
    /// (`Overloaded` / `SloUnattainable`; an unknown task is a caller
    /// bug, not a shed).  With result caching on, a repeated (task,
    /// quantized-input) is answered here — in front of the router —
    /// with `batch_size == 0` marking the cache hit; the boards never
    /// see it.
    pub fn submit_tagged(
        &self,
        task: &str,
        x: Vec<f32>,
        tag: RequestTag,
    ) -> Result<ReplyReceiver, RouteError> {
        match self.submit_inner(task, x, tag) {
            Ok(rx) => Ok(rx),
            Err((e, reason)) => {
                // A definitive refusal is a shed, counted under its
                // reason; an unknown task is a caller bug, not a shed.
                if let Some(reason) = reason {
                    self.state.telemetry.record_shed(tag.priority, reason);
                    if let Some(t) = &self.state.trace {
                        t.log.record_fleet(FleetEvent::Shed {
                            class: tag.priority,
                            reason,
                        });
                    }
                }
                Err(e)
            }
        }
    }

    /// The error side carries the shed reason to record (`None` for
    /// non-shed refusals like an unknown task) so `submit_tagged` can
    /// count it without re-deriving the classification.
    fn submit_inner(
        &self,
        task: &str,
        x: Vec<f32>,
        tag: RequestTag,
    ) -> Result<ReplyReceiver, (RouteError, Option<ShedReason>)> {
        // Wrong-length inputs are a caller bug: refuse them here with a
        // typed error instead of letting the worker silently truncate or
        // zero-pad.  `feature_dim_of` is a lock-free table lookup (no
        // registry mutex on the hot path); tasks outside the standard
        // trio have no known dimension and skip the check.
        if let Some(expected) = crate::data::feature_dim_of(task) {
            if x.len() != expected {
                return Err((
                    RouteError::InvalidInput { expected, got: x.len() },
                    None,
                ));
            }
        }
        // One branch when tracing is off (`state.trace` is `None`); with
        // tracing on, one relaxed fetch_add decides sampling.
        let mut trace_ctx = match &self.state.trace {
            Some(t) if t.sampler.sample() => Some(Box::new(TraceCtx::new())),
            _ => None,
        };
        let mut cache_key = None;
        if let Some(cache) = &self.state.cache {
            let probe_start = trace_ctx.as_ref().map(|_| Instant::now());
            let key = ResultCache::key(task, &x);
            // Hits copy into a pooled reply buffer (returned to the
            // pool when the caller drops the reply) and, for
            // Interactive traffic, upgrade the entry's admission class
            // so a Batch sweep cannot evict the live working set.  The
            // buffer is acquired lazily inside the hit callback, so a
            // miss pays no pool traffic.  The global_hotpath control
            // allocates instead (pre-PR path).
            let hit = cache.get_hit(task, key, tag.priority, |out, top1| {
                let mut output = match &self.state.reply_pool {
                    Some(p) => p.take(),
                    None => PooledVec::detached(Vec::new()),
                };
                output.vec_mut().extend_from_slice(out);
                (output, top1)
            });
            if let Some((output, top1)) = hit {
                // A cache hit ends the request's lifecycle here; its
                // trace context (if any) is dropped, never folded.
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Ok(Reply {
                    output,
                    top1,
                    batch_size: 0,
                    queue_us: 0,
                    exec_us: 0,
                }));
                return Ok(rx);
            }
            cache_key = Some(key);
            if let (Some(t), Some(p0)) = (trace_ctx.as_deref_mut(), probe_start) {
                t.cache_lookup_us = p0.elapsed().as_micros() as u32;
            }
        }
        // select_class() reads a depth snapshot; the push re-checks the
        // class bound (and closed-ness) under the queue lock, so a
        // racing submit can at worst bounce to the next replica — never
        // overfill, never land on a retiring board.  try_push hands the
        // request back on failure, so the input is never copied.
        let (tx, rx) = mpsc::channel();
        // Single-flight probe: a duplicate of an in-flight request (same
        // coalescing key, compatible class) attaches as a follower and
        // returns its receiver immediately — the leader's worker fans the
        // reply out at batch completion.  The key is the cache digest,
        // computed above on a cache miss and here when the cache is off.
        let mut flight = None;
        if let Some(co) = &self.state.coalescer {
            let key = cache_key.unwrap_or_else(|| ResultCache::key(task, &x));
            match co.attach_or_lead(key, tag.priority, &tx) {
                coalesce::Attach::Follow => return Ok(rx),
                // A stronger-class duplicate: attached as a follower and
                // upgraded the flight's class; chase the queued leader to
                // its board (stamped at push time) and promote it in
                // place so the whole flight serves at the duplicate's
                // urgency.  A miss (leader already dequeued, or not yet
                // pushed) costs nothing — the follower still gets the
                // leader's reply at the leader's original urgency.
                coalesce::Attach::FollowUpgraded(f) => {
                    if let Some(b) = f.board() {
                        let p = self.state.plane.read().unwrap();
                        if let Some(q) = p.queues.get(b) {
                            q.promote_flight(&f, tag.priority);
                        }
                    }
                    return Ok(rx);
                }
                coalesce::Attach::Lead(f) => flight = Some(f),
                coalesce::Attach::Solo => {}
            }
        }
        // Absolute deadline: the request's own tag wins; the fleet-wide
        // default fills in when the tag carries none.  Stamped at submit
        // so queueing, routing retries, and execution all count against
        // the same budget.
        let deadline_us = if tag.deadline_us > 0 {
            tag.deadline_us
        } else {
            self.state.config.deadline_us
        };
        let deadline =
            (deadline_us > 0).then(|| Instant::now() + Duration::from_micros(deadline_us));
        let route_start = trace_ctx.as_ref().map(|_| Instant::now());
        let mut req = FleetRequest {
            x,
            reply: tx,
            enqueued: Instant::now(),
            cache_key,
            tag,
            trace: trace_ctx,
            attempts: 0,
            failed_on: queue::NOT_FAILED,
            flight,
            deadline,
            hedge: false,
        };
        let fifo = self.state.config.fifo_queues;
        let time_scale = self.state.config.time_scale;
        let plane = self.state.plane.read().unwrap();
        // Hedge bookkeeping across routing retries: the decision is made
        // once, on the first board the router settles on; the duplicate
        // leg is queued only after the primary push lands.
        let mut hedge_decided = false;
        let mut hedge_dup: Option<(Arc<coalesce::Flight>, Vec<f32>)> = None;
        for _ in 0..3 {
            let mut depths: Vec<usize> =
                plane.queues.iter().map(|q| q.depth()).collect();
            // An open circuit breaker masks its replica from routing the
            // same way a full queue does: the depth is forced past every
            // admission bound.  `allows` also flips a cooled-down
            // breaker to half-open here, so probe traffic resumes
            // through the normal route path — no side channel.
            if let Some(bs) = &self.state.breakers {
                let now = Instant::now();
                for (i, b) in bs.read().unwrap().iter().enumerate() {
                    if !b.allows(now) {
                        depths[i] = usize::MAX;
                    }
                }
            }
            // Load signal for ordering/SLO prediction: only the backlog
            // that is actually *ahead of this class* counts.  An
            // Interactive request jumps every queued Standard/Batch
            // request, so it is predicted (and balanced) against the
            // interactive backlog alone; Standard jumps Batch; Batch
            // waits behind everything (as does every class in FIFO-compat
            // mode), so those just borrow `depths` — no second Vec.  The
            // jump model is optimistic by up to ~one device window (see
            // `Router::select_class` for the bound).
            let ahead_own: Option<Vec<usize>> = match tag.priority {
                _ if fifo => None,
                Priority::Interactive => Some(
                    plane
                        .queues
                        .iter()
                        .map(|q| q.depth_class(Priority::Interactive))
                        .collect(),
                ),
                Priority::Standard => {
                    Some(plane.queues.iter().map(|q| q.depth_urgent()).collect())
                }
                Priority::Batch => None,
            };
            let ahead: &[usize] = ahead_own.as_deref().unwrap_or(&depths);
            let idx = match plane.router.select_class(task, &depths, ahead, tag.priority)
            {
                Ok(idx) => idx,
                Err(e) => {
                    let reason = match e {
                        RouteError::Overloaded => Some(ShedReason::AdmissionTier),
                        RouteError::SloUnattainable => Some(ShedReason::SloPredict),
                        // Caller bugs, not sheds (and `InvalidInput` is
                        // caught before routing — unreachable here).
                        RouteError::UnknownTask => None,
                        RouteError::InvalidInput { .. } => None,
                        // Generated by the deadline triage below, never
                        // by the router itself.
                        RouteError::DeadlineUnmeetable => Some(ShedReason::Deadline),
                    };
                    // A refused leader never executes: resolve every
                    // follower with a typed error (`attempts: 0` marks
                    // "leader never ran") instead of leaving them parked
                    // on a flight nobody will finish.
                    if let (Some(co), Some(f)) =
                        (&self.state.coalescer, req.flight.as_ref())
                    {
                        co.fan_err(f, &FleetError::Exhausted { attempts: 0 });
                    }
                    return Err((e, reason));
                }
            };
            // Deadline triage: when the flow-predicted completion on
            // the chosen board already misses the deadline, refuse now
            // — a typed `DeadlineUnmeetable` and a `deadline` shed —
            // instead of queueing work every later stage would discard.
            if let Some(dl) = req.deadline {
                let pred_us =
                    plane.router.predicted_latency_us(idx, depths[idx]) * time_scale;
                if Instant::now() + Duration::from_micros(pred_us as u64) > dl {
                    self.state
                        .deadline_stats
                        .shed_submit
                        .fetch_add(1, Ordering::Relaxed);
                    if let (Some(co), Some(f)) =
                        (&self.state.coalescer, req.flight.as_ref())
                    {
                        co.fan_err(f, &FleetError::DeadlineExceeded);
                    }
                    return Err((
                        RouteError::DeadlineUnmeetable,
                        Some(ShedReason::Deadline),
                    ));
                }
            }
            // Hedge decision (once): when this board's drift-corrected
            // flow estimate crosses the class's observed-p99 threshold,
            // move the caller's reply sender into a coalesce flight —
            // the primary keeps a throwaway channel, a duplicate leg is
            // queued on a sibling after the primary push lands, and the
            // first terminal outcome fans to the caller.  The loser
            // finds the flight `Done` at its next stage boundary and
            // discards itself without executing.
            if !hedge_decided {
                hedge_decided = true;
                if let (Some(hc), Some(co)) =
                    (&self.state.hedge, &self.state.coalescer)
                {
                    let est = hc.drift_ratio(idx)
                        * plane.router.predicted_latency_us(idx, depths[idx])
                        * time_scale;
                    if hc.should_hedge(tag.priority, est) {
                        let f = match &req.flight {
                            Some(f) => f.clone(),
                            None => {
                                let f = coalesce::Flight::standalone(tag.priority);
                                req.flight = Some(f.clone());
                                f
                            }
                        };
                        if co.enroll_follower(&f, &req.reply) {
                            req.reply = mpsc::channel().0;
                            req.hedge = true;
                            hedge_dup = Some((f, req.x.clone()));
                        }
                    }
                }
            }
            // Cumulative, so the surviving value covers admission/route
            // up to the winning push (retries included).
            if let (Some(t), Some(r0)) = (req.trace.as_deref_mut(), route_start) {
                t.route_us = r0.elapsed().as_micros() as u32;
            }
            match plane.queues[idx].try_push(req) {
                Ok(()) => {
                    if let Some((f, dup_x)) = hedge_dup.take() {
                        // Stamp the board first: a stronger-class
                        // duplicate arriving later promotes the queued
                        // primary through `promote_flight`.
                        f.note_board(idx);
                        // Best same-task sibling with the primary's
                        // board masked out.  `ahead == depths` is
                        // deliberately conservative for this best-effort
                        // pick; any refusal just runs the request
                        // unhedged (the flight still resolves through
                        // the primary).
                        let mut masked = depths.clone();
                        masked[idx] = usize::MAX;
                        if let Ok(j) = plane.router.select_class(
                            task,
                            &masked,
                            &masked,
                            tag.priority,
                        ) {
                            let dup = FleetRequest {
                                x: dup_x,
                                reply: mpsc::channel().0,
                                enqueued: Instant::now(),
                                cache_key,
                                tag,
                                trace: None,
                                attempts: 0,
                                failed_on: queue::NOT_FAILED,
                                flight: Some(f),
                                deadline,
                                hedge: true,
                            };
                            if plane.queues[j].try_push(dup).is_ok() {
                                if let Some(hc) = &self.state.hedge {
                                    hc.note_hedged();
                                }
                            }
                        }
                    }
                    return Ok(rx);
                }
                Err(r) => req = r,
            }
        }
        // Admission said yes but every retry found the queue closed or
        // re-filled: a queue-full shed, distinct from the tier refusal.
        // Same leader-abort rule as the admission refusal above.
        if let (Some(co), Some(f)) = (&self.state.coalescer, req.flight.as_ref()) {
            co.fan_err(f, &FleetError::Exhausted { attempts: 0 });
        }
        Err((RouteError::Overloaded, Some(ShedReason::QueueFull)))
    }

    /// Blocking round trip with the default tag.
    pub fn infer(&self, task: &str, x: Vec<f32>) -> Result<Reply> {
        self.infer_tagged(task, x, RequestTag::default())
    }

    /// Blocking round trip with an explicit (tenant, priority) tag.
    pub fn infer_tagged(&self, task: &str, x: Vec<f32>, tag: RequestTag) -> Result<Reply> {
        let rx = self
            .submit_tagged(task, x, tag)
            .map_err(|e| anyhow!("fleet rejected {task} request: {e}"))?;
        match rx.recv() {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(anyhow!("fleet {task} request failed: {e}")),
            // The fleet shut down with the request admitted but
            // unresolved — a typed lifecycle error, not a bare channel
            // `RecvError`.
            Err(_) => {
                Err(anyhow!("fleet {task} request failed: {}", FleetError::Disconnected))
            }
        }
    }

    /// Blocking round trip that never outlives the request's deadline:
    /// waits at most `tag.deadline_us` (falling back to the fleet-wide
    /// [`FleetConfig::deadline_us`]) for the reply, then resolves to a
    /// typed [`FleetError::DeadlineExceeded`] without blocking on the
    /// fleet's own stage-boundary cancellation.  With no deadline from
    /// either source this is exactly [`Self::infer_tagged`].
    pub fn infer_deadline(&self, task: &str, x: Vec<f32>, tag: RequestTag) -> Result<Reply> {
        let deadline_us = if tag.deadline_us > 0 {
            tag.deadline_us
        } else {
            self.state.config.deadline_us
        };
        if deadline_us == 0 {
            return self.infer_tagged(task, x, tag);
        }
        let rx = self
            .submit_tagged(task, x, tag)
            .map_err(|e| anyhow!("fleet rejected {task} request: {e}"))?;
        match rx.recv_timeout(Duration::from_micros(deadline_us)) {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(anyhow!("fleet {task} request failed: {e}")),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(anyhow!(
                "fleet {task} request failed: {}",
                FleetError::DeadlineExceeded
            )),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(anyhow!("fleet {task} request failed: {}", FleetError::Disconnected))
            }
        }
    }

    /// Instantaneous queue depths, one per slot (observability).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.state.plane.read().unwrap().queues.iter().map(|q| q.depth()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_registry() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 300.0, 60.0, 1.8),
                BoardInstance::synthetic(2, "ad", 40.0, 5.0, 1.5),
                BoardInstance::synthetic(3, "ic", 600.0, 100.0, 1.6),
            ],
        }
    }

    fn input_for(task: &str) -> Vec<f32> {
        vec![0.1; crate::data::feature_dim(task)]
    }

    #[test]
    fn mixed_workload_round_trips() {
        let fleet =
            Fleet::start(synthetic_registry(), FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        let tasks = ["kws", "ad", "ic", "kws", "kws", "ad"];
        let mut rxs = Vec::new();
        for &t in tasks.iter().cycle().take(60) {
            rxs.push((t, handle.submit(t, input_for(t)).unwrap()));
        }
        for (t, rx) in rxs {
            let r = rx.recv().unwrap().unwrap();
            let want = match t {
                "kws" => 12,
                "ad" => 128,
                _ => 10,
            };
            assert_eq!(r.output.len(), want, "{t}");
            assert!(r.batch_size >= 1);
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 60);
        assert_eq!(summary.served_per_worker.iter().sum::<u64>(), 60);
        assert!(summary.snapshot.p99_us >= summary.snapshot.p50_us);
        assert!(summary.snapshot.energy_per_inference_uj > 0.0);
        assert!(summary.snapshot.board_seconds > 0.0);
        assert!(summary.snapshot.scale_events.is_empty(), "no autoscaler ran");
    }

    #[test]
    fn unknown_task_is_rejected_not_dropped() {
        let fleet =
            Fleet::start(synthetic_registry(), FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        assert_eq!(
            handle.submit("vww", vec![0.0; 10]).unwrap_err(),
            RouteError::UnknownTask
        );
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 0);
    }

    #[test]
    fn backpressure_bounds_queues() {
        let cfg = FleetConfig {
            queue_cap: 4,
            work_stealing: false,
            // Slow the boards down so queues actually fill.
            time_scale: 20.0,
            ..Default::default()
        };
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 2000.0, 500.0, 1.5)],
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match handle.submit("kws", input_for("kws")) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(RouteError::Overloaded) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
            assert!(handle.queue_depths()[0] <= 4);
        }
        assert!(rejected > 0, "cap 4 must reject under a 64-burst");
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served as usize, accepted);
        // Every rejection was recorded as a shed of the request's class
        // (untagged submits default to Standard).
        assert_eq!(summary.snapshot.classes[1].shed as usize, rejected);
        assert_eq!(summary.snapshot.classes[0].shed, 0);
    }

    #[test]
    fn priority_classes_round_trip_with_per_class_stats() {
        // One slow board, a pile of Batch work, then a few Interactive
        // requests: priority pickup must let the interactive tail beat
        // the batch tail, and the snapshot must split stats per class
        // and per tenant.
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 500.0, 100.0, 1.5)],
        };
        let cfg = FleetConfig {
            time_scale: 5.0,
            work_stealing: false,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for i in 0..30u32 {
            let tag = RequestTag::new(i % 3, Priority::Batch);
            rxs.push(handle.submit_tagged("kws", input_for("kws"), tag).unwrap());
        }
        for _ in 0..6 {
            let tag = RequestTag::new(7, Priority::Interactive);
            rxs.push(handle.submit_tagged("kws", input_for("kws"), tag).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 36);
        let classes = &summary.snapshot.classes;
        assert_eq!((classes[0].served, classes[1].served, classes[2].served), (6, 0, 30));
        assert_eq!(classes.iter().map(|c| c.shed).sum::<u64>(), 0);
        assert!(
            classes[0].p99_us <= classes[2].p99_us,
            "interactive p99 {:.0} us must not exceed batch p99 {:.0} us",
            classes[0].p99_us,
            classes[2].p99_us
        );
        // Tenants 0,1,2 (batch) + 7 (interactive).
        assert_eq!(summary.snapshot.tenants.len(), 4);
        assert_eq!(
            summary.snapshot.tenants.iter().map(|t| t.served).sum::<u64>(),
            36
        );
        let json = summary.snapshot.to_json().to_json();
        assert!(json.contains("\"classes\""), "{json}");
        assert!(json.contains("\"tenants\""), "{json}");
        assert!(json.contains("\"depth_peak_class\""), "{json}");
    }

    #[test]
    fn fifo_mode_round_trips_and_accounts_sheds_per_class() {
        // FIFO-compat queues behave like the pre-priority fleet (uniform
        // tail-drop, arrival-order pickup — the deterministic version of
        // that property lives in the queue unit tests); shed accounting
        // still splits per class.
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 2000.0, 500.0, 1.5)],
        };
        let cfg = FleetConfig {
            queue_cap: 4,
            fifo_queues: true,
            work_stealing: false,
            time_scale: 20.0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        let mut shed = [0u64; 3];
        let mut admitted = 0u64;
        let classes =
            [Priority::Batch, Priority::Batch, Priority::Batch, Priority::Interactive];
        for i in 0..64 {
            let p = classes[i % classes.len()];
            match handle.submit_tagged("kws", input_for("kws"), RequestTag::new(0, p)) {
                Ok(rx) => {
                    rxs.push(rx);
                    admitted += 1;
                }
                Err(RouteError::Overloaded) => shed[p.idx()] += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(shed.iter().sum::<u64>() > 0, "cap 4 must shed under a 64-burst");
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, admitted);
        for (i, c) in summary.snapshot.classes.iter().enumerate() {
            assert_eq!(c.shed, shed[i], "class {} shed accounting", c.class);
        }
    }

    #[test]
    fn result_cache_answers_repeats_in_front_of_the_router() {
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5)],
        };
        let cfg = FleetConfig { cache_cap: 64, ..Default::default() };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let x = input_for("kws");
        // First round trip executes on the board and populates the memo.
        let first = handle.infer("kws", x.clone()).unwrap();
        assert!(first.batch_size >= 1, "first request must hit a board");
        // Repeat must be a hit: same output, batch_size 0 (no board).
        let hit = handle.infer("kws", x.clone()).unwrap();
        assert_eq!(hit.output, first.output);
        assert_eq!(hit.top1, first.top1);
        assert_eq!(hit.batch_size, 0, "repeat should be served from cache");
        // A different input misses.
        let mut y = x.clone();
        y[0] += 1.0;
        let other = handle.infer("kws", y).unwrap();
        assert!(other.batch_size >= 1);
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 2, "cache hit must not reach a board");
        assert_eq!(summary.snapshot.cache.hits, 1);
        assert_eq!(summary.snapshot.cache.misses, 2);
        assert!(summary.snapshot.cache.entries >= 1);
        let kws = summary
            .snapshot
            .cache
            .per_task
            .iter()
            .find(|t| t.task == "kws")
            .expect("per-task cache stats");
        assert_eq!((kws.hits, kws.misses), (1, 2));
        let json = summary.snapshot.to_json().to_json();
        assert!(json.contains("\"cache_hits\""), "{json}");
        assert!(json.contains("\"cache_per_task\""), "{json}");
    }

    /// The A/B control must be behaviorally identical: same outputs bit
    /// for bit (pooled replies lose nothing), same served/hit/per-class
    /// accounting (sharded telemetry loses no events) — only the locking
    /// layout differs.
    #[test]
    fn global_hotpath_control_serves_identically() {
        let run = |global: bool| {
            let reg = Registry {
                instances: vec![BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5)],
            };
            let cfg = FleetConfig {
                cache_cap: 32,
                global_hotpath: global,
                ..Default::default()
            };
            let fleet = Fleet::start(reg, cfg).unwrap();
            let handle = fleet.handle();
            let mut outs = Vec::new();
            for i in 0..20u32 {
                let mut x = input_for("kws");
                x[0] = (i % 5) as f32; // 5 distinct inputs -> 15 repeats hit
                let tag = RequestTag::new(i % 3, Priority::ALL[(i % 3) as usize]);
                let r = handle.infer_tagged("kws", x, tag).unwrap();
                outs.push(r.output.to_vec());
            }
            (outs, fleet.shutdown())
        };
        let (sharded_outs, sharded) = run(false);
        let (global_outs, global) = run(true);
        assert_eq!(
            sharded_outs, global_outs,
            "pooled replies must be bit-identical to the allocating path"
        );
        assert_eq!(sharded.snapshot.served, global.snapshot.served);
        assert_eq!(sharded.snapshot.cache.hits, global.snapshot.cache.hits);
        assert_eq!(sharded.snapshot.cache.hits, 15);
        for (a, b) in sharded.snapshot.classes.iter().zip(&global.snapshot.classes) {
            assert_eq!(a.served, b.served, "class {}", a.class);
            assert_eq!(a.shed, b.shed, "class {}", a.class);
        }
        assert_eq!(sharded.snapshot.tenants.len(), global.snapshot.tenants.len());
    }

    #[test]
    fn tracing_samples_spans_events_and_drift() {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 200.0, 40.0, 1.0),
                BoardInstance::synthetic(1, "kws", 200.0, 40.0, 2.0),
            ],
        };
        let cfg = FleetConfig {
            trace_sample: 1,
            policy: Policy::EnergyAware,
            time_scale: 5.0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for _ in 0..60 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 60);
        // Every request was sampled (1-in-1): the Standard class must
        // expose stage histograms with 60 completed spans per stage.
        let stages =
            summary.snapshot.classes[1].stages.as_ref().expect("traced stages");
        for h in stages.iter() {
            assert_eq!(h.count, 60, "each stage folds one span per request");
        }
        // Drift accrues on every executed batch somewhere in the fleet.
        let drift_batches: u64 = summary
            .snapshot
            .per_board
            .iter()
            .filter_map(|b| b.drift)
            .map(|d| d.batches)
            .sum();
        assert!(drift_batches >= 1, "at least one batch executed");
        assert!(summary
            .snapshot
            .per_board
            .iter()
            .filter_map(|b| b.drift)
            .all(|d| d.predicted_exec_us > 0.0));
        // The event log dump is seq-sorted (steal events are timing-
        // dependent, so only the ordering invariant is asserted).
        let events = &summary.trace_events;
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq), "sorted by seq");
        // The JSON snapshot carries the trace fields.
        let json = summary.snapshot.to_json().to_json();
        assert!(json.contains("\"stages\""), "{json}");
        assert!(json.contains("\"queue_wait\""), "{json}");
        assert!(json.contains("\"drift\""), "{json}");
        // Untraced control: no stage histograms, no drift.
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 200.0, 40.0, 1.0)],
        };
        let fleet = Fleet::start(reg, FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        handle.infer("kws", input_for("kws")).unwrap();
        let summary = fleet.shutdown();
        assert!(summary.snapshot.classes.iter().all(|c| c.stages.is_none()));
        assert!(summary.snapshot.per_board.iter().all(|b| b.drift.is_none()));
        assert!(summary.trace_events.is_empty());
    }

    #[test]
    fn work_stealing_drains_hot_replica() {
        // Two same-task replicas; all requests land on board 0 (energy-
        // aware always picks the cheaper), but the idle replica steals.
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 500.0, 100.0, 1.0),
                BoardInstance::synthetic(1, "kws", 500.0, 100.0, 2.0),
            ],
        };
        let cfg = FleetConfig {
            policy: Policy::EnergyAware,
            time_scale: 10.0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for _ in 0..120 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 120);
        let stolen: u64 = summary.snapshot.per_board.iter().map(|b| b.stolen).sum();
        assert!(stolen > 0, "idle replica should have stolen work");
    }

    #[test]
    fn manual_scale_up_and_down_conserves_requests() {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 200.0, 40.0, 1.5),
                BoardInstance::synthetic(1, "kws", 200.0, 40.0, 1.5),
            ],
        };
        let cfg = FleetConfig { time_scale: 5.0, ..Default::default() };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for _ in 0..40 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        // Grow while traffic is in flight.
        let id = fleet.add_replica("kws").unwrap();
        assert_eq!(id, 2);
        assert_eq!(fleet.active_replicas("kws"), 3);
        for _ in 0..40 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        // Shrink while traffic is in flight: drain-then-join means the
        // retired board's backlog still comes back.
        let served_by_retired = fleet.retire_replica(0).unwrap();
        assert_eq!(fleet.active_replicas("kws"), 2);
        for _ in 0..40 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            rx.recv()
                .expect("admitted request must not be dropped by scaling")
                .expect("scaling must not fail requests");
        }
        // Retiring twice or below one replica is refused.
        assert!(fleet.retire_replica(0).is_err(), "already retired");
        fleet.retire_replica(1).unwrap();
        assert!(
            fleet.retire_replica(2).is_err(),
            "last active replica must be kept"
        );
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 120);
        assert_eq!(summary.served_per_worker.iter().sum::<u64>(), 120);
        assert!(summary.served_per_worker[0] >= served_by_retired);
        assert_eq!(summary.snapshot.scale_events.len(), 3, "up, down, down");
        assert_eq!(summary.snapshot.scale_events[0].action, ScaleAction::Up);
        assert!(!summary.snapshot.per_board[0].active, "slot 0 retired");
        let json = summary.snapshot.to_json().to_json();
        assert!(json.contains("\"scale_events\""), "{json}");
        assert!(json.contains("\"board_seconds\""), "{json}");
    }

    #[test]
    fn autoscaler_grows_under_burst_and_shrinks_when_idle() {
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 500.0, 100.0, 1.5)],
        };
        let acfg = AutoscaleConfig {
            interval: Duration::from_millis(2),
            high_queue: 2.0,
            low_util: 0.3,
            min_replicas: 1,
            max_replicas: 3,
            cooldown: Duration::from_millis(4),
            ..Default::default()
        };
        let cfg = FleetConfig {
            queue_cap: 512,
            time_scale: 20.0,
            autoscale: Some(acfg),
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        // Burst: ~2 ms of device time per batch against a 100-burst —
        // the queue is deep for many controller intervals.
        let mut rxs = Vec::new();
        for _ in 0..100 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // Idle: utilization collapses; wait out several intervals +
        // cooldowns for the controller to shrink back to the floor.
        let deadline = Instant::now() + Duration::from_secs(5);
        while fleet.active_replicas("kws") > 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            fleet.active_replicas("kws"),
            1,
            "controller should shrink an idle fleet to min_replicas"
        );
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 100, "scaling must not drop requests");
        let ups = summary
            .snapshot
            .scale_events
            .iter()
            .filter(|e| e.action == ScaleAction::Up)
            .count();
        let downs = summary
            .snapshot
            .scale_events
            .iter()
            .filter(|e| e.action == ScaleAction::Down)
            .count();
        assert!(ups >= 1 && downs >= 1, "{:?}", summary.snapshot.scale_events);
    }

    #[test]
    fn wrong_length_input_is_rejected_with_typed_error() {
        let fleet =
            Fleet::start(synthetic_registry(), FleetConfig::default()).unwrap();
        let handle = fleet.handle();
        let expected = crate::data::feature_dim("kws");
        assert_eq!(
            handle.submit("kws", vec![0.0; 7]).unwrap_err(),
            RouteError::InvalidInput { expected, got: 7 }
        );
        assert_eq!(
            handle.submit("kws", vec![0.0; expected + 1]).unwrap_err(),
            RouteError::InvalidInput { expected, got: expected + 1 }
        );
        // A caller bug is not a shed: admission counters stay clean.
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 0);
        assert_eq!(summary.snapshot.classes.iter().map(|c| c.shed).sum::<u64>(), 0);
    }

    #[test]
    fn ejection_never_removes_a_tasks_last_healthy_replica() {
        let reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5)],
        };
        let fleet = Fleet::start(reg, FleetConfig::default()).unwrap();
        let err = eject_replica_inner(&fleet.state, 0, "ejected:test")
            .expect_err("sole replica must survive ejection");
        assert!(
            err.to_string().contains("last active"),
            "guard should name the reason: {err}"
        );
        // Degraded service beats no service: the fleet still answers.
        let handle = fleet.handle();
        handle.infer("kws", input_for("kws")).unwrap();
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 1);
        assert_eq!(summary.snapshot.ejections, 0);
    }

    #[test]
    fn single_replica_death_loses_no_requests_and_ejects_the_dead_board() {
        // Board 0 dies on its first batch (seeded chaos).  Every
        // admitted request must still resolve — riders of failed batches
        // re-route to the surviving replica — and the health controller
        // must eject the dead board automatically.
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 200.0, 40.0, 1.5),
                BoardInstance::synthetic(1, "kws", 200.0, 40.0, 1.5),
            ],
        };
        let cfg = FleetConfig {
            time_scale: 5.0,
            chaos: Some(ChaosSpec::parse("kill=0@1", 42).unwrap()),
            health: Some(HealthConfig {
                interval: Duration::from_millis(1),
                max_consecutive_failures: 2,
                ..Default::default()
            }),
            // Generous: pre-ejection, the dead board can steal (and
            // fail) the same request more than once.
            retry_budget: 50,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rxs = Vec::new();
        for _ in 0..60 {
            rxs.push(handle.submit("kws", input_for("kws")).unwrap());
        }
        for rx in rxs {
            let outcome = rx
                .recv()
                .expect("an admitted request must never be silently dropped");
            outcome.expect("surviving replica should absorb every retry");
        }
        // The controller needs a couple of ticks to observe the streak.
        let deadline = Instant::now() + Duration::from_secs(5);
        while fleet.ejections() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(fleet.ejections(), 1, "dead board must be ejected for cause");
        assert_eq!(fleet.active_replicas("kws"), 1);
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, 60, "zero lost requests");
        assert_eq!(summary.snapshot.ejections, 1);
        assert!(!summary.snapshot.per_board[0].active, "slot 0 ejected");
        assert!(
            summary.snapshot.per_board[0].exec_failures > 0,
            "failures must be observable in telemetry"
        );
        assert!(
            summary.snapshot.scale_events.iter().any(|e| {
                e.action == ScaleAction::Down && e.reason.starts_with("ejected:")
            }),
            "ejection rides the scale-event history: {:?}",
            summary.snapshot.scale_events
        );
        // Nothing served on the dead board: its counter stays zero and
        // the survivor carries the fleet.
        assert_eq!(summary.snapshot.per_board[0].served, 0);
        assert_eq!(summary.snapshot.per_board[1].served, 60);
    }
}
