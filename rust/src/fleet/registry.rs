//! Board-instance registry: which accelerators exist, what they host,
//! and what the codesign flow says they cost.
//!
//! Every [`BoardInstance`] bundles a board model (Pynq-Z2 / Arty
//! A7-100T), a task, a built-in topology, and the numbers the full
//! codesign flow produces for that (model, board, folding) triple:
//! batch-1 dataflow latency, steady-state initiation interval, total
//! power, and energy per inference.  The router and the workers consume
//! these numbers — the fleet never re-estimates what the flow already
//! knows.
//!
//! Folding (the FINN multiplier budget / hls4ml reuse factor) is part of
//! the instance, not the model: the same KWS MLP deployed with a 1024-
//! multiplier budget and with a 128-multiplier budget are two *different*
//! serving instances with ~8x different throughput — exactly the
//! latency/resource knob of §4.2.3, lifted to fleet scope.

use crate::board::{arty_a7_100t, pynq_z2, Board};
use crate::coordinator::flow::{run_flow, FlowOptions};
use crate::dataflow::schedule::ScheduleConfig;
use crate::error::{bail, Result};
use crate::ir::Graph;

/// Built-in serving topologies (self-contained: no artifacts needed).
pub const BUILTIN_MODELS: [&str; 3] = ["kws_mlp_w3a3", "ad_autoencoder", "ic_cnv_w1a1"];

/// Topology JSON for a built-in serving model.
pub fn builtin_topology(model: &str) -> Result<Graph> {
    let json = match model {
        "kws_mlp_w3a3" => {
            r#"{
            "name":"kws_mlp_w3a3","task":"kws","flow":"finn","input_shape":[490],
            "input_bits":8,"nodes":[
              {"op":"Dense","name":"fc1","in_features":490,"out_features":256,
               "weight_bits":3,"params":125440},
              {"op":"BatchNorm","name":"bn1","channels":256,"params":1024},
              {"op":"ReLU","name":"r1","channels":256,"act_bits":3,"params":0},
              {"op":"Dense","name":"fc2","in_features":256,"out_features":128,
               "weight_bits":3,"params":32768},
              {"op":"BatchNorm","name":"bn2","channels":128,"params":512},
              {"op":"ReLU","name":"r2","channels":128,"act_bits":3,"params":0},
              {"op":"Dense","name":"fc3","in_features":128,"out_features":12,
               "weight_bits":3,"params":1536},
              {"op":"BatchNorm","name":"bn3","channels":12,"params":48}
            ],"total_params":161328}"#
        }
        "ad_autoencoder" => {
            r#"{
            "name":"ad_autoencoder","task":"ad","flow":"hls4ml","input_shape":[128],
            "input_bits":8,"reuse_factor":128,"nodes":[
              {"op":"Dense","name":"enc1","in_features":128,"out_features":64,
               "weight_bits":6,"params":8192},
              {"op":"ReLU","name":"r1","channels":64,"act_bits":6,"params":0},
              {"op":"Dense","name":"enc2","in_features":64,"out_features":8,
               "weight_bits":6,"params":512},
              {"op":"ReLU","name":"r2","channels":8,"act_bits":6,"params":0},
              {"op":"Dense","name":"dec1","in_features":8,"out_features":64,
               "weight_bits":6,"params":512},
              {"op":"ReLU","name":"r3","channels":64,"act_bits":6,"params":0},
              {"op":"Dense","name":"dec2","in_features":64,"out_features":128,
               "weight_bits":6,"params":8192}
            ],"total_params":17408}"#
        }
        "ic_cnv_w1a1" => {
            r#"{
            "name":"ic_cnv_w1a1","task":"ic","flow":"finn","input_shape":[32,32,3],
            "input_bits":8,"nodes":[
              {"op":"Conv2D","name":"c1","in_hw":32,"out_hw":30,"in_ch":3,
               "out_ch":16,"kernel":3,"stride":1,"padding":"VALID",
               "weight_bits":1,"params":432},
              {"op":"BatchNorm","name":"bn1","channels":16,"params":64},
              {"op":"BipolarAct","name":"a1","channels":16,"params":0},
              {"op":"MaxPool","name":"p1","in_hw":30,"out_hw":15,"channels":16,
               "size":2,"params":0},
              {"op":"Conv2D","name":"c2","in_hw":15,"out_hw":13,"in_ch":16,
               "out_ch":32,"kernel":3,"stride":1,"padding":"VALID",
               "weight_bits":1,"params":4608},
              {"op":"BatchNorm","name":"bn2","channels":32,"params":128},
              {"op":"BipolarAct","name":"a2","channels":32,"params":0},
              {"op":"MaxPool","name":"p2","in_hw":13,"out_hw":6,"channels":32,
               "size":2,"params":0},
              {"op":"Flatten","name":"fl","features":1152,"params":0},
              {"op":"Dense","name":"fc1","in_features":1152,"out_features":64,
               "weight_bits":1,"params":73728},
              {"op":"BatchNorm","name":"bn3","channels":64,"params":256},
              {"op":"BipolarAct","name":"a3","channels":64,"params":0},
              {"op":"Dense","name":"fc2","in_features":64,"out_features":10,
               "weight_bits":1,"params":640}
            ],"total_params":79856}"#
        }
        other => bail!("no built-in topology for '{other}'"),
    };
    Graph::from_json_str(json)
}

/// One serving accelerator: a (board, task, model, folding) bundle with
/// its flow-estimated performance envelope.
#[derive(Clone, Debug)]
pub struct BoardInstance {
    pub id: usize,
    /// `"<board>#<id>/<model>"`, for telemetry.
    pub label: String,
    pub board: Board,
    pub task: String,
    pub model: String,
    /// Batch-1 end-to-end latency (dataflow simulation).
    pub latency_s: f64,
    /// Steady-state per-inference interval once the pipeline is full.
    pub ii_s: f64,
    pub power_w: f64,
    pub energy_per_inference_uj: f64,
}

impl BoardInstance {
    /// Device time for a back-to-back batch of `n` inferences.
    /// Delegates to [`super::worker::DataflowTiming`] — the single home
    /// of the `latency + (n-1) * ii` model — so the executors' simulated
    /// device holds and the workers' energy accounting can never
    /// diverge.
    pub fn batch_latency_s(&self, n: usize) -> f64 {
        super::worker::DataflowTiming::for_instance(self, 1.0).batch_device_s(n)
    }

    /// Default executor for this instance: the simulated board, with the
    /// instance's flow-estimated latency/II as the device hold.  This is
    /// the factory the fleet hands to `run_worker` — one executor per
    /// replica, all behind `BatchExecutor`, so swapping in the
    /// pjrt-feature executor (or a mock in tests) changes nothing in the
    /// worker loop.
    pub fn executor(
        &self,
        device_batch: usize,
        time_scale: f64,
    ) -> super::worker::SimBoardExecutor {
        super::worker::SimBoardExecutor::for_instance(self, device_batch, time_scale)
    }

    /// Hand-specified instance (µs units) for tests and benches that
    /// don't want to run the codesign flow.
    pub fn synthetic(
        id: usize,
        task: &str,
        latency_us: f64,
        ii_us: f64,
        power_w: f64,
    ) -> Self {
        BoardInstance {
            id,
            label: format!("synthetic#{id}/{task}"),
            board: pynq_z2(),
            task: task.to_string(),
            model: format!("synthetic_{task}"),
            latency_s: latency_us * 1e-6,
            ii_s: ii_us * 1e-6,
            power_w,
            energy_per_inference_uj: power_w * ii_us,
        }
    }
}

/// The fleet's view of its hardware.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    pub instances: Vec<BoardInstance>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an instance with the default folding schedule.
    pub fn add(&mut self, board: Board, model: &str) -> Result<usize> {
        self.add_with(board, model, &ScheduleConfig::default())
    }

    /// Add an instance with an explicit folding schedule (heterogeneous
    /// replicas of the same model).  Runs the full codesign flow and
    /// refuses instances that do not fit their board.
    pub fn add_with(
        &mut self,
        board: Board,
        model: &str,
        schedule: &ScheduleConfig,
    ) -> Result<usize> {
        let g = builtin_topology(model)?;
        let fr = run_flow(&g, &board, &FlowOptions::default(), schedule)?;
        if !fr.fits {
            bail!("{model} does not fit on {}: {:?}", board.name, fr.resources.total);
        }
        let id = self.instances.len();
        let ii_s = fr.ii_cycles.max(1) as f64 / board.clock_hz;
        self.instances.push(BoardInstance {
            id,
            label: format!("{}#{id}/{model}", board.name),
            board,
            task: g.task.clone(),
            model: model.to_string(),
            latency_s: fr.latency_s,
            ii_s,
            power_w: fr.power_w,
            energy_per_inference_uj: fr.energy_per_inference_uj,
        });
        Ok(id)
    }

    /// The reference heterogeneous fleet: every task on both boards (6
    /// instances), with the Arty replicas folded down to a quarter of the
    /// multiplier budget — slower but cheaper, the codesign trade the
    /// router gets to play with.
    pub fn standard_fleet() -> Result<Registry> {
        let mut reg = Registry::new();
        let fast = ScheduleConfig::default();
        let slow = ScheduleConfig {
            finn_mult_budget: fast.finn_mult_budget / 4,
            ..fast.clone()
        };
        for model in BUILTIN_MODELS {
            reg.add_with(pynq_z2(), model, &fast)?;
            reg.add_with(arty_a7_100t(), model, &slow)?;
        }
        Ok(reg)
    }

    /// Clone instance `template` as a new replica with the next id and a
    /// fresh label.  The codesign numbers (latency, II, power, energy)
    /// carry over — replicating a deployed accelerator re-uses its flow
    /// results; it does not re-run the flow.  This is how the autoscaler
    /// grows a task's replica set at runtime.
    pub fn add_replica_of(&mut self, template: usize) -> Result<usize> {
        let Some(tmpl) = self.instances.get(template) else {
            bail!("no instance {template} to replicate");
        };
        let mut inst = tmpl.clone();
        inst.id = self.instances.len();
        inst.label = format!("{}#{}/{}", inst.board.name, inst.id, inst.model);
        self.instances.push(inst);
        Ok(self.instances.len() - 1)
    }

    /// Instance ids hosting `task`'s model.
    pub fn eligible(&self, task: &str) -> Vec<usize> {
        self.instances
            .iter()
            .filter(|i| i.task == task)
            .map(|i| i.id)
            .collect()
    }

    /// Distinct tasks the fleet can serve, in instance order.
    pub fn tasks(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for i in &self.instances {
            if !out.contains(&i.task) {
                out.push(i.task.clone());
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_topologies_validate() {
        for m in BUILTIN_MODELS {
            let g = builtin_topology(m).unwrap();
            assert!(g.validate().is_ok(), "{m}");
            assert!(g.total_macs() > 0, "{m}");
        }
        assert!(builtin_topology("nope").is_err());
    }

    #[test]
    fn standard_fleet_covers_all_tasks_on_both_boards() {
        let reg = Registry::standard_fleet().unwrap();
        assert_eq!(reg.len(), 6);
        for task in ["kws", "ad", "ic"] {
            let ids = reg.eligible(task);
            assert_eq!(ids.len(), 2, "{task}: {ids:?}");
        }
        assert_eq!(reg.tasks().len(), 3);
        for inst in &reg.instances {
            assert!(inst.latency_s > 0.0, "{}", inst.label);
            assert!(inst.ii_s > 0.0 && inst.ii_s <= inst.latency_s, "{}", inst.label);
            assert!(inst.energy_per_inference_uj > 0.0, "{}", inst.label);
        }
    }

    #[test]
    fn folded_down_replica_is_slower() {
        let mut reg = Registry::new();
        let fast = ScheduleConfig::default();
        let slow = ScheduleConfig { finn_mult_budget: 64, ..fast.clone() };
        let a = reg.add_with(pynq_z2(), "kws_mlp_w3a3", &fast).unwrap();
        let b = reg.add_with(pynq_z2(), "kws_mlp_w3a3", &slow).unwrap();
        assert!(reg.instances[b].ii_s > reg.instances[a].ii_s * 2.0);
    }

    #[test]
    fn add_replica_of_clones_costs_with_fresh_identity() {
        let mut reg = Registry {
            instances: vec![BoardInstance::synthetic(0, "kws", 100.0, 10.0, 1.5)],
        };
        let id = reg.add_replica_of(0).unwrap();
        assert_eq!(id, 1);
        let (a, b) = (&reg.instances[0], &reg.instances[1]);
        assert_eq!(b.id, 1);
        assert_ne!(a.label, b.label);
        assert_eq!(a.task, b.task);
        assert_eq!(a.latency_s, b.latency_s);
        assert_eq!(a.ii_s, b.ii_s);
        assert_eq!(a.energy_per_inference_uj, b.energy_per_inference_uj);
        assert_eq!(reg.eligible("kws"), vec![0, 1]);
        assert!(reg.add_replica_of(9).is_err());
    }

    #[test]
    fn batch_latency_scales_with_ii() {
        let i = BoardInstance::synthetic(0, "kws", 100.0, 10.0, 1.5);
        let one = i.batch_latency_s(1);
        let eight = i.batch_latency_s(8);
        assert!((one - 100e-6).abs() < 1e-12);
        assert!((eight - 170e-6).abs() < 1e-12);
    }
}
