//! Class-aware request queues: the fleet's multi-tenant priority plane.
//!
//! The paper's accelerators hit their 20 µs-class latencies because every
//! request class gets a hardware path sized for it; a single FIFO per
//! board throws that away at the serving layer — one burst of low-value
//! batch traffic parks latency-critical requests behind a full queue.
//! This module replaces the PR 1 single-FIFO `BoardQueue` with a
//! **class-aware queue plane**:
//!
//! * every [`FleetRequest`] carries a [`RequestTag`] — `(tenant,
//!   [`Priority`])` — set at submit time;
//! * each board queue keeps one FIFO subqueue *per class* and picks work
//!   with **strict priority for `Interactive`** plus weighted
//!   deficit-round-robin (unit-cost DRR, [`WRR_WEIGHTS`]) between
//!   `Standard` and `Batch`;
//! * a bounded **anti-starvation guard** ([`INTERACTIVE_BURST`]) forces
//!   one lower-class pick after that many consecutive `Interactive` pops
//!   while lower-class work waits, so even a saturating interactive
//!   stream cannot starve the other classes;
//! * admission is **tiered** ([`admit_limit`]): `Batch` is admitted only
//!   while the queue is under half its capacity, `Standard` up to
//!   capacity minus a small interactive reserve, `Interactive` up to the
//!   full bound — so overload sheds `Batch` first instead of
//!   tail-dropping every class uniformly.
//!
//! Per-class depth and peak-depth counters ride next to the aggregate
//! ones; `reset_peak` rolls *all* of them over in one place, so each
//! counter keeps a single consumer (the `Fleet::snapshot_phase` report
//! rollover — the autoscaler samples instantaneous depth instead).
//!
//! [`BoardQueue::fifo`] builds the queue in **FIFO-compat mode** (one
//! arrival order across classes, uniform admission): the control
//! baseline `benches/fleet.rs` measures priority scheduling against, and
//! the `FleetConfig::fifo_queues` escape hatch.

use super::trace::TraceCtx;
use crate::coordinator::engine::Reply;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

/// Request class, highest urgency first.  The discriminants index the
/// per-class counters everywhere (queues, telemetry, report JSON).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-critical (a user is waiting).  Strict-priority pickup,
    /// admitted up to the full queue bound, never shed before the
    /// other classes.
    Interactive = 0,
    /// The default class: normal request traffic.
    #[default]
    Standard = 1,
    /// Throughput traffic with no latency target (bulk scoring, AD
    /// archive sweeps).  First to be shed under overload, served through
    /// the DRR weight so it still progresses under sustained load.
    Batch = 2,
}

/// Number of priority classes (array dimension of every per-class
/// counter).
pub const N_CLASSES: usize = 3;

impl Priority {
    pub const ALL: [Priority; N_CLASSES] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Index into per-class counter arrays.
    pub fn idx(&self) -> usize {
        *self as usize
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Who a request belongs to and how urgent it is.  Rides every
/// [`FleetRequest`]; the default tag (`tenant 0`, `Standard`) keeps the
/// untagged `FleetHandle::submit` path behaving like the pre-priority
/// fleet — with one deliberate admission delta: on queues of 16+ slots,
/// `Standard` is admitted only up to `cap - cap/16` ([`admit_limit`]),
/// the small reserve held for `Interactive`, so an all-Standard burst
/// that used to fill the last `cap/16` slots now sheds there instead.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestTag {
    pub tenant: u32,
    pub priority: Priority,
    /// Relative deadline in µs (wall clock, unscaled), `0` = none.  The
    /// submit path turns it into an absolute [`FleetRequest::deadline`];
    /// past it the request is refused at submit, discarded at dequeue /
    /// window-close, and never retried.
    pub deadline_us: u64,
}

impl RequestTag {
    pub fn new(tenant: u32, priority: Priority) -> Self {
        RequestTag { tenant, priority, deadline_us: 0 }
    }

    /// Builder: attach a relative deadline (µs from submit; 0 = none).
    pub fn with_deadline_us(mut self, deadline_us: u64) -> Self {
        self.deadline_us = deadline_us;
        self
    }
}

/// One request in flight inside the fleet.
pub struct FleetRequest {
    pub x: Vec<f32>,
    /// Every admitted request gets **exactly one** terminal send on this
    /// channel: `Ok(Reply)` on success, or a typed
    /// [`FleetError`](super::FleetError) when its batch failed and the
    /// retry budget ran out — never a silently dropped sender.
    pub reply: mpsc::Sender<Result<Reply, super::FleetError>>,
    pub enqueued: Instant,
    /// Set by the submit path when result caching is on: the worker
    /// inserts its output under this key after executing.
    pub cache_key: Option<u64>,
    /// Tenant + priority class; drives queue pickup, admission, and the
    /// per-class telemetry split.
    pub tag: RequestTag,
    /// Lifecycle trace context, `Some` only for sampled requests
    /// (`FleetConfig::trace_sample`).  Boxed so the unsampled hot path
    /// carries one pointer-sized `None` and pays exactly one branch.
    pub trace: Option<Box<TraceCtx>>,
    /// Failed batches this request has ridden (bounded by
    /// `FleetConfig::retry_budget`; past it the caller gets
    /// `FleetError::Exhausted`).
    pub attempts: u32,
    /// Slot id of the last replica this request failed on
    /// ([`NOT_FAILED`] = none) — the retry pump avoids re-routing onto
    /// it while siblings survive.
    pub failed_on: u32,
    /// Single-flight coalescing ([`super::coalesce`]): `Some` when this
    /// request leads an open flight.  It rides the request through
    /// routing and retries; whoever delivers the terminal outcome fans
    /// it to the flight's followers.  `None` on every request when
    /// coalescing is off — one pointer-sized field, zero hot-path cost.
    pub flight: Option<std::sync::Arc<super::coalesce::Flight>>,
    /// Absolute expiry, derived from `tag.deadline_us` at submit.
    /// Workers check it at dequeue and window-close and resolve expired
    /// requests with `FleetError::DeadlineExceeded` instead of
    /// executing dead work; the retry pump refuses to resubmit past it.
    pub deadline: Option<Instant>,
    /// `true` on the duplicate leg of a hedged request.  Both legs ride
    /// the same flight; the first terminal outcome fans to the caller,
    /// the loser is discarded at its next stage boundary (the flight is
    /// already `Done`).
    pub hedge: bool,
}

/// Sentinel for [`FleetRequest::failed_on`]: the request has not failed
/// anywhere yet.
pub const NOT_FAILED: u32 = u32::MAX;

/// Admission bound for `class` on a queue of capacity `cap` (total
/// depth, all classes combined, must be *below* this for the push to be
/// admitted).  `Batch` only gets the bottom half of the queue, so
/// overload sheds it first; `Standard` leaves a small reserve
/// (`cap/16`, only on queues of 16+) that only `Interactive` may use;
/// `Interactive` is admitted to the full bound.
pub fn admit_limit(cap: usize, class: Priority) -> usize {
    match class {
        Priority::Interactive => cap,
        Priority::Standard => cap - if cap >= 16 { cap / 16 } else { 0 },
        Priority::Batch => (cap / 2).max(1),
    }
}

/// Unit-cost DRR quanta for the non-interactive classes
/// (`[Standard, Batch]`): with both backlogged, pickup serves four
/// `Standard` requests per `Batch` request.
pub const WRR_WEIGHTS: [u32; 2] = [4, 1];

/// Anti-starvation bound on strict priority: after this many consecutive
/// `Interactive` pops while lower-class work waits, one DRR pick from
/// the lower classes is forced, so `Standard`/`Batch` progress is
/// guaranteed even under a saturating interactive stream (at worst
/// 1/(`INTERACTIVE_BURST`+1) of pickups, split 4:1 by the DRR weights).
pub const INTERACTIVE_BURST: u32 = 16;

struct Inner {
    /// One FIFO per class, entries tagged with an arrival sequence so
    /// FIFO-compat mode can interleave classes in true arrival order.
    q: [VecDeque<(u64, FleetRequest)>; N_CLASSES],
    next_seq: u64,
    /// Remaining DRR credit for `[Standard, Batch]`.
    credit: [u32; 2],
    /// Which lower class the DRR visits first.
    wrr_cursor: usize,
    /// Consecutive Interactive pops while lower-class work waited.
    interactive_run: u32,
}

impl Inner {
    fn total(&self) -> usize {
        self.q.iter().map(|q| q.len()).sum()
    }
}

/// Bounded MPMC queue in front of one board (router pushes, the owning
/// worker pops, same-task workers steal), with per-class subqueues and
/// the pickup/admission policy described in the module docs.
pub struct BoardQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    depth: AtomicUsize,
    depth_class: [AtomicUsize; N_CLASSES],
    /// High-water marks, updated at push time (where depth is
    /// authoritative) — sampling depth after a batch drain would
    /// systematically read 0.  One total + one per class; all rolled
    /// over together by [`Self::reset_peak`] (single consumer).
    peak: AtomicUsize,
    peak_class: [AtomicUsize; N_CLASSES],
    cap: usize,
    /// `false` = FIFO-compat mode: arrival-order pickup, uniform
    /// admission (the pre-priority behavior, kept as the bench control).
    classful: bool,
    closed: AtomicBool,
}

impl BoardQueue {
    /// Class-aware queue (the default plane).
    pub fn new(cap: usize) -> Self {
        Self::with_mode(cap, true)
    }

    /// Single-FIFO control: arrival-order pickup, uniform tail-drop.
    pub fn fifo(cap: usize) -> Self {
        Self::with_mode(cap, false)
    }

    pub fn with_mode(cap: usize, classful: bool) -> Self {
        BoardQueue {
            inner: Mutex::new(Inner {
                q: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                next_seq: 0,
                credit: WRR_WEIGHTS,
                wrr_cursor: 0,
                interactive_run: 0,
            }),
            cv: Condvar::new(),
            depth: AtomicUsize::new(0),
            depth_class: Default::default(),
            peak: AtomicUsize::new(0),
            peak_class: Default::default(),
            cap: cap.max(1),
            classful,
            closed: AtomicBool::new(false),
        }
    }

    /// Lock-free read of the current total depth (router load signal).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Current depth of one class's subqueue.
    pub fn depth_class(&self, class: Priority) -> usize {
        self.depth_class[class.idx()].load(Ordering::Relaxed)
    }

    /// Interactive + Standard depth — the backlog that actually gates
    /// latency (Batch is deferrable and jumped by both other classes).
    /// The autoscaler's queue signal.
    pub fn depth_urgent(&self) -> usize {
        self.depth_class(Priority::Interactive) + self.depth_class(Priority::Standard)
    }

    /// Highest total depth observed at push time since the last
    /// [`Self::reset_peak`].
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Per-class push-time high-water marks since the last
    /// [`Self::reset_peak`].
    pub fn peak_class(&self) -> [usize; N_CLASSES] {
        [
            self.peak_class[0].load(Ordering::Relaxed),
            self.peak_class[1].load(Ordering::Relaxed),
            self.peak_class[2].load(Ordering::Relaxed),
        ]
    }

    /// Roll every high-water mark (total and per class) over to the
    /// *current* depth (not zero — a standing backlog must stay
    /// visible).  Called when telemetry snapshots roll over
    /// (`Fleet::snapshot_phase` at bench phase boundaries).
    /// Deliberately the **only** consumer of the peak counters: the
    /// autoscaler samples instantaneous depth instead, so a reset here
    /// never clobbers a control signal — and because the total and
    /// per-class marks reset together under the queue lock, they stay
    /// mutually consistent.
    pub fn reset_peak(&self) {
        let inner = self.inner.lock().unwrap();
        self.peak.store(inner.total(), Ordering::Relaxed);
        for (c, q) in inner.q.iter().enumerate() {
            self.peak_class[c].store(q.len(), Ordering::Relaxed);
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// `true` when this queue runs the class-aware plane (`false` in
    /// FIFO-compat mode).  Workers consult this so class-special
    /// behavior — like the Interactive execute-immediately batch opener
    /// — switches off together with the queue's priority pickup.
    pub fn is_classful(&self) -> bool {
        self.classful
    }

    /// Admit a request; hands it back if its class's admission bound is
    /// reached or the queue is closed.  Both conditions are checked
    /// under the lock: the bound so depth can never exceed it, and
    /// `closed` so a submit racing with shutdown cannot enqueue after
    /// the worker's final drain (the request would be stranded forever).
    /// In class-aware mode the bound is [`admit_limit`] for the
    /// request's class — overload sheds `Batch` first; FIFO-compat mode
    /// tail-drops every class at `cap` uniformly.
    pub fn try_push(&self, r: FleetRequest) -> Result<(), FleetRequest> {
        let mut inner = self.inner.lock().unwrap();
        if self.closed.load(Ordering::Acquire) {
            return Err(r);
        }
        let total = inner.total();
        let limit =
            if self.classful { admit_limit(self.cap, r.tag.priority) } else { self.cap };
        if total >= limit {
            return Err(r);
        }
        let c = r.tag.priority.idx();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.q[c].push_back((seq, r));
        let class_len = inner.q[c].len();
        self.depth.store(total + 1, Ordering::Relaxed);
        self.depth_class[c].store(class_len, Ordering::Relaxed);
        self.peak.fetch_max(total + 1, Ordering::Relaxed);
        self.peak_class[c].fetch_max(class_len, Ordering::Relaxed);
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Stop admitting; wakes the worker so it can drain and exit.  Takes
    /// the queue lock so closing serializes with in-flight pushes: after
    /// close() returns, any request that won the race is in the queue
    /// (depth > 0) and will be drained, and any later push is rejected.
    pub fn close(&self) {
        let guard = self.inner.lock().unwrap();
        self.closed.store(true, Ordering::Release);
        drop(guard);
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Remove the head of class subqueue `c` and refresh the depth
    /// counters.  Caller guarantees non-empty.
    fn take(&self, inner: &mut Inner, c: usize) -> FleetRequest {
        let (_seq, r) = inner.q[c].pop_front().expect("take from empty subqueue");
        self.depth_class[c].store(inner.q[c].len(), Ordering::Relaxed);
        self.depth.store(inner.total(), Ordering::Relaxed);
        r
    }

    /// One DRR pick over the non-interactive classes.  Terminates: if
    /// either subqueue is non-empty, a refill makes the next sweep
    /// spend.
    fn pop_lower(&self, inner: &mut Inner) -> Option<FleetRequest> {
        if inner.q[1].is_empty() && inner.q[2].is_empty() {
            return None;
        }
        loop {
            for k in 0..2 {
                let c = (inner.wrr_cursor + k) % 2;
                if !inner.q[c + 1].is_empty() && inner.credit[c] > 0 {
                    inner.credit[c] -= 1;
                    // Keep serving this class while it has credit; move
                    // the cursor on when the quantum is spent.
                    inner.wrr_cursor = if inner.credit[c] == 0 { (c + 1) % 2 } else { c };
                    return Some(self.take(inner, c + 1));
                }
            }
            inner.credit = WRR_WEIGHTS;
        }
    }

    /// Class-aware pickup: strict priority for Interactive, bounded by
    /// the anti-starvation guard; DRR between Standard and Batch.
    /// FIFO-compat mode pops in pure arrival order across classes.
    fn pop_locked(&self, inner: &mut Inner) -> Option<FleetRequest> {
        if !self.classful {
            let c = (0..N_CLASSES)
                .filter(|&c| !inner.q[c].is_empty())
                .min_by_key(|&c| inner.q[c].front().expect("non-empty").0)?;
            return Some(self.take(inner, c));
        }
        let lower_waiting = !inner.q[1].is_empty() || !inner.q[2].is_empty();
        if !inner.q[0].is_empty() {
            if !lower_waiting || inner.interactive_run < INTERACTIVE_BURST {
                inner.interactive_run =
                    if lower_waiting { inner.interactive_run + 1 } else { 0 };
                return Some(self.take(inner, 0));
            }
            // Guard tripped: one lower-class pick, then strict priority
            // resumes.
            inner.interactive_run = 0;
            return self.pop_lower(inner);
        }
        inner.interactive_run = 0;
        self.pop_lower(inner)
    }

    /// Block until a request is available; `None` once closed *and*
    /// drained.  Used by workers with stealing disabled — no periodic
    /// wakeups, `close()`'s notify_all is the exit signal.
    pub fn pop_blocking(&self) -> Option<FleetRequest> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(r) = self.pop_locked(&mut inner) {
                return Some(r);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            inner = self.cv.wait(inner).unwrap();
        }
    }

    /// Pop with a deadline (the batching window's `next` source).
    pub fn pop_until(&self, deadline: Instant) -> Option<FleetRequest> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(r) = self.pop_locked(&mut inner) {
                return Some(r);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timeout) =
                self.cv.wait_timeout(inner, deadline.duration_since(now)).unwrap();
            inner = guard;
        }
    }

    /// Non-blocking pop (same-task replicas balancing a hot queue,
    /// draining a retired replica's closed queue, or an Interactive
    /// batch opener topping up without waiting out the window).  Uses
    /// the same class-aware pickup as the blocking pops, so a thief
    /// relieves the hottest class first.
    pub fn try_steal(&self) -> Option<FleetRequest> {
        let mut inner = self.inner.lock().unwrap();
        self.pop_locked(&mut inner)
    }

    /// Best-effort class upgrade of a queued coalesce leader: move the
    /// request carrying `flight` from a lower-class subqueue into
    /// `to`'s, retagging it, so a more urgent duplicate arriving behind
    /// a `Batch` leader lifts the whole flight instead of soloing.
    /// O(queue depth) under the lock, but only runs on the coalesce-hit
    /// path with a class mismatch — never on the hot path.  Returns
    /// `false` when the leader was already dequeued (or lives in this
    /// queue at `to` or better); the upgrade is then moot because the
    /// flight is at (or past) pickup anyway.
    pub fn promote_flight(
        &self,
        flight: &std::sync::Arc<super::coalesce::Flight>,
        to: Priority,
    ) -> bool {
        if !self.classful {
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        for c in (to.idx() + 1)..N_CLASSES {
            let pos = inner.q[c].iter().position(|(_, r)| {
                r.flight.as_ref().is_some_and(|f| std::sync::Arc::ptr_eq(f, flight))
            });
            if let Some(pos) = pos {
                let (seq, mut r) = inner.q[c].remove(pos).expect("position just found");
                r.tag.priority = to;
                inner.q[to.idx()].push_back((seq, r));
                self.depth_class[c].store(inner.q[c].len(), Ordering::Relaxed);
                let class_len = inner.q[to.idx()].len();
                self.depth_class[to.idx()].store(class_len, Ordering::Relaxed);
                self.peak_class[to.idx()].fetch_max(class_len, Ordering::Relaxed);
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(
        tag: RequestTag,
    ) -> (FleetRequest, mpsc::Receiver<Result<Reply, super::super::FleetError>>) {
        let (tx, rx) = mpsc::channel();
        (
            FleetRequest {
                x: vec![0.0],
                reply: tx,
                enqueued: Instant::now(),
                cache_key: None,
                tag,
                trace: None,
                attempts: 0,
                failed_on: NOT_FAILED,
                flight: None,
                deadline: None,
                hedge: false,
            },
            rx,
        )
    }

    fn push(q: &BoardQueue, p: Priority) -> bool {
        let (r, _rx) = mk(RequestTag::new(0, p));
        q.try_push(r).is_ok()
    }

    #[test]
    fn queue_bounds_are_strict() {
        let q = BoardQueue::new(2);
        assert!(push(&q, Priority::Standard));
        assert!(push(&q, Priority::Standard));
        assert!(!push(&q, Priority::Standard), "cap 2 must reject the 3rd");
        assert_eq!(q.depth(), 2);
        assert!(q.try_steal().is_some());
        assert_eq!(q.depth(), 1);
        q.close();
        assert!(!push(&q, Priority::Standard), "closed queue rejects");
        assert!(q.pop_until(Instant::now()).is_some(), "drains after close");
        assert!(q.pop_until(Instant::now()).is_none());
    }

    #[test]
    fn admission_is_tiered_batch_shed_first() {
        // cap 16: batch bound 8, standard bound 15 (one-slot interactive
        // reserve), interactive bound 16.
        assert_eq!(admit_limit(16, Priority::Batch), 8);
        assert_eq!(admit_limit(16, Priority::Standard), 15);
        assert_eq!(admit_limit(16, Priority::Interactive), 16);
        let q = BoardQueue::new(16);
        let mut batch_in = 0;
        for _ in 0..20 {
            if push(&q, Priority::Batch) {
                batch_in += 1;
            }
        }
        assert_eq!(batch_in, 8, "batch stops at half the queue");
        let mut std_in = 0;
        for _ in 0..20 {
            if push(&q, Priority::Standard) {
                std_in += 1;
            }
        }
        assert_eq!(std_in, 7, "standard stops at the interactive reserve");
        assert!(push(&q, Priority::Interactive), "reserve admits interactive");
        assert!(!push(&q, Priority::Interactive), "full queue rejects even interactive");
        assert_eq!(q.depth(), 16);
        assert_eq!(q.depth_class(Priority::Batch), 8);
        assert_eq!(q.depth_urgent(), 8);
    }

    #[test]
    fn small_queues_have_no_interactive_reserve() {
        // Below 16 slots the reserve rounds to zero: standard admits to
        // the full bound (the pre-priority behavior for tiny queues).
        assert_eq!(admit_limit(4, Priority::Standard), 4);
        assert_eq!(admit_limit(4, Priority::Batch), 2);
        assert_eq!(admit_limit(1, Priority::Batch), 1);
    }

    #[test]
    fn pickup_is_strict_priority_then_weighted() {
        let q = BoardQueue::new(64);
        for _ in 0..8 {
            push(&q, Priority::Standard);
        }
        for _ in 0..2 {
            push(&q, Priority::Batch);
        }
        for _ in 0..3 {
            push(&q, Priority::Interactive);
        }
        let mut order = Vec::new();
        while let Some(r) = q.try_steal() {
            order.push(r.tag.priority);
        }
        use Priority::*;
        assert_eq!(
            order,
            vec![
                Interactive,
                Interactive,
                Interactive,
                // DRR 4:1 over standard/batch once interactive drains.
                Standard,
                Standard,
                Standard,
                Standard,
                Batch,
                Standard,
                Standard,
                Standard,
                Standard,
                Batch,
            ]
        );
    }

    #[test]
    fn interactive_burst_cannot_starve_lower_classes() {
        let q = BoardQueue::new(4096);
        for _ in 0..20 {
            push(&q, Priority::Standard);
        }
        for _ in 0..20 {
            push(&q, Priority::Batch);
        }
        // Sustained interactive load: one new interactive arrival per
        // pickup, forever.  Without the guard the lower classes would
        // never be served.
        let mut lower_served = 0;
        let mut batch_served = 0;
        let mut pops = 0;
        while lower_served < 40 {
            push(&q, Priority::Interactive);
            let r = q.try_steal().expect("queue cannot be empty here");
            pops += 1;
            if r.tag.priority != Priority::Interactive {
                lower_served += 1;
            }
            if r.tag.priority == Priority::Batch {
                batch_served += 1;
            }
            assert!(
                pops <= 40 * (INTERACTIVE_BURST as usize + 1) + 1,
                "lower classes starving: {lower_served}/40 after {pops} pops"
            );
        }
        assert_eq!(batch_served, 20, "batch must fully drain too");
        // Interactive itself was never starved: it got the lion's share.
        assert!(pops as u32 >= 40 * INTERACTIVE_BURST);
    }

    #[test]
    fn fifo_mode_preserves_arrival_order_and_uniform_admission() {
        let q = BoardQueue::fifo(4);
        assert!(push(&q, Priority::Batch));
        assert!(push(&q, Priority::Interactive));
        assert!(push(&q, Priority::Batch));
        assert!(push(&q, Priority::Standard));
        // Uniform tail-drop: batch filled the queue and interactive is
        // rejected like everyone else.
        assert!(!push(&q, Priority::Interactive), "fifo mode has no reserve");
        use Priority::*;
        let mut order = Vec::new();
        while let Some(r) = q.try_steal() {
            order.push(r.tag.priority);
        }
        assert_eq!(order, vec![Batch, Interactive, Batch, Standard]);
    }

    #[test]
    fn promote_flight_moves_queued_leader_to_stronger_class() {
        use super::super::coalesce::{Attach, Coalescer};
        let co = Coalescer::new();
        let (ltx, _lrx) = mpsc::channel();
        let flight = match co.attach_or_lead(42, Priority::Batch, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("first keyed request must lead"),
        };
        let q = BoardQueue::new(64);
        push(&q, Priority::Standard);
        let (mut leader, _rx) = mk(RequestTag::new(0, Priority::Batch));
        leader.flight = Some(flight.clone());
        q.try_push(leader).map_err(|_| ()).expect("push leader");
        push(&q, Priority::Standard);
        assert_eq!(q.depth_class(Priority::Batch), 1);

        assert!(q.promote_flight(&flight, Priority::Interactive));
        assert_eq!(q.depth_class(Priority::Batch), 0);
        assert_eq!(q.depth_class(Priority::Interactive), 1);
        assert_eq!(q.depth(), 3, "promotion moves, never drops");

        // The promoted leader now wins pickup and carries the new class.
        let first = q.try_steal().expect("non-empty");
        assert_eq!(first.tag.priority, Priority::Interactive);
        assert!(first.flight.is_some());
        // A second promotion finds nothing: the leader is gone.
        assert!(!q.promote_flight(&flight, Priority::Interactive));
    }

    #[test]
    fn per_class_peaks_reset_to_current_depth_not_zero() {
        let q = BoardQueue::new(64);
        for _ in 0..5 {
            push(&q, Priority::Standard);
        }
        for _ in 0..3 {
            push(&q, Priority::Batch);
        }
        for _ in 0..6 {
            q.try_steal();
        }
        assert_eq!(q.peak(), 8);
        assert_eq!(q.peak_class(), [0, 5, 3]);
        q.reset_peak();
        // Standing backlog of 2 stays visible after the rollover; class
        // marks roll to their own current depths.
        assert_eq!(q.peak(), 2);
        let pc = q.peak_class();
        assert_eq!(pc.iter().sum::<usize>(), 2);
        push(&q, Priority::Interactive);
        assert_eq!(q.peak(), 3, "peak tracks pushes again after reset");
        assert_eq!(q.peak_class()[0], 1);
    }
}
