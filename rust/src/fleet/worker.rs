//! Per-board worker threads and their bounded request queues.
//!
//! Each board instance owns one [`BoardQueue`] (Mutex + Condvar; the
//! vendored crate set has no crossbeam) and one worker thread.  The
//! worker drains its queue through the *same* dynamic-batching window as
//! the single-model engine ([`crate::coordinator::engine::fill_window`]),
//! optionally steals queued requests from same-task replicas when its own
//! queue runs dry before the device batch fills, then "executes" the
//! batch by holding the board for the dataflow-simulated device time:
//! `latency + (n-1) * ii`, scaled by the fleet's `time_scale`.
//!
//! Outputs come from the packed quantized kernel core
//! ([`crate::kernels`]): each task's class templates are quantized and
//! packed **once per process** behind a `OnceLock` and shared by every
//! replica worker (the seed rebuilt the f32 templates per replica
//! thread), and each worker drives the shared matrix with its own
//! scratch arena and staging buffers, reused across batches — the
//! steady-state serve loop allocates only the per-request reply vectors.

use super::cache::ResultCache;
use super::registry::BoardInstance;
use super::telemetry::Telemetry;
use crate::coordinator::engine::{fill_window, BatchPolicy, Reply};
use crate::kernels::{PackedLinear, ScratchArena, SmoothKernel};
use crate::runtime::argmax;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// One request in flight inside the fleet.
pub struct FleetRequest {
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
    pub enqueued: Instant,
    /// Set by the submit path when result caching is on: the worker
    /// inserts its output under this key after executing.
    pub cache_key: Option<u64>,
}

/// Bounded MPMC queue in front of one board (router pushes, the owning
/// worker pops, same-task workers steal).
pub struct BoardQueue {
    q: Mutex<VecDeque<FleetRequest>>,
    cv: Condvar,
    depth: AtomicUsize,
    /// High-water mark, updated at push time (where depth is
    /// authoritative) — sampling depth after a batch drain would
    /// systematically read 0.
    peak: AtomicUsize,
    cap: usize,
    closed: AtomicBool,
}

impl BoardQueue {
    pub fn new(cap: usize) -> Self {
        BoardQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            depth: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            cap: cap.max(1),
            closed: AtomicBool::new(false),
        }
    }

    /// Lock-free read of the current depth (router load signal).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Highest depth ever observed at push time.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Admit a request; hands it back if the queue is full or closed.
    /// Both conditions are checked under the lock: the cap so depth can
    /// never exceed it, and `closed` so a submit racing with shutdown
    /// cannot enqueue after the worker's final drain (the request would
    /// be stranded forever).
    pub fn try_push(&self, r: FleetRequest) -> Result<(), FleetRequest> {
        let mut q = self.q.lock().unwrap();
        if self.closed.load(Ordering::Acquire) || q.len() >= self.cap {
            return Err(r);
        }
        q.push_back(r);
        self.depth.store(q.len(), Ordering::Relaxed);
        self.peak.fetch_max(q.len(), Ordering::Relaxed);
        drop(q);
        self.cv.notify_one();
        Ok(())
    }

    /// Stop admitting; wakes the worker so it can drain and exit.  Takes
    /// the queue lock so closing serializes with in-flight pushes: after
    /// close() returns, any request that won the race is in the queue
    /// (depth > 0) and will be drained, and any later push is rejected.
    pub fn close(&self) {
        let guard = self.q.lock().unwrap();
        self.closed.store(true, Ordering::Release);
        drop(guard);
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Block until a request is available; `None` once closed *and*
    /// drained.  Used by workers with nothing to steal from — no
    /// periodic wakeups, `close()`'s notify_all is the exit signal.
    pub fn pop_blocking(&self) -> Option<FleetRequest> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(r) = q.pop_front() {
                self.depth.store(q.len(), Ordering::Relaxed);
                return Some(r);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    /// Pop with a deadline (the batching window's `next` source).
    pub fn pop_until(&self, deadline: Instant) -> Option<FleetRequest> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(r) = q.pop_front() {
                self.depth.store(q.len(), Ordering::Relaxed);
                return Some(r);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) =
                self.cv.wait_timeout(q, deadline.duration_since(now)).unwrap();
            q = guard;
            if timeout.timed_out() && q.is_empty() {
                return None;
            }
        }
    }

    /// Non-blocking steal (same-task replicas balancing a hot queue).
    pub fn try_steal(&self) -> Option<FleetRequest> {
        let mut q = self.q.lock().unwrap();
        let r = q.pop_front();
        if r.is_some() {
            self.depth.store(q.len(), Ordering::Relaxed);
        }
        r
    }
}

/// Per-task packed class templates, quantized once per process and
/// shared by every replica worker of that task.
static PACKED_KWS: OnceLock<Arc<PackedLinear>> = OnceLock::new();
static PACKED_IC: OnceLock<Arc<PackedLinear>> = OnceLock::new();

/// `None` for any task without a template matrix (ad, or a hand-built
/// registry's nonstandard task name) — the caller falls back to the
/// smoothing path, which tolerates any input length.
fn shared_packed_templates(task: &str) -> Option<Arc<PackedLinear>> {
    let (cell, n_out, feat) = match task {
        "kws" => (&PACKED_KWS, crate::data::KWS_CLASSES, crate::data::KWS_DIM),
        "ic" => (&PACKED_IC, crate::data::IC_CLASSES, crate::data::IC_DIM),
        _ => return None,
    };
    Some(
        cell.get_or_init(|| {
            Arc::new(PackedLinear::pack(
                &crate::data::class_templates_f32(task, n_out),
                1.0 / feat as f32,
            ))
        })
        .clone(),
    )
}

/// Deterministic surrogate forward for a task (same family as
/// `runtime::sim`, minus the training dynamics — fleet boards serve a
/// frozen deployed model).  The packed weight matrix is shared across
/// replicas; scratch and staging are private to this executor.
pub struct SimBoardExecutor {
    /// Shared packed class templates (`None` for AD, which smooths).
    packed: Option<Arc<PackedLinear>>,
    smooth: SmoothKernel,
    scratch: ScratchArena,
    n_out: usize,
    feat: usize,
}

impl SimBoardExecutor {
    pub fn for_task(task: &str) -> Self {
        let (n_out, feat) = match task {
            "kws" => (crate::data::KWS_CLASSES, crate::data::KWS_DIM),
            "ic" => (crate::data::IC_CLASSES, crate::data::IC_DIM),
            _ => (crate::data::AD_DIM, crate::data::AD_DIM),
        };
        let packed = shared_packed_templates(task);
        SimBoardExecutor {
            packed,
            smooth: SmoothKernel::new(crate::data::AD_SMOOTH_WINDOW),
            scratch: ScratchArena::new(),
            n_out,
            feat,
        }
    }

    pub fn input_elems(&self) -> usize {
        self.feat
    }

    pub fn num_outputs(&self) -> usize {
        self.n_out
    }

    /// Forward `n` contiguous samples into `out` (`n * num_outputs`).
    /// One tiled pass over the shared packed weights per call.
    pub fn forward_batch_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), n * self.feat);
        debug_assert_eq!(out.len(), n * self.n_out);
        match &self.packed {
            Some(p) => p.gemm_batch(x, out, &mut self.scratch),
            None => {
                // Reconstruction: the deployed autoencoder returns the
                // denoised spectral profile (9-tap smoothing).
                for s in 0..n {
                    self.smooth.smooth_into(
                        &x[s * self.feat..(s + 1) * self.feat],
                        &mut out[s * self.n_out..(s + 1) * self.n_out],
                        &mut self.scratch,
                    );
                }
            }
        }
    }

    /// Single-sample convenience wrapper (tests, spot checks).
    pub fn forward1(&mut self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_out];
        self.forward_batch_into(x, 1, &mut out);
        out
    }
}

/// Hold the thread for `dur` with µs precision (plain `sleep` alone is
/// too coarse for microsecond-class accelerator latencies).  The
/// busy-wait is bounded in three stages: far out the thread *sleeps*
/// (leaving slack for timer jitter), in the mid window it yields to the
/// scheduler, and only the final `SPIN_WINDOW` spins — with periodic
/// yields in case of oversubscription — so boards simulating long
/// device times don't pin cores at 100%.
pub fn precise_sleep(dur: Duration) {
    let deadline = Instant::now() + dur;
    // Inside this remaining-time window, spin for µs precision; a
    // yield's latency (~1 µs) cannot overshoot meaningfully above it.
    const SPIN_WINDOW: Duration = Duration::from_micros(50);
    // Above this, hand the core back to the OS: sleep up to ~200 µs
    // short of the deadline (Linux timer slack is well under that).
    const SLEEP_WINDOW: Duration = Duration::from_micros(500);
    const SLEEP_SLACK: Duration = Duration::from_micros(200);
    const SPINS_PER_YIELD: u32 = 4096;
    let mut spins = 0u32;
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        let remaining = deadline - now;
        if remaining > SLEEP_WINDOW {
            std::thread::sleep(remaining - SLEEP_SLACK);
        } else if remaining > SPIN_WINDOW {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
            spins += 1;
            if spins >= SPINS_PER_YIELD {
                spins = 0;
                std::thread::yield_now();
            }
        }
    }
}

/// Knobs a worker needs beyond its instance.
pub struct WorkerConfig {
    pub batch: BatchPolicy,
    /// Wall-seconds per simulated device-second (1.0 = real time).
    pub time_scale: f64,
    /// Steal from same-task replicas when the own queue runs dry.
    pub work_stealing: bool,
}

/// Run one board's serve loop until its queue is closed and drained.
/// Returns the number of requests served.
pub fn run_worker(
    inst: &BoardInstance,
    own: &Arc<BoardQueue>,
    peers: &[Arc<BoardQueue>],
    cfg: &WorkerConfig,
    telemetry: &Telemetry,
    cache: Option<&ResultCache>,
) -> u64 {
    let mut exec = SimBoardExecutor::for_task(&inst.task);
    let feat = exec.input_elems();
    let n_out = exec.num_outputs();
    // Batch staging, reused across batches (grown to high-water mark).
    let mut xbuf: Vec<f32> = Vec::new();
    let mut obuf: Vec<f32> = Vec::new();
    let mut served = 0u64;
    // How long to wait on the own queue before checking peers for work
    // to steal (bounds the idle-replica pickup latency).
    let steal_poll = Duration::from_micros(200);

    let stealing = cfg.work_stealing && !peers.is_empty();

    loop {
        // First request of a batch: own queue first, then — if idle —
        // steal one from a same-task replica.  Without anyone to steal
        // from, park on the condvar instead of polling.
        let mut stolen = 0u64;
        let first = if stealing {
            loop {
                if let Some(r) = own.pop_until(Instant::now() + steal_poll) {
                    break r;
                }
                if let Some(r) = peers.iter().find_map(|p| p.try_steal()) {
                    stolen += 1;
                    break r;
                }
                if own.is_closed() && own.depth() == 0 {
                    return served;
                }
            }
        } else {
            match own.pop_blocking() {
                Some(r) => r,
                None => return served,
            }
        };
        let mut batch = fill_window(first, &cfg.batch, |deadline| own.pop_until(deadline));
        if stealing {
            'steal: for peer in peers {
                while batch.len() < cfg.batch.max_batch {
                    match peer.try_steal() {
                        Some(r) => {
                            batch.push(r);
                            stolen += 1;
                        }
                        None => continue 'steal,
                    }
                }
                break;
            }
        }

        let n = batch.len();
        // Hold the (simulated) accelerator for the batch's device time.
        let device_s = inst.batch_latency_s(n);
        let exec_start = Instant::now();
        precise_sleep(Duration::from_secs_f64(device_s * cfg.time_scale));
        let exec_us = exec_start.elapsed().as_micros();
        let energy_uj = inst.power_w * device_s * 1e6;

        // One tiled pass over the shared packed weights for the whole
        // batch (the seed re-walked the f32 template set per request).
        if xbuf.len() < n * feat {
            xbuf.resize(n * feat, 0.0);
        }
        if obuf.len() < n * n_out {
            obuf.resize(n * n_out, 0.0);
        }
        for (i, req) in batch.iter().enumerate() {
            // No length validation exists on the submit path, so degrade
            // gracefully on malformed inputs: truncate long ones, zero-pad
            // short ones (the logit scale stays 1/feat — deterministic
            // garbage out, never a panic).
            let m = req.x.len().min(feat);
            xbuf[i * feat..i * feat + m].copy_from_slice(&req.x[..m]);
            xbuf[i * feat + m..(i + 1) * feat].fill(0.0);
        }
        exec.forward_batch_into(&xbuf[..n * feat], n, &mut obuf[..n * n_out]);

        let mut latencies_us = Vec::with_capacity(n);
        let mut queue_us_sum = 0u128;
        for (i, req) in batch.iter().enumerate() {
            let out = obuf[i * n_out..(i + 1) * n_out].to_vec();
            let top1 = argmax(&out);
            if let (Some(c), Some(key)) = (cache, req.cache_key) {
                // Insert before replying so a caller that observed the
                // reply is guaranteed to hit on the next submit.
                c.insert(key, &out, top1);
            }
            let queue_us = exec_start.duration_since(req.enqueued).as_micros();
            queue_us_sum += queue_us;
            latencies_us.push(req.enqueued.elapsed().as_micros() as f64);
            let _ = req.reply.send(Reply {
                output: out,
                top1,
                batch_size: n,
                queue_us,
                exec_us,
            });
            served += 1;
        }
        telemetry.record_batch(
            inst.id,
            &latencies_us,
            queue_us_sum,
            exec_us,
            energy_uj,
            stolen,
            own.peak(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_bounds_are_strict() {
        let q = BoardQueue::new(2);
        let (tx, _rx) = mpsc::channel();
        let mk = || FleetRequest {
            x: vec![0.0],
            reply: tx.clone(),
            enqueued: Instant::now(),
            cache_key: None,
        };
        assert!(q.try_push(mk()).is_ok());
        assert!(q.try_push(mk()).is_ok());
        assert!(q.try_push(mk()).is_err(), "cap 2 must reject the 3rd");
        assert_eq!(q.depth(), 2);
        assert!(q.try_steal().is_some());
        assert_eq!(q.depth(), 1);
        q.close();
        assert!(q.try_push(mk()).is_err(), "closed queue rejects");
        assert!(q.pop_until(Instant::now()).is_some(), "drains after close");
        assert!(q.pop_until(Instant::now()).is_none());
    }

    #[test]
    fn sim_executor_shapes_and_determinism() {
        let mut e = SimBoardExecutor::for_task("kws");
        let x = vec![0.3f32; e.input_elems()];
        let a = e.forward1(&x);
        let b = e.forward1(&x);
        assert_eq!(a.len(), 12);
        assert_eq!(a, b);
        let mut ad = SimBoardExecutor::for_task("ad");
        let x = vec![0.5f32; ad.input_elems()];
        assert_eq!(ad.forward1(&x).len(), 128);
    }

    #[test]
    fn replicas_share_one_packed_matrix() {
        let a = SimBoardExecutor::for_task("kws");
        let b = SimBoardExecutor::for_task("kws");
        let (pa, pb) = (a.packed.as_ref().unwrap(), b.packed.as_ref().unwrap());
        assert!(Arc::ptr_eq(pa, pb), "replicas must share packed weights");
    }

    #[test]
    fn batched_forward_matches_single() {
        let mut e = SimBoardExecutor::for_task("kws");
        let feat = e.input_elems();
        let n_out = e.num_outputs();
        let ts = crate::data::test_set("kws", 5, 0xB00);
        let mut x = Vec::new();
        for s in &ts.samples {
            x.extend_from_slice(&s.x);
        }
        let mut out = vec![0.0f32; 5 * n_out];
        e.forward_batch_into(&x, 5, &mut out);
        for (i, s) in ts.samples.iter().enumerate() {
            assert_eq!(s.x.len(), feat);
            let single = e.forward1(&s.x);
            assert_eq!(&out[i * n_out..(i + 1) * n_out], &single[..], "sample {i}");
        }
    }

    #[test]
    fn precise_sleep_hits_target() {
        let t0 = Instant::now();
        precise_sleep(Duration::from_micros(300));
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_micros(300));
        assert!(dt < Duration::from_millis(50), "{dt:?}");
    }
}
