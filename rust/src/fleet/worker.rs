//! Per-board worker threads.
//!
//! Each board instance owns one class-aware [`BoardQueue`]
//! ([`super::queue`]; Mutex + Condvar — the vendored crate set has no
//! crossbeam) and one worker thread.  The worker drains its queue
//! through the *same* dynamic-batching window as the single-model engine
//! ([`crate::coordinator::engine::fill_window`]),
//! optionally steals queued requests from same-task replicas when its own
//! queue runs dry before the device batch fills, then hands the staged
//! batch to a [`BatchExecutor`] — the worker loop contains **no execute
//! path of its own**.  The dataflow device timing (`latency + (n-1) * ii`
//! stretched by `time_scale`) lives inside the executor
//! ([`DataflowTiming`]), so the engine's `serve_with`, these fleet
//! workers, and the pjrt-feature workers share one execution plane.
//!
//! Batch gathering is **class-aware**: the queue's pickup already hands
//! out `Interactive` work first, and a window *opened by* an Interactive
//! request never waits out the batching timer — it tops up with whatever
//! is queued right now and executes immediately.  Together the two rules
//! mean an Interactive request is never parked behind a full Batch
//! window: it either rides the earliest window (priority pickup pulls it
//! in while the window fills) or opens its own without the wait.
//!
//! Peer queues are a shared, **live** list ([`PeerList`]): replicas added
//! or retired at runtime by the autoscaler become visible to every
//! same-task worker on its next steal attempt, with no thread restarts.
//!
//! The steady-state loop takes **no fleet-global locks and allocates
//! nothing per request**: telemetry goes through a [`TelemetrySink`]
//! resolved once at spawn (the worker's own lock-shard — see
//! [`super::telemetry`]), staging (batch buffers, telemetry samples) is
//! reused across batches, and replies copy into recycled
//! [`crate::coordinator::pool::ReplyPool`] buffers that return to the
//! worker's pool when the caller drops them.  (The per-request `mpsc`
//! reply channel is the submit side's, not this loop's.)
//! `WorkerConfig::pooled_replies` switches the pool off for the
//! `FleetConfig::global_hotpath` A/B baseline.
//!
//! With single-flight coalescing on (`FleetConfig::coalesce`), the reply
//! loop is also the **fan-out point**: a leader's batch completion
//! deregisters its [`super::coalesce::Flight`] and sends a bit-identical
//! copy of the output to every follower; a leader whose retry budget
//! runs out fans the same typed [`FleetError`] instead — followers share
//! the leader's fate exactly (see [`super::coalesce`]).
//!
//! The worker is also the deadline plane's **cancellation point**: every
//! stage boundary a request crosses — dequeue, window close, and the
//! failed-batch retry decision — re-checks its absolute deadline, and an
//! expired request resolves to a typed
//! [`FleetError::DeadlineExceeded`] *instead of executing*.  The same
//! boundaries discard a hedge loser (a leg whose
//! [`Flight`](super::coalesce::Flight) another leg already resolved)
//! silently — the caller was answered through the flight fan-out, so the
//! loser owes nobody anything.  Each executed batch's outcome also feeds
//! the board's optional [`CircuitBreaker`], whose trip/restore
//! transitions land in the trace ring as fleet events.
//!
//! Outputs come from the packed quantized kernel core
//! ([`crate::kernels`]): each task's class templates are quantized and
//! packed **once per process** behind a `OnceLock` and shared by every
//! replica worker (the seed rebuilt the f32 templates per replica
//! thread), and each executor drives the shared matrix with its own
//! scratch arena; the worker's staging buffers are reused across
//! batches.  The batched GEMM's inner dot runs at the process-wide
//! [`crate::kernels::simd`] dispatch level (AVX2 / SSE2 / NEON;
//! `TINYML_FORCE_SCALAR=1` pins the scalar oracle), selected once at
//! first use — replica counts don't re-detect, and every replica is
//! bit-identical to every other regardless of level.

use super::cache::ResultCache;
use super::coalesce::Coalescer;
use super::health::{BoardHealth, BreakerTransition, CircuitBreaker};
use super::hedge::{DeadlineStats, HedgeController};
use super::queue::{BoardQueue, FleetRequest, Priority};
use super::registry::BoardInstance;
use super::telemetry::{ReplySample, TelemetrySink};
use super::trace::{DriftSample, EventRing, FleetEvent, TraceSample};
use super::FleetError;
use crate::coordinator::engine::{fill_window, BatchExecutor, BatchPolicy, Reply};
use crate::coordinator::pool::{PooledVec, ReplyPool};
use crate::error::{bail, Result};
use crate::kernels::{PackedLinear, ScratchArena, SmoothKernel};
use crate::runtime::argmax;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// Live same-task replica queues (own queue included; workers skip
/// themselves by pointer identity).  Shared between the fleet and its
/// workers so membership changes from `add_replica` / `retire_replica`
/// are visible without restarting anyone.
pub type PeerList = Arc<RwLock<Vec<Arc<BoardQueue>>>>;

/// Per-task packed class templates, quantized once per process and
/// shared by every replica worker of that task.
static PACKED_KWS: OnceLock<Arc<PackedLinear>> = OnceLock::new();
static PACKED_IC: OnceLock<Arc<PackedLinear>> = OnceLock::new();

/// `None` for any task without a template matrix (ad, or a hand-built
/// registry's nonstandard task name) — the caller falls back to the
/// smoothing path, which tolerates any input length.
fn shared_packed_templates(task: &str) -> Option<Arc<PackedLinear>> {
    let (cell, n_out, feat) = match task {
        "kws" => (&PACKED_KWS, crate::data::KWS_CLASSES, crate::data::KWS_DIM),
        "ic" => (&PACKED_IC, crate::data::IC_CLASSES, crate::data::IC_DIM),
        _ => return None,
    };
    Some(
        cell.get_or_init(|| {
            Arc::new(PackedLinear::pack(
                &crate::data::class_templates_f32(task, n_out),
                1.0 / feat as f32,
            ))
        })
        .clone(),
    )
}

/// Dataflow-predicted device occupancy for one executed batch: the board
/// is held for `latency + (n-1) * ii` device-seconds, stretched by the
/// fleet's wall-clock `time_scale`.  The timing lives *inside* the
/// executor (behind [`BatchExecutor::execute`]) — serve loops never time
/// devices themselves.
#[derive(Clone, Copy, Debug)]
pub struct DataflowTiming {
    /// Batch-1 end-to-end latency (device-seconds).
    pub latency_s: f64,
    /// Steady-state per-inference interval once the pipeline is full.
    pub ii_s: f64,
    /// Wall-seconds per simulated device-second (1.0 = real time).
    pub time_scale: f64,
}

impl DataflowTiming {
    /// No device hold at all (unit tests, conformance harnesses).
    pub const OFF: DataflowTiming =
        DataflowTiming { latency_s: 0.0, ii_s: 0.0, time_scale: 1.0 };

    pub fn for_instance(inst: &BoardInstance, time_scale: f64) -> Self {
        DataflowTiming { latency_s: inst.latency_s, ii_s: inst.ii_s, time_scale }
    }

    /// Unscaled device time for a back-to-back batch of `n` inferences.
    pub fn batch_device_s(&self, n: usize) -> f64 {
        self.latency_s + n.saturating_sub(1) as f64 * self.ii_s
    }

    /// Hold the calling thread for the batch's scaled device time.
    pub fn hold(&self, n: usize) {
        let wall_s = self.batch_device_s(n) * self.time_scale;
        if wall_s > 0.0 {
            precise_sleep(Duration::from_secs_f64(wall_s));
        }
    }
}

/// Deterministic surrogate forward for a task (same family as
/// `runtime::sim`, minus the training dynamics — fleet boards serve a
/// frozen deployed model), as a [`BatchExecutor`]: `execute` holds the
/// simulated accelerator for the batch's dataflow-predicted device time,
/// then runs one tiled pass over the shared packed weights.  The packed
/// weight matrix is shared across replicas; scratch and staging are
/// private to this executor.
pub struct SimBoardExecutor {
    /// Shared packed class templates (`None` for AD, which smooths).
    packed: Option<Arc<PackedLinear>>,
    smooth: SmoothKernel,
    scratch: ScratchArena,
    n_out: usize,
    feat: usize,
    timing: DataflowTiming,
    device_batch: usize,
}

impl SimBoardExecutor {
    /// Untimed executor (tests, conformance): no device hold, default
    /// batch capacity.
    pub fn for_task(task: &str) -> Self {
        Self::with_timing(task, DataflowTiming::OFF, 64)
    }

    /// Executor for a registry instance: the instance's flow-estimated
    /// latency/II become the device hold, capped at `device_batch`
    /// samples per execute.
    pub fn for_instance(
        inst: &BoardInstance,
        device_batch: usize,
        time_scale: f64,
    ) -> Self {
        Self::with_timing(
            &inst.task,
            DataflowTiming::for_instance(inst, time_scale),
            device_batch,
        )
    }

    pub fn with_timing(task: &str, timing: DataflowTiming, device_batch: usize) -> Self {
        let (n_out, feat) = match task {
            "kws" => (crate::data::KWS_CLASSES, crate::data::KWS_DIM),
            "ic" => (crate::data::IC_CLASSES, crate::data::IC_DIM),
            _ => (crate::data::AD_DIM, crate::data::AD_DIM),
        };
        let packed = shared_packed_templates(task);
        SimBoardExecutor {
            packed,
            smooth: SmoothKernel::new(crate::data::AD_SMOOTH_WINDOW),
            scratch: ScratchArena::new(),
            n_out,
            feat,
            timing,
            device_batch: device_batch.max(1),
        }
    }

    /// Forward `n` contiguous samples into `out` (`n * num_outputs`).
    /// One tiled pass over the shared packed weights per call.  Pure
    /// compute — no device hold (that is [`BatchExecutor::execute`]'s
    /// job).
    pub fn forward_batch_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), n * self.feat);
        debug_assert_eq!(out.len(), n * self.n_out);
        match &self.packed {
            Some(p) => p.gemm_batch(x, out, &mut self.scratch),
            None => {
                // Reconstruction: the deployed autoencoder returns the
                // denoised spectral profile (9-tap smoothing).
                for s in 0..n {
                    self.smooth.smooth_into(
                        &x[s * self.feat..(s + 1) * self.feat],
                        &mut out[s * self.n_out..(s + 1) * self.n_out],
                        &mut self.scratch,
                    );
                }
            }
        }
    }

    /// Single-sample convenience wrapper (tests, spot checks).
    pub fn forward1(&mut self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_out];
        self.forward_batch_into(x, 1, &mut out);
        out
    }
}

impl BatchExecutor for SimBoardExecutor {
    fn device_batch(&mut self) -> Result<usize> {
        Ok(self.device_batch)
    }

    fn input_elems(&self) -> usize {
        self.feat
    }

    fn num_outputs(&self) -> usize {
        self.n_out
    }

    fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
        if n == 0 || n > self.device_batch {
            bail!("live count {n} outside 1..={}", self.device_batch);
        }
        if x.len() < n * self.feat || out.len() < n * self.n_out {
            bail!(
                "batch buffers too small: x {} (need {}), out {} (need {})",
                x.len(),
                n * self.feat,
                out.len(),
                n * self.n_out
            );
        }
        self.timing.hold(n);
        let (feat, n_out) = (self.feat, self.n_out);
        self.forward_batch_into(&x[..n * feat], n, &mut out[..n * n_out]);
        Ok(())
    }
}

/// Fleet executor for the real PJRT backend (`--features pjrt`): the AOT
/// executable owns the compute, [`DataflowTiming`] holds the board for
/// the flow-predicted device occupancy exactly as the surrogate executor
/// does — `run_worker` cannot tell them apart.
#[cfg(feature = "pjrt")]
pub struct PjrtBoardExecutor {
    rt: crate::runtime::Runtime,
    model: crate::runtime::LoadedModel,
    timing: DataflowTiming,
}

#[cfg(feature = "pjrt")]
impl PjrtBoardExecutor {
    /// Load `inst.model`'s artifacts from `art_dir` and wrap them with
    /// `inst`'s dataflow-predicted occupancy.  PJRT handles are not
    /// `Send`, so call this *inside* the worker thread.
    pub fn load(
        art_dir: &std::path::Path,
        inst: &BoardInstance,
        time_scale: f64,
    ) -> Result<Self> {
        Ok(PjrtBoardExecutor {
            rt: crate::runtime::Runtime::cpu()?,
            model: crate::runtime::LoadedModel::load(art_dir, &inst.model)?,
            timing: DataflowTiming::for_instance(inst, time_scale),
        })
    }
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for PjrtBoardExecutor {
    fn device_batch(&mut self) -> Result<usize> {
        self.model.ensure_fwd_batch(&self.rt)
    }

    fn input_elems(&self) -> usize {
        self.model.manifest.input_elems()
    }

    fn num_outputs(&self) -> usize {
        self.model.manifest.num_outputs
    }

    fn execute(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> Result<()> {
        self.timing.hold(n);
        self.model.infer_prefix_into(&self.rt, x, n, out)
    }
}

/// Hold the thread for `dur` with µs precision (plain `sleep` alone is
/// too coarse for microsecond-class accelerator latencies).  The
/// busy-wait is bounded in three stages: far out the thread *sleeps*
/// (leaving slack for timer jitter), in the mid window it yields to the
/// scheduler, and only the final `SPIN_WINDOW` spins — with periodic
/// yields in case of oversubscription — so boards simulating long
/// device times don't pin cores at 100%.
pub fn precise_sleep(dur: Duration) {
    let deadline = Instant::now() + dur;
    // Inside this remaining-time window, spin for µs precision; a
    // yield's latency (~1 µs) cannot overshoot meaningfully above it.
    const SPIN_WINDOW: Duration = Duration::from_micros(50);
    // Above this, hand the core back to the OS: sleep up to ~200 µs
    // short of the deadline (Linux timer slack is well under that).
    const SLEEP_WINDOW: Duration = Duration::from_micros(500);
    const SLEEP_SLACK: Duration = Duration::from_micros(200);
    const SPINS_PER_YIELD: u32 = 4096;
    let mut spins = 0u32;
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        let remaining = deadline - now;
        if remaining > SLEEP_WINDOW {
            std::thread::sleep(remaining - SLEEP_SLACK);
        } else if remaining > SPIN_WINDOW {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
            spins += 1;
            if spins >= SPINS_PER_YIELD {
                spins = 0;
                std::thread::yield_now();
            }
        }
    }
}

/// A request rescued off a failed batch, headed back through the router
/// (the fleet's retry pump consumes these; `task` is the routing key).
pub struct RetryItem {
    pub task: String,
    pub req: FleetRequest,
}

/// Knobs a worker needs beyond its instance and executor.
pub struct WorkerConfig {
    pub batch: BatchPolicy,
    /// Steal from same-task replicas when the own queue runs dry.
    pub work_stealing: bool,
    /// Reply through a per-worker [`ReplyPool`] (the zero-allocation
    /// path).  `false` = allocate a fresh reply vector per request, the
    /// pre-PR behavior kept for the `global_hotpath` A/B control.
    pub pooled_replies: bool,
    /// Lifecycle tracing (`FleetConfig::trace_sample > 0`): the worker
    /// stamps dequeue / window-close edges on sampled requests, folds
    /// completed spans into its telemetry shard's stage histograms,
    /// accumulates flow-vs-measured exec drift per batch, and records
    /// steal / cache-insert-denied events into its board's event ring.
    /// `None` = tracing off; the serve loop pays one branch per edge.
    pub trace: Option<WorkerTraceConfig>,
    /// Where requests from failed batches go to be re-routed (`None` =
    /// no pump; they resolve to typed errors immediately).
    pub retry: Option<mpsc::Sender<RetryItem>>,
    /// Failed batches one request may ride before `Exhausted`.
    pub retry_budget: u32,
    /// This board's slot in the fleet's health plane: the worker beats
    /// it on every batch outcome so the controller can tell a sick
    /// replica from an idle one.  `None` = health off.
    pub health: Option<Arc<BoardHealth>>,
    /// Record flow-vs-measured drift per batch at this time scale.
    /// `Some` when tracing **or** health is on — health's drift-ratio
    /// ejection signal must not require request tracing.
    pub drift_time_scale: Option<f64>,
    /// Fleet-wide deadline ledger.  Unconditional (a request can carry
    /// its own deadline even in a fleet with no default): the worker
    /// checks expiry at every stage boundary and counts what it
    /// discarded here.
    pub deadline: Arc<DeadlineStats>,
    /// Hedge plane (`FleetConfig::hedge_p99 > 0`): the worker feeds
    /// observed spans + per-board drift into it and counts the hedge
    /// losers it discards.  `None` = hedging off.
    pub hedge: Option<Arc<HedgeController>>,
    /// This board's circuit breaker (`FleetConfig::breaker`): beaten
    /// with every executed batch's outcome; trip/restore transitions go
    /// to the trace ring.  `None` = breakers off.
    pub breaker: Option<Arc<CircuitBreaker>>,
}

/// Resolve one request from a failed batch: hand it to the retry pump
/// (budget permitting) or send the definitive typed error.  Exactly one
/// of those happens — the reply channel is never just dropped.  Returns
/// `true` when the request went back out for retry.
///
/// A retried request keeps its flight: only the *terminal* outcome fans
/// to coalesced followers, so both `Exhausted` sends here fan first —
/// followers share the leader's fate, reply or typed error.
///
/// Two triage rules run before the budget: a hedge loser whose flight
/// already resolved is discarded silently (the caller was answered by
/// the winning leg), and a request past its deadline resolves
/// [`FleetError::DeadlineExceeded`] instead of burning retry budget on
/// work nobody can use — the retry budget never outlives the deadline.
fn fail_request(
    mut req: FleetRequest,
    instance: usize,
    task: &str,
    retry: &Option<mpsc::Sender<RetryItem>>,
    budget: u32,
    coalesce: Option<&Coalescer>,
    deadline: &DeadlineStats,
    hedge: Option<&HedgeController>,
) -> bool {
    if req.hedge && req.flight.as_ref().is_some_and(|f| f.is_done()) {
        if let Some(hc) = hedge {
            hc.note_cancelled();
        }
        return false;
    }
    if req.deadline.is_some_and(|dl| Instant::now() >= dl) {
        deadline.expired_retry.fetch_add(1, Ordering::Relaxed);
        if let (Some(co), Some(f)) = (coalesce, req.flight.as_ref()) {
            co.fan_err(f, &FleetError::DeadlineExceeded);
        }
        let _ = req.reply.send(Err(FleetError::DeadlineExceeded));
        return false;
    }
    req.attempts += 1;
    req.failed_on = instance as u32;
    if req.attempts <= budget {
        if let Some(tx) = retry {
            return match tx.send(RetryItem { task: task.to_string(), req }) {
                Ok(()) => true,
                // Pump already gone (shutdown tail): resolve here.
                Err(mpsc::SendError(item)) => {
                    let attempts = item.req.attempts;
                    if let (Some(co), Some(f)) = (coalesce, item.req.flight.as_ref()) {
                        co.fan_err(f, &FleetError::Exhausted { attempts });
                    }
                    let _ = item.req.reply.send(Err(FleetError::Exhausted { attempts }));
                    false
                }
            };
        }
    }
    let attempts = req.attempts;
    if let (Some(co), Some(f)) = (coalesce, req.flight.as_ref()) {
        co.fan_err(f, &FleetError::Exhausted { attempts });
    }
    let _ = req.reply.send(Err(FleetError::Exhausted { attempts }));
    false
}

/// Which stage boundary a triage check runs at (selects the
/// [`DeadlineStats`] counter an expiry discard is booked under).
#[derive(Clone, Copy)]
enum TriageStage {
    Dequeue,
    WindowClose,
}

/// Stage-boundary triage: decide whether a picked-up request is still
/// worth serving *at* `now`.  A hedge loser (its flight already resolved
/// through the other leg) is discarded silently; a request past its
/// deadline resolves to a typed [`FleetError::DeadlineExceeded`] — in
/// both cases the request never reaches the executor.  Returns the
/// request back when it should keep going.
fn triage_request(
    req: FleetRequest,
    now: Instant,
    stage: TriageStage,
    cfg: &WorkerConfig,
    coalesce: Option<&Coalescer>,
) -> Option<FleetRequest> {
    if req.hedge && req.flight.as_ref().is_some_and(|f| f.is_done()) {
        if let Some(hc) = &cfg.hedge {
            hc.note_cancelled();
        }
        return None;
    }
    if req.deadline.is_some_and(|dl| now >= dl) {
        let counter = match stage {
            TriageStage::Dequeue => &cfg.deadline.expired_dequeue,
            TriageStage::WindowClose => &cfg.deadline.expired_window,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        if let (Some(co), Some(f)) = (coalesce, req.flight.as_ref()) {
            co.fan_err(f, &FleetError::DeadlineExceeded);
        }
        let _ = req.reply.send(Err(FleetError::DeadlineExceeded));
        return None;
    }
    Some(req)
}

/// Per-worker handles for the tracing layer ([`super::trace`]).
pub struct WorkerTraceConfig {
    /// This board's event ring in the fleet's
    /// [`EventLog`](super::trace::EventLog).
    pub ring: Arc<EventRing>,
    /// Wall-seconds per simulated device-second — scales the registry's
    /// flow-predicted device hold to wall time so the drift accumulator
    /// compares like with like.
    pub time_scale: f64,
}

/// Run one board's serve loop until its queue is closed and drained.
/// Returns the number of requests served.
///
/// Generic over the executor: the default fleet passes
/// [`SimBoardExecutor`], the pjrt feature passes `PjrtBoardExecutor`
/// (not linked: it only exists under `--features pjrt`), and tests pass
/// mocks — the loop itself only stages batches, steals, replies, and
/// records telemetry.  It contains no inference and no device timing;
/// both live behind [`BatchExecutor::execute`].
pub fn run_worker<E: BatchExecutor>(
    inst: &BoardInstance,
    mut exec: E,
    own: &Arc<BoardQueue>,
    peers: &PeerList,
    cfg: &WorkerConfig,
    telemetry: &TelemetrySink,
    cache: Option<&ResultCache>,
    coalesce: Option<&Coalescer>,
) -> u64 {
    let device_batch = match exec.device_batch() {
        Ok(b) => b.max(1),
        Err(_) => {
            // An executor that cannot report capacity can never serve.
            // Keep draining so every caller gets a terminal outcome —
            // retried elsewhere or a typed error, never a hang.
            while let Some(req) = own.pop_blocking() {
                fail_request(
                    req,
                    inst.id,
                    &inst.task,
                    &cfg.retry,
                    cfg.retry_budget,
                    coalesce,
                    &cfg.deadline,
                    cfg.hedge.as_deref(),
                );
            }
            return 0;
        }
    };
    let window = BatchPolicy {
        max_batch: cfg.batch.max_batch.min(device_batch).max(1),
        max_wait: cfg.batch.max_wait,
    };
    let feat = exec.input_elems();
    let n_out = exec.num_outputs();
    // Batch staging sized to the full device batch once — fixed-batch
    // executors (PJRT AOT) require the whole padded buffer.
    let mut xbuf = vec![0.0f32; device_batch * feat];
    let mut obuf = vec![0.0f32; device_batch * n_out];
    // Reply buffers recycle through this worker's pool: a reply returns
    // its buffer when the caller drops it, so steady state the loop
    // allocates nothing per request.
    let pool = cfg.pooled_replies.then(|| ReplyPool::new(4 * device_batch.max(16)));
    // Telemetry staging, reused across batches (cleared, never shrunk).
    let mut samples: Vec<ReplySample> = Vec::with_capacity(window.max_batch);
    // Completed spans of sampled requests, reused the same way.
    let mut trace_samples: Vec<TraceSample> = Vec::with_capacity(window.max_batch);
    let mut served = 0u64;
    // How long to wait on the own queue before checking peers for work
    // to steal (bounds the idle-replica pickup latency).
    let steal_poll = Duration::from_micros(200);

    // One stolen request from a live same-task peer (the membership is
    // re-read every call, so replicas added or retired at runtime are
    // picked up without restarting this worker).
    let steal_one = |own: &Arc<BoardQueue>| -> Option<FleetRequest> {
        let list = peers.read().unwrap();
        list.iter().filter(|q| !Arc::ptr_eq(q, own)).find_map(|q| q.try_steal())
    };

    // Stamp the dequeue edge on a sampled request (first pickup wins —
    // a request stolen mid-window keeps its original dequeue stamp).
    fn stamp_dequeue(r: &mut FleetRequest) {
        if let Some(t) = r.trace.as_deref_mut() {
            if t.dequeued.is_none() {
                t.dequeued = Some(Instant::now());
            }
        }
    }

    loop {
        // First request of a batch: own queue first, then — if idle —
        // steal one from a same-task replica.  The closed check comes
        // *before* the steal so a retiring replica exits as soon as its
        // own queue is drained instead of lingering on peers' work.
        // Every pickup passes dequeue-stage triage (deadline expiry,
        // hedge-loser discard) before it counts as the batch opener.
        let mut stolen = 0u64;
        let mut first = if cfg.work_stealing {
            loop {
                if let Some(r) = own.pop_until(Instant::now() + steal_poll) {
                    match triage_request(r, Instant::now(), TriageStage::Dequeue, cfg, coalesce)
                    {
                        Some(r) => break r,
                        None => continue,
                    }
                }
                if own.is_closed() && own.depth() == 0 {
                    return served;
                }
                if let Some(r) = steal_one(own) {
                    match triage_request(r, Instant::now(), TriageStage::Dequeue, cfg, coalesce)
                    {
                        Some(r) => {
                            stolen += 1;
                            break r;
                        }
                        None => continue,
                    }
                }
            }
        } else {
            loop {
                match own.pop_blocking() {
                    Some(r) => match triage_request(
                        r,
                        Instant::now(),
                        TriageStage::Dequeue,
                        cfg,
                        coalesce,
                    ) {
                        Some(r) => break r,
                        None => continue,
                    },
                    None => return served,
                }
            }
        };
        stamp_dequeue(&mut first);
        // Class-aware gathering: an Interactive opener tops up with
        // whatever is queued *right now* and executes immediately —
        // holding a user-facing request hostage to the batching timer
        // just to fill the device would invert the priority the queue
        // worked to enforce.  Lower-class openers wait out the normal
        // window (and priority pickup pulls any Interactive arrival
        // into that same window ahead of the remaining backlog).  In
        // FIFO-compat mode the queue ignores priority, so this layer
        // must too — otherwise the control run the benches compare
        // against would keep a slice of the priority behavior.
        let mut batch = if own.is_classful() && first.tag.priority == Priority::Interactive
        {
            // Non-blocking `next`: the first empty poll ends the window,
            // so the timer never actually waits.  Triaged-away pickups
            // loop for a replacement instead of closing the window.
            fill_window(first, &window, |_| loop {
                match own.try_steal() {
                    Some(r) => match triage_request(
                        r,
                        Instant::now(),
                        TriageStage::Dequeue,
                        cfg,
                        coalesce,
                    ) {
                        Some(mut r) => {
                            stamp_dequeue(&mut r);
                            break Some(r);
                        }
                        None => continue,
                    },
                    None => break None,
                }
            })
        } else {
            fill_window(first, &window, |deadline| loop {
                match own.pop_until(deadline) {
                    Some(r) => match triage_request(
                        r,
                        Instant::now(),
                        TriageStage::Dequeue,
                        cfg,
                        coalesce,
                    ) {
                        Some(mut r) => {
                            stamp_dequeue(&mut r);
                            break Some(r);
                        }
                        None => continue,
                    },
                    None => break None,
                }
            })
        };
        if cfg.work_stealing && batch.len() < window.max_batch {
            // Top the batch up from peers under ONE read of the live
            // list: membership staleness within a single batch fill is
            // harmless, and re-locking per stolen request would put
            // O(batch) lock traffic on the serve loop.
            let list = peers.read().unwrap();
            'peers: for q in list.iter().filter(|q| !Arc::ptr_eq(q, own)) {
                while batch.len() < window.max_batch {
                    match q.try_steal() {
                        Some(r) => {
                            if let Some(mut r) = triage_request(
                                r,
                                Instant::now(),
                                TriageStage::Dequeue,
                                cfg,
                                coalesce,
                            ) {
                                stamp_dequeue(&mut r);
                                batch.push(r);
                                stolen += 1;
                            }
                        }
                        None => continue 'peers,
                    }
                }
                break;
            }
        }

        if cfg.trace.is_some() {
            // One stamp for the whole batch: the window closes for every
            // rider at the instant staging begins.
            let closed = Instant::now();
            for r in batch.iter_mut() {
                if let Some(t) = r.trace.as_deref_mut() {
                    t.window_closed = Some(closed);
                }
            }
        }

        // Window-close triage: batch membership is final here — the last
        // stage boundary where a dead request (expired, or a hedge leg
        // whose race is already lost) can be dropped without executing.
        // One timestamp covers the whole pass so the commitment check
        // below evaluates against the same instant.
        let committed = Instant::now();
        batch = batch
            .into_iter()
            .filter_map(|r| {
                triage_request(r, committed, TriageStage::WindowClose, cfg, coalesce)
            })
            .collect();
        if batch.is_empty() {
            continue;
        }
        // Commitment point: from here the batch reaches
        // `BatchExecutor::execute`.  Anything still expired at
        // `committed` would be executed dead work; the triage above
        // keeps this counter structurally zero (the scenario bench and
        // the ci smoke pin it there).
        for r in batch.iter() {
            if r.deadline.is_some_and(|dl| committed >= dl) {
                cfg.deadline.executed_expired.fetch_add(1, Ordering::Relaxed);
            }
        }

        let n = batch.len();
        for (i, req) in batch.iter().enumerate() {
            // Submit rejects wrong-length inputs for known tasks
            // (`RouteError::InvalidInput`), so a mismatch here means a
            // nonstandard task or a hand-built request.  Degrade
            // gracefully either way: truncate long ones, zero-pad short
            // ones (the logit scale stays 1/feat — deterministic garbage
            // out, never a panic).
            debug_assert!(
                crate::data::feature_dim_of(&inst.task).is_none()
                    || req.x.len() == feat,
                "wrong-length input slipped past submit validation: {} != {feat}",
                req.x.len()
            );
            let m = req.x.len().min(feat);
            xbuf[i * feat..i * feat + m].copy_from_slice(&req.x[..m]);
            xbuf[i * feat + m..(i + 1) * feat].fill(0.0);
        }
        // Fixed-batch executors run the padded tail too; keep it zeroed.
        xbuf[n * feat..].fill(0.0);

        // Energy comes from the registry's power model over *unscaled*
        // device time, so it is invariant to time_scale.
        let energy_uj = inst.power_w * inst.batch_latency_s(n) * 1e6;
        let exec_start = Instant::now();
        // The execute boundary is also the panic boundary: an executor
        // (or injected chaos) panic is caught here and handled exactly
        // like a device error, so one poisoned batch cannot take the
        // whole replica thread down silently.
        let exec_ok = matches!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                exec.execute(&xbuf, n, &mut obuf)
            })),
            Ok(Ok(()))
        );
        // Every executed batch's outcome beats this board's breaker;
        // trip/restore transitions are fleet events like ejections.
        if let Some(b) = &cfg.breaker {
            if let Some(transition) = b.note_batch(exec_ok, Instant::now()) {
                if let Some(tr) = &cfg.trace {
                    tr.ring.push(match transition {
                        BreakerTransition::Tripped { failure_rate_pct } => {
                            FleetEvent::BreakerTripped {
                                instance: inst.id,
                                failure_rate_pct,
                            }
                        }
                        BreakerTransition::Restored => {
                            FleetEvent::BreakerRestored { instance: inst.id }
                        }
                    });
                }
            }
        }
        if !exec_ok {
            // Device failure: the batch is **not lost**.  Every rider
            // goes back through the router via the retry pump — avoiding
            // this board while siblings survive — or resolves to a typed
            // `Exhausted` once its budget is spent.  The worker keeps
            // serving subsequent batches (health decides ejection).
            telemetry.record_exec_failure();
            if let Some(h) = &cfg.health {
                h.note_failure();
            }
            if let Some(tr) = &cfg.trace {
                tr.ring.push(FleetEvent::ExecFailed { instance: inst.id, batch: n });
            }
            let mut retried = 0usize;
            for req in batch.drain(..) {
                if fail_request(
                    req,
                    inst.id,
                    &inst.task,
                    &cfg.retry,
                    cfg.retry_budget,
                    coalesce,
                    &cfg.deadline,
                    cfg.hedge.as_deref(),
                ) {
                    retried += 1;
                }
            }
            if retried > 0 {
                telemetry.record_retried(retried as u64);
                if let Some(tr) = &cfg.trace {
                    tr.ring.push(FleetEvent::Retried {
                        instance: inst.id,
                        requests: retried,
                    });
                }
            }
            continue;
        }
        if let Some(h) = &cfg.health {
            h.note_success();
        }
        let exec_end = Instant::now();
        let exec_us = exec_end.duration_since(exec_start).as_micros();

        samples.clear();
        trace_samples.clear();
        let mut queue_us_sum = 0u128;
        for (i, req) in batch.iter().enumerate() {
            let slice = &obuf[i * n_out..(i + 1) * n_out];
            let out = match &pool {
                Some(p) => p.take_copy(slice),
                None => PooledVec::detached(slice.to_vec()),
            };
            let top1 = argmax(&out);
            if let (Some(c), Some(key)) = (cache, req.cache_key) {
                // Insert before replying so a caller that observed the
                // reply is guaranteed to hit on the next submit.  The
                // request's class tags the entry for class-aware
                // admission (Batch sweeps cannot flush Interactive's
                // working set).
                let admitted =
                    c.insert_tagged(&inst.task, key, &out, top1, req.tag.priority);
                if !admitted {
                    if let Some(tr) = &cfg.trace {
                        tr.ring.push(FleetEvent::CacheInsertDenied {
                            task: inst.task.clone(),
                            class: req.tag.priority,
                        });
                    }
                }
            }
            let queue_us = exec_start.duration_since(req.enqueued).as_micros();
            queue_us_sum += queue_us;
            samples.push(ReplySample {
                tenant: req.tag.tenant,
                priority: req.tag.priority,
                latency_us: req.enqueued.elapsed().as_micros() as f64,
            });
            // This batch completion is the request's terminal outcome:
            // deregister its flight and fan a bit-identical copy of the
            // output to every coalesced follower.  `finish` removes the
            // flight under the stripe lock first, so no new follower can
            // enrol after this snapshot (it would lead its own flight).
            if let (Some(co), Some(flight)) = (coalesce, req.flight.as_ref()) {
                let followers = co.finish(flight);
                if !followers.is_empty() {
                    co.note_fanned_ok(followers.len() as u64);
                    for ftx in &followers {
                        let copy = match &pool {
                            Some(p) => p.take_copy(&out),
                            None => PooledVec::detached(out.to_vec()),
                        };
                        let _ = ftx.send(Ok(Reply {
                            output: copy,
                            top1,
                            batch_size: n,
                            queue_us,
                            exec_us,
                        }));
                    }
                    // A hedged leg that took its flight's followers won
                    // the race: the caller was reached through the fan.
                    if req.hedge {
                        if let Some(hc) = &cfg.hedge {
                            hc.note_win();
                        }
                    }
                }
            }
            let _ = req.reply.send(Ok(Reply {
                output: out,
                top1,
                batch_size: n,
                queue_us,
                exec_us,
            }));
            // Feed the hedge threshold with this request's *observed*
            // submit→reply span.  Losers never execute, so a brownout's
            // slow spans stop polluting the seed once hedging starts
            // winning — the threshold stays anchored to healthy-sibling
            // latency.
            if let Some(hc) = &cfg.hedge {
                hc.note_span(req.tag.priority, req.enqueued.elapsed().as_micros() as u64);
            }
            if let Some(t) = req.trace.as_deref() {
                // Spans close here: reply = execute end → this send.
                // Missing stamps (hand-built requests) fall back to the
                // execute start so no span goes negative.
                let dequeued = t.dequeued.unwrap_or(exec_start);
                let closed = t.window_closed.unwrap_or(exec_start);
                trace_samples.push(TraceSample {
                    class: req.tag.priority,
                    queue_wait_us: dequeued.duration_since(req.enqueued).as_micros()
                        as u64,
                    window_wait_us: closed.duration_since(dequeued).as_micros() as u64,
                    exec_us: exec_us as u64,
                    reply_us: Instant::now().duration_since(exec_end).as_micros() as u64,
                });
            }
            served += 1;
        }
        telemetry.record_batch(
            &samples,
            queue_us_sum,
            exec_us,
            energy_uj,
            stolen,
            own.peak(),
            own.peak_class(),
        );
        if let Some(ts) = cfg.drift_time_scale {
            // Drift covers every executed batch (not only sampled ones):
            // the flow prediction and the measured hold both exist
            // regardless of request sampling — and the health
            // controller's drift-ratio signal reads this with tracing
            // off (`trace_samples` is just empty then).
            let pred_us = inst.batch_latency_s(n) * ts * 1e6;
            telemetry
                .record_trace(&trace_samples, Some(DriftSample { pred_us, obs_us: exec_us }));
            // The same per-batch drift corrects the submit path's hedge
            // estimate for this board (EWMA toward observed/predicted).
            if let Some(hc) = &cfg.hedge {
                hc.note_drift(inst.id, pred_us, exec_us as u64);
            }
        }
        if let Some(tr) = &cfg.trace {
            if stolen > 0 {
                tr.ring.push(FleetEvent::Steal { thief: inst.id, stolen });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_executor_shapes_and_determinism() {
        let mut e = SimBoardExecutor::for_task("kws");
        let x = vec![0.3f32; e.input_elems()];
        let a = e.forward1(&x);
        let b = e.forward1(&x);
        assert_eq!(a.len(), 12);
        assert_eq!(a, b);
        let mut ad = SimBoardExecutor::for_task("ad");
        let x = vec![0.5f32; ad.input_elems()];
        assert_eq!(ad.forward1(&x).len(), 128);
    }

    #[test]
    fn replicas_share_one_packed_matrix() {
        let a = SimBoardExecutor::for_task("kws");
        let b = SimBoardExecutor::for_task("kws");
        let (pa, pb) = (a.packed.as_ref().unwrap(), b.packed.as_ref().unwrap());
        assert!(Arc::ptr_eq(pa, pb), "replicas must share packed weights");
    }

    #[test]
    fn batched_forward_matches_single() {
        let mut e = SimBoardExecutor::for_task("kws");
        let feat = e.input_elems();
        let n_out = e.num_outputs();
        let ts = crate::data::test_set("kws", 5, 0xB00);
        let mut x = Vec::new();
        for s in &ts.samples {
            x.extend_from_slice(&s.x);
        }
        let mut out = vec![0.0f32; 5 * n_out];
        e.forward_batch_into(&x, 5, &mut out);
        for (i, s) in ts.samples.iter().enumerate() {
            assert_eq!(s.x.len(), feat);
            let single = e.forward1(&s.x);
            assert_eq!(&out[i * n_out..(i + 1) * n_out], &single[..], "sample {i}");
        }
    }

    #[test]
    fn executor_execute_respects_live_count_and_bounds() {
        let mut e = SimBoardExecutor::for_task("kws");
        let feat = e.input_elems();
        let n_out = e.num_outputs();
        let cap = e.device_batch().unwrap();
        let ts = crate::data::test_set("kws", 2, 0xB01);
        let mut x = vec![0.0f32; cap * feat];
        for (i, s) in ts.samples.iter().enumerate() {
            x[i * feat..(i + 1) * feat].copy_from_slice(&s.x);
        }
        let mut out = vec![f32::NAN; cap * n_out];
        e.execute(&x, 2, &mut out).unwrap();
        let single = e.forward1(&ts.samples[1].x);
        assert_eq!(&out[n_out..2 * n_out], &single[..]);
        assert!(out[2 * n_out..].iter().all(|v| v.is_nan()), "tail untouched");
        assert!(e.execute(&x, 0, &mut out).is_err());
        assert!(e.execute(&x, cap + 1, &mut out).is_err());
    }

    #[test]
    fn dataflow_timing_holds_scaled_device_time() {
        let t = DataflowTiming { latency_s: 100e-6, ii_s: 10e-6, time_scale: 2.0 };
        assert!((t.batch_device_s(1) - 100e-6).abs() < 1e-12);
        assert!((t.batch_device_s(8) - 170e-6).abs() < 1e-12);
        let t0 = Instant::now();
        t.hold(8); // 170 us * 2.0 = 340 us wall
        assert!(t0.elapsed() >= Duration::from_micros(340));
        let t0 = Instant::now();
        DataflowTiming::OFF.hold(64);
        assert!(t0.elapsed() < Duration::from_millis(5), "OFF must not sleep");
    }

    #[test]
    fn precise_sleep_hits_target() {
        let t0 = Instant::now();
        precise_sleep(Duration::from_micros(300));
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_micros(300));
        assert!(dt < Duration::from_millis(50), "{dt:?}");
    }

    use super::super::queue::RequestTag;

    fn mk_req(
        reply: mpsc::Sender<std::result::Result<Reply, FleetError>>,
        deadline: Option<Instant>,
        hedge: bool,
        flight: Option<Arc<super::super::coalesce::Flight>>,
    ) -> FleetRequest {
        FleetRequest {
            x: vec![0.0; 4],
            reply,
            enqueued: Instant::now(),
            cache_key: None,
            tag: RequestTag::default(),
            trace: None,
            attempts: 0,
            failed_on: super::super::queue::NOT_FAILED,
            flight,
            deadline,
            hedge,
        }
    }

    #[test]
    fn fail_request_never_retries_past_the_deadline() {
        // Budget remaining AND a live pump — but the deadline already
        // passed, so the rescue resolves typed instead of retrying.
        let (tx, rx) = mpsc::channel();
        let (ptx, prx) = mpsc::channel::<RetryItem>();
        let stats = DeadlineStats::default();
        let expired = Instant::now() - Duration::from_micros(1);
        let req = mk_req(tx, Some(expired), false, None);
        let retried =
            fail_request(req, 0, "kws", &Some(ptx), 3, None, &stats, None);
        assert!(!retried);
        assert!(matches!(rx.try_recv(), Ok(Err(FleetError::DeadlineExceeded))));
        assert!(prx.try_recv().is_err(), "nothing went to the pump");
        assert_eq!(stats.snapshot().expired_retry, 1);

        // Same request shape with headroom left on the deadline: the
        // retry budget applies as before and the rescue is pumped.
        let (tx, rx) = mpsc::channel();
        let (ptx, prx) = mpsc::channel::<RetryItem>();
        let live = Instant::now() + Duration::from_secs(60);
        let req = mk_req(tx, Some(live), false, None);
        let retried =
            fail_request(req, 0, "kws", &Some(ptx), 3, None, &stats, None);
        assert!(retried);
        assert!(rx.try_recv().is_err(), "no outcome yet — it is mid-retry");
        let item = prx.try_recv().expect("rescue reached the pump");
        assert_eq!(item.req.attempts, 1);
        assert_eq!(stats.snapshot().expired_retry, 1, "unchanged");
    }

    #[test]
    fn fail_request_discards_a_resolved_hedge_loser_silently() {
        let co = Coalescer::new();
        let hc = HedgeController::new(2.0);
        let stats = DeadlineStats::default();
        let flight = super::super::coalesce::Flight::standalone(Priority::Standard);
        // The other leg already resolved the race.
        co.fan_err(&flight, &FleetError::Exhausted { attempts: 1 });
        assert!(flight.is_done());
        let (tx, rx) = mpsc::channel();
        let req = mk_req(tx, None, true, Some(flight));
        let retried =
            fail_request(req, 0, "kws", &None, 3, Some(&co), &stats, Some(&hc));
        assert!(!retried);
        assert!(rx.try_recv().is_err(), "loser's throwaway channel owes nothing");
        assert_eq!(hc.stats().cancelled, 1);
        assert_eq!(stats.snapshot(), Default::default(), "no deadline discard booked");
    }
}
