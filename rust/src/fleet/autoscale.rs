//! Telemetry-driven replica autoscaling: grow/shrink same-task replicas
//! at runtime (the ROADMAP "Replica autoscaling" open item).
//!
//! The paper's designs are fixed spatial dataflow accelerators tuned per
//! board; hls4ml's reuse factor shows parallelism is a *configuration*,
//! not a constant.  This module lifts that to fleet scope: replica
//! *count* becomes a runtime knob.  A controller thread samples the
//! serving plane every [`AutoscaleConfig::interval`] and reads three
//! signals per task:
//!
//! * **queue depth** — each replica's instantaneous **urgent** depth
//!   (Interactive + Standard; a Batch backlog is deferrable by design
//!   and must not buy hardware) at the sampling instant, averaged over
//!   the task's replicas (the queues' peak high-water marks are left to
//!   `Fleet::snapshot_phase` — one reset-on-read counter cannot serve
//!   two consumers);
//! * **predicted latency vs SLO** — the same rule4ml-style flow
//!   estimate the latency-SLO router uses (`latency + depth * ii`, in
//!   unscaled device-µs), evaluated on the task's *least-loaded* active
//!   replica: if even the best replica would blow the SLO, queueing has
//!   outrun the hardware;
//! * **utilization** — Δ(device-execution µs) / (interval × replicas)
//!   from [`super::Telemetry::exec_us_totals`].
//!
//! Scale **up** clones the task's fastest instance
//! ([`super::Registry::add_replica_of`] — the flow numbers carry over,
//! no re-estimation) when the mean per-replica queue depth crosses
//! [`AutoscaleConfig::high_queue`] or the SLO estimate trips.  Scale
//! **down** retires the emptiest replica once utilization has stayed
//! below [`AutoscaleConfig::low_util`] with quiet queues for
//! [`LOW_TICKS_FOR_SCALE_DOWN`] consecutive samples (utilization is
//! quantized to batch completions, so one low sample can just mean a
//! long batch hold straddled the interval).  Retirement is
//! drain-then-join — the replica's queue is closed (racing submits
//! bounce to the re-read router and land on surviving replicas), the
//! worker drains every queued request, and only then is the thread
//! joined — so scale-down can never drop an admitted request.  Every
//! decision is recorded as a [`ScaleEvent`] riding the fleet snapshot
//! into `report::json`.

use crate::report::json::{num, obj, s, Value};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Autoscaler knobs.  Defaults suit time-scaled simulation (µs-class
/// device latencies stretched into the ms range); real deployments
/// would sample at seconds.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Sampling period of the controller thread.
    pub interval: Duration,
    /// Scale up when the mean per-replica *urgent* queue depth
    /// (Interactive + Standard — Batch backlog never buys hardware) at
    /// a sampling instant exceeds this.
    pub high_queue: f64,
    /// Scale up when the task's best replica's predicted completion
    /// latency (`latency + depth * ii`, unscaled device-µs — the same
    /// estimate the latency-SLO router uses) exceeds this.  `0` disables
    /// the SLO signal.
    pub slo_p99_us: f64,
    /// Scale down when per-replica utilization (device-µs executed per
    /// wall-µs) stays below this — with quiet queues — for
    /// [`LOW_TICKS_FOR_SCALE_DOWN`] consecutive samples.
    pub low_util: f64,
    /// Never shrink a task below this many replicas.
    pub min_replicas: usize,
    /// Never grow a task above this many replicas.
    pub max_replicas: usize,
    /// Minimum time between scale operations on the same task — one
    /// decision must show up in the signals before the next is made.
    pub cooldown: Duration,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            interval: Duration::from_millis(5),
            high_queue: 3.0,
            slo_p99_us: 0.0,
            low_util: 0.2,
            min_replicas: 1,
            max_replicas: 4,
            cooldown: Duration::from_millis(20),
        }
    }
}

/// Which way a scale operation went.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    Up,
    Down,
}

impl ScaleAction {
    pub fn name(&self) -> &'static str {
        match self {
            ScaleAction::Up => "up",
            ScaleAction::Down => "down",
        }
    }
}

/// One recorded scale decision (manual or controller-driven).
#[derive(Clone, Debug)]
pub struct ScaleEvent {
    /// Seconds since the fleet started.
    pub t_s: f64,
    pub action: ScaleAction,
    pub task: String,
    /// Instance slot added (Up) or retired (Down).
    pub instance: usize,
    pub label: String,
    /// What tripped the decision ("queue", "slo", "idle", "manual", ...).
    pub reason: String,
    /// Active replicas of `task` after the operation.
    pub replicas_after: usize,
}

impl ScaleEvent {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("t_s", num(self.t_s)),
            ("action", s(self.action.name())),
            ("task", s(&self.task)),
            ("instance", num(self.instance as f64)),
            ("label", s(&self.label)),
            ("reason", s(&self.reason)),
            ("replicas_after", num(self.replicas_after as f64)),
        ])
    }
}

impl fmt::Display for ScaleEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "t={:.3}s {} {} -> {} replicas ({}, [{}] {})",
            self.t_s,
            self.task,
            self.action.name(),
            self.replicas_after,
            self.reason,
            self.instance,
            self.label
        )
    }
}

/// Consecutive low-utilization ticks required before a scale-down.
/// Utilization is quantized to *batch completions* (a worker adds its
/// `exec_us` to telemetry only when a batch finishes), so a single tick
/// can read util = 0 while a replica is mid-hold on a batch longer than
/// the sampling interval; requiring several low ticks in a row makes
/// the observation window span at least one batch hold and keeps the
/// controller from retiring a busy replica and oscillating.
pub const LOW_TICKS_FOR_SCALE_DOWN: u32 = 3;

/// Per-task controller state across ticks.
struct TaskCtl {
    last_op: Option<Instant>,
    /// Consecutive ticks with util below the floor and quiet queues.
    low_ticks: u32,
}

/// The controller thread body: sample every `cfg.interval` until the
/// stop signal fires.  Spawned by `Fleet::start` when
/// `FleetConfig::autoscale` is set; `Fleet::shutdown` stops it *before*
/// closing queues, so no scale operation races the final drain.
pub(super) fn run_controller(
    state: Arc<super::FleetState>,
    cfg: AutoscaleConfig,
    stop: super::StopSignal,
) {
    let mut ctl: BTreeMap<String, TaskCtl> = BTreeMap::new();
    let mut prev_exec_us: Vec<u128> = state.telemetry.exec_us_totals();
    let mut last_tick = Instant::now();
    loop {
        {
            let (flag, cv) = &*stop;
            let guard = flag.lock().unwrap();
            if *guard {
                return;
            }
            let (guard, _) = cv.wait_timeout(guard, cfg.interval).unwrap();
            if *guard {
                return;
            }
        }
        let now = Instant::now();
        let interval_us = now.duration_since(last_tick).as_micros().max(1);
        last_tick = now;
        tick(&state, &cfg, &mut ctl, &mut prev_exec_us, interval_us);
    }
}

/// One sampling tick: read the three signals per task and decide.
fn tick(
    state: &Arc<super::FleetState>,
    cfg: &AutoscaleConfig,
    ctl: &mut BTreeMap<String, TaskCtl>,
    prev_exec_us: &mut Vec<u128>,
    interval_us: u128,
) {
    let reg = state.registry.lock().unwrap().clone();
    let exec_us = state.telemetry.exec_us_totals();
    // One read of the live plane: instantaneous depths, active flags,
    // and the router's latency estimator — released before any scale
    // operation.  The controller deliberately does NOT consume the
    // queues' peak high-water marks: those belong to
    // `Fleet::snapshot_phase` (report rollover), and sharing one
    // reset-on-read counter between two consumers would clobber both
    // signals.  Sampled every `interval`, instantaneous depth is an
    // equally persistent signal during a real backlog.
    //
    // The depth signal is the *urgent* backlog (Interactive + Standard):
    // Batch traffic is deferrable and shed first by admission, so a
    // Batch pile-up must not trip a scale-up — and since Interactive
    // work jumps the queue, the urgent depth is also what the
    // flow-latency SLO estimate should be evaluated over.  In
    // FIFO-compat mode there is no jumping — queued Batch work really
    // does delay urgent requests — so there the total depth is the
    // honest signal.
    let fifo = state.config.fifo_queues;
    let (active, depths, router) = {
        let p = state.plane.read().unwrap();
        let depths: Vec<usize> = p
            .queues
            .iter()
            .map(|q| if fifo { q.depth() } else { q.depth_urgent() })
            .collect();
        (p.active.clone(), depths, p.router.clone())
    };
    for task in reg.tasks() {
        let ids: Vec<usize> = reg
            .instances
            .iter()
            .filter(|i| i.task == task && active.get(i.id).copied().unwrap_or(false))
            .map(|i| i.id)
            .collect();
        if ids.is_empty() {
            continue;
        }
        let entry = ctl
            .entry(task.clone())
            .or_insert_with(|| TaskCtl { last_op: None, low_ticks: 0 });
        if entry.last_op.is_some_and(|t| t.elapsed() < cfg.cooldown) {
            continue;
        }
        // Signal 1: queue depth at sampling time, averaged per replica.
        let mean_depth = ids
            .iter()
            .map(|&i| depths.get(i).copied().unwrap_or(0) as f64)
            .sum::<f64>()
            / ids.len() as f64;
        // Signal 2: flow-estimated completion latency on the *best*
        // replica — if even it blows the SLO, queueing has outrun the
        // hardware and more hardware is the only fix.
        let best_pred_us = ids
            .iter()
            .map(|&i| router.predicted_latency_us(i, depths.get(i).copied().unwrap_or(0)))
            .fold(f64::INFINITY, f64::min);
        let slo_tripped = cfg.slo_p99_us > 0.0 && best_pred_us > cfg.slo_p99_us;
        // Signal 3: device-time utilization over the interval.
        let delta_us: u128 = ids
            .iter()
            .map(|&i| {
                exec_us
                    .get(i)
                    .copied()
                    .unwrap_or(0)
                    .saturating_sub(prev_exec_us.get(i).copied().unwrap_or(0))
            })
            .sum();
        let util = delta_us as f64 / (interval_us as f64 * ids.len() as f64);

        let quiet = util < cfg.low_util && mean_depth <= 1.0;
        entry.low_ticks = if quiet { entry.low_ticks + 1 } else { 0 };

        if (mean_depth > cfg.high_queue || slo_tripped) && ids.len() < cfg.max_replicas
        {
            let reason = match (mean_depth > cfg.high_queue, slo_tripped) {
                (true, true) => "queue+slo",
                (false, true) => "slo",
                _ => "queue",
            };
            if super::add_replica_inner(state, &task, reason).is_ok() {
                entry.last_op = Some(Instant::now());
                entry.low_ticks = 0;
            }
        } else if entry.low_ticks >= LOW_TICKS_FOR_SCALE_DOWN
            && ids.len() > cfg.min_replicas
        {
            // Retire the emptiest replica; ties retire the costliest
            // µJ/inference first, keeping the efficient boards.
            let victim = ids
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    depths[a].cmp(&depths[b]).then(
                        reg.instances[b]
                            .energy_per_inference_uj
                            .total_cmp(&reg.instances[a].energy_per_inference_uj),
                    )
                })
                .expect("ids non-empty");
            if super::retire_replica_inner(state, victim, "idle").is_ok() {
                entry.last_op = Some(Instant::now());
                entry.low_ticks = 0;
            }
        }
    }
    *prev_exec_us = exec_us;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = AutoscaleConfig::default();
        assert!(c.min_replicas >= 1);
        assert!(c.max_replicas >= c.min_replicas);
        assert!(c.high_queue > 0.0);
        assert!(c.low_util > 0.0 && c.low_util < 1.0);
        assert!(c.interval > Duration::ZERO);
    }

    #[test]
    fn events_serialize_and_render() {
        let e = ScaleEvent {
            t_s: 0.125,
            action: ScaleAction::Up,
            task: "kws".into(),
            instance: 6,
            label: "Pynq-Z2#6/kws_mlp_w3a3".into(),
            reason: "queue".into(),
            replicas_after: 3,
        };
        let j = e.to_json().to_json();
        assert!(j.contains("\"action\":\"up\""), "{j}");
        assert!(j.contains("\"replicas_after\":3"), "{j}");
        let text = e.to_string();
        assert!(text.contains("kws up -> 3 replicas"), "{text}");
    }
}
