//! Per-request lifecycle tracing: stage-latency histograms, a sequenced
//! fleet event log, and flow-vs-measured drift bookkeeping.
//!
//! # Design
//!
//! A sampled request (1 in `FleetConfig::trace_sample`; 0 = tracing off)
//! carries a boxed [`TraceCtx`] stamped at every lifecycle edge:
//!
//! ```text
//! submit --(cache lookup)--> route/admit --> enqueue ... dequeue
//!        --> batch-window close --> execute start/end --> reply copy
//! ```
//!
//! Workers fold each completed context into four per-class stage spans —
//! `queue_wait` (enqueue → dequeue), `window_wait` (dequeue → window
//! close), `exec` (device hold), `reply` (execute end → reply sent) —
//! recorded as [`StageHistogram`]s with **fixed log2 buckets** inside the
//! worker's own telemetry shard. Because a bucket count is just a `u64`,
//! shard histograms merge *losslessly* in `Telemetry::snapshot` by
//! element-wise addition: the merged histogram is bucket-exact equal to a
//! single global collector fed the same spans (property-tested in
//! `rust/tests/proptests.rs`).
//!
//! The hot path stays clean by construction: with tracing off a request
//! pays exactly one branch (`Option<Box<TraceCtx>>` is `None`); with
//! sampling on, unsampled requests additionally pay one relaxed atomic
//! increment in the sampler. `benches/hotpath.rs` pins the sampled
//! overhead (`traced_over_untraced_throughput >= 0.9`) as a gated
//! headline.
//!
//! Alongside spans, a bounded per-shard [`EventRing`] records discrete
//! fleet events — scale up/down with reason, sheds with a
//! [`ShedReason`] class, work steals, and cache Batch-insert denials —
//! under a fleet-wide monotone sequence number ([`SeqClock`]). Sequence
//! numbers are allocated *under the ring lock*, so each ring is
//! internally ordered by construction and the merged dump
//! ([`EventLog::dump_sorted`]) is a total order. `fleet --trace-dump`
//! emits the merged ring as JSONL, one strict-parsed object per line.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use super::queue::Priority;
use crate::report::json::{num, obj, s, Value};

// ---------------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------------

/// Lifecycle stages folded into per-class / per-board histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Enqueue → dequeue: time spent waiting in the board queue.
    QueueWait = 0,
    /// Dequeue → batch-window close: time spent waiting for the batch to fill.
    WindowWait = 1,
    /// Execute start → end: the device hold for the whole batch.
    Exec = 2,
    /// Execute end → reply sent: output copy, argmax, cache insert, send.
    Reply = 3,
}

/// Number of [`Stage`] variants (array dimension for stage sets).
pub const N_STAGES: usize = 4;

impl Stage {
    /// All stages in index order.
    pub const ALL: [Stage; N_STAGES] =
        [Stage::QueueWait, Stage::WindowWait, Stage::Exec, Stage::Reply];

    /// Stable snake_case name used in JSON reports.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::WindowWait => "window_wait",
            Stage::Exec => "exec",
            Stage::Reply => "reply",
        }
    }

    /// Array index for this stage.
    pub fn idx(self) -> usize {
        self as usize
    }
}

// ---------------------------------------------------------------------------
// Log2-bucket stage histogram
// ---------------------------------------------------------------------------

/// Number of log2 buckets; bucket 31 covers everything >= 2^31 µs (~36 min).
pub const N_BUCKETS: usize = 32;

/// Fixed log2-bucket latency histogram over microseconds.
///
/// Bucket `i` covers `[2^i, 2^(i+1))` µs, with 0 µs folded into bucket 0
/// and the last bucket open-ended. Merging is element-wise bucket
/// addition and therefore lossless: order of recording and sharding never
/// changes the merged result.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageHistogram {
    /// Per-bucket counts; bucket `i` covers `[2^i, 2^(i+1))` µs.
    pub buckets: [u64; N_BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Exact sum of recorded values (for the mean; merges losslessly too).
    pub sum_us: u128,
}

impl StageHistogram {
    /// Bucket index for a value in µs.
    pub fn bucket_of(us: u64) -> usize {
        if us < 2 {
            0
        } else {
            ((63 - us.leading_zeros()) as usize).min(N_BUCKETS - 1)
        }
    }

    /// Inclusive upper edge of bucket `i` in µs (used as the percentile
    /// estimate; the histogram rounds *up* to the bucket edge).
    pub fn bucket_edge_us(i: usize) -> u64 {
        if i >= N_BUCKETS - 1 {
            u64::MAX
        } else {
            1u64 << (i + 1)
        }
    }

    /// Record one span.
    pub fn record(&mut self, us: u64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us as u128;
    }

    /// Lossless merge: element-wise bucket addition.
    pub fn merge(&mut self, other: &StageHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of the recorded spans (exact, from the sum), 0 when empty.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile estimate, reported as the upper edge of
    /// the bucket holding the rank (an upper bound on the true value).
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_edge_us(i) as f64;
            }
        }
        Self::bucket_edge_us(N_BUCKETS - 1) as f64
    }

    /// JSON: `{count, mean_us, p50_us, p99_us, buckets: [[idx, count], ..]}`
    /// with only non-zero buckets listed.
    pub fn to_json(&self) -> Value {
        let buckets: Vec<Value> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Value::Arr(vec![num(i as f64), num(c as f64)]))
            .collect();
        obj(vec![
            ("count", num(self.count as f64)),
            ("mean_us", num(self.mean_us())),
            ("p50_us", num(self.percentile_us(0.50))),
            ("p99_us", num(self.percentile_us(0.99))),
            ("buckets", Value::Arr(buckets)),
        ])
    }
}

/// One histogram per [`Stage`], in `Stage::idx` order.
pub type StageSet = [StageHistogram; N_STAGES];

/// JSON object mapping stage names to their histogram JSON.
pub fn stage_set_to_json(set: &StageSet) -> Value {
    Value::Obj(
        Stage::ALL
            .iter()
            .map(|st| (st.name().to_string(), set[st.idx()].to_json()))
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Per-request trace context and folded samples
// ---------------------------------------------------------------------------

/// Lifecycle stamps carried by a sampled request (boxed on
/// `FleetRequest` so unsampled requests stay small and pay one branch).
///
/// Enqueue time is the request's own `enqueued` field; execute start/end
/// are batch-level stamps taken by the worker. Cache-served requests
/// never reach a worker, so their contexts end at the cache probe.
#[derive(Clone, Copy, Debug)]
pub struct TraceCtx {
    /// Stamped in `Fleet::submit` when the request is sampled.
    pub submitted: Instant,
    /// µs spent probing the result cache at submit (0 when caching off).
    pub cache_lookup_us: u32,
    /// µs spent in admission/route up to the winning queue push.
    pub route_us: u32,
    /// Stamped by the worker when the request is popped (or stolen).
    pub dequeued: Option<Instant>,
    /// Stamped by the worker once the batch window closes.
    pub window_closed: Option<Instant>,
}

impl TraceCtx {
    /// A fresh context stamped `submitted = now`.
    pub fn new() -> Self {
        TraceCtx {
            submitted: Instant::now(),
            cache_lookup_us: 0,
            route_us: 0,
            dequeued: None,
            window_closed: None,
        }
    }
}

impl Default for TraceCtx {
    fn default() -> Self {
        Self::new()
    }
}

/// A completed per-request trace folded into the four stage spans,
/// recorded into the worker's telemetry shard.
#[derive(Clone, Copy, Debug)]
pub struct TraceSample {
    /// Priority class of the traced request.
    pub class: Priority,
    /// Enqueue → dequeue, µs.
    pub queue_wait_us: u64,
    /// Dequeue → batch-window close, µs.
    pub window_wait_us: u64,
    /// Device hold for the batch the request rode in, µs.
    pub exec_us: u64,
    /// Execute end → reply sent, µs.
    pub reply_us: u64,
}

/// Per-batch flow-vs-measured drift observation: the registry's
/// flow-predicted device hold `latency + (n-1)·ii` (scaled by the
/// fleet's `time_scale`) vs the wall-clock `exec` span.
#[derive(Clone, Copy, Debug)]
pub struct DriftSample {
    /// Predicted device hold for the batch, µs.
    pub pred_us: f64,
    /// Observed device hold for the batch, µs.
    pub obs_us: u128,
}

// ---------------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------------

/// 1-in-N request sampler. With tracing off the sampler is never built,
/// so an unsampled request pays only the `Option` branch; with tracing
/// on, each submit pays one relaxed `fetch_add`.
pub struct Sampler {
    every: u64,
    counter: AtomicU64,
}

impl Sampler {
    /// `every` is clamped to at least 1 (1 = trace every request).
    pub fn new(every: usize) -> Self {
        Sampler { every: (every as u64).max(1), counter: AtomicU64::new(0) }
    }

    /// True for one request in `every`, starting with the first.
    pub fn sample(&self) -> bool {
        self.counter.fetch_add(1, Ordering::Relaxed) % self.every == 0
    }
}

// ---------------------------------------------------------------------------
// Shed reasons
// ---------------------------------------------------------------------------

/// Why a request was shed, split so overload diagnosis can tell tiered
/// admission, SLO-predicted infeasibility, and plain queue exhaustion
/// apart.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The router's tiered admission found no queue below the class's
    /// admit limit (`RouteError::Overloaded` out of `select_class`).
    AdmissionTier = 0,
    /// The SLO policy predicted the deadline cannot be met on any
    /// replica (`RouteError::SloUnattainable`).
    SloPredict = 1,
    /// Admission passed but every `try_push` retry found the queue
    /// closed or re-filled past the limit.
    QueueFull = 2,
    /// The flow-predicted completion time already misses the request's
    /// deadline at submit, so the request was refused instead of
    /// queueing dead work.
    Deadline = 3,
}

/// Number of [`ShedReason`] variants (array dimension for counters).
pub const N_SHED_REASONS: usize = 4;

impl ShedReason {
    /// All reasons in index order.
    pub const ALL: [ShedReason; N_SHED_REASONS] = [
        ShedReason::AdmissionTier,
        ShedReason::SloPredict,
        ShedReason::QueueFull,
        ShedReason::Deadline,
    ];

    /// Stable snake_case name used in JSON reports.
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::AdmissionTier => "admission_tier",
            ShedReason::SloPredict => "slo_predict",
            ShedReason::QueueFull => "queue_full",
            ShedReason::Deadline => "deadline",
        }
    }

    /// Array index for this reason.
    pub fn idx(self) -> usize {
        self as usize
    }
}

// ---------------------------------------------------------------------------
// Event ring
// ---------------------------------------------------------------------------

/// Discrete fleet events recorded in the ring alongside spans.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetEvent {
    /// A replica was added (autoscaler or manual), with the scale reason.
    ScaleUp { task: String, instance: usize, reason: String },
    /// A replica was retired, with the scale reason.
    ScaleDown { task: String, instance: usize, reason: String },
    /// A request was shed at submit, with its class and reason.
    Shed { class: Priority, reason: ShedReason },
    /// A worker stole work from peers while topping up a batch.
    Steal { thief: usize, stolen: u64 },
    /// The result cache refused a Batch-class insert to protect the
    /// interactive working set.
    CacheInsertDenied { task: String, class: Priority },
    /// A batch failed to execute on a board (device error, chaos
    /// injection, or a caught worker panic); its `batch` requests went
    /// to the retry channel or got typed errors.
    ExecFailed { instance: usize, batch: usize },
    /// Requests from a failed batch were re-submitted through the
    /// router.
    Retried { instance: usize, requests: usize },
    /// The health controller retired a sick replica (drain-then-join;
    /// the reason names the tripped signal, e.g. `ejected:failures:3`).
    ReplicaEjected { task: String, instance: usize, reason: String },
    /// A replica's circuit breaker tripped open: its rolling batch
    /// failure rate crossed the configured threshold, so the router
    /// masks it until the cooldown elapses and half-open probes pass.
    BreakerTripped { instance: usize, failure_rate_pct: u64 },
    /// A replica's breaker closed again after the half-open probe
    /// batches all succeeded; the replica is routable once more.
    BreakerRestored { instance: usize },
}

/// A sequenced, timestamped event as stored in a ring.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Fleet-wide monotone sequence number (allocated under the ring lock).
    pub seq: u64,
    /// µs since the event log was created.
    pub t_us: u64,
    /// The event payload.
    pub event: FleetEvent,
}

impl TraceEvent {
    /// One flat JSON object per event — the JSONL line shape emitted by
    /// `fleet --trace-dump`.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("seq".to_string(), num(self.seq as f64)),
            ("t_us".to_string(), num(self.t_us as f64)),
        ];
        let kind = match &self.event {
            FleetEvent::ScaleUp { task, instance, reason } => {
                fields.push(("task".to_string(), s(task)));
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("reason".to_string(), s(reason)));
                "scale_up"
            }
            FleetEvent::ScaleDown { task, instance, reason } => {
                fields.push(("task".to_string(), s(task)));
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("reason".to_string(), s(reason)));
                "scale_down"
            }
            FleetEvent::Shed { class, reason } => {
                fields.push(("class".to_string(), s(class.name())));
                fields.push(("reason".to_string(), s(reason.name())));
                "shed"
            }
            FleetEvent::Steal { thief, stolen } => {
                fields.push(("board".to_string(), num(*thief as f64)));
                fields.push(("stolen".to_string(), num(*stolen as f64)));
                "steal"
            }
            FleetEvent::CacheInsertDenied { task, class } => {
                fields.push(("task".to_string(), s(task)));
                fields.push(("class".to_string(), s(class.name())));
                "cache_insert_denied"
            }
            FleetEvent::ExecFailed { instance, batch } => {
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("batch".to_string(), num(*batch as f64)));
                "exec_failed"
            }
            FleetEvent::Retried { instance, requests } => {
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("requests".to_string(), num(*requests as f64)));
                "retried"
            }
            FleetEvent::ReplicaEjected { task, instance, reason } => {
                fields.push(("task".to_string(), s(task)));
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("reason".to_string(), s(reason)));
                "replica_ejected"
            }
            FleetEvent::BreakerTripped { instance, failure_rate_pct } => {
                fields.push(("instance".to_string(), num(*instance as f64)));
                fields.push(("failure_rate_pct".to_string(), num(*failure_rate_pct as f64)));
                "breaker_tripped"
            }
            FleetEvent::BreakerRestored { instance } => {
                fields.push(("instance".to_string(), num(*instance as f64)));
                "breaker_restored"
            }
        };
        fields.push(("event".to_string(), s(kind)));
        Value::Obj(fields.into_iter().collect())
    }
}

/// Default per-ring capacity (events, not bytes).
pub const EVENT_RING_CAP: usize = 1024;

/// Shared sequence allocator + epoch for all rings of one [`EventLog`].
pub struct SeqClock {
    seq: AtomicU64,
    t0: Instant,
}

struct RingInner {
    buf: VecDeque<TraceEvent>,
    dropped: u64,
    cap: usize,
}

/// A bounded event ring. When full, the *oldest* event is dropped (and
/// counted), so the ring always holds the newest `cap` events in
/// sequence order.
pub struct EventRing {
    clock: Arc<SeqClock>,
    inner: Mutex<RingInner>,
}

impl EventRing {
    fn new(clock: Arc<SeqClock>, cap: usize) -> Self {
        EventRing {
            clock,
            inner: Mutex::new(RingInner {
                buf: VecDeque::with_capacity(cap.min(64)),
                dropped: 0,
                cap: cap.max(1),
            }),
        }
    }

    /// Record an event; returns its sequence number. The sequence is
    /// allocated while holding the ring lock, so events within one ring
    /// are always stored in increasing-sequence order.
    pub fn push(&self, event: FleetEvent) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let seq = self.clock.seq.fetch_add(1, Ordering::Relaxed);
        let t_us = self.clock.t0.elapsed().as_micros() as u64;
        if g.buf.len() == g.cap {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(TraceEvent { seq, t_us, event });
        seq
    }

    /// Events currently retained, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.inner.lock().unwrap().buf.iter().cloned().collect()
    }

    /// Events dropped because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }
}

/// The fleet's event log: one ring per board shard plus a fleet-level
/// ring for submit-path and scaling events, all sequenced by one
/// [`SeqClock`]. Rings grow with replicas exactly like telemetry shards.
pub struct EventLog {
    clock: Arc<SeqClock>,
    fleet: Arc<EventRing>,
    rings: RwLock<Vec<Arc<EventRing>>>,
    cap_per_ring: usize,
}

impl EventLog {
    /// A log with `n_rings` board rings at the default capacity.
    pub fn new(n_rings: usize) -> Self {
        Self::with_capacity(n_rings, EVENT_RING_CAP)
    }

    /// A log with `n_rings` board rings of `cap` events each.
    pub fn with_capacity(n_rings: usize, cap: usize) -> Self {
        let clock = Arc::new(SeqClock { seq: AtomicU64::new(0), t0: Instant::now() });
        let rings = (0..n_rings)
            .map(|_| Arc::new(EventRing::new(clock.clone(), cap)))
            .collect();
        EventLog {
            fleet: Arc::new(EventRing::new(clock.clone(), cap)),
            clock,
            rings: RwLock::new(rings),
            cap_per_ring: cap,
        }
    }

    /// Add a ring for a new board shard; returns its id.
    pub fn add_ring(&self) -> usize {
        let mut rings = self.rings.write().unwrap();
        rings.push(Arc::new(EventRing::new(self.clock.clone(), self.cap_per_ring)));
        rings.len() - 1
    }

    /// The board ring with the given shard id.
    pub fn ring(&self, id: usize) -> Arc<EventRing> {
        self.rings.read().unwrap()[id].clone()
    }

    /// Record a fleet-level (non-board) event.
    pub fn record_fleet(&self, event: FleetEvent) -> u64 {
        self.fleet.push(event)
    }

    /// All retained events across every ring, sorted by sequence number.
    pub fn dump_sorted(&self) -> Vec<TraceEvent> {
        let mut all = self.fleet.snapshot();
        for r in self.rings.read().unwrap().iter() {
            all.extend(r.snapshot());
        }
        all.sort_by_key(|e| e.seq);
        all
    }

    /// Total events dropped across every ring.
    pub fn total_dropped(&self) -> u64 {
        let mut d = self.fleet.dropped();
        for r in self.rings.read().unwrap().iter() {
            d += r.dropped();
        }
        d
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_log2() {
        assert_eq!(StageHistogram::bucket_of(0), 0);
        assert_eq!(StageHistogram::bucket_of(1), 0);
        assert_eq!(StageHistogram::bucket_of(2), 1);
        assert_eq!(StageHistogram::bucket_of(3), 1);
        assert_eq!(StageHistogram::bucket_of(4), 2);
        assert_eq!(StageHistogram::bucket_of(1023), 9);
        assert_eq!(StageHistogram::bucket_of(1024), 10);
        assert_eq!(StageHistogram::bucket_of(u64::MAX), N_BUCKETS - 1);
        assert_eq!(StageHistogram::bucket_edge_us(0), 2);
        assert_eq!(StageHistogram::bucket_edge_us(10), 2048);
        assert_eq!(StageHistogram::bucket_edge_us(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn merge_is_elementwise_and_lossless() {
        let mut a = StageHistogram::default();
        let mut b = StageHistogram::default();
        let mut whole = StageHistogram::default();
        for (i, v) in [0u64, 1, 7, 300, 5_000, 1 << 40].iter().enumerate() {
            if i % 2 == 0 { a.record(*v) } else { b.record(*v) }
            whole.record(*v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, whole);
        assert_eq!(merged.count, 6);
        assert_eq!(merged.sum_us, (0u128 + 1 + 7 + 300 + 5_000 + (1 << 40)));
    }

    #[test]
    fn percentiles_round_up_to_bucket_edges() {
        let mut h = StageHistogram::default();
        for _ in 0..99 {
            h.record(100); // bucket 6: [64, 128)
        }
        h.record(1_000_000); // bucket 19
        assert_eq!(h.percentile_us(0.50), 128.0);
        assert_eq!(h.percentile_us(0.99), 128.0);
        assert_eq!(h.percentile_us(1.0), StageHistogram::bucket_edge_us(19) as f64);
        assert_eq!(StageHistogram::default().percentile_us(0.99), 0.0);
    }

    #[test]
    fn sampler_fires_one_in_n() {
        let s = Sampler::new(4);
        let fired: Vec<bool> = (0..12).map(|_| s.sample()).collect();
        let expect: Vec<bool> = (0..12).map(|i| i % 4 == 0).collect();
        assert_eq!(fired, expect);
        let every = Sampler::new(1);
        assert!((0..5).all(|_| every.sample()));
        // 0 clamps to 1 rather than dividing by zero.
        assert!(Sampler::new(0).sample());
    }

    #[test]
    fn ring_keeps_newest_in_order_and_counts_drops() {
        let log = EventLog::with_capacity(1, 4);
        let ring = log.ring(0);
        for i in 0..10usize {
            ring.push(FleetEvent::Steal { thief: i, stolen: 1 });
        }
        let kept = ring.snapshot();
        assert_eq!(kept.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let seqs: Vec<u64> = kept.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
    }

    #[test]
    fn dump_merges_rings_sorted_by_seq() {
        let log = EventLog::with_capacity(2, 16);
        log.ring(0).push(FleetEvent::Steal { thief: 0, stolen: 1 });
        log.record_fleet(FleetEvent::Shed {
            class: Priority::Batch,
            reason: ShedReason::QueueFull,
        });
        log.ring(1).push(FleetEvent::Steal { thief: 1, stolen: 2 });
        let id = log.add_ring();
        assert_eq!(id, 2);
        log.ring(2).push(FleetEvent::CacheInsertDenied {
            task: "kws".to_string(),
            class: Priority::Batch,
        });
        let all = log.dump_sorted();
        assert_eq!(all.len(), 4);
        let seqs: Vec<u64> = all.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
        assert_eq!(log.total_dropped(), 0);
    }

    #[test]
    fn events_serialize_to_strict_jsonl_lines() {
        let log = EventLog::with_capacity(1, 8);
        log.record_fleet(FleetEvent::ScaleUp {
            task: "ad".to_string(),
            instance: 3,
            reason: "queue+slo".to_string(),
        });
        log.record_fleet(FleetEvent::ScaleDown {
            task: "ad".to_string(),
            instance: 3,
            reason: "idle".to_string(),
        });
        log.ring(0).push(FleetEvent::Shed {
            class: Priority::Interactive,
            reason: ShedReason::AdmissionTier,
        });
        for e in log.dump_sorted() {
            let line = e.to_json().to_json();
            let parsed = Value::parse(&line).expect("trace-dump line must parse");
            assert_eq!(parsed.req("seq").expect("seq"), &num(e.seq as f64));
            assert!(parsed.req("event").is_ok());
        }
    }

    #[test]
    fn stage_set_json_names_all_stages() {
        let mut set = StageSet::default();
        set[Stage::Exec.idx()].record(500);
        let v = stage_set_to_json(&set);
        for st in Stage::ALL {
            assert!(v.req(st.name()).is_ok(), "missing stage {}", st.name());
        }
        let line = v.to_json();
        Value::parse(&line).expect("stage set JSON must parse");
    }
}
