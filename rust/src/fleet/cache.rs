//! Result cache: a bounded memo of (task, quantized input) → output
//! sitting **in front of** the router (the ROADMAP "Result caching"
//! open item), **lock-striped** so a cache-on fleet does not serialize
//! every submit on one mutex.
//!
//! Repeated requests — identical or near-identical after quantizing the
//! input to a 1/256 grid (comfortably finer than the i8 grid the packed
//! kernels themselves execute at) — skip routing, queueing, and device
//! execution entirely: the submit path answers from the memo and the
//! boards never see the request.  Workers populate the memo after
//! executing, keyed by a digest the submit path computed.
//!
//! **Striping (v3).**  The store is split into N independent lock
//! shards picked by the low bits of the key digest; every client thread
//! probing the cache and every worker inserting behind a miss lands on
//! its key's shard only.  The v2 cache held one mutex over the whole
//! map — with 8 concurrent submitters the entire fleet serialized on
//! it (the single hottest lock `benches/hotpath.rs` measures; the
//! `FleetConfig::global_hotpath` A/B control rebuilds that one-shard
//! layout via [`ResultCache::with_shards`]).  Each shard runs its own
//! LRU over its own slice of the capacity, so get/insert stay O(log n)
//! under one *short, shard-local* lock.
//!
//! **Class-aware admission (caching v3, ROADMAP follow-up).**  Entries
//! remember the [`Priority`] of the traffic that populated (or, for
//! `Interactive`, last hit) them.  A `Batch`-class insert may evict
//! only non-`Interactive` entries — if its shard is wall-to-wall
//! interactive working set, the batch result is simply not admitted —
//! so a bulk scoring sweep can flow through a full cache without
//! flushing the entries interactive users are actually hitting.
//! `Interactive`/`Standard` inserts evict plain LRU (an interactive
//! working set that really has gone cold is still reclaimable).
//!
//! **Caching v5: TTL, per-task splits, hit-rate-aware admission.**
//! Three admission/eviction features land together, all driven by the
//! per-task counters earlier versions already kept (see
//! [`CacheOptions`]):
//!
//! * **Per-entry TTL.**  With [`CacheOptions::ttl`] set, an entry older
//!   than the TTL is dropped the moment a probe touches it and the
//!   probe reports a plain miss.  Crucially the expired probe counts
//!   **nothing** — neither a hit (the stale answer was not served) nor
//!   a miss (misses are counted once, at [`ResultCache::insert_tagged`]
//!   time, when the re-executed result lands).  Counting the expiry as
//!   a hit — what a naive "found the key" path would do — would feed
//!   hit-rate-aware admission stale-hit noise and keep dead tasks
//!   looking cacheable.
//! * **Per-task capacity splits.**  With [`CacheOptions::task_cap`]
//!   set, no task may hold more than its split (divided over the
//!   shards like the total capacity).  A task at its split evicts *its
//!   own* oldest entry — class-aware, so a `Batch` insert still cannot
//!   reclaim an `Interactive` entry even of its own task — instead of
//!   squeezing its neighbours.  The victim scan walks the shard's LRU
//!   index (shards hold ≲64 entries, so the walk is short and stays
//!   under the shard-local lock).
//! * **Hit-rate-aware admission.**  With
//!   [`CacheOptions::hitrate_admission`], a task whose observed hit
//!   rate in a shard stays under [`HITRATE_ADMIT_FLOOR`] after
//!   [`HITRATE_MIN_OBS`] probes is mostly turned away: only one insert
//!   in [`HITRATE_PROBE_EVERY`] is admitted, so the counters keep
//!   learning and a workload that *starts* repeating is re-admitted
//!   within a few probes.  This is the "stop caching AD frames that
//!   never repeat" knob: anomaly-detection traffic is near-unique by
//!   construction, and before v5 it continually churned cache slots
//!   that KWS wake-words would actually re-hit.
//!
//! The key is a 64-bit FNV-1a digest of the task name and the quantized
//! input.  A 64-bit digest can collide in principle; at fleet request
//! volumes the probability is negligible (birthday bound ~n²/2⁶⁵) and
//! this is the standard memo-cache trade.  Eviction is LRU (since v2 —
//! the v1 memo was FIFO, which evicted hot steady-traffic entries as
//! soon as enough one-off AD frames flowed past them): recency is a
//! per-shard monotone tick plus a `BTreeMap<tick, key>` index.
//! Hit/miss counters are kept fleet-wide *and* per task, so the
//! snapshot can show which workload actually benefits (AD frames rarely
//! repeat; KWS wake-words do).
//!
//! The in-flight companion to this memo is [`super::coalesce`]: the
//! cache answers repeats of *completed* executions, the coalescer
//! collapses repeats of executions still *in flight*.

use super::queue::Priority;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default shard sizing: one lock per ~64 entries, between 1 (tiny
/// caches keep the exact single-lock semantics) and 16 shards.
const MAX_SHARDS: usize = 16;
const ENTRIES_PER_SHARD: usize = 64;

/// Hit-rate-aware admission only engages after this many per-shard
/// observations (hits + misses) of a task — a cold start must not be
/// mistaken for a never-repeating workload.
pub const HITRATE_MIN_OBS: u64 = 128;
/// Tasks observed below this hit rate are throttled.
pub const HITRATE_ADMIT_FLOOR: f64 = 0.05;
/// While throttled, one insert in this many is still admitted as a
/// probe so a workload that starts repeating is re-discovered.
pub const HITRATE_PROBE_EVERY: u64 = 8;

/// Per-task slice of the hit/miss counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskCacheStats {
    pub task: String,
    pub hits: u64,
    pub misses: u64,
}

/// Hit/miss counters plus occupancy, for telemetry and `report::json`.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub cap: usize,
    /// Per-task counters, sorted by task name.
    pub per_task: Vec<TaskCacheStats>,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Caching-v5 admission/eviction knobs.  `Default` (no TTL, no split,
/// admission off) reproduces the v4 cache exactly — [`ResultCache::new`]
/// and [`ResultCache::with_shards`] build that configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheOptions {
    /// Per-entry time-to-live; `None` disables expiry.
    pub ttl: Option<Duration>,
    /// Cache-wide per-task entry budget (split over shards like the
    /// total capacity); `0` disables the split.
    pub task_cap: usize,
    /// Throttle inserts for tasks whose observed hit rate stays under
    /// [`HITRATE_ADMIT_FLOOR`] after [`HITRATE_MIN_OBS`] observations.
    pub hitrate_admission: bool,
}

struct Entry {
    output: Vec<f32>,
    top1: usize,
    /// Recency tick; key into `Inner::lru`.
    tick: u64,
    /// Most urgent class that populated or (for `Interactive`) hit this
    /// entry — the admission shield: `Batch` inserts cannot evict
    /// `Interactive`-classed entries.
    class: Priority,
    /// Index into the shard's `per_task` table (per-task split
    /// accounting without a String per entry).
    task: usize,
    /// Insert time, for TTL expiry.
    at: Instant,
}

/// Per-shard, per-task counters.  One short Vec scanned linearly so the
/// steady-state hot path never allocates a key String (the task name is
/// only cloned the first time a task is seen in a shard).
struct TaskCounters {
    name: String,
    hits: u64,
    misses: u64,
    /// Live entries of this task in this shard (per-task splits).
    entries: usize,
    /// Inserts attempted while throttled by hit-rate admission; every
    /// [`HITRATE_PROBE_EVERY`]-th one is admitted as a probe.
    probes: u64,
}

struct Inner {
    /// This shard's slice of the total capacity.
    cap: usize,
    /// This shard's slice of the per-task budget (0 = no split).
    task_cap: usize,
    map: HashMap<u64, Entry>,
    /// Recency index: tick → key, oldest first.  Ticks are unique per
    /// shard (one monotone counter), so this is a faithful LRU order.
    lru: BTreeMap<u64, u64>,
    /// Recency index over the **non-Interactive** entries only — the
    /// Batch eviction candidates.  Kept in lockstep with `lru` (same
    /// ticks) so class-aware eviction is an O(log n) head pop instead
    /// of a scan past the protected prefix under the shard lock.
    lru_unprotected: BTreeMap<u64, u64>,
    tick: u64,
    per_task: Vec<TaskCounters>,
}

/// Bump a task's hit (or miss) counter without allocating when the task
/// is already known, returning the task's index in the table.
/// Index-first lookup keeps the borrow checker happy and the insert
/// path out of the steady state.
fn bump_task(per_task: &mut Vec<TaskCounters>, task: &str, hit: bool) -> usize {
    match per_task.iter().position(|t| t.name == task) {
        Some(i) => {
            if hit {
                per_task[i].hits += 1;
            } else {
                per_task[i].misses += 1;
            }
            i
        }
        None => {
            per_task.push(TaskCounters {
                name: task.to_string(),
                hits: hit as u64,
                misses: !hit as u64,
                entries: 0,
                probes: 0,
            });
            per_task.len() - 1
        }
    }
}

/// Bounded (task, quantized-input) → (output, top1) memo: lock-striped,
/// per-shard LRU, class-aware admission, optional TTL / per-task splits
/// / hit-rate-aware admission (caching v5).
pub struct ResultCache {
    cap: usize,
    /// Power-of-two shard count; a key lives in shard
    /// `key & (shards.len() - 1)`.
    shards: Vec<Mutex<Inner>>,
    ttl: Option<Duration>,
    hitrate_admission: bool,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[inline]
fn fnv_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x100000001b3)
}

impl ResultCache {
    /// Striped cache sized for `cap` entries (shard count scales with
    /// the capacity; tiny caches get one shard and keep exact
    /// single-lock LRU semantics).  V4 semantics: no TTL, no per-task
    /// split, no hit-rate admission.
    pub fn new(cap: usize) -> Self {
        let want = (cap / ENTRIES_PER_SHARD).next_power_of_two().min(MAX_SHARDS);
        Self::with_shards(cap, want)
    }

    /// Like [`ResultCache::new`] (auto-derived shard count) but with the
    /// v5 [`CacheOptions`] knobs applied.
    pub fn with_options(cap: usize, opts: CacheOptions) -> Self {
        let want = (cap / ENTRIES_PER_SHARD).next_power_of_two().min(MAX_SHARDS);
        Self::with_config(cap, want, opts)
    }

    /// Explicit shard count (rounded up to a power of two, at least 1).
    /// `with_shards(cap, 1)` rebuilds the pre-striping single-mutex
    /// cache — the `FleetConfig::global_hotpath` A/B control.  Each
    /// shard owns `cap / n` (±1) entries; with more shards than
    /// capacity, every shard still holds at least one entry, so the
    /// total bound is `max(cap, n)`.
    pub fn with_shards(cap: usize, n: usize) -> Self {
        Self::with_config(cap, n, CacheOptions::default())
    }

    /// Full v5 constructor: explicit shard count plus the
    /// [`CacheOptions`] admission/eviction knobs.
    pub fn with_config(cap: usize, n: usize, opts: CacheOptions) -> Self {
        let n = n.max(1).next_power_of_two();
        let cap = cap.max(1);
        let (base, rem) = (cap / n, cap % n);
        let (tbase, trem) = (opts.task_cap / n, opts.task_cap % n);
        ResultCache {
            cap,
            shards: (0..n)
                .map(|i| {
                    Mutex::new(Inner {
                        cap: (base + usize::from(i < rem)).max(1),
                        task_cap: if opts.task_cap == 0 {
                            0
                        } else {
                            (tbase + usize::from(i < trem)).max(1)
                        },
                        map: HashMap::new(),
                        lru: BTreeMap::new(),
                        lru_unprotected: BTreeMap::new(),
                        tick: 0,
                        per_task: Vec::new(),
                    })
                })
                .collect(),
            ttl: opts.ttl,
            hitrate_admission: opts.hitrate_admission,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Number of lock stripes (observability / tests).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard(&self, key: u64) -> &Mutex<Inner> {
        &self.shards[key as usize & (self.shards.len() - 1)]
    }

    /// Digest of (task, input quantized to a 1/256 grid).  Pure and
    /// cheap: one pass over the input, no allocation.
    pub fn key(task: &str, x: &[f32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in task.bytes() {
            h = fnv_byte(h, b);
        }
        h = fnv_byte(h, 0xFF); // separator: task name cannot bleed into data
        for &v in x {
            // Saturating float→int cast: NaN → 0, ±inf → extremes, all
            // deterministic.
            let q = (v * 256.0).round() as i32;
            for b in q.to_le_bytes() {
                h = fnv_byte(h, b);
            }
        }
        h
    }

    /// Look up a key; on a hit, hand the output slice and top1 to
    /// `on_hit` **under the shard lock** (exactly where the pre-striping
    /// cache cloned) and return its result — so the caller acquires its
    /// reply buffer lazily and a *miss pays nothing* beyond the probe.
    /// Counts the hit (fleet-wide and for `task`), refreshes the
    /// entry's LRU position, and — when `class` is `Interactive` —
    /// upgrades the entry's admission class, shielding the live
    /// interactive working set from `Batch` eviction.  Misses are
    /// counted at [`Self::insert_tagged`] time instead, so a submit
    /// that is rejected by admission control (and retried, possibly
    /// many times) does not inflate the miss counter: `hits + misses`
    /// stays equal to the cached-path traffic that actually completed.
    ///
    /// **TTL (v5).**  A probe that finds an entry older than the
    /// configured TTL drops it and returns `None` *without counting
    /// anything*: it is not a hit (no stale answer was served) and the
    /// miss is counted — once — when the re-executed result reaches
    /// [`Self::insert_tagged`].  Counting the expired probe as a hit
    /// would feed hit-rate-aware admission stale-hit noise.
    pub fn get_hit<R>(
        &self,
        task: &str,
        key: u64,
        class: Priority,
        on_hit: impl FnOnce(&[f32], usize) -> R,
    ) -> Option<R> {
        let mut inner = self.shard(key).lock().unwrap();
        // Reborrow once so `map` and `lru` can be field-split; one map
        // probe does lookup + recency refresh under one short,
        // shard-local lock.
        let inner = &mut *inner;
        let e = inner.map.get_mut(&key)?;
        if let Some(ttl) = self.ttl {
            if e.at.elapsed() >= ttl {
                let (tick, ti) = (e.tick, e.task);
                inner.lru.remove(&tick);
                inner.lru_unprotected.remove(&tick);
                inner.map.remove(&key);
                inner.per_task[ti].entries -= 1;
                return None;
            }
        }
        inner.tick += 1;
        inner.lru.remove(&e.tick);
        inner.lru_unprotected.remove(&e.tick);
        if class == Priority::Interactive {
            e.class = Priority::Interactive;
        }
        e.tick = inner.tick;
        inner.lru.insert(e.tick, key);
        if e.class != Priority::Interactive {
            inner.lru_unprotected.insert(e.tick, key);
        }
        let r = on_hit(&e.output, e.top1);
        self.hits.fetch_add(1, Ordering::Relaxed);
        bump_task(&mut inner.per_task, task, true);
        Some(r)
    }

    /// [`Self::get_hit`] copying the output into `dst` (cleared first)
    /// — for callers that already hold a destination buffer.
    pub fn get_copy(
        &self,
        task: &str,
        key: u64,
        class: Priority,
        dst: &mut Vec<f32>,
    ) -> Option<usize> {
        self.get_hit(task, key, class, |out, top1| {
            dst.clear();
            dst.extend_from_slice(out);
            top1
        })
    }

    /// Allocating convenience wrapper over [`Self::get_copy`]
    /// (`Standard` class — no admission upgrade; tests and spot
    /// checks).
    pub fn get(&self, task: &str, key: u64) -> Option<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        let top1 = self.get_copy(task, key, Priority::Standard, &mut out)?;
        Some((out, top1))
    }

    /// Insert (or refresh) an entry populated by a `class` request,
    /// evicting past the shard's capacity — LRU for
    /// `Interactive`/`Standard`; `Batch` may only evict
    /// non-`Interactive` entries and is turned away (not admitted) when
    /// its shard holds nothing but interactive working set.  Each
    /// insert is one executed cache miss (see [`Self::get_copy`]).
    ///
    /// V5 admission runs in order: (1) a refresh of a live key is
    /// always admitted — the key is demonstrably repeating; (2)
    /// hit-rate-aware admission may turn the insert away (a throttled
    /// task still lands one probe in [`HITRATE_PROBE_EVERY`]); (3) a
    /// task at its per-task split evicts its own oldest entry
    /// (class-aware) before the shard-capacity LRU loop runs.
    ///
    /// Returns `true` when the entry was admitted (inserted or
    /// refreshed); `false` when it was turned away — the denial the
    /// tracing layer records as a `cache_insert_denied` fleet event.
    pub fn insert_tagged(
        &self,
        task: &str,
        key: u64,
        output: &[f32],
        top1: usize,
        class: Priority,
    ) -> bool {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.shard(key).lock().unwrap();
        // Reborrow through the guard once so `map` and `lru` can be
        // field-split below.
        let inner = &mut *inner;
        let ti = bump_task(&mut inner.per_task, task, false);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            // Refresh in place.  Keep the more urgent class: a refresh
            // by Batch must not strip an entry's interactive shield.
            let class = if e.class.idx() < class.idx() { e.class } else { class };
            let old_tick = e.tick;
            let old_ti = e.task;
            *e = Entry {
                output: output.to_vec(),
                top1,
                tick,
                class,
                task: ti,
                at: Instant::now(),
            };
            if old_ti != ti {
                // 64-bit key collision across tasks: vanishingly rare,
                // but keep the split accounting consistent.
                inner.per_task[old_ti].entries -= 1;
                inner.per_task[ti].entries += 1;
            }
            inner.lru.remove(&old_tick);
            inner.lru_unprotected.remove(&old_tick);
            inner.lru.insert(tick, key);
            if class != Priority::Interactive {
                inner.lru_unprotected.insert(tick, key);
            }
            return true;
        }
        if self.hitrate_admission {
            let t = &mut inner.per_task[ti];
            let obs = t.hits + t.misses;
            if obs >= HITRATE_MIN_OBS && (t.hits as f64) < HITRATE_ADMIT_FLOOR * obs as f64
            {
                t.probes += 1;
                if t.probes % HITRATE_PROBE_EVERY != 0 {
                    return false;
                }
            }
        }
        if inner.task_cap > 0 && inner.per_task[ti].entries >= inner.task_cap {
            // The task is at its split: evict its own oldest entry.
            // Batch scans only the unprotected index, so the shield
            // holds even within a task's own slice.
            let pool = if class == Priority::Batch {
                &inner.lru_unprotected
            } else {
                &inner.lru
            };
            let victim = pool
                .iter()
                .find(|(_, k)| inner.map.get(*k).map_or(false, |e| e.task == ti))
                .map(|(&t, &k)| (t, k));
            let Some((t, k)) = victim else {
                // Batch vs an all-interactive slice of its own task.
                return false;
            };
            inner.lru.remove(&t);
            inner.lru_unprotected.remove(&t);
            inner.map.remove(&k);
            inner.per_task[ti].entries -= 1;
        }
        while inner.map.len() >= inner.cap {
            // Oldest evictable entry, O(log n): Batch pops the head of
            // the unprotected index (and so cannot flush interactive
            // entries); other classes pop the full LRU head.
            let victim = if class == Priority::Batch {
                inner.lru_unprotected.iter().next().map(|(&t, &k)| (t, k))
            } else {
                inner.lru.iter().next().map(|(&t, &k)| (t, k))
            };
            let Some((t, k)) = victim else {
                // Batch vs a wall of interactive working set: not
                // admitted.
                return false;
            };
            inner.lru.remove(&t);
            inner.lru_unprotected.remove(&t);
            if let Some(e) = inner.map.remove(&k) {
                inner.per_task[e.task].entries -= 1;
            }
        }
        inner.map.insert(
            key,
            Entry { output: output.to_vec(), top1, tick, class, task: ti, at: Instant::now() },
        );
        inner.per_task[ti].entries += 1;
        inner.lru.insert(tick, key);
        if class != Priority::Interactive {
            inner.lru_unprotected.insert(tick, key);
        }
        true
    }

    /// [`Self::insert_tagged`] with the default (`Standard`) class
    /// (Standard inserts are always admitted).
    pub fn insert(&self, task: &str, key: u64, output: &[f32], top1: usize) {
        self.insert_tagged(task, key, output, top1, Priority::Standard);
    }

    /// Merged counters and occupancy.  `entries` counts resident
    /// entries — with a TTL configured, an entry that has aged out but
    /// has not been probed since is still resident (expiry is lazy,
    /// paid by the probe that discovers it).
    pub fn stats(&self) -> CacheStats {
        let mut entries = 0usize;
        let mut merged: Vec<TaskCacheStats> = Vec::new();
        for shard in &self.shards {
            let inner = shard.lock().unwrap();
            entries += inner.map.len();
            for t in &inner.per_task {
                match merged.iter_mut().find(|m| m.task == t.name) {
                    Some(m) => {
                        m.hits += t.hits;
                        m.misses += t.misses;
                    }
                    None => merged.push(TaskCacheStats {
                        task: t.name.clone(),
                        hits: t.hits,
                        misses: t.misses,
                    }),
                }
            }
        }
        // Sorted for stable snapshots/JSON regardless of first-seen order.
        merged.sort_by(|a, b| a.task.cmp(&b.task));
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
            cap: self.cap,
            per_task: merged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hits_and_misses_per_task() {
        let c = ResultCache::new(8);
        assert_eq!(c.n_shards(), 1, "tiny caches keep the single-lock layout");
        let k = ResultCache::key("kws", &[0.1, 0.2]);
        assert!(c.get("kws", k).is_none());
        c.insert("kws", k, &[1.0, 2.0], 1);
        let (out, top1) = c.get("kws", k).expect("hit after insert");
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(top1, 1);
        let ka = ResultCache::key("ad", &[0.3]);
        c.insert("ad", ka, &[3.0], 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(
            s.per_task,
            vec![
                TaskCacheStats { task: "ad".into(), hits: 0, misses: 1 },
                TaskCacheStats { task: "kws".into(), hits: 1, misses: 1 },
            ]
        );
    }

    #[test]
    fn key_quantizes_and_separates_tasks() {
        let x = vec![0.5f32, -1.25, 3.0];
        let mut y = x.clone();
        y[1] += 1e-6; // below the 1/256 grid: same key
        assert_eq!(ResultCache::key("kws", &x), ResultCache::key("kws", &y));
        let mut z = x.clone();
        z[1] += 0.5; // well above the grid: different key
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("kws", &z));
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("ic", &x));
    }

    #[test]
    fn lru_eviction_bounds_entries() {
        let c = ResultCache::new(4);
        for i in 0..20u32 {
            c.insert("kws", ResultCache::key("kws", &[i as f32]), &[i as f32], 0);
            assert!(c.stats().entries <= 4, "at insert {i}");
        }
        // Oldest evicted, newest retained.
        assert!(c.get("kws", ResultCache::key("kws", &[0.0])).is_none());
        assert!(c.get("kws", ResultCache::key("kws", &[19.0])).is_some());
    }

    #[test]
    fn hits_refresh_recency_where_fifo_would_evict() {
        let c = ResultCache::new(2);
        let hot = ResultCache::key("kws", &[1.0]);
        let cold = ResultCache::key("kws", &[2.0]);
        c.insert("kws", hot, &[1.0], 0);
        c.insert("kws", cold, &[2.0], 0);
        // Touch the older entry: under FIFO it would still be first out.
        assert!(c.get("kws", hot).is_some());
        c.insert("kws", ResultCache::key("kws", &[3.0]), &[3.0], 0);
        assert!(
            c.get("kws", hot).is_some(),
            "LRU must keep the recently-hit entry"
        );
        assert!(c.get("kws", cold).is_none(), "LRU evicts the stale entry");
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn reinsert_refreshes_without_duplicating() {
        let c = ResultCache::new(2);
        let k = ResultCache::key("ad", &[1.0]);
        c.insert("ad", k, &[1.0], 0);
        c.insert("ad", k, &[2.0], 0);
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.get("ad", k).unwrap().0, vec![2.0]);
    }

    #[test]
    fn striping_spreads_keys_and_keeps_the_total_bound() {
        let c = ResultCache::new(1024);
        assert_eq!(c.n_shards(), 16);
        for i in 0..4096u32 {
            let k = ResultCache::key("kws", &[i as f32]);
            c.insert("kws", k, &[i as f32], 0);
            assert!(c.stats().entries <= 1024, "at insert {i}");
        }
        // Every shard actually holds entries (FNV spreads the keys) and
        // recent keys are retrievable wherever they landed.
        let occupied =
            c.shards.iter().filter(|s| !s.lock().unwrap().map.is_empty()).count();
        assert_eq!(occupied, 16, "keys must spread over all shards");
        let hot = ResultCache::key("kws", &[4095.0]);
        assert_eq!(c.get("kws", hot).unwrap().0, vec![4095.0]);
        // The forced single-shard layout (the A/B control) still works.
        let one = ResultCache::with_shards(1024, 1);
        assert_eq!(one.n_shards(), 1);
        one.insert("kws", hot, &[1.0], 0);
        assert!(one.get("kws", hot).is_some());
    }

    #[test]
    fn batch_inserts_cannot_evict_the_interactive_working_set() {
        let c = ResultCache::with_shards(3, 1);
        let ik: Vec<u64> =
            (0..3).map(|i| ResultCache::key("kws", &[i as f32])).collect();
        for (i, &k) in ik.iter().enumerate() {
            c.insert_tagged("kws", k, &[i as f32], 0, Priority::Interactive);
        }
        // A 20-key batch sweep over the full cache: nothing admitted,
        // nothing evicted — and every denial is reported to the caller.
        for i in 100..120u32 {
            let k = ResultCache::key("kws", &[i as f32]);
            assert!(
                !c.insert_tagged("kws", k, &[i as f32], 0, Priority::Batch),
                "batch insert {i} must report denial"
            );
        }
        for (i, &k) in ik.iter().enumerate() {
            assert_eq!(
                c.get("kws", k).expect("interactive entry flushed by batch sweep").0,
                vec![i as f32]
            );
        }
        // Standard traffic can still reclaim a cold interactive entry
        // (plain LRU), so the shield is not a leak.
        let sk = ResultCache::key("kws", &[500.0]);
        assert!(c.insert_tagged("kws", sk, &[5.0], 0, Priority::Standard));
        assert_eq!(c.stats().entries, 3);
        assert!(c.get("kws", sk).is_some());
    }

    #[test]
    fn interactive_hits_upgrade_and_batch_evicts_lru_among_unprotected() {
        let c = ResultCache::with_shards(2, 1);
        let a = ResultCache::key("kws", &[1.0]);
        let b = ResultCache::key("kws", &[2.0]);
        c.insert_tagged("kws", a, &[1.0], 0, Priority::Standard);
        c.insert_tagged("kws", b, &[2.0], 0, Priority::Standard);
        // An interactive hit on `a` shields it from batch eviction even
        // though it was populated by Standard traffic.
        let mut dst = Vec::new();
        assert_eq!(c.get_copy("kws", a, Priority::Interactive, &mut dst), Some(0));
        assert_eq!(dst, vec![1.0]);
        let bk = ResultCache::key("kws", &[9.0]);
        c.insert_tagged("kws", bk, &[9.0], 0, Priority::Batch);
        assert!(c.get("kws", a).is_some(), "upgraded entry survives the batch insert");
        assert!(c.get("kws", b).is_none(), "batch evicted the unprotected LRU entry");
        assert!(c.get("kws", bk).is_some());
        // A batch refresh of the protected key must not strip its shield.
        c.insert_tagged("kws", a, &[1.5], 0, Priority::Batch);
        let bk2 = ResultCache::key("kws", &[11.0]);
        c.insert_tagged("kws", bk2, &[11.0], 0, Priority::Batch);
        assert!(c.get("kws", a).is_some(), "batch refresh stripped the shield");
    }

    /// TTL expiry works at (and across) the shard boundary, and an
    /// expired probe counts as a *miss*, not a hit: the probe itself
    /// counts nothing, and the miss lands when the re-executed result
    /// is re-inserted — the ISSUE-9 counter fix, pinned.
    #[test]
    fn ttl_expiry_at_the_shard_boundary_counts_as_miss_not_hit() {
        let opts = CacheOptions { ttl: Some(Duration::from_millis(250)), ..Default::default() };
        let c = ResultCache::with_config(64, 4, opts);
        assert_eq!(c.n_shards(), 4);
        // Spread keys until at least two shards are occupied, so expiry
        // bookkeeping is exercised on both sides of a shard boundary.
        let keys: Vec<u64> =
            (0..8u32).map(|i| ResultCache::key("kws", &[i as f32])).collect();
        for (i, &k) in keys.iter().enumerate() {
            c.insert("kws", k, &[i as f32], 0);
        }
        let occupied =
            c.shards.iter().filter(|s| !s.lock().unwrap().map.is_empty()).count();
        assert!(occupied >= 2, "keys must straddle a shard boundary");
        // Fresh probes hit.
        assert!(c.get("kws", keys[0]).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 8, 8));
        std::thread::sleep(Duration::from_millis(600));
        // Expired probes: every entry is dropped, and NOTHING is
        // counted — hits stay at 1 and misses stay at 8.
        for &k in &keys {
            assert!(c.get("kws", k).is_none(), "expired entry served");
        }
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 8, 0));
        assert_eq!(
            s.per_task,
            vec![TaskCacheStats { task: "kws".into(), hits: 1, misses: 8 }],
            "expired probes must not pollute per-task admission counters"
        );
        // The miss is counted exactly once, by the re-insert after the
        // re-execution — and the refreshed entry hits again.
        c.insert("kws", keys[0], &[42.0], 0);
        assert_eq!(c.get("kws", keys[0]).unwrap().0, vec![42.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 9, 1));
    }

    /// A task at its per-task split evicts its own oldest entry instead
    /// of squeezing its neighbours.
    #[test]
    fn per_task_capacity_split_bounds_each_task() {
        let opts = CacheOptions { task_cap: 2, ..Default::default() };
        let c = ResultCache::with_config(8, 1, opts);
        let kws: Vec<u64> =
            (0..2u32).map(|i| ResultCache::key("kws", &[i as f32])).collect();
        for (i, &k) in kws.iter().enumerate() {
            c.insert("kws", k, &[i as f32], 0);
        }
        // Five AD frames through a split of two: only the newest two
        // survive, and the KWS working set is untouched even though the
        // shard (cap 8) had room for all seven entries.
        let ad: Vec<u64> =
            (0..5u32).map(|i| ResultCache::key("ad", &[i as f32])).collect();
        for (i, &k) in ad.iter().enumerate() {
            assert!(c.insert_tagged("ad", k, &[i as f32], 0, Priority::Standard));
        }
        assert_eq!(c.stats().entries, 4, "2 kws + 2 ad");
        assert!(c.get("ad", ad[0]).is_none());
        assert!(c.get("ad", ad[1]).is_none());
        assert!(c.get("ad", ad[2]).is_none());
        assert!(c.get("ad", ad[3]).is_some());
        assert!(c.get("ad", ad[4]).is_some());
        for &k in &kws {
            assert!(c.get("kws", k).is_some(), "neighbour task squeezed out");
        }
        // Batch still cannot reclaim an Interactive entry of its own
        // task through the per-task eviction path.
        let c2 = ResultCache::with_config(8, 1, CacheOptions { task_cap: 1, ..Default::default() });
        let ik = ResultCache::key("ic", &[1.0]);
        c2.insert_tagged("ic", ik, &[1.0], 0, Priority::Interactive);
        let bk = ResultCache::key("ic", &[2.0]);
        assert!(
            !c2.insert_tagged("ic", bk, &[2.0], 0, Priority::Batch),
            "batch evicted interactive via the per-task split"
        );
        assert!(c2.get("ic", ik).is_some());
    }

    /// A task observed to never repeat is throttled to probe-only
    /// admission; a task with a healthy hit rate is unaffected.
    #[test]
    fn hitrate_admission_throttles_never_repeating_tasks() {
        let opts = CacheOptions { hitrate_admission: true, ..Default::default() };
        let c = ResultCache::with_config(256, 1, opts);
        // A hot KWS key with a near-1.0 hit rate.
        let hot = ResultCache::key("kws", &[7.0]);
        c.insert("kws", hot, &[7.0], 0);
        for _ in 0..16 {
            assert!(c.get("kws", hot).is_some());
        }
        // 128 unique AD frames: warm-up, all admitted (the floor must
        // not fire before HITRATE_MIN_OBS observations).
        for i in 0..HITRATE_MIN_OBS {
            let k = ResultCache::key("ad", &[i as f32]);
            assert!(c.insert_tagged("ad", k, &[0.0], 0, Priority::Standard), "warm-up insert {i}");
        }
        // Past warm-up at hit rate 0: only one insert in
        // HITRATE_PROBE_EVERY is admitted.
        let tries = 2 * HITRATE_PROBE_EVERY;
        let admitted = (0..tries)
            .filter(|i| {
                let k = ResultCache::key("ad", &[1000.0 + *i as f32]);
                c.insert_tagged("ad", k, &[0.0], 0, Priority::Standard)
            })
            .count() as u64;
        assert_eq!(admitted, tries / HITRATE_PROBE_EVERY, "throttle admits probes only");
        // The healthy task is still admitted unconditionally.
        for i in 0..16u32 {
            let k = ResultCache::key("kws", &[100.0 + i as f32]);
            assert!(c.insert_tagged("kws", k, &[0.0], 0, Priority::Standard));
        }
    }
}
