//! Result cache: a bounded memo of (task, quantized input) → output
//! sitting **in front of** the router (the ROADMAP "Result caching"
//! open item), **lock-striped** so a cache-on fleet does not serialize
//! every submit on one mutex.
//!
//! Repeated requests — identical or near-identical after quantizing the
//! input to a 1/256 grid (comfortably finer than the i8 grid the packed
//! kernels themselves execute at) — skip routing, queueing, and device
//! execution entirely: the submit path answers from the memo and the
//! boards never see the request.  Workers populate the memo after
//! executing, keyed by a digest the submit path computed.
//!
//! **Striping (v3).**  The store is split into N independent lock
//! shards picked by the low bits of the key digest; every client thread
//! probing the cache and every worker inserting behind a miss lands on
//! its key's shard only.  The v2 cache held one mutex over the whole
//! map — with 8 concurrent submitters the entire fleet serialized on
//! it (the single hottest lock `benches/hotpath.rs` measures; the
//! `FleetConfig::global_hotpath` A/B control rebuilds that one-shard
//! layout via [`ResultCache::with_shards`]).  Each shard runs its own
//! LRU over its own slice of the capacity, so get/insert stay O(log n)
//! under one *short, shard-local* lock.
//!
//! **Class-aware admission (caching v3, ROADMAP follow-up).**  Entries
//! remember the [`Priority`] of the traffic that populated (or, for
//! `Interactive`, last hit) them.  A `Batch`-class insert may evict
//! only non-`Interactive` entries — if its shard is wall-to-wall
//! interactive working set, the batch result is simply not admitted —
//! so a bulk scoring sweep can flow through a full cache without
//! flushing the entries interactive users are actually hitting.
//! `Interactive`/`Standard` inserts evict plain LRU (an interactive
//! working set that really has gone cold is still reclaimable).
//!
//! The key is a 64-bit FNV-1a digest of the task name and the quantized
//! input.  A 64-bit digest can collide in principle; at fleet request
//! volumes the probability is negligible (birthday bound ~n²/2⁶⁵) and
//! this is the standard memo-cache trade.  Eviction is LRU (since v2 —
//! the v1 memo was FIFO, which evicted hot steady-traffic entries as
//! soon as enough one-off AD frames flowed past them): recency is a
//! per-shard monotone tick plus a `BTreeMap<tick, key>` index.
//! Hit/miss counters are kept fleet-wide *and* per task, so the
//! snapshot can show which workload actually benefits (AD frames rarely
//! repeat; KWS wake-words do).

use super::queue::Priority;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default shard sizing: one lock per ~64 entries, between 1 (tiny
/// caches keep the exact single-lock semantics) and 16 shards.
const MAX_SHARDS: usize = 16;
const ENTRIES_PER_SHARD: usize = 64;

/// Per-task slice of the hit/miss counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskCacheStats {
    pub task: String,
    pub hits: u64,
    pub misses: u64,
}

/// Hit/miss counters plus occupancy, for telemetry and `report::json`.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub cap: usize,
    /// Per-task counters, sorted by task name.
    pub per_task: Vec<TaskCacheStats>,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    output: Vec<f32>,
    top1: usize,
    /// Recency tick; key into `Inner::lru`.
    tick: u64,
    /// Most urgent class that populated or (for `Interactive`) hit this
    /// entry — the admission shield: `Batch` inserts cannot evict
    /// `Interactive`-classed entries.
    class: Priority,
}

struct Inner {
    /// This shard's slice of the total capacity.
    cap: usize,
    map: HashMap<u64, Entry>,
    /// Recency index: tick → key, oldest first.  Ticks are unique per
    /// shard (one monotone counter), so this is a faithful LRU order.
    lru: BTreeMap<u64, u64>,
    /// Recency index over the **non-Interactive** entries only — the
    /// Batch eviction candidates.  Kept in lockstep with `lru` (same
    /// ticks) so class-aware eviction is an O(log n) head pop instead
    /// of a scan past the protected prefix under the shard lock.
    lru_unprotected: BTreeMap<u64, u64>,
    tick: u64,
    /// (task, hits, misses) — a handful of entries, scanned linearly so
    /// the steady-state hot path never allocates a key String (the task
    /// name is only cloned the first time a task is seen).
    per_task: Vec<(String, u64, u64)>,
}

/// Bump a task's hit (or miss) counter without allocating when the task
/// is already known.  Index-first lookup keeps the borrow checker happy
/// and the insert path out of the steady state.
fn bump_task(per_task: &mut Vec<(String, u64, u64)>, task: &str, hit: bool) {
    match per_task.iter().position(|t| t.0 == task) {
        Some(i) => {
            if hit {
                per_task[i].1 += 1;
            } else {
                per_task[i].2 += 1;
            }
        }
        None => per_task.push((task.to_string(), hit as u64, !hit as u64)),
    }
}

/// Bounded (task, quantized-input) → (output, top1) memo: lock-striped,
/// per-shard LRU, class-aware admission.
pub struct ResultCache {
    cap: usize,
    /// Power-of-two shard count; a key lives in shard
    /// `key & (shards.len() - 1)`.
    shards: Vec<Mutex<Inner>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[inline]
fn fnv_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x100000001b3)
}

impl ResultCache {
    /// Striped cache sized for `cap` entries (shard count scales with
    /// the capacity; tiny caches get one shard and keep exact
    /// single-lock LRU semantics).
    pub fn new(cap: usize) -> Self {
        let want = (cap / ENTRIES_PER_SHARD).next_power_of_two().min(MAX_SHARDS);
        Self::with_shards(cap, want)
    }

    /// Explicit shard count (rounded up to a power of two, at least 1).
    /// `with_shards(cap, 1)` rebuilds the pre-striping single-mutex
    /// cache — the `FleetConfig::global_hotpath` A/B control.  Each
    /// shard owns `cap / n` (±1) entries; with more shards than
    /// capacity, every shard still holds at least one entry, so the
    /// total bound is `max(cap, n)`.
    pub fn with_shards(cap: usize, n: usize) -> Self {
        let n = n.max(1).next_power_of_two();
        let cap = cap.max(1);
        let (base, rem) = (cap / n, cap % n);
        ResultCache {
            cap,
            shards: (0..n)
                .map(|i| {
                    Mutex::new(Inner {
                        cap: (base + usize::from(i < rem)).max(1),
                        map: HashMap::new(),
                        lru: BTreeMap::new(),
                        lru_unprotected: BTreeMap::new(),
                        tick: 0,
                        per_task: Vec::new(),
                    })
                })
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Number of lock stripes (observability / tests).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard(&self, key: u64) -> &Mutex<Inner> {
        &self.shards[key as usize & (self.shards.len() - 1)]
    }

    /// Digest of (task, input quantized to a 1/256 grid).  Pure and
    /// cheap: one pass over the input, no allocation.
    pub fn key(task: &str, x: &[f32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in task.bytes() {
            h = fnv_byte(h, b);
        }
        h = fnv_byte(h, 0xFF); // separator: task name cannot bleed into data
        for &v in x {
            // Saturating float→int cast: NaN → 0, ±inf → extremes, all
            // deterministic.
            let q = (v * 256.0).round() as i32;
            for b in q.to_le_bytes() {
                h = fnv_byte(h, b);
            }
        }
        h
    }

    /// Look up a key; on a hit, hand the output slice and top1 to
    /// `on_hit` **under the shard lock** (exactly where the pre-striping
    /// cache cloned) and return its result — so the caller acquires its
    /// reply buffer lazily and a *miss pays nothing* beyond the probe.
    /// Counts the hit (fleet-wide and for `task`), refreshes the
    /// entry's LRU position, and — when `class` is `Interactive` —
    /// upgrades the entry's admission class, shielding the live
    /// interactive working set from `Batch` eviction.  Misses are
    /// counted at [`Self::insert_tagged`] time instead, so a submit
    /// that is rejected by admission control (and retried, possibly
    /// many times) does not inflate the miss counter: `hits + misses`
    /// stays equal to the cached-path traffic that actually completed.
    pub fn get_hit<R>(
        &self,
        task: &str,
        key: u64,
        class: Priority,
        on_hit: impl FnOnce(&[f32], usize) -> R,
    ) -> Option<R> {
        let mut inner = self.shard(key).lock().unwrap();
        // Reborrow once so `map` and `lru` can be field-split; one map
        // probe does lookup + recency refresh under one short,
        // shard-local lock.
        let inner = &mut *inner;
        let e = inner.map.get_mut(&key)?;
        inner.tick += 1;
        inner.lru.remove(&e.tick);
        inner.lru_unprotected.remove(&e.tick);
        if class == Priority::Interactive {
            e.class = Priority::Interactive;
        }
        e.tick = inner.tick;
        inner.lru.insert(e.tick, key);
        if e.class != Priority::Interactive {
            inner.lru_unprotected.insert(e.tick, key);
        }
        let r = on_hit(&e.output, e.top1);
        self.hits.fetch_add(1, Ordering::Relaxed);
        bump_task(&mut inner.per_task, task, true);
        Some(r)
    }

    /// [`Self::get_hit`] copying the output into `dst` (cleared first)
    /// — for callers that already hold a destination buffer.
    pub fn get_copy(
        &self,
        task: &str,
        key: u64,
        class: Priority,
        dst: &mut Vec<f32>,
    ) -> Option<usize> {
        self.get_hit(task, key, class, |out, top1| {
            dst.clear();
            dst.extend_from_slice(out);
            top1
        })
    }

    /// Allocating convenience wrapper over [`Self::get_copy`]
    /// (`Standard` class — no admission upgrade; tests and spot
    /// checks).
    pub fn get(&self, task: &str, key: u64) -> Option<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        let top1 = self.get_copy(task, key, Priority::Standard, &mut out)?;
        Some((out, top1))
    }

    /// Insert (or refresh) an entry populated by a `class` request,
    /// evicting past the shard's capacity — LRU for
    /// `Interactive`/`Standard`; `Batch` may only evict
    /// non-`Interactive` entries and is turned away (not admitted) when
    /// its shard holds nothing but interactive working set.  Each
    /// insert is one executed cache miss (see [`Self::get_copy`]).
    /// Returns `true` when the entry was admitted (inserted or
    /// refreshed); `false` when a `Batch` insert was turned away — the
    /// denial the tracing layer records as a `cache_insert_denied`
    /// fleet event.
    pub fn insert_tagged(
        &self,
        task: &str,
        key: u64,
        output: &[f32],
        top1: usize,
        class: Priority,
    ) -> bool {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.shard(key).lock().unwrap();
        // Reborrow through the guard once so `map` and `lru` can be
        // field-split below.
        let inner = &mut *inner;
        bump_task(&mut inner.per_task, task, false);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            // Refresh in place.  Keep the more urgent class: a refresh
            // by Batch must not strip an entry's interactive shield.
            let class = if e.class.idx() < class.idx() { e.class } else { class };
            let old_tick = e.tick;
            *e = Entry { output: output.to_vec(), top1, tick, class };
            inner.lru.remove(&old_tick);
            inner.lru_unprotected.remove(&old_tick);
            inner.lru.insert(tick, key);
            if class != Priority::Interactive {
                inner.lru_unprotected.insert(tick, key);
            }
            return true;
        }
        while inner.map.len() >= inner.cap {
            // Oldest evictable entry, O(log n): Batch pops the head of
            // the unprotected index (and so cannot flush interactive
            // entries); other classes pop the full LRU head.
            let victim = if class == Priority::Batch {
                inner.lru_unprotected.iter().next().map(|(&t, &k)| (t, k))
            } else {
                inner.lru.iter().next().map(|(&t, &k)| (t, k))
            };
            let Some((t, k)) = victim else {
                // Batch vs a wall of interactive working set: not
                // admitted.
                return false;
            };
            inner.lru.remove(&t);
            inner.lru_unprotected.remove(&t);
            inner.map.remove(&k);
        }
        inner.map.insert(key, Entry { output: output.to_vec(), top1, tick, class });
        inner.lru.insert(tick, key);
        if class != Priority::Interactive {
            inner.lru_unprotected.insert(tick, key);
        }
        true
    }

    /// [`Self::insert_tagged`] with the default (`Standard`) class
    /// (Standard inserts are always admitted).
    pub fn insert(&self, task: &str, key: u64, output: &[f32], top1: usize) {
        self.insert_tagged(task, key, output, top1, Priority::Standard);
    }

    pub fn stats(&self) -> CacheStats {
        let mut entries = 0usize;
        let mut merged: Vec<TaskCacheStats> = Vec::new();
        for shard in &self.shards {
            let inner = shard.lock().unwrap();
            entries += inner.map.len();
            for (task, hits, misses) in &inner.per_task {
                match merged.iter_mut().find(|t| &t.task == task) {
                    Some(t) => {
                        t.hits += hits;
                        t.misses += misses;
                    }
                    None => merged.push(TaskCacheStats {
                        task: task.clone(),
                        hits: *hits,
                        misses: *misses,
                    }),
                }
            }
        }
        // Sorted for stable snapshots/JSON regardless of first-seen order.
        merged.sort_by(|a, b| a.task.cmp(&b.task));
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
            cap: self.cap,
            per_task: merged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hits_and_misses_per_task() {
        let c = ResultCache::new(8);
        assert_eq!(c.n_shards(), 1, "tiny caches keep the single-lock layout");
        let k = ResultCache::key("kws", &[0.1, 0.2]);
        assert!(c.get("kws", k).is_none());
        c.insert("kws", k, &[1.0, 2.0], 1);
        let (out, top1) = c.get("kws", k).expect("hit after insert");
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(top1, 1);
        let ka = ResultCache::key("ad", &[0.3]);
        c.insert("ad", ka, &[3.0], 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(
            s.per_task,
            vec![
                TaskCacheStats { task: "ad".into(), hits: 0, misses: 1 },
                TaskCacheStats { task: "kws".into(), hits: 1, misses: 1 },
            ]
        );
    }

    #[test]
    fn key_quantizes_and_separates_tasks() {
        let x = vec![0.5f32, -1.25, 3.0];
        let mut y = x.clone();
        y[1] += 1e-6; // below the 1/256 grid: same key
        assert_eq!(ResultCache::key("kws", &x), ResultCache::key("kws", &y));
        let mut z = x.clone();
        z[1] += 0.5; // well above the grid: different key
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("kws", &z));
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("ic", &x));
    }

    #[test]
    fn lru_eviction_bounds_entries() {
        let c = ResultCache::new(4);
        for i in 0..20u32 {
            c.insert("kws", ResultCache::key("kws", &[i as f32]), &[i as f32], 0);
            assert!(c.stats().entries <= 4, "at insert {i}");
        }
        // Oldest evicted, newest retained.
        assert!(c.get("kws", ResultCache::key("kws", &[0.0])).is_none());
        assert!(c.get("kws", ResultCache::key("kws", &[19.0])).is_some());
    }

    #[test]
    fn hits_refresh_recency_where_fifo_would_evict() {
        let c = ResultCache::new(2);
        let hot = ResultCache::key("kws", &[1.0]);
        let cold = ResultCache::key("kws", &[2.0]);
        c.insert("kws", hot, &[1.0], 0);
        c.insert("kws", cold, &[2.0], 0);
        // Touch the older entry: under FIFO it would still be first out.
        assert!(c.get("kws", hot).is_some());
        c.insert("kws", ResultCache::key("kws", &[3.0]), &[3.0], 0);
        assert!(
            c.get("kws", hot).is_some(),
            "LRU must keep the recently-hit entry"
        );
        assert!(c.get("kws", cold).is_none(), "LRU evicts the stale entry");
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn reinsert_refreshes_without_duplicating() {
        let c = ResultCache::new(2);
        let k = ResultCache::key("ad", &[1.0]);
        c.insert("ad", k, &[1.0], 0);
        c.insert("ad", k, &[2.0], 0);
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.get("ad", k).unwrap().0, vec![2.0]);
    }

    #[test]
    fn striping_spreads_keys_and_keeps_the_total_bound() {
        let c = ResultCache::new(1024);
        assert_eq!(c.n_shards(), 16);
        for i in 0..4096u32 {
            let k = ResultCache::key("kws", &[i as f32]);
            c.insert("kws", k, &[i as f32], 0);
            assert!(c.stats().entries <= 1024, "at insert {i}");
        }
        // Every shard actually holds entries (FNV spreads the keys) and
        // recent keys are retrievable wherever they landed.
        let occupied =
            c.shards.iter().filter(|s| !s.lock().unwrap().map.is_empty()).count();
        assert_eq!(occupied, 16, "keys must spread over all shards");
        let hot = ResultCache::key("kws", &[4095.0]);
        assert_eq!(c.get("kws", hot).unwrap().0, vec![4095.0]);
        // The forced single-shard layout (the A/B control) still works.
        let one = ResultCache::with_shards(1024, 1);
        assert_eq!(one.n_shards(), 1);
        one.insert("kws", hot, &[1.0], 0);
        assert!(one.get("kws", hot).is_some());
    }

    #[test]
    fn batch_inserts_cannot_evict_the_interactive_working_set() {
        let c = ResultCache::with_shards(3, 1);
        let ik: Vec<u64> =
            (0..3).map(|i| ResultCache::key("kws", &[i as f32])).collect();
        for (i, &k) in ik.iter().enumerate() {
            c.insert_tagged("kws", k, &[i as f32], 0, Priority::Interactive);
        }
        // A 20-key batch sweep over the full cache: nothing admitted,
        // nothing evicted — and every denial is reported to the caller.
        for i in 100..120u32 {
            let k = ResultCache::key("kws", &[i as f32]);
            assert!(
                !c.insert_tagged("kws", k, &[i as f32], 0, Priority::Batch),
                "batch insert {i} must report denial"
            );
        }
        for (i, &k) in ik.iter().enumerate() {
            assert_eq!(
                c.get("kws", k).expect("interactive entry flushed by batch sweep").0,
                vec![i as f32]
            );
        }
        // Standard traffic can still reclaim a cold interactive entry
        // (plain LRU), so the shield is not a leak.
        let sk = ResultCache::key("kws", &[500.0]);
        assert!(c.insert_tagged("kws", sk, &[5.0], 0, Priority::Standard));
        assert_eq!(c.stats().entries, 3);
        assert!(c.get("kws", sk).is_some());
    }

    #[test]
    fn interactive_hits_upgrade_and_batch_evicts_lru_among_unprotected() {
        let c = ResultCache::with_shards(2, 1);
        let a = ResultCache::key("kws", &[1.0]);
        let b = ResultCache::key("kws", &[2.0]);
        c.insert_tagged("kws", a, &[1.0], 0, Priority::Standard);
        c.insert_tagged("kws", b, &[2.0], 0, Priority::Standard);
        // An interactive hit on `a` shields it from batch eviction even
        // though it was populated by Standard traffic.
        let mut dst = Vec::new();
        assert_eq!(c.get_copy("kws", a, Priority::Interactive, &mut dst), Some(0));
        assert_eq!(dst, vec![1.0]);
        let bk = ResultCache::key("kws", &[9.0]);
        c.insert_tagged("kws", bk, &[9.0], 0, Priority::Batch);
        assert!(c.get("kws", a).is_some(), "upgraded entry survives the batch insert");
        assert!(c.get("kws", b).is_none(), "batch evicted the unprotected LRU entry");
        assert!(c.get("kws", bk).is_some());
        // A batch refresh of the protected key must not strip its shield.
        c.insert_tagged("kws", a, &[1.5], 0, Priority::Batch);
        let bk2 = ResultCache::key("kws", &[11.0]);
        c.insert_tagged("kws", bk2, &[11.0], 0, Priority::Batch);
        assert!(c.get("kws", a).is_some(), "batch refresh stripped the shield");
    }
}
