//! Result cache: a bounded memo of (task, quantized input) → output
//! sitting **in front of** the router (the ROADMAP "Result caching"
//! open item).
//!
//! Repeated requests — identical or near-identical after quantizing the
//! input to a 1/256 grid (comfortably finer than the i8 grid the packed
//! kernels themselves execute at) — skip routing, queueing, and device
//! execution entirely: the submit path answers from the memo and the
//! boards never see the request.  Workers populate the memo after
//! executing, keyed by a digest the submit path computed.
//!
//! The key is a 64-bit FNV-1a digest of the task name and the quantized
//! input.  A 64-bit digest can collide in principle; at fleet request
//! volumes the probability is negligible (birthday bound ~n²/2⁶⁵) and
//! this is the standard memo-cache trade.  Eviction is **LRU** (v2 —
//! the v1 memo was FIFO, which evicted hot steady-traffic entries as
//! soon as enough one-off AD frames flowed past them): every hit
//! refreshes the entry's recency, and eviction removes the
//! least-recently-*used* key.  Recency is a monotone tick plus a
//! `BTreeMap<tick, key>` index, so get/insert stay O(log n) under one
//! short lock — no unsafe linked lists.  Hit/miss counters are kept
//! fleet-wide *and* per task, so the snapshot can show which workload
//! actually benefits (AD frames rarely repeat; KWS wake-words do).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-task slice of the hit/miss counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskCacheStats {
    pub task: String,
    pub hits: u64,
    pub misses: u64,
}

/// Hit/miss counters plus occupancy, for telemetry and `report::json`.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub cap: usize,
    /// Per-task counters, sorted by task name.
    pub per_task: Vec<TaskCacheStats>,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    output: Vec<f32>,
    top1: usize,
    /// Recency tick; key into `Inner::lru`.
    tick: u64,
}

struct Inner {
    map: HashMap<u64, Entry>,
    /// Recency index: tick → key, oldest first.  Ticks are unique (one
    /// monotone counter), so this is a faithful LRU order.
    lru: BTreeMap<u64, u64>,
    tick: u64,
    /// (task, hits, misses) — a handful of entries, scanned linearly so
    /// the steady-state hot path never allocates a key String (the task
    /// name is only cloned the first time a task is seen).
    per_task: Vec<(String, u64, u64)>,
}

/// Bump a task's hit (or miss) counter without allocating when the task
/// is already known.  Index-first lookup keeps the borrow checker happy
/// and the insert path out of the steady state.
fn bump_task(per_task: &mut Vec<(String, u64, u64)>, task: &str, hit: bool) {
    match per_task.iter().position(|t| t.0 == task) {
        Some(i) => {
            if hit {
                per_task[i].1 += 1;
            } else {
                per_task[i].2 += 1;
            }
        }
        None => per_task.push((task.to_string(), hit as u64, !hit as u64)),
    }
}

/// Bounded (task, quantized-input) → (output, top1) memo with LRU
/// eviction.
pub struct ResultCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[inline]
fn fnv_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x100000001b3)
}

impl ResultCache {
    pub fn new(cap: usize) -> Self {
        ResultCache {
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                lru: BTreeMap::new(),
                tick: 0,
                per_task: Vec::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Digest of (task, input quantized to a 1/256 grid).  Pure and
    /// cheap: one pass over the input, no allocation.
    pub fn key(task: &str, x: &[f32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in task.bytes() {
            h = fnv_byte(h, b);
        }
        h = fnv_byte(h, 0xFF); // separator: task name cannot bleed into data
        for &v in x {
            // Saturating float→int cast: NaN → 0, ±inf → extremes, all
            // deterministic.
            let q = (v * 256.0).round() as i32;
            for b in q.to_le_bytes() {
                h = fnv_byte(h, b);
            }
        }
        h
    }

    /// Look up a key, counting hits (fleet-wide and for `task`) and
    /// refreshing the entry's LRU position.  Misses are counted at
    /// [`Self::insert`] time instead, so a submit that is rejected by
    /// admission control (and retried, possibly many times) does not
    /// inflate the miss counter: `hits + misses` stays equal to the
    /// cached-path traffic that actually completed.
    pub fn get(&self, task: &str, key: u64) -> Option<(Vec<f32>, usize)> {
        let mut inner = self.inner.lock().unwrap();
        // Reborrow once so `map` and `lru` can be field-split; one map
        // probe does lookup + recency refresh (this is the submit hot
        // path and the whole cache serializes on this lock).
        let inner = &mut *inner;
        let e = inner.map.get_mut(&key)?;
        inner.tick += 1;
        inner.lru.remove(&e.tick);
        e.tick = inner.tick;
        inner.lru.insert(e.tick, key);
        let result = (e.output.clone(), e.top1);
        self.hits.fetch_add(1, Ordering::Relaxed);
        bump_task(&mut inner.per_task, task, true);
        Some(result)
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used
    /// key past the capacity.  Each insert is one executed cache miss
    /// (see [`Self::get`]).
    pub fn insert(&self, task: &str, key: u64, output: &[f32], top1: usize) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        // Reborrow through the guard once so `map` and `lru` can be
        // field-split below.
        let inner = &mut *inner;
        bump_task(&mut inner.per_task, task, false);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut o) => {
                let old_tick = o.get().tick;
                *o.get_mut() = Entry { output: output.to_vec(), top1, tick };
                inner.lru.remove(&old_tick);
                inner.lru.insert(tick, key);
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(Entry { output: output.to_vec(), top1, tick });
                inner.lru.insert(tick, key);
                while inner.map.len() > self.cap {
                    let Some((&oldest, &victim)) = inner.lru.iter().next() else {
                        break;
                    };
                    inner.lru.remove(&oldest);
                    inner.map.remove(&victim);
                }
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        let mut per_task: Vec<TaskCacheStats> = inner
            .per_task
            .iter()
            .map(|(task, hits, misses)| TaskCacheStats {
                task: task.clone(),
                hits: *hits,
                misses: *misses,
            })
            .collect();
        // Sorted for stable snapshots/JSON regardless of first-seen order.
        per_task.sort_by(|a, b| a.task.cmp(&b.task));
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: inner.map.len(),
            cap: self.cap,
            per_task,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hits_and_misses_per_task() {
        let c = ResultCache::new(8);
        let k = ResultCache::key("kws", &[0.1, 0.2]);
        assert!(c.get("kws", k).is_none());
        c.insert("kws", k, &[1.0, 2.0], 1);
        let (out, top1) = c.get("kws", k).expect("hit after insert");
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(top1, 1);
        let ka = ResultCache::key("ad", &[0.3]);
        c.insert("ad", ka, &[3.0], 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(
            s.per_task,
            vec![
                TaskCacheStats { task: "ad".into(), hits: 0, misses: 1 },
                TaskCacheStats { task: "kws".into(), hits: 1, misses: 1 },
            ]
        );
    }

    #[test]
    fn key_quantizes_and_separates_tasks() {
        let x = vec![0.5f32, -1.25, 3.0];
        let mut y = x.clone();
        y[1] += 1e-6; // below the 1/256 grid: same key
        assert_eq!(ResultCache::key("kws", &x), ResultCache::key("kws", &y));
        let mut z = x.clone();
        z[1] += 0.5; // well above the grid: different key
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("kws", &z));
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("ic", &x));
    }

    #[test]
    fn lru_eviction_bounds_entries() {
        let c = ResultCache::new(4);
        for i in 0..20u32 {
            c.insert("kws", ResultCache::key("kws", &[i as f32]), &[i as f32], 0);
            assert!(c.stats().entries <= 4, "at insert {i}");
        }
        // Oldest evicted, newest retained.
        assert!(c.get("kws", ResultCache::key("kws", &[0.0])).is_none());
        assert!(c.get("kws", ResultCache::key("kws", &[19.0])).is_some());
    }

    #[test]
    fn hits_refresh_recency_where_fifo_would_evict() {
        let c = ResultCache::new(2);
        let hot = ResultCache::key("kws", &[1.0]);
        let cold = ResultCache::key("kws", &[2.0]);
        c.insert("kws", hot, &[1.0], 0);
        c.insert("kws", cold, &[2.0], 0);
        // Touch the older entry: under FIFO it would still be first out.
        assert!(c.get("kws", hot).is_some());
        c.insert("kws", ResultCache::key("kws", &[3.0]), &[3.0], 0);
        assert!(
            c.get("kws", hot).is_some(),
            "LRU must keep the recently-hit entry"
        );
        assert!(c.get("kws", cold).is_none(), "LRU evicts the stale entry");
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn reinsert_refreshes_without_duplicating() {
        let c = ResultCache::new(2);
        let k = ResultCache::key("ad", &[1.0]);
        c.insert("ad", k, &[1.0], 0);
        c.insert("ad", k, &[2.0], 0);
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.get("ad", k).unwrap().0, vec![2.0]);
    }
}
