//! Result cache: a bounded memo of (task, quantized input) → output
//! sitting **in front of** the router (the ROADMAP "Result caching"
//! open item).
//!
//! Repeated requests — identical or near-identical after quantizing the
//! input to a 1/256 grid (comfortably finer than the i8 grid the packed
//! kernels themselves execute at) — skip routing, queueing, and device
//! execution entirely: the submit path answers from the memo and the
//! boards never see the request.  Workers populate the memo after
//! executing, keyed by a digest the submit path computed.
//!
//! The key is a 64-bit FNV-1a digest of the task name and the quantized
//! input.  A 64-bit digest can collide in principle; at fleet request
//! volumes the probability is negligible (birthday bound ~n²/2⁶⁵) and
//! this is the standard memo-cache trade.  Eviction is FIFO — the memo
//! is a bounded buffer, not an LRU — which keeps the insert path to one
//! `VecDeque` operation under the lock.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Hit/miss counters plus occupancy, for telemetry and `report::json`.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub cap: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Inner {
    map: HashMap<u64, (Vec<f32>, usize)>,
    /// Insertion order for FIFO eviction (one entry per live key).
    fifo: VecDeque<u64>,
}

/// Bounded (task, quantized-input) → (output, top1) memo.
pub struct ResultCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[inline]
fn fnv_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x100000001b3)
}

impl ResultCache {
    pub fn new(cap: usize) -> Self {
        ResultCache {
            cap: cap.max(1),
            inner: Mutex::new(Inner { map: HashMap::new(), fifo: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Digest of (task, input quantized to a 1/256 grid).  Pure and
    /// cheap: one pass over the input, no allocation.
    pub fn key(task: &str, x: &[f32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in task.bytes() {
            h = fnv_byte(h, b);
        }
        h = fnv_byte(h, 0xFF); // separator: task name cannot bleed into data
        for &v in x {
            // Saturating float→int cast: NaN → 0, ±inf → extremes, all
            // deterministic.
            let q = (v * 256.0).round() as i32;
            for b in q.to_le_bytes() {
                h = fnv_byte(h, b);
            }
        }
        h
    }

    /// Look up a key, counting hits.  Misses are counted at
    /// [`Self::insert`] time instead, so a submit that is rejected by
    /// admission control (and retried, possibly many times) does not
    /// inflate the miss counter: `hits + misses` stays equal to the
    /// cached-path traffic that actually completed.
    pub fn get(&self, key: u64) -> Option<(Vec<f32>, usize)> {
        let inner = self.inner.lock().unwrap();
        match inner.map.get(&key) {
            Some((out, top1)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((out.clone(), *top1))
            }
            None => None,
        }
    }

    /// Insert (or refresh) an entry, evicting FIFO past the capacity.
    /// Each insert is one executed cache miss (see [`Self::get`]).
    pub fn insert(&self, key: u64, output: &[f32], top1: usize) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(key, (output.to_vec(), top1)).is_none() {
            inner.fifo.push_back(key);
            while inner.map.len() > self.cap {
                let Some(old) = inner.fifo.pop_front() else { break };
                inner.map.remove(&old);
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.inner.lock().unwrap().map.len(),
            cap: self.cap,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hits_and_misses() {
        let c = ResultCache::new(8);
        let k = ResultCache::key("kws", &[0.1, 0.2]);
        assert!(c.get(k).is_none());
        c.insert(k, &[1.0, 2.0], 1);
        let (out, top1) = c.get(k).expect("hit after insert");
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(top1, 1);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn key_quantizes_and_separates_tasks() {
        let x = vec![0.5f32, -1.25, 3.0];
        let mut y = x.clone();
        y[1] += 1e-6; // below the 1/256 grid: same key
        assert_eq!(ResultCache::key("kws", &x), ResultCache::key("kws", &y));
        let mut z = x.clone();
        z[1] += 0.5; // well above the grid: different key
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("kws", &z));
        assert_ne!(ResultCache::key("kws", &x), ResultCache::key("ic", &x));
    }

    #[test]
    fn fifo_eviction_bounds_entries() {
        let c = ResultCache::new(4);
        for i in 0..20u32 {
            c.insert(ResultCache::key("kws", &[i as f32]), &[i as f32], 0);
            assert!(c.stats().entries <= 4, "at insert {i}");
        }
        // Oldest evicted, newest retained.
        assert!(c.get(ResultCache::key("kws", &[0.0])).is_none());
        assert!(c.get(ResultCache::key("kws", &[19.0])).is_some());
    }

    #[test]
    fn reinsert_refreshes_without_duplicating() {
        let c = ResultCache::new(2);
        let k = ResultCache::key("ad", &[1.0]);
        c.insert(k, &[1.0], 0);
        c.insert(k, &[2.0], 0);
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.get(k).unwrap().0, vec![2.0]);
    }
}
