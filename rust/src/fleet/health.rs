//! Per-replica health checking and automatic ejection.
//!
//! Every replica slot carries a [`BoardHealth`]: its worker bumps three
//! relaxed atomics at the *batch* boundary (consecutive execute
//! failures, total failures, and a last-completed-batch heartbeat).  A
//! controller thread — same stop-signal/`wait_timeout` skeleton as the
//! [`autoscaler`](super::autoscale) — samples them every
//! [`HealthConfig::interval`] and **ejects** a replica
//! (drain-then-join retirement through the same path as scale-down,
//! plus a `ReplicaEjected` trace event) when any of three signals
//! trips:
//!
//! * **consecutive execute failures** ≥
//!   [`HealthConfig::max_consecutive_failures`] — a dead or dying
//!   device (chaos `kill=`/`panic=`, or a real executor error streak);
//! * **flow-vs-measured drift** — the drift accumulator
//!   (`observed exec / flow-predicted exec`, per board) exceeding
//!   [`HealthConfig::max_drift_ratio`] over at least
//!   [`HealthConfig::min_drift_batches`] batches: the board still
//!   answers but has stopped meeting the service rate the codesign
//!   flow promised (a brownout — chaos `slow=`, thermal throttling on
//!   real hardware);
//! * **stalled heartbeat** — queued work exists but no batch has
//!   completed for [`HealthConfig::stall_timeout`] (a wedged worker;
//!   an *idle* replica never trips this, because the depth gate keeps
//!   "no work" distinct from "no progress").
//!
//! Ejection can never strand work or the fleet: retirement refuses a
//! task's last active replica, and a dead replica's drained requests
//! flow through the retry channel back to the router (see
//! [`super::FleetError`]).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Health-controller knobs.  Defaults suit time-scaled simulation
/// (ms-class batch holds); real deployments would sample at seconds.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// Sampling period of the controller thread.
    pub interval: Duration,
    /// Eject after this many consecutive failed batches (0 disables the
    /// failure signal).
    pub max_consecutive_failures: u32,
    /// Eject when observed/flow-predicted exec time exceeds this ratio
    /// (0.0 disables the drift signal).  Needs the drift accumulator,
    /// which enabling health turns on (`WorkerConfig::drift_time_scale`).
    pub max_drift_ratio: f64,
    /// Trust the drift ratio only after this many executed batches
    /// (startup jitter makes small samples noisy).
    pub min_drift_batches: u64,
    /// Eject when the queue is non-empty but no batch has completed for
    /// this long (`Duration::ZERO` disables the stall signal).
    pub stall_timeout: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            interval: Duration::from_millis(2),
            max_consecutive_failures: 3,
            max_drift_ratio: 3.0,
            min_drift_batches: 16,
            stall_timeout: Duration::from_millis(500),
        }
    }
}

/// Per-replica health state: written by the replica's worker at batch
/// boundaries (relaxed atomics — two stores per *batch*, nothing per
/// request), read by the health controller.
pub struct BoardHealth {
    consecutive_failures: AtomicU32,
    total_failures: AtomicU64,
    /// µs since `t0` of the last completed batch (served *or* failed —
    /// a failing worker is alive, just sick; stall means *no* batches).
    last_beat_us: AtomicU64,
    t0: Instant,
}

impl BoardHealth {
    pub fn new() -> Self {
        BoardHealth {
            consecutive_failures: AtomicU32::new(0),
            total_failures: AtomicU64::new(0),
            last_beat_us: AtomicU64::new(0),
            t0: Instant::now(),
        }
    }

    fn beat(&self) {
        self.last_beat_us
            .store(self.t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    /// A batch failed to execute (error or caught panic).
    pub fn note_failure(&self) {
        self.consecutive_failures.fetch_add(1, Ordering::Relaxed);
        self.total_failures.fetch_add(1, Ordering::Relaxed);
        self.beat();
    }

    /// A batch executed successfully.
    pub fn note_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.beat();
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    pub fn total_failures(&self) -> u64 {
        self.total_failures.load(Ordering::Relaxed)
    }

    /// Time since the last completed batch (or since creation).
    pub fn beat_age(&self) -> Duration {
        let now_us = self.t0.elapsed().as_micros() as u64;
        Duration::from_micros(now_us.saturating_sub(self.last_beat_us.load(Ordering::Relaxed)))
    }
}

impl Default for BoardHealth {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Per-replica circuit breaker
// ---------------------------------------------------------------------------

/// Circuit-breaker knobs — the *reversible* complement to ejection.
/// Ejection is for replicas that are gone for good (dead device,
/// persistent brownout); the breaker handles transient failure storms
/// (chaos `exec=P`, a flaky link): trip open on a failure-rate window,
/// cool down masked from routing, then re-admit through half-open probe
/// batches instead of paying a permanent replica.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Rolling batch-outcome window the failure rate is computed over;
    /// the breaker only trips once the window is full, so a single
    /// early failure cannot open it.
    pub window: usize,
    /// Trip open when the failed fraction of the window reaches this.
    pub trip_failure_rate: f64,
    /// How long an open breaker masks the replica from routing before
    /// it goes half-open.
    pub cooldown: Duration,
    /// Consecutive successful probe batches a half-open breaker needs
    /// to close; any probe failure re-opens it for another cooldown.
    pub probe_batches: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        // Sized for time-scaled simulation like HealthConfig: windows
        // of ms-class batches, cooldowns a few sampling ticks long.
        BreakerConfig {
            window: 16,
            trip_failure_rate: 0.5,
            cooldown: Duration::from_millis(5),
            probe_batches: 4,
        }
    }
}

/// A state transition worth a trace event, returned by
/// [`CircuitBreaker::note_batch`] so the worker can log it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerTransition {
    /// Closed → Open: the window's failure rate crossed the threshold.
    Tripped { failure_rate_pct: u64 },
    /// HalfOpen → Closed: all probe batches succeeded.
    Restored,
}

enum BreakerState {
    /// Routable; tracking a rolling outcome window.
    Closed { window: VecDeque<bool>, failures: usize },
    /// Masked from routing until `since + cooldown`.
    Open { since: Instant },
    /// Routable again, on probation: counting consecutive successes.
    HalfOpen { successes: u32 },
}

/// Per-replica breaker.  The worker reports batch outcomes
/// ([`Self::note_batch`]); the submit path and retry pump consult
/// [`Self::allows`] when building routing depths.  Every method takes
/// an explicit `now` so the trip/cooldown/probe ladder is unit-testable
/// without sleeping.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: Mutex<BreakerState>,
    trips: AtomicU64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        CircuitBreaker {
            cfg,
            state: Mutex::new(BreakerState::Closed { window: VecDeque::new(), failures: 0 }),
            trips: AtomicU64::new(0),
        }
    }

    /// Record one batch outcome and run the state machine.  Outcomes
    /// landing while the breaker is open (stragglers admitted before
    /// the trip) are ignored — they predate the mask.
    pub fn note_batch(&self, ok: bool, now: Instant) -> Option<BreakerTransition> {
        let mut st = self.state.lock().unwrap();
        match &mut *st {
            BreakerState::Closed { window, failures } => {
                window.push_back(ok);
                if !ok {
                    *failures += 1;
                }
                if window.len() > self.cfg.window {
                    if let Some(evicted) = window.pop_front() {
                        if !evicted {
                            *failures -= 1;
                        }
                    }
                }
                if window.len() >= self.cfg.window && self.cfg.window > 0 {
                    let rate = *failures as f64 / window.len() as f64;
                    if rate >= self.cfg.trip_failure_rate {
                        *st = BreakerState::Open { since: now };
                        self.trips.fetch_add(1, Ordering::Relaxed);
                        return Some(BreakerTransition::Tripped {
                            failure_rate_pct: (rate * 100.0) as u64,
                        });
                    }
                }
                None
            }
            BreakerState::Open { .. } => None,
            BreakerState::HalfOpen { successes } => {
                if ok {
                    *successes += 1;
                    if *successes >= self.cfg.probe_batches {
                        *st = BreakerState::Closed { window: VecDeque::new(), failures: 0 };
                        return Some(BreakerTransition::Restored);
                    }
                    None
                } else {
                    // A failed probe restarts the cooldown; no event —
                    // the replica was never declared routable-healthy.
                    *st = BreakerState::Open { since: now };
                    None
                }
            }
        }
    }

    /// `true` when the replica may take new work.  An open breaker
    /// whose cooldown has elapsed flips to half-open here (the check is
    /// the natural "first request after cooldown" edge).
    pub fn allows(&self, now: Instant) -> bool {
        let mut st = self.state.lock().unwrap();
        match &*st {
            BreakerState::Closed { .. } | BreakerState::HalfOpen { .. } => true,
            BreakerState::Open { since } => {
                if now.duration_since(*since) >= self.cfg.cooldown {
                    *st = BreakerState::HalfOpen { successes: 0 };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Times this breaker has tripped open (monotone; telemetry).
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Current state name for reports: `closed` / `open` / `half-open`.
    pub fn state_name(&self) -> &'static str {
        match &*self.state.lock().unwrap() {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen { .. } => "half-open",
        }
    }
}

/// The health-controller thread body: sample every `cfg.interval` until
/// the stop signal fires.  Spawned by `Fleet::start` when health
/// checking is enabled; `Fleet::shutdown` stops it *before* closing
/// queues, so no ejection races the final drain.
pub(super) fn run_health(
    state: Arc<super::FleetState>,
    cfg: HealthConfig,
    stop: super::StopSignal,
) {
    loop {
        {
            let (flag, cv) = &*stop;
            let guard = flag.lock().unwrap();
            if *guard {
                return;
            }
            let (guard, _) = cv.wait_timeout(guard, cfg.interval).unwrap();
            if *guard {
                return;
            }
        }
        tick(&state, &cfg);
    }
}

/// One sampling tick: scan every active replica's three signals and
/// eject the sick ones.  Ejection failures (e.g. the last-replica
/// guard) are deliberately swallowed — a fleet down to one sick replica
/// keeps serving what it can rather than going dark.
fn tick(state: &Arc<super::FleetState>, cfg: &HealthConfig) {
    let healths: Vec<Arc<BoardHealth>> = match &state.health {
        Some(h) => h.read().unwrap().clone(),
        None => return,
    };
    let (active, depths) = {
        let p = state.plane.read().unwrap();
        let depths: Vec<usize> = p.queues.iter().map(|q| q.depth()).collect();
        (p.active.clone(), depths)
    };
    let drifts = state.telemetry.drift_totals();
    for (id, h) in healths.iter().enumerate() {
        if !active.get(id).copied().unwrap_or(false) {
            continue;
        }
        let mut reason = None;
        let fails = h.consecutive_failures();
        if cfg.max_consecutive_failures > 0 && fails >= cfg.max_consecutive_failures {
            reason = Some(format!("failures:{fails}"));
        } else if cfg.max_drift_ratio > 0.0 {
            if let Some(&(batches, pred_us, obs_us)) = drifts.get(id) {
                if batches >= cfg.min_drift_batches && pred_us > 0.0 {
                    let ratio = obs_us as f64 / pred_us;
                    if ratio > cfg.max_drift_ratio {
                        reason = Some(format!("drift:{ratio:.2}x"));
                    }
                }
            }
        }
        if reason.is_none()
            && cfg.stall_timeout > Duration::ZERO
            && depths.get(id).copied().unwrap_or(0) > 0
            && h.beat_age() > cfg.stall_timeout
        {
            reason = Some(format!("stall:{}ms", h.beat_age().as_millis()));
        }
        if let Some(r) = reason {
            let _ = super::eject_replica_inner(state, id, &format!("ejected:{r}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = HealthConfig::default();
        assert!(c.interval > Duration::ZERO);
        assert!(c.max_consecutive_failures >= 1);
        assert!(c.max_drift_ratio > 1.0);
        assert!(c.min_drift_batches >= 1);
        assert!(c.stall_timeout > Duration::ZERO);
    }

    #[test]
    fn board_health_tracks_consecutive_failures_and_beats() {
        let h = BoardHealth::new();
        assert_eq!(h.consecutive_failures(), 0);
        h.note_failure();
        h.note_failure();
        assert_eq!(h.consecutive_failures(), 2);
        assert_eq!(h.total_failures(), 2);
        // Success resets the streak but not the total.
        h.note_success();
        assert_eq!(h.consecutive_failures(), 0);
        assert_eq!(h.total_failures(), 2);
        h.note_failure();
        assert_eq!(h.consecutive_failures(), 1);
        assert!(h.beat_age() < Duration::from_secs(1));
    }

    fn probe_cfg() -> BreakerConfig {
        BreakerConfig {
            window: 4,
            trip_failure_rate: 0.5,
            cooldown: Duration::from_millis(10),
            probe_batches: 2,
        }
    }

    #[test]
    fn breaker_trips_only_on_a_full_window_failure_rate() {
        let b = CircuitBreaker::new(probe_cfg());
        let t0 = Instant::now();
        // Three failures straight off: window not full, no trip.
        assert_eq!(b.note_batch(false, t0), None);
        assert_eq!(b.note_batch(false, t0), None);
        assert_eq!(b.note_batch(false, t0), None);
        assert!(b.allows(t0), "not tripped below a full window");
        // Fourth outcome fills the window at 75% failures: trip.
        assert_eq!(
            b.note_batch(true, t0),
            Some(BreakerTransition::Tripped { failure_rate_pct: 75 })
        );
        assert_eq!(b.state_name(), "open");
        assert_eq!(b.trips(), 1);
        assert!(!b.allows(t0), "open breaker masks the replica");
    }

    #[test]
    fn breaker_half_opens_after_cooldown_and_closes_on_probe_successes() {
        let b = CircuitBreaker::new(probe_cfg());
        let t0 = Instant::now();
        for _ in 0..4 {
            b.note_batch(false, t0);
        }
        assert!(!b.allows(t0 + Duration::from_millis(9)), "cooldown still running");
        assert!(b.allows(t0 + Duration::from_millis(10)), "cooldown elapsed: half-open");
        assert_eq!(b.state_name(), "half-open");
        let t1 = t0 + Duration::from_millis(11);
        assert_eq!(b.note_batch(true, t1), None, "one probe is not enough");
        assert_eq!(b.note_batch(true, t1), Some(BreakerTransition::Restored));
        assert_eq!(b.state_name(), "closed");
        // The window restarted: old failures cannot re-trip it.
        assert_eq!(b.note_batch(false, t1), None);
        assert!(b.allows(t1));
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn failed_probe_reopens_for_another_cooldown() {
        let b = CircuitBreaker::new(probe_cfg());
        let t0 = Instant::now();
        for _ in 0..4 {
            b.note_batch(false, t0);
        }
        let t1 = t0 + Duration::from_millis(10);
        assert!(b.allows(t1));
        assert_eq!(b.note_batch(false, t1), None, "failed probe: silent reopen");
        assert_eq!(b.state_name(), "open");
        assert!(!b.allows(t1 + Duration::from_millis(9)), "fresh cooldown from the probe");
        assert!(b.allows(t1 + Duration::from_millis(10)));
        assert_eq!(b.trips(), 1, "a probe failure is not a new trip");
    }

    #[test]
    fn open_breaker_ignores_straggler_outcomes() {
        let b = CircuitBreaker::new(probe_cfg());
        let t0 = Instant::now();
        for _ in 0..4 {
            b.note_batch(false, t0);
        }
        // Stragglers (batches admitted before the trip) land while open:
        // no state change, no early half-open shortcut.
        assert_eq!(b.note_batch(true, t0), None);
        assert_eq!(b.state_name(), "open");
        assert!(!b.allows(t0 + Duration::from_millis(5)));
    }

    #[test]
    fn beat_age_grows_without_beats() {
        let h = BoardHealth::new();
        h.note_success();
        std::thread::sleep(Duration::from_millis(5));
        assert!(h.beat_age() >= Duration::from_millis(4));
        h.note_success();
        assert!(h.beat_age() < Duration::from_millis(4));
    }
}
