//! Per-replica health checking and automatic ejection.
//!
//! Every replica slot carries a [`BoardHealth`]: its worker bumps three
//! relaxed atomics at the *batch* boundary (consecutive execute
//! failures, total failures, and a last-completed-batch heartbeat).  A
//! controller thread — same stop-signal/`wait_timeout` skeleton as the
//! [`autoscaler`](super::autoscale) — samples them every
//! [`HealthConfig::interval`] and **ejects** a replica
//! (drain-then-join retirement through the same path as scale-down,
//! plus a `ReplicaEjected` trace event) when any of three signals
//! trips:
//!
//! * **consecutive execute failures** ≥
//!   [`HealthConfig::max_consecutive_failures`] — a dead or dying
//!   device (chaos `kill=`/`panic=`, or a real executor error streak);
//! * **flow-vs-measured drift** — the drift accumulator
//!   (`observed exec / flow-predicted exec`, per board) exceeding
//!   [`HealthConfig::max_drift_ratio`] over at least
//!   [`HealthConfig::min_drift_batches`] batches: the board still
//!   answers but has stopped meeting the service rate the codesign
//!   flow promised (a brownout — chaos `slow=`, thermal throttling on
//!   real hardware);
//! * **stalled heartbeat** — queued work exists but no batch has
//!   completed for [`HealthConfig::stall_timeout`] (a wedged worker;
//!   an *idle* replica never trips this, because the depth gate keeps
//!   "no work" distinct from "no progress").
//!
//! Ejection can never strand work or the fleet: retirement refuses a
//! task's last active replica, and a dead replica's drained requests
//! flow through the retry channel back to the router (see
//! [`super::FleetError`]).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Health-controller knobs.  Defaults suit time-scaled simulation
/// (ms-class batch holds); real deployments would sample at seconds.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// Sampling period of the controller thread.
    pub interval: Duration,
    /// Eject after this many consecutive failed batches (0 disables the
    /// failure signal).
    pub max_consecutive_failures: u32,
    /// Eject when observed/flow-predicted exec time exceeds this ratio
    /// (0.0 disables the drift signal).  Needs the drift accumulator,
    /// which enabling health turns on (`WorkerConfig::drift_time_scale`).
    pub max_drift_ratio: f64,
    /// Trust the drift ratio only after this many executed batches
    /// (startup jitter makes small samples noisy).
    pub min_drift_batches: u64,
    /// Eject when the queue is non-empty but no batch has completed for
    /// this long (`Duration::ZERO` disables the stall signal).
    pub stall_timeout: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            interval: Duration::from_millis(2),
            max_consecutive_failures: 3,
            max_drift_ratio: 3.0,
            min_drift_batches: 16,
            stall_timeout: Duration::from_millis(500),
        }
    }
}

/// Per-replica health state: written by the replica's worker at batch
/// boundaries (relaxed atomics — two stores per *batch*, nothing per
/// request), read by the health controller.
pub struct BoardHealth {
    consecutive_failures: AtomicU32,
    total_failures: AtomicU64,
    /// µs since `t0` of the last completed batch (served *or* failed —
    /// a failing worker is alive, just sick; stall means *no* batches).
    last_beat_us: AtomicU64,
    t0: Instant,
}

impl BoardHealth {
    pub fn new() -> Self {
        BoardHealth {
            consecutive_failures: AtomicU32::new(0),
            total_failures: AtomicU64::new(0),
            last_beat_us: AtomicU64::new(0),
            t0: Instant::now(),
        }
    }

    fn beat(&self) {
        self.last_beat_us
            .store(self.t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    /// A batch failed to execute (error or caught panic).
    pub fn note_failure(&self) {
        self.consecutive_failures.fetch_add(1, Ordering::Relaxed);
        self.total_failures.fetch_add(1, Ordering::Relaxed);
        self.beat();
    }

    /// A batch executed successfully.
    pub fn note_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.beat();
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    pub fn total_failures(&self) -> u64 {
        self.total_failures.load(Ordering::Relaxed)
    }

    /// Time since the last completed batch (or since creation).
    pub fn beat_age(&self) -> Duration {
        let now_us = self.t0.elapsed().as_micros() as u64;
        Duration::from_micros(now_us.saturating_sub(self.last_beat_us.load(Ordering::Relaxed)))
    }
}

impl Default for BoardHealth {
    fn default() -> Self {
        Self::new()
    }
}

/// The health-controller thread body: sample every `cfg.interval` until
/// the stop signal fires.  Spawned by `Fleet::start` when health
/// checking is enabled; `Fleet::shutdown` stops it *before* closing
/// queues, so no ejection races the final drain.
pub(super) fn run_health(
    state: Arc<super::FleetState>,
    cfg: HealthConfig,
    stop: super::StopSignal,
) {
    loop {
        {
            let (flag, cv) = &*stop;
            let guard = flag.lock().unwrap();
            if *guard {
                return;
            }
            let (guard, _) = cv.wait_timeout(guard, cfg.interval).unwrap();
            if *guard {
                return;
            }
        }
        tick(&state, &cfg);
    }
}

/// One sampling tick: scan every active replica's three signals and
/// eject the sick ones.  Ejection failures (e.g. the last-replica
/// guard) are deliberately swallowed — a fleet down to one sick replica
/// keeps serving what it can rather than going dark.
fn tick(state: &Arc<super::FleetState>, cfg: &HealthConfig) {
    let healths: Vec<Arc<BoardHealth>> = match &state.health {
        Some(h) => h.read().unwrap().clone(),
        None => return,
    };
    let (active, depths) = {
        let p = state.plane.read().unwrap();
        let depths: Vec<usize> = p.queues.iter().map(|q| q.depth()).collect();
        (p.active.clone(), depths)
    };
    let drifts = state.telemetry.drift_totals();
    for (id, h) in healths.iter().enumerate() {
        if !active.get(id).copied().unwrap_or(false) {
            continue;
        }
        let mut reason = None;
        let fails = h.consecutive_failures();
        if cfg.max_consecutive_failures > 0 && fails >= cfg.max_consecutive_failures {
            reason = Some(format!("failures:{fails}"));
        } else if cfg.max_drift_ratio > 0.0 {
            if let Some(&(batches, pred_us, obs_us)) = drifts.get(id) {
                if batches >= cfg.min_drift_batches && pred_us > 0.0 {
                    let ratio = obs_us as f64 / pred_us;
                    if ratio > cfg.max_drift_ratio {
                        reason = Some(format!("drift:{ratio:.2}x"));
                    }
                }
            }
        }
        if reason.is_none()
            && cfg.stall_timeout > Duration::ZERO
            && depths.get(id).copied().unwrap_or(0) > 0
            && h.beat_age() > cfg.stall_timeout
        {
            reason = Some(format!("stall:{}ms", h.beat_age().as_millis()));
        }
        if let Some(r) = reason {
            let _ = super::eject_replica_inner(state, id, &format!("ejected:{r}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = HealthConfig::default();
        assert!(c.interval > Duration::ZERO);
        assert!(c.max_consecutive_failures >= 1);
        assert!(c.max_drift_ratio > 1.0);
        assert!(c.min_drift_batches >= 1);
        assert!(c.stall_timeout > Duration::ZERO);
    }

    #[test]
    fn board_health_tracks_consecutive_failures_and_beats() {
        let h = BoardHealth::new();
        assert_eq!(h.consecutive_failures(), 0);
        h.note_failure();
        h.note_failure();
        assert_eq!(h.consecutive_failures(), 2);
        assert_eq!(h.total_failures(), 2);
        // Success resets the streak but not the total.
        h.note_success();
        assert_eq!(h.consecutive_failures(), 0);
        assert_eq!(h.total_failures(), 2);
        h.note_failure();
        assert_eq!(h.consecutive_failures(), 1);
        assert!(h.beat_age() < Duration::from_secs(1));
    }

    #[test]
    fn beat_age_grows_without_beats() {
        let h = BoardHealth::new();
        h.note_success();
        std::thread::sleep(Duration::from_millis(5));
        assert!(h.beat_age() >= Duration::from_millis(4));
        h.note_success();
        assert!(h.beat_age() < Duration::from_millis(4));
    }
}
