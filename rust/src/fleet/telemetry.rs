//! Fleet-level observability: **lock-sharded** per-worker accumulators +
//! latency reservoirs, merged into p50/p99 latency, throughput, energy
//! per inference, and queue depths — renderable as a table or as
//! [`crate::report::json`].
//!
//! **Sharding (the hot-path contract).**  Every worker owns one
//! [`TelemetryShard`] and records into it through a [`TelemetrySink`]
//! resolved once at spawn: a single uncontended mutex per executed
//! batch, touched by nobody else on the hot path.  Per-class latency
//! reservoirs, per-class served counts, and per-tenant served counts
//! all live *inside* the worker's shard; [`Telemetry::snapshot`] (the
//! existing single-consumer rollover point, `Fleet::snapshot_phase`)
//! merges the shards.  Nothing on the record path takes a fleet-global
//! lock — the pre-PR collector serialized every worker on three
//! fleet-wide class mutexes plus a `Mutex<BTreeMap>` of tenants per
//! batch, on top of a reader-writer lock over the slot table.
//!
//! The pre-PR path is kept, verbatim in behavior, behind
//! [`Telemetry::with_global_locks`] — the A/B control that
//! `benches/hotpath.rs` measures the sharded plane against
//! (`FleetConfig::global_hotpath`).  The merge is **lossless**: on the
//! same trace, the sharded snapshot's per-class served/shed counts and
//! p50/p99 equal the global-lock collector's exactly (whenever no
//! reservoir has saturated, the merged multiset of samples is identical
//! — the bench asserts this equivalence on a deterministic replay).
//!
//! Shed counters are fleet-wide relaxed atomics in both modes (they are
//! recorded by the *submit* path on definitive rejection, which has no
//! shard of its own), split per class **and per [`ShedReason`]** so
//! overload diagnosis can tell tiered admission, SLO-predicted
//! infeasibility, and queue exhaustion apart.  When lifecycle tracing is
//! on (`FleetConfig::trace_sample`), workers additionally fold sampled
//! requests' stage spans into per-class [`StageHistogram`]s and a
//! flow-vs-measured drift accumulator inside their own shard
//! ([`TelemetrySink::record_trace`]); log2 bucket counts merge by
//! element-wise addition, so the snapshot's merged histograms are
//! bucket-exact against a single global collector in *both* telemetry
//! modes.  The board set is *growable*:
//! [`Telemetry::add_board`] appends a shard when the autoscaler spins up
//! a replica, and retired replicas keep their slots so their history
//! stays in the final report (the snapshot marks them inactive).

use super::autoscale::ScaleEvent;
use super::cache::CacheStats;
use super::coalesce::CoalesceStats;
use super::queue::{Priority, N_CLASSES};
use super::registry::Registry;
use super::trace::{
    stage_set_to_json, DriftSample, ShedReason, StageSet, TraceSample, N_SHED_REASONS,
};
use crate::data::prng::SplitMix64;
use crate::report::json::{num, obj, s, Value};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Latency samples kept per reservoir (reservoir-sampled beyond this).
const RESERVOIR_CAP: usize = 8192;

/// Distinct tenants tracked per shard (and, in global-lock mode, in the
/// global map) — and the row bound of the merged `tenants` report.
/// Beyond this, new tenant ids are counted in fleet/class aggregates
/// but get no per-tenant row: the report must not grow without bound
/// when callers use high-cardinality tenant ids.  Past the cap the two
/// collector modes may retain *different* subsets (global keeps
/// first-seen, the sharded merge keeps the lowest ids across shards) —
/// both bounded; below it they are identical.
const TENANT_CAP: usize = 1024;

/// One served request as the worker reports it to telemetry.
#[derive(Clone, Copy, Debug)]
pub struct ReplySample {
    pub tenant: u32,
    pub priority: Priority,
    /// End-to-end latency (enqueue → reply), µs.
    pub latency_us: f64,
}

/// Reservoir-sampled latency stream (Algorithm R).
#[derive(Debug)]
struct Reservoir {
    lat_us: Vec<f64>,
    seen: u64,
    rng: SplitMix64,
}

impl Reservoir {
    fn new(seed: u64) -> Self {
        Reservoir { lat_us: Vec::new(), seen: 0, rng: SplitMix64::new(seed) }
    }

    fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.lat_us.len() < RESERVOIR_CAP {
            self.lat_us.push(v);
        } else {
            // Algorithm R: keep each of the first n samples w.p. cap/n.
            let j = self.rng.next_below(self.seen) as usize;
            if j < RESERVOIR_CAP {
                self.lat_us[j] = v;
            }
        }
    }

    /// `true` once samples have been dropped (kept < seen): percentile
    /// merges must weight this reservoir instead of concatenating flat.
    fn saturated(&self) -> bool {
        self.seen as usize > self.lat_us.len()
    }
}

/// Per-class slice of one shard: served count + latency reservoir.
#[derive(Debug)]
struct ClassLocal {
    served: u64,
    lat: Reservoir,
}

/// Everything one worker accumulates, behind its own (uncontended) lock.
#[derive(Debug)]
struct ShardStats {
    served: u64,
    batches: u64,
    stolen: u64,
    /// Batches whose execute failed (device error or caught panic).
    /// Their riders were retried or typed-failed, never counted served.
    exec_failures: u64,
    /// Requests this board handed back for re-routing off failed
    /// batches.
    retried: u64,
    queue_us_sum: u128,
    exec_us_sum: u128,
    energy_uj_sum: f64,
    /// End-to-end request latencies (µs), reservoir-sampled.
    lat: Reservoir,
    depth_peak: usize,
    depth_peak_class: [usize; N_CLASSES],
    /// Per-class served/latency, merged fleet-wide at snapshot time
    /// (written only in sharded mode — global-lock mode keeps these in
    /// the collector's [`GlobalAggs`] instead, the pre-PR layout).
    class: [ClassLocal; N_CLASSES],
    /// (tenant, served) pairs, capped at [`TENANT_CAP`]; a handful of
    /// entries scanned linearly, so the hot path never allocates.
    tenants: Vec<(u32, u64)>,
    /// `true` once this shard has refused a tenant row (table full):
    /// merged per-tenant counts may then undercount tenants another
    /// shard still tracks, and the snapshot flags it
    /// ([`FleetSnapshot::tenants_complete`]).
    tenant_dropped: bool,
    /// Per-class stage-latency histograms over sampled requests
    /// (board-scope: written in *both* telemetry modes, merged by
    /// element-wise bucket addition at snapshot time — lossless).
    stage: [StageSet; N_CLASSES],
    /// Sampled requests folded into `stage`.
    sampled: u64,
    /// Flow-vs-measured drift over executed batches while tracing is
    /// on: Σ predicted device hold (`latency + (n-1)·ii`, scaled) vs
    /// Σ observed `exec` wall time, in µs.
    drift_pred_us: f64,
    drift_obs_us: u128,
    drift_batches: u64,
}

impl ShardStats {
    fn new(id: usize) -> Self {
        let cseed = |c: u64| 0xC1A5_0000 ^ ((id as u64) << 8) ^ c;
        ShardStats {
            served: 0,
            batches: 0,
            stolen: 0,
            exec_failures: 0,
            retried: 0,
            queue_us_sum: 0,
            exec_us_sum: 0,
            energy_uj_sum: 0.0,
            lat: Reservoir::new(0x7E1E_0000 + id as u64),
            depth_peak: 0,
            depth_peak_class: [0; N_CLASSES],
            class: [
                ClassLocal { served: 0, lat: Reservoir::new(cseed(0)) },
                ClassLocal { served: 0, lat: Reservoir::new(cseed(1)) },
                ClassLocal { served: 0, lat: Reservoir::new(cseed(2)) },
            ],
            tenants: Vec::new(),
            tenant_dropped: false,
            stage: Default::default(),
            sampled: 0,
            drift_pred_us: 0.0,
            drift_obs_us: 0,
            drift_batches: 0,
        }
    }

    /// Board-scope fields (both modes).
    #[allow(clippy::too_many_arguments)]
    fn apply_board(
        &mut self,
        samples: &[ReplySample],
        queue_us_sum: u128,
        exec_us: u128,
        energy_uj: f64,
        stolen: u64,
        peak: usize,
        peak_class: [usize; N_CLASSES],
    ) {
        self.served += samples.len() as u64;
        self.batches += 1;
        self.stolen += stolen;
        self.queue_us_sum += queue_us_sum;
        self.exec_us_sum += exec_us;
        self.energy_uj_sum += energy_uj;
        self.depth_peak = self.depth_peak.max(peak);
        for c in 0..N_CLASSES {
            self.depth_peak_class[c] = self.depth_peak_class[c].max(peak_class[c]);
        }
        for s in samples {
            self.lat.push(s.latency_us);
        }
    }

    /// Fold sampled lifecycle spans + per-batch drift (board-scope,
    /// both modes; only called while tracing is on).
    fn apply_trace(&mut self, samples: &[TraceSample], drift: Option<DriftSample>) {
        for t in samples {
            let set = &mut self.stage[t.class.idx()];
            set[0].record(t.queue_wait_us);
            set[1].record(t.window_wait_us);
            set[2].record(t.exec_us);
            set[3].record(t.reply_us);
            self.sampled += 1;
        }
        if let Some(d) = drift {
            self.drift_batches += 1;
            self.drift_pred_us += d.pred_us;
            self.drift_obs_us += d.obs_us;
        }
    }

    /// Class + tenant slices (sharded mode only).
    fn apply_class_tenant(&mut self, samples: &[ReplySample]) {
        for s in samples {
            let cl = &mut self.class[s.priority.idx()];
            cl.served += 1;
            cl.lat.push(s.latency_us);
            match self.tenants.iter().position(|t| t.0 == s.tenant) {
                Some(i) => self.tenants[i].1 += 1,
                None if self.tenants.len() < TENANT_CAP => {
                    self.tenants.push((s.tenant, 1))
                }
                None => self.tenant_dropped = true,
            }
        }
    }
}

/// One worker's private accumulator.  The owning worker is the only
/// hot-path writer (a single uncontended lock per executed batch);
/// snapshots lock it briefly to merge.
pub struct TelemetryShard {
    stats: Mutex<ShardStats>,
}

impl TelemetryShard {
    fn new(id: usize) -> Self {
        TelemetryShard { stats: Mutex::new(ShardStats::new(id)) }
    }

    /// One executed device batch, recorded entirely inside this shard
    /// (board stats + per-class + per-tenant) — the sharded hot path.
    /// `peak` / `peak_class` are the owning queue's push-time high-water
    /// marks (total and per class).
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        samples: &[ReplySample],
        queue_us_sum: u128,
        exec_us: u128,
        energy_uj: f64,
        stolen: u64,
        peak: usize,
        peak_class: [usize; N_CLASSES],
    ) {
        let mut st = self.stats.lock().unwrap();
        st.apply_board(samples, queue_us_sum, exec_us, energy_uj, stolen, peak, peak_class);
        st.apply_class_tenant(samples);
    }

    /// Fold sampled lifecycle spans and one batch's drift observation
    /// into this shard (only called while tracing is on).
    pub fn record_trace(&self, samples: &[TraceSample], drift: Option<DriftSample>) {
        self.stats.lock().unwrap().apply_trace(samples, drift);
    }

    /// One failed execute on this board (board-scope, both modes).
    pub fn record_exec_failure(&self) {
        self.stats.lock().unwrap().exec_failures += 1;
    }

    /// `n` requests handed back for re-routing off a failed batch.
    pub fn record_retried(&self, n: u64) {
        self.stats.lock().unwrap().retried += n;
    }
}

/// The pre-PR fleet-global aggregates (class mutexes + tenant map),
/// kept only for the `global_hotpath` A/B control.
struct GlobalAggs {
    classes: [Mutex<ClassLocal>; N_CLASSES],
    tenants: Mutex<BTreeMap<u32, u64>>,
}

impl GlobalAggs {
    fn new() -> Self {
        GlobalAggs {
            classes: [
                Mutex::new(ClassLocal { served: 0, lat: Reservoir::new(0xC1A5_0000) }),
                Mutex::new(ClassLocal { served: 0, lat: Reservoir::new(0xC1A5_0001) }),
                Mutex::new(ClassLocal { served: 0, lat: Reservoir::new(0xC1A5_0002) }),
            ],
            tenants: Mutex::new(BTreeMap::new()),
        }
    }

    /// The pre-PR record path: one fleet-global lock per class per
    /// batch, plus the tenant-map lock — every worker serializes here.
    fn record(&self, samples: &[ReplySample]) {
        for p in Priority::ALL {
            let mut it = samples.iter().filter(|s| s.priority == p).peekable();
            if it.peek().is_none() {
                continue;
            }
            let mut agg = self.classes[p.idx()].lock().unwrap();
            for s in it {
                agg.served += 1;
                agg.lat.push(s.latency_us);
            }
        }
        let mut tenants = self.tenants.lock().unwrap();
        for s in samples {
            if let Some(n) = tenants.get_mut(&s.tenant) {
                *n += 1;
            } else if tenants.len() < TENANT_CAP {
                tenants.insert(s.tenant, 1);
            }
        }
    }
}

/// Where a worker's `record_batch` goes, resolved **once** at worker
/// spawn so the hot path never re-derives it:
///
/// * `Sharded` — a direct handle to the worker's own shard.  No slot
///   table read, no fleet-global locks: one uncontended mutex per batch.
/// * `Global` — the pre-PR path through [`Telemetry::record_batch`]
///   (slot-table read lock + shard lock + global class/tenant mutexes),
///   kept as the A/B baseline for `benches/hotpath.rs`.
pub enum TelemetrySink {
    Sharded(Arc<TelemetryShard>),
    Global(Arc<Telemetry>, usize),
}

impl TelemetrySink {
    /// Resolve slot `id`'s record sink once (worker spawn time): the
    /// worker's own shard in sharded mode, the collector itself in
    /// global-lock mode.
    pub fn resolve(telemetry: &Arc<Telemetry>, id: usize) -> TelemetrySink {
        if telemetry.global.is_none() {
            TelemetrySink::Sharded(telemetry.boards.read().unwrap()[id].clone())
        } else {
            TelemetrySink::Global(telemetry.clone(), id)
        }
    }

    /// Record one executed device batch (see
    /// [`TelemetryShard::record_batch`] for the argument contract).
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        samples: &[ReplySample],
        queue_us_sum: u128,
        exec_us: u128,
        energy_uj: f64,
        stolen: u64,
        peak: usize,
        peak_class: [usize; N_CLASSES],
    ) {
        match self {
            TelemetrySink::Sharded(shard) => shard.record_batch(
                samples,
                queue_us_sum,
                exec_us,
                energy_uj,
                stolen,
                peak,
                peak_class,
            ),
            TelemetrySink::Global(t, id) => t.record_batch(
                *id,
                samples,
                queue_us_sum,
                exec_us,
                energy_uj,
                stolen,
                peak,
                peak_class,
            ),
        }
    }

    /// Fold sampled lifecycle spans + drift.  Board-scope data, so both
    /// sink modes land in the slot's own shard — the snapshot's stage
    /// merge is identical either way (the A/B control stays honest).
    pub fn record_trace(&self, samples: &[TraceSample], drift: Option<DriftSample>) {
        match self {
            TelemetrySink::Sharded(shard) => shard.record_trace(samples, drift),
            TelemetrySink::Global(t, id) => t.record_trace(*id, samples, drift),
        }
    }

    /// One failed execute (board-scope: both modes land in the shard).
    pub fn record_exec_failure(&self) {
        match self {
            TelemetrySink::Sharded(shard) => shard.record_exec_failure(),
            TelemetrySink::Global(t, id) => t.record_exec_failure(*id),
        }
    }

    /// `n` requests handed back for re-routing (board-scope).
    pub fn record_retried(&self, n: u64) {
        match self {
            TelemetrySink::Sharded(shard) => shard.record_retried(n),
            TelemetrySink::Global(t, id) => t.record_retried(*id, n),
        }
    }
}

/// Shared collector; workers record (through their [`TelemetrySink`]),
/// anyone can snapshot.  Slots are append-only: [`Self::add_board`]
/// grows the set while workers are recording (the autoscaler's scale-up
/// path).
pub struct Telemetry {
    boards: RwLock<Vec<Arc<TelemetryShard>>>,
    /// `Some` = pre-PR global-lock mode (the A/B control); `None` =
    /// sharded (default).
    global: Option<GlobalAggs>,
    /// Admission rejections per class **and per reason** (recorded by
    /// the submit path when a request is definitively refused — the
    /// shed counters the bench asserts on).  A class's total shed is
    /// the sum over its reasons.  Lock-free in both modes.
    shed: [[AtomicU64; N_SHED_REASONS]; N_CLASSES],
    t0: Instant,
}

impl Telemetry {
    /// Sharded collector (the default hot path).
    pub fn new(n_boards: usize) -> Self {
        Self::with_mode(n_boards, false)
    }

    /// Pre-PR global-lock collector: per-class and per-tenant aggregates
    /// live behind fleet-wide mutexes crossed on every `record_batch`.
    /// Only the `FleetConfig::global_hotpath` A/B control builds this.
    pub fn with_global_locks(n_boards: usize) -> Self {
        Self::with_mode(n_boards, true)
    }

    fn with_mode(n_boards: usize, global: bool) -> Self {
        Telemetry {
            boards: RwLock::new(
                (0..n_boards).map(|i| Arc::new(TelemetryShard::new(i))).collect(),
            ),
            global: global.then(GlobalAggs::new),
            shed: Default::default(),
            t0: Instant::now(),
        }
    }

    /// `false` when running the global-lock A/B baseline.
    pub fn is_sharded(&self) -> bool {
        self.global.is_none()
    }

    /// One definitive rejection of a `class` request, classified by
    /// [`ShedReason`] (admission tier vs SLO prediction vs queue
    /// exhaustion).
    pub fn record_shed(&self, class: Priority, reason: ShedReason) {
        self.shed[class.idx()][reason.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Per-reason shed counts for one class.
    fn shed_reasons_of(&self, class: usize) -> [u64; N_SHED_REASONS] {
        let mut out = [0u64; N_SHED_REASONS];
        for (r, slot) in out.iter_mut().enumerate() {
            *slot = self.shed[class][r].load(Ordering::Relaxed);
        }
        out
    }

    /// Fold sampled lifecycle spans + drift into slot `id`'s shard
    /// (board-scope in both modes — see [`TelemetrySink::record_trace`]).
    pub fn record_trace(&self, id: usize, samples: &[TraceSample], drift: Option<DriftSample>) {
        let shard = self.boards.read().unwrap()[id].clone();
        shard.record_trace(samples, drift);
    }

    /// One failed execute on slot `id` (board-scope in both modes).
    pub fn record_exec_failure(&self, id: usize) {
        let shard = self.boards.read().unwrap()[id].clone();
        shard.record_exec_failure();
    }

    /// `n` requests re-routed off slot `id`'s failed batches.
    pub fn record_retried(&self, id: usize, n: u64) {
        let shard = self.boards.read().unwrap()[id].clone();
        shard.record_retried(n);
    }

    /// Append a shard for a newly spawned replica; returns its id.
    pub fn add_board(&self) -> usize {
        let mut boards = self.boards.write().unwrap();
        let id = boards.len();
        boards.push(Arc::new(TelemetryShard::new(id)));
        id
    }

    pub fn len(&self) -> usize {
        self.boards.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One executed device batch on slot `id`, routed by mode: sharded
    /// collectors record everything in the slot's own shard; the
    /// global-lock baseline additionally crosses the fleet-wide class
    /// and tenant mutexes (the pre-PR behavior).  Workers go through
    /// their resolved [`TelemetrySink`] instead of calling this per
    /// batch; tests and the A/B sink land here.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        id: usize,
        samples: &[ReplySample],
        queue_us_sum: u128,
        exec_us: u128,
        energy_uj: f64,
        stolen: u64,
        peak: usize,
        peak_class: [usize; N_CLASSES],
    ) {
        let shard = self.boards.read().unwrap()[id].clone();
        match &self.global {
            None => shard.record_batch(
                samples,
                queue_us_sum,
                exec_us,
                energy_uj,
                stolen,
                peak,
                peak_class,
            ),
            Some(g) => {
                let mut st = shard.stats.lock().unwrap();
                st.apply_board(
                    samples,
                    queue_us_sum,
                    exec_us,
                    energy_uj,
                    stolen,
                    peak,
                    peak_class,
                );
                drop(st);
                g.record(samples);
            }
        }
    }

    /// Cumulative device-execution µs per board — the autoscaler's
    /// utilization signal (it differences consecutive reads over its
    /// sampling interval).
    pub fn exec_us_totals(&self) -> Vec<u128> {
        self.boards
            .read()
            .unwrap()
            .iter()
            .map(|s| s.stats.lock().unwrap().exec_us_sum)
            .collect()
    }

    /// Per-board `(batches, Σ predicted µs, Σ observed µs)` from the
    /// drift accumulator — the health controller's service-rate signal
    /// (observed ≫ predicted flags a board running far off its flow
    /// model).  Same scan shape as [`Self::exec_us_totals`].
    pub fn drift_totals(&self) -> Vec<(u64, f64, u128)> {
        self.boards
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                let st = s.stats.lock().unwrap();
                (st.drift_batches, st.drift_pred_us, st.drift_obs_us)
            })
            .collect()
    }

    /// Per-board failed-execute counts (the health controller reads
    /// consecutive streaks from [`super::health::BoardHealth`]; this is
    /// the cumulative telemetry view).
    pub fn exec_failure_totals(&self) -> Vec<u64> {
        self.boards
            .read()
            .unwrap()
            .iter()
            .map(|s| s.stats.lock().unwrap().exec_failures)
            .collect()
    }

    /// Roll per-board queue-depth peaks (total and per class) over to
    /// zero; paired with [`super::queue::BoardQueue::reset_peak`] at
    /// snapshot/phase boundaries so `depth_peak` reads per-phase, not
    /// since-birth.
    pub fn reset_depth_peaks(&self) {
        for shard in self.boards.read().unwrap().iter() {
            let mut st = shard.stats.lock().unwrap();
            st.depth_peak = 0;
            st.depth_peak_class = [0; N_CLASSES];
        }
    }

    pub fn snapshot(&self, reg: &Registry) -> FleetSnapshot {
        let elapsed_s = self.t0.elapsed().as_secs_f64().max(1e-9);
        let mut per_board = Vec::new();
        // Fleet percentiles weight each board's reservoir samples by the
        // traffic they represent (served / samples-kept): once a hot
        // board's reservoir saturates, its samples each stand for many
        // requests, and a flat merge would overrepresent idle boards.
        let mut weighted: Vec<(f64, f64)> = Vec::new();
        let mut served = 0u64;
        let mut energy = 0.0f64;
        // Sharded-mode class/tenant merge accumulators.  One lock per
        // shard for the whole merge (class merges cover *every* shard,
        // including slots newer than `reg` — the global-lock baseline
        // counts those too, and the merge must lose nothing).
        let mut class_served = [0u64; N_CLASSES];
        let mut class_vals: [Vec<(f64, f64)>; N_CLASSES] = Default::default();
        let mut class_saturated = [false; N_CLASSES];
        let mut tenant_map: BTreeMap<u32, u64> = BTreeMap::new();
        let mut tenants_complete = true;
        // Stage histograms are board-scope (shard-resident in both
        // telemetry modes), merged fleet-wide per class by element-wise
        // bucket addition — lossless by construction.
        let mut class_stage: [StageSet; N_CLASSES] = Default::default();
        let boards = self.boards.read().unwrap();
        for (i, shard) in boards.iter().enumerate() {
            let b = shard.stats.lock().unwrap();
            for (c, set) in b.stage.iter().enumerate() {
                for (st, h) in set.iter().enumerate() {
                    class_stage[c][st].merge(h);
                }
            }
            if self.global.is_none() {
                for (c, cl) in b.class.iter().enumerate() {
                    class_served[c] += cl.served;
                    if !cl.lat.lat_us.is_empty() {
                        let w = cl.served as f64 / cl.lat.lat_us.len() as f64;
                        class_saturated[c] |= cl.lat.saturated();
                        class_vals[c].extend(cl.lat.lat_us.iter().map(|&v| (v, w)));
                    }
                }
                for &(tenant, n) in &b.tenants {
                    *tenant_map.entry(tenant).or_insert(0) += n;
                }
                tenants_complete &= !b.tenant_dropped;
            }
            if i >= reg.len() {
                continue;
            }
            let inst = &reg.instances[i];
            let mut lat = b.lat.lat_us.clone();
            if !lat.is_empty() {
                let w = b.served as f64 / lat.len() as f64;
                weighted.extend(lat.iter().map(|&v| (v, w)));
            }
            served += b.served;
            energy += b.energy_uj_sum;
            lat.sort_by(|a, c| a.total_cmp(c));
            let mut board_stage = StageSet::default();
            for set in &b.stage {
                for (st, h) in set.iter().enumerate() {
                    board_stage[st].merge(h);
                }
            }
            let stages =
                (!board_stage.iter().all(|h| h.is_empty())).then(|| Box::new(board_stage));
            let drift = (b.drift_batches > 0).then(|| DriftSnapshot {
                batches: b.drift_batches,
                predicted_exec_us: b.drift_pred_us,
                observed_exec_us: b.drift_obs_us as f64,
                ratio: if b.drift_pred_us > 0.0 {
                    b.drift_obs_us as f64 / b.drift_pred_us
                } else {
                    0.0
                },
            });
            per_board.push(BoardSnapshot {
                label: inst.label.clone(),
                task: inst.task.clone(),
                active: true,
                served: b.served,
                batches: b.batches,
                stolen: b.stolen,
                exec_failures: b.exec_failures,
                retried: b.retried,
                mean_batch: if b.batches > 0 {
                    b.served as f64 / b.batches as f64
                } else {
                    0.0
                },
                mean_queue_us: if b.served > 0 {
                    b.queue_us_sum as f64 / b.served as f64
                } else {
                    0.0
                },
                p50_us: percentile(&lat, 0.50),
                p99_us: percentile(&lat, 0.99),
                energy_per_inference_uj: if b.served > 0 {
                    b.energy_uj_sum / b.served as f64
                } else {
                    0.0
                },
                depth_peak: b.depth_peak,
                depth_peak_class: b.depth_peak_class,
                stages,
                drift,
            });
        }
        drop(boards);
        let class_stages: Vec<Option<Box<StageSet>>> = class_stage
            .into_iter()
            .map(|set| {
                if set.iter().any(|h| !h.is_empty()) {
                    Some(Box::new(set))
                } else {
                    None
                }
            })
            .collect();
        weighted.sort_by(|a, c| a.0.total_cmp(&c.0));
        let classes = match &self.global {
            // Pre-PR path: one fleet-wide reservoir per class.
            Some(g) => Priority::ALL
                .iter()
                .map(|p| {
                    let agg = g.classes[p.idx()].lock().unwrap();
                    let mut lat = agg.lat.lat_us.clone();
                    lat.sort_by(|a, c| a.total_cmp(c));
                    let shed_reasons = self.shed_reasons_of(p.idx());
                    ClassSnapshot {
                        class: p.name(),
                        served: agg.served,
                        shed: shed_reasons.iter().sum(),
                        shed_reasons,
                        p50_us: percentile(&lat, 0.50),
                        p99_us: percentile(&lat, 0.99),
                        stages: class_stages[p.idx()].clone(),
                    }
                })
                .collect(),
            // Sharded merge.  Unsaturated reservoirs kept every sample,
            // so the merged multiset is *identical* to what one global
            // reservoir would hold — flat nearest-rank percentiles make
            // the merge bit-equal to the pre-PR path (the equivalence
            // `benches/hotpath.rs` asserts).  Once any shard has
            // dropped samples, fall back to traffic-weighted
            // percentiles, mirroring the per-board fleet merge.
            None => Priority::ALL
                .iter()
                .map(|p| {
                    let c = p.idx();
                    let mut vals = std::mem::take(&mut class_vals[c]);
                    vals.sort_by(|a, b| a.0.total_cmp(&b.0));
                    let (p50_us, p99_us) = if class_saturated[c] {
                        (
                            weighted_percentile(&vals, 0.50),
                            weighted_percentile(&vals, 0.99),
                        )
                    } else {
                        let flat: Vec<f64> = vals.iter().map(|&(v, _)| v).collect();
                        (percentile(&flat, 0.50), percentile(&flat, 0.99))
                    };
                    let shed_reasons = self.shed_reasons_of(c);
                    ClassSnapshot {
                        class: p.name(),
                        served: class_served[c],
                        shed: shed_reasons.iter().sum(),
                        shed_reasons,
                        p50_us,
                        p99_us,
                        stages: class_stages[c].clone(),
                    }
                })
                .collect(),
        };
        let tenants = match &self.global {
            Some(g) => g
                .tenants
                .lock()
                .unwrap()
                .iter()
                .map(|(&tenant, &served)| TenantSnapshot { tenant, served })
                .collect(),
            // Re-cap after the merge: shards cap independently, so the
            // union could otherwise grow to boards x TENANT_CAP rows.
            None => tenant_map
                .iter()
                .take(TENANT_CAP)
                .map(|(&tenant, &served)| TenantSnapshot { tenant, served })
                .collect(),
        };
        FleetSnapshot {
            elapsed_s,
            served,
            throughput_rps: served as f64 / elapsed_s,
            p50_us: weighted_percentile(&weighted, 0.50),
            p99_us: weighted_percentile(&weighted, 0.99),
            energy_per_inference_uj: if served > 0 { energy / served as f64 } else { 0.0 },
            cache: CacheStats::default(),
            coalesce: None,
            classes,
            tenants,
            // Global-lock mode tracks tenants in one table, so any row
            // it emits is a complete fleet-wide count by construction.
            tenants_complete: self.global.is_some() || tenants_complete,
            // The fleet layer grafts these on: board lifecycle, scale
            // history, and the ejection count live beside the queues,
            // not in the per-board stats.
            board_seconds: 0.0,
            scale_events: Vec::new(),
            ejections: 0,
            hedge: None,
            deadline: super::hedge::DeadlineSnapshot::default(),
            breaker_trips: None,
            per_board,
        }
    }
}

/// Lossless-merge self-check shared by `benches/hotpath.rs` (part 3),
/// the unit tests, and the property tests (same precedent as
/// `crate::report::gate::self_test`): replay one deterministic trace
/// into a sharded and a global-lock collector and assert the merged
/// snapshots agree **exactly** — per-class served/shed and p50/p99,
/// tenant rows, per-board served/p99, and the fleet total.  Panics on
/// divergence; returns the batch count replayed.  Per-class exactness
/// requires unsaturated class reservoirs, so `batches` is capped
/// (expected samples per class stay well under the reservoir size).
pub fn assert_merge_equivalence(n_boards: usize, batches: usize, seed: u64) -> usize {
    assert!(n_boards >= 1);
    assert!(
        batches <= 2000,
        "per-class exactness needs unsaturated reservoirs; keep batches <= 2000"
    );
    let reg = Registry {
        instances: (0..n_boards)
            .map(|id| {
                super::registry::BoardInstance::synthetic(id, "kws", 100.0, 10.0, 1.5)
            })
            .collect(),
    };
    let sharded = Telemetry::new(n_boards);
    let global = Telemetry::with_global_locks(n_boards);
    let mut rng = SplitMix64::new(seed);
    for _ in 0..batches {
        let id = rng.next_below(n_boards as u64) as usize;
        let n = 1 + rng.next_below(8) as usize;
        let samples: Vec<ReplySample> = (0..n)
            .map(|_| ReplySample {
                tenant: rng.next_below(32) as u32,
                priority: Priority::ALL[rng.next_below(3) as usize],
                latency_us: rng.next_below(1_000_000) as f64 / 100.0,
            })
            .collect();
        for t in [&sharded, &global] {
            t.record_batch(id, &samples, 7, 13, 1.0, 0, n, [0, n, 0]);
        }
        // Sampled lifecycle spans: board-scope, so both modes record
        // into the slot's shard and must merge identically.
        if rng.next_below(3) == 0 {
            let ts = TraceSample {
                class: Priority::ALL[rng.next_below(3) as usize],
                queue_wait_us: rng.next_below(1 << 20),
                window_wait_us: rng.next_below(1 << 14),
                exec_us: rng.next_below(1 << 16),
                reply_us: rng.next_below(1 << 10),
            };
            let drift = DriftSample { pred_us: 100.0 + (n as f64 - 1.0) * 10.0, obs_us: 13 };
            for t in [&sharded, &global] {
                t.record_trace(id, &[ts], Some(drift));
            }
        }
        if rng.next_below(7) == 0 {
            let p = Priority::ALL[rng.next_below(3) as usize];
            let r = ShedReason::ALL[rng.next_below(ShedReason::ALL.len() as u64) as usize];
            sharded.record_shed(p, r);
            global.record_shed(p, r);
        }
        // Failed batches + retried riders: board-scope, so both modes
        // must land them in the slot's shard and merge identically.
        if rng.next_below(5) == 0 {
            for t in [&sharded, &global] {
                t.record_exec_failure(id);
                t.record_retried(id, n as u64);
            }
        }
    }
    let a = sharded.snapshot(&reg);
    let b = global.snapshot(&reg);
    assert_eq!(a.served, b.served, "total served must merge losslessly");
    for (ca, cb) in a.classes.iter().zip(&b.classes) {
        assert_eq!(ca.served, cb.served, "class {} served", ca.class);
        assert_eq!(ca.shed, cb.shed, "class {} shed", ca.class);
        assert_eq!(ca.shed_reasons, cb.shed_reasons, "class {} shed reasons", ca.class);
        assert_eq!(
            ca.shed_reasons.iter().sum::<u64>(),
            ca.shed,
            "class {} shed must equal the sum of its reasons",
            ca.class
        );
        assert_eq!(ca.p50_us, cb.p50_us, "class {} p50 must merge exactly", ca.class);
        assert_eq!(ca.p99_us, cb.p99_us, "class {} p99 must merge exactly", ca.class);
        assert_eq!(
            ca.stages, cb.stages,
            "class {} stage histograms must be bucket-exact across modes",
            ca.class
        );
    }
    assert_eq!(a.tenants.len(), b.tenants.len(), "tenant rows");
    for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!((ta.tenant, ta.served), (tb.tenant, tb.served));
    }
    for (ba, bb) in a.per_board.iter().zip(&b.per_board) {
        assert_eq!(ba.served, bb.served, "per-board served");
        assert_eq!(ba.p99_us, bb.p99_us, "per-board p99");
        assert_eq!(ba.exec_failures, bb.exec_failures, "per-board exec failures");
        assert_eq!(ba.retried, bb.retried, "per-board retried");
    }
    batches
}

/// Percentile over a pre-sorted slice (nearest-rank).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Percentile over (value, weight) pairs pre-sorted by value: the first
/// value whose cumulative weight reaches `q` of the total.
fn weighted_percentile(sorted: &[(f64, f64)], q: f64) -> f64 {
    let total: f64 = sorted.iter().map(|&(_, w)| w).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let target = total * q;
    let mut cum = 0.0;
    for &(v, w) in sorted {
        cum += w;
        if cum >= target {
            return v;
        }
    }
    sorted.last().map(|&(v, _)| v).unwrap_or(0.0)
}

/// Per-board aggregate view.
#[derive(Clone, Debug)]
pub struct BoardSnapshot {
    pub label: String,
    pub task: String,
    /// `false` once the replica has been retired (history retained).
    pub active: bool,
    pub served: u64,
    pub batches: u64,
    pub stolen: u64,
    /// Batches whose execute failed here (their riders were re-routed
    /// or typed-failed; nonzero without chaos means a real device
    /// error).
    pub exec_failures: u64,
    /// Requests this board handed back for re-routing.
    pub retried: u64,
    pub mean_batch: f64,
    pub mean_queue_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub energy_per_inference_uj: f64,
    pub depth_peak: usize,
    /// Push-time queue peak per priority class
    /// (`[interactive, standard, batch]`), rolled over with
    /// `depth_peak` at phase boundaries.
    pub depth_peak_class: [usize; N_CLASSES],
    /// Stage-latency histograms over this board's sampled requests
    /// (`Some` only when lifecycle tracing recorded data here).
    pub stages: Option<Box<StageSet>>,
    /// Flow-vs-measured `exec` drift for this instance (`Some` only
    /// while tracing is on and batches executed here).
    pub drift: Option<DriftSnapshot>,
}

/// Per-instance flow-vs-measured drift: the registry's flow-predicted
/// device hold (`latency + (n-1)·ii`, scaled by the fleet's
/// `time_scale`) summed over executed batches vs the observed `exec`
/// wall time.  `ratio > 1` means the board runs slower than the flow
/// estimate the router/autoscaler act on.
#[derive(Clone, Copy, Debug)]
pub struct DriftSnapshot {
    /// Executed batches folded in.
    pub batches: u64,
    /// Σ flow-predicted device hold, µs.
    pub predicted_exec_us: f64,
    /// Σ observed device hold, µs.
    pub observed_exec_us: f64,
    /// `observed / predicted` (0 when the prediction is 0).
    pub ratio: f64,
}

impl DriftSnapshot {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("batches", num(self.batches as f64)),
            ("predicted_exec_us", num(self.predicted_exec_us)),
            ("observed_exec_us", num(self.observed_exec_us)),
            ("ratio", num(self.ratio)),
        ])
    }
}

/// Fleet-wide per-priority-class aggregate: latency percentiles over the
/// class's merged samples, served count, and sheds (admission
/// rejections).
#[derive(Clone, Debug)]
pub struct ClassSnapshot {
    pub class: &'static str,
    pub served: u64,
    /// Total rejections for this class — always the sum of
    /// `shed_reasons`.
    pub shed: u64,
    /// Rejections split by [`ShedReason`], indexed by `ShedReason::idx`
    /// (`[admission_tier, slo_predict, queue_full]`).
    pub shed_reasons: [u64; N_SHED_REASONS],
    pub p50_us: f64,
    pub p99_us: f64,
    /// Fleet-wide stage-latency histograms for this class (`Some` only
    /// when lifecycle tracing recorded data).
    pub stages: Option<Box<StageSet>>,
}

impl ClassSnapshot {
    /// The one JSON shape for per-class stats — shared by
    /// [`FleetSnapshot::to_json`] and the bench reports so the schema
    /// cannot drift between them.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("class", s(self.class)),
            ("served", num(self.served as f64)),
            ("shed", num(self.shed as f64)),
            (
                "shed_reasons",
                obj(ShedReason::ALL
                    .iter()
                    .map(|r| (r.name(), num(self.shed_reasons[r.idx()] as f64)))
                    .collect()),
            ),
            ("p50_us", num(self.p50_us)),
            ("p99_us", num(self.p99_us)),
        ];
        if let Some(set) = &self.stages {
            fields.push(("stages", stage_set_to_json(set)));
        }
        obj(fields)
    }
}

/// Served count for one tenant.
#[derive(Clone, Copy, Debug)]
pub struct TenantSnapshot {
    pub tenant: u32,
    pub served: u64,
}

/// Fleet aggregate view.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub elapsed_s: f64,
    pub served: u64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub energy_per_inference_uj: f64,
    /// Result-cache counters (all zero when caching is disabled);
    /// `served` counts only board-executed requests, so total traffic is
    /// `served + cache.hits`.
    pub cache: CacheStats,
    /// Single-flight coalescing counters; `None` when coalescing is off
    /// (the JSON then omits the `coalesce` block entirely).  A coalesced
    /// follower never reaches a board, so total traffic with coalescing
    /// on is `served + cache.hits + coalesce.followers`.
    pub coalesce: Option<CoalesceStats>,
    /// Per-priority-class p50/p99/served/shed, always all three classes
    /// in `[interactive, standard, batch]` order.
    pub classes: Vec<ClassSnapshot>,
    /// Served count per tenant (tenant 0 is the untagged default; at
    /// most `TENANT_CAP` rows — ids beyond the cap are counted in the
    /// aggregates but get no row).
    pub tenants: Vec<TenantSnapshot>,
    /// `true` while every emitted tenant row is an exact fleet-wide
    /// count.  `false` once tenant cardinality overflowed any shard's
    /// table: a row may then undercount (the tenant was dropped by one
    /// shard but tracked by another) — the rows stay bounded either
    /// way.
    pub tenants_complete: bool,
    /// Total board-alive time: Σ over replicas of (retired-or-now −
    /// started).  The autoscaler's cost axis — an elastic fleet should
    /// serve the same trace with fewer board-seconds than a fixed one.
    pub board_seconds: f64,
    /// Scale-up/-down history (empty without the autoscaler).
    pub scale_events: Vec<ScaleEvent>,
    /// Replicas ejected for cause by the health controller (each is
    /// also a `scale_events` entry with an `ejected:` reason).
    pub ejections: u64,
    /// Hedge counters; `None` when hedging is off (the JSON then omits
    /// the `hedge` block, same absence-vs-zero rule as `coalesce`).
    pub hedge: Option<super::hedge::HedgeStats>,
    /// Deadline-plane ledger (always present; all zeros in a
    /// deadline-free fleet — the JSON block appears once any counter is
    /// nonzero).
    pub deadline: super::hedge::DeadlineSnapshot,
    /// Total circuit-breaker trips across slots; `None` when breakers
    /// are off.
    pub breaker_trips: Option<u64>,
    pub per_board: Vec<BoardSnapshot>,
}

impl FleetSnapshot {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("elapsed_s", num(self.elapsed_s)),
            ("served", num(self.served as f64)),
            ("throughput_rps", num(self.throughput_rps)),
            ("p50_us", num(self.p50_us)),
            ("p99_us", num(self.p99_us)),
            ("energy_per_inference_uj", num(self.energy_per_inference_uj)),
            ("cache_hits", num(self.cache.hits as f64)),
            ("cache_misses", num(self.cache.misses as f64)),
            ("cache_entries", num(self.cache.entries as f64)),
            ("cache_hit_rate", num(self.cache.hit_rate())),
            (
                "cache_per_task",
                Value::Arr(
                    self.cache
                        .per_task
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("task", s(&t.task)),
                                ("hits", num(t.hits as f64)),
                                ("misses", num(t.misses as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "classes",
                Value::Arr(self.classes.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "tenants",
                Value::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("tenant", num(t.tenant as f64)),
                                ("served", num(t.served as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("tenants_complete", Value::Bool(self.tenants_complete)),
            ("board_seconds", num(self.board_seconds)),
            (
                "scale_events",
                Value::Arr(self.scale_events.iter().map(|e| e.to_json()).collect()),
            ),
            ("ejections", num(self.ejections as f64)),
            (
                "boards",
                Value::Arr(
                    self.per_board
                        .iter()
                        .map(|b| {
                            let mut fields = vec![
                                ("label", s(&b.label)),
                                ("task", s(&b.task)),
                                ("active", Value::Bool(b.active)),
                                ("served", num(b.served as f64)),
                                ("batches", num(b.batches as f64)),
                                ("stolen", num(b.stolen as f64)),
                                ("exec_failures", num(b.exec_failures as f64)),
                                ("retried", num(b.retried as f64)),
                                ("mean_batch", num(b.mean_batch)),
                                ("mean_queue_us", num(b.mean_queue_us)),
                                ("p50_us", num(b.p50_us)),
                                ("p99_us", num(b.p99_us)),
                                (
                                    "energy_per_inference_uj",
                                    num(b.energy_per_inference_uj),
                                ),
                                ("depth_peak", num(b.depth_peak as f64)),
                                (
                                    "depth_peak_class",
                                    Value::Arr(
                                        b.depth_peak_class
                                            .iter()
                                            .map(|&p| num(p as f64))
                                            .collect(),
                                    ),
                                ),
                            ];
                            if let Some(set) = &b.stages {
                                fields.push(("stages", stage_set_to_json(set)));
                            }
                            if let Some(d) = &b.drift {
                                fields.push(("drift", d.to_json()));
                            }
                            obj(fields)
                        })
                        .collect(),
                ),
            ),
        ];
        // Present only when coalescing is on — absence vs. all-zero
        // distinguishes "off" from "on but no duplicates arrived".
        if let Some(co) = &self.coalesce {
            fields.push((
                "coalesce",
                obj(vec![
                    ("leaders", num(co.leaders as f64)),
                    ("followers", num(co.followers as f64)),
                    ("fanned_ok", num(co.fanned_ok as f64)),
                    ("fanned_err", num(co.fanned_err as f64)),
                    ("overflow", num(co.overflow as f64)),
                    ("upgrades", num(co.upgrades as f64)),
                ]),
            ));
        }
        // Same absence rule for hedging and breakers.
        if let Some(h) = &self.hedge {
            fields.push((
                "hedge",
                obj(vec![
                    ("hedged", num(h.hedged as f64)),
                    ("cancelled", num(h.cancelled as f64)),
                    ("wins", num(h.wins as f64)),
                ]),
            ));
        }
        if self.deadline.any() {
            fields.push((
                "deadline",
                obj(vec![
                    ("shed_submit", num(self.deadline.shed_submit as f64)),
                    ("expired_dequeue", num(self.deadline.expired_dequeue as f64)),
                    ("expired_window", num(self.deadline.expired_window as f64)),
                    ("expired_retry", num(self.deadline.expired_retry as f64)),
                    ("executed_expired", num(self.deadline.executed_expired as f64)),
                ]),
            ));
        }
        if let Some(trips) = self.breaker_trips {
            fields.push(("breaker_trips", num(trips as f64)));
        }
        obj(fields)
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        writeln!(
            out,
            "fleet: {} served in {:.3} s = {:.0} req/s | p50 {:.1} us  p99 {:.1} us | {:.2} uJ/inf",
            self.served,
            self.elapsed_s,
            self.throughput_rps,
            self.p50_us,
            self.p99_us,
            self.energy_per_inference_uj
        )
        .ok();
        if self.cache.hits + self.cache.misses > 0 {
            writeln!(
                out,
                "  cache: {} hits / {} misses ({:.1}% hit rate, {} of {} entries)",
                self.cache.hits,
                self.cache.misses,
                100.0 * self.cache.hit_rate(),
                self.cache.entries,
                self.cache.cap
            )
            .ok();
            for t in &self.cache.per_task {
                writeln!(
                    out,
                    "    {:<4} {} hits / {} misses",
                    t.task, t.hits, t.misses
                )
                .ok();
            }
        }
        if let Some(co) = &self.coalesce {
            writeln!(
                out,
                "  coalesce: {} leaders / {} followers ({} fanned ok, {} err, {} overflow, {} upgrades)",
                co.leaders, co.followers, co.fanned_ok, co.fanned_err, co.overflow, co.upgrades
            )
            .ok();
        }
        if let Some(h) = &self.hedge {
            writeln!(
                out,
                "  hedge: {} hedged / {} wins / {} losers cancelled",
                h.hedged, h.wins, h.cancelled
            )
            .ok();
        }
        if self.deadline.any() {
            writeln!(
                out,
                "  deadline: {} shed at submit, expired {} dequeue / {} window / {} retry, {} executed expired",
                self.deadline.shed_submit,
                self.deadline.expired_dequeue,
                self.deadline.expired_window,
                self.deadline.expired_retry,
                self.deadline.executed_expired
            )
            .ok();
        }
        if let Some(trips) = self.breaker_trips {
            if trips > 0 {
                writeln!(out, "  breaker: {trips} trips").ok();
            }
        }
        // Per-class breakdown, shown once any non-default class has
        // traffic or anything was shed (all-Standard runs stay terse).
        let classful = self
            .classes
            .iter()
            .any(|c| c.shed > 0 || (c.class != "standard" && c.served > 0));
        if classful {
            writeln!(
                out,
                "  {:<12} {:>7} {:>7} {:>9} {:>9}  {:>14}",
                "class", "served", "shed", "p50(us)", "p99(us)", "adm/slo/qf/dl"
            )
            .ok();
            for c in &self.classes {
                let [adm, slo, qf, dl] = c.shed_reasons;
                writeln!(
                    out,
                    "  {:<12} {:>7} {:>7} {:>9.1} {:>9.1}  {:>14}",
                    c.class,
                    c.served,
                    c.shed,
                    c.p50_us,
                    c.p99_us,
                    format!("{adm}/{slo}/{qf}/{dl}")
                )
                .ok();
            }
        }
        // Stage breakdown, present only when lifecycle tracing sampled
        // requests (percentiles are log2-bucket upper bounds).
        if self.classes.iter().any(|c| c.stages.is_some()) {
            writeln!(
                out,
                "  {:<12} {:<12} {:>7} {:>9} {:>9}",
                "trace", "stage", "count", "p50(us)", "p99(us)"
            )
            .ok();
            for c in &self.classes {
                if let Some(set) = &c.stages {
                    for (st, h) in set.iter().enumerate() {
                        if h.is_empty() {
                            continue;
                        }
                        writeln!(
                            out,
                            "  {:<12} {:<12} {:>7} {:>9.0} {:>9.0}",
                            c.class,
                            super::trace::Stage::ALL[st].name(),
                            h.count,
                            h.percentile_us(0.50),
                            h.percentile_us(0.99)
                        )
                        .ok();
                    }
                }
            }
        }
        if self.tenants.len() > 1 {
            let list: Vec<String> = self
                .tenants
                .iter()
                .map(|t| format!("t{}:{}", t.tenant, t.served))
                .collect();
            writeln!(
                out,
                "  tenants: {} ({}){}",
                self.tenants.len(),
                list.join(" "),
                if self.tenants_complete { "" } else { " [rows may undercount]" }
            )
            .ok();
        }
        if !self.scale_events.is_empty() {
            writeln!(
                out,
                "  autoscale: {} events, {:.3} board-seconds{}",
                self.scale_events.len(),
                self.board_seconds,
                if self.ejections > 0 {
                    format!(", {} ejected for cause", self.ejections)
                } else {
                    String::new()
                }
            )
            .ok();
            for e in &self.scale_events {
                writeln!(out, "    {e}").ok();
            }
        }
        writeln!(
            out,
            "  {:<26} {:>6} {:>7} {:>7} {:>5} {:>9} {:>9} {:>9} {:>6} {:>6}",
            "board", "served", "batches", "stolen", "fail", "p50(us)", "p99(us)", "uJ/inf", "avg_b", "peakQ"
        )
        .ok();
        for b in &self.per_board {
            let label = if b.active {
                b.label.clone()
            } else {
                format!("{} (retired)", b.label)
            };
            writeln!(
                out,
                "  {:<26} {:>6} {:>7} {:>7} {:>5} {:>9.1} {:>9.1} {:>9.2} {:>6.2} {:>6}",
                label,
                b.served,
                b.batches,
                b.stolen,
                b.exec_failures,
                b.p50_us,
                b.p99_us,
                b.energy_per_inference_uj,
                b.mean_batch,
                b.depth_peak
            )
            .ok();
        }
        if self.per_board.iter().any(|b| b.drift.is_some()) {
            writeln!(out, "  flow-vs-measured exec drift:").ok();
            for b in &self.per_board {
                if let Some(d) = &b.drift {
                    writeln!(
                        out,
                        "    {:<26} predicted {:>10.0} us  observed {:>10.0} us  ratio {:.2} ({} batches)",
                        b.label, d.predicted_exec_us, d.observed_exec_us, d.ratio, d.batches
                    )
                    .ok();
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::{BoardInstance, Registry};

    fn reg2() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 100.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 400.0, 40.0, 1.8),
            ],
        }
    }

    fn smp(priority: Priority, latency_us: f64) -> ReplySample {
        ReplySample { tenant: 0, priority, latency_us }
    }

    #[test]
    fn snapshot_aggregates_and_serializes() {
        let reg = reg2();
        let t = Telemetry::new(2);
        assert!(t.is_sharded());
        t.record_batch(
            0,
            &[
                smp(Priority::Standard, 100.0),
                smp(Priority::Interactive, 120.0),
                smp(Priority::Standard, 140.0),
            ],
            30,
            90,
            450.0,
            1,
            3,
            [1, 2, 0],
        );
        t.record_batch(1, &[smp(Priority::Batch, 400.0)], 10, 380, 720.0, 0, 0, [0, 0, 0]);
        t.record_shed(Priority::Batch, ShedReason::AdmissionTier);
        t.record_shed(Priority::Batch, ShedReason::QueueFull);
        t.record_shed(Priority::Batch, ShedReason::QueueFull);
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 4);
        assert!(snap.p50_us >= 100.0 && snap.p50_us <= 400.0);
        assert!(snap.p99_us >= snap.p50_us);
        let e = snap.energy_per_inference_uj;
        assert!((e - (450.0 + 720.0) / 4.0).abs() < 1e-9, "{e}");
        // Per-class split: 1 interactive, 2 standard, 1 batch (+1 shed).
        assert_eq!(snap.classes.len(), 3);
        assert_eq!(
            snap.classes.iter().map(|c| c.served).collect::<Vec<_>>(),
            vec![1, 2, 1]
        );
        assert_eq!(
            snap.classes.iter().map(|c| c.shed).collect::<Vec<_>>(),
            vec![0, 0, 3]
        );
        assert_eq!(snap.classes[2].shed_reasons, [1, 0, 2, 0]);
        assert_eq!(snap.classes[0].p50_us, 120.0);
        assert_eq!(snap.classes[2].p99_us, 400.0);
        assert_eq!(snap.per_board[0].depth_peak_class, [1, 2, 0]);
        // No tracing recorded: the optional trace fields stay absent.
        assert!(snap.classes.iter().all(|c| c.stages.is_none()));
        assert!(snap.per_board.iter().all(|b| b.stages.is_none() && b.drift.is_none()));
        let json = snap.to_json().to_json();
        assert!(json.contains("\"throughput_rps\""));
        assert!(json.contains("synthetic#1/kws"));
        assert!(json.contains("\"classes\""), "{json}");
        assert!(json.contains("\"class\":\"interactive\""), "{json}");
        assert!(json.contains("\"shed\""), "{json}");
        assert!(json.contains("\"shed_reasons\""), "{json}");
        assert!(json.contains("\"queue_full\":2"), "{json}");
        assert!(!json.contains("\"stages\""), "untraced runs must not emit stages");
        let parsed = crate::report::json::Value::parse(&json).unwrap();
        assert_eq!(parsed.u64_of("served").unwrap(), 4);
        assert_eq!(parsed.req("classes").unwrap().as_arr().unwrap().len(), 3);
        let rendered = snap.render();
        assert!(rendered.contains("fleet: 4 served"));
        assert!(rendered.contains("interactive"), "{rendered}");
    }

    #[test]
    fn boards_grow_at_runtime_and_peaks_roll_over() {
        let mut reg = reg2();
        let t = Telemetry::new(2);
        assert_eq!(t.len(), 2);
        let id = t.add_board();
        assert_eq!(id, 2);
        reg.instances.push(BoardInstance::synthetic(2, "kws", 100.0, 10.0, 1.5));
        t.record_batch(2, &[smp(Priority::Standard, 50.0)], 5, 45, 100.0, 0, 4, [0, 4, 0]);
        let snap = t.snapshot(&reg);
        assert_eq!(snap.per_board.len(), 3);
        assert_eq!(snap.per_board[2].served, 1);
        assert_eq!(snap.per_board[2].depth_peak, 4);
        assert_eq!(snap.per_board[2].depth_peak_class, [0, 4, 0]);
        assert_eq!(t.exec_us_totals(), vec![0, 0, 45]);
        t.reset_depth_peaks();
        let rolled = t.snapshot(&reg);
        assert_eq!(rolled.per_board[2].depth_peak, 0);
        assert_eq!(rolled.per_board[2].depth_peak_class, [0, 0, 0]);
    }

    /// Shards cap their tenant tables independently, so the merge must
    /// re-cap: the report may never exceed `TENANT_CAP` rows no matter
    /// how many distinct tenant ids flow through (ids past the cap stay
    /// counted in the aggregates).
    #[test]
    fn merged_tenant_rows_stay_bounded() {
        let reg = reg2();
        let t = Telemetry::new(2);
        for i in 0..(2 * TENANT_CAP as u32) {
            t.record_batch(
                (i % 2) as usize,
                &[ReplySample { tenant: i, priority: Priority::Standard, latency_us: 1.0 }],
                1,
                1,
                1.0,
                0,
                1,
                [0, 1, 0],
            );
        }
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 2 * TENANT_CAP as u64);
        assert_eq!(snap.tenants.len(), TENANT_CAP, "merged rows must re-cap");
        assert!(snap.tenants.iter().all(|x| x.served == 1));
        // No shard's own table overflowed (each saw exactly TENANT_CAP
        // distinct ids), so every emitted row is still exact.
        assert!(snap.tenants_complete);
        // Overflow one shard: rows stay bounded but may now undercount,
        // and the snapshot says so.
        for i in 0..(TENANT_CAP as u32 + 1) {
            t.record_batch(
                0,
                &[ReplySample { tenant: 1_000_000 + i, priority: Priority::Standard, latency_us: 1.0 }],
                1,
                1,
                1.0,
                0,
                1,
                [0, 1, 0],
            );
        }
        let over = t.snapshot(&reg);
        assert!(!over.tenants_complete, "shard overflow must be flagged");
        assert!(over.tenants.len() <= TENANT_CAP);
        assert!(over.to_json().to_json().contains("\"tenants_complete\":false"));
    }

    #[test]
    fn fleet_percentiles_weight_by_traffic() {
        // 99% of traffic at 1 us (hot board, saturated reservoir stands
        // for many requests), 1% at 100 us: weighted median must stay
        // at the hot board's latency.
        let samples = vec![(1.0, 99.0), (100.0, 1.0)];
        assert_eq!(weighted_percentile(&samples, 0.50), 1.0);
        assert_eq!(weighted_percentile(&samples, 0.999), 100.0);
        assert_eq!(weighted_percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn reservoir_keeps_percentiles_bounded() {
        let reg = reg2();
        let t = Telemetry::new(2);
        for i in 0..20_000u64 {
            t.record_batch(
                0,
                &[smp(Priority::Standard, (i % 1000) as f64)],
                1,
                1,
                1.0,
                0,
                0,
                [0, 0, 0],
            );
        }
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 20_000);
        assert!(snap.per_board[0].p50_us >= 300.0 && snap.per_board[0].p50_us <= 700.0);
        assert!(snap.per_board[0].p99_us >= 900.0);
        // The standard-class merge weighted the saturated shard; the
        // percentiles stay representative of the (uniform) stream.
        assert!(snap.classes[1].p50_us >= 300.0 && snap.classes[1].p50_us <= 700.0);
    }

    /// The refactor's lossless-merge guarantee, via the shared harness
    /// (the bench and the property tests drive the same one at other
    /// sizes/seeds).
    #[test]
    fn sharded_merge_equals_global_lock_collector_exactly() {
        assert!(!Telemetry::with_global_locks(1).is_sharded());
        assert_eq!(assert_merge_equivalence(4, 500, 0x5AAD_ED01), 500);
    }

    /// A resolved sharded sink records without ever touching the
    /// collector's slot table again (the merge still sees everything).
    #[test]
    fn sink_resolves_to_shard_and_merges() {
        let reg = reg2();
        let t = Arc::new(Telemetry::new(2));
        let sink0 = TelemetrySink::resolve(&t, 0);
        let sink1 = TelemetrySink::resolve(&t, 1);
        assert!(matches!(&sink0, TelemetrySink::Sharded(_)));
        sink0.record_batch(&[smp(Priority::Interactive, 10.0)], 1, 2, 3.0, 0, 1, [1, 0, 0]);
        sink1.record_batch(&[smp(Priority::Batch, 90.0)], 1, 2, 3.0, 0, 1, [0, 0, 1]);
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 2);
        assert_eq!(snap.classes[0].served, 1);
        assert_eq!(snap.classes[2].served, 1);
        assert_eq!(snap.classes[0].p99_us, 10.0);
        let g = Arc::new(Telemetry::with_global_locks(2));
        assert!(matches!(TelemetrySink::resolve(&g, 0), TelemetrySink::Global(..)));
    }

    /// Trace folds land in the shard and surface as per-class/per-board
    /// stage histograms plus per-instance drift in snapshot and JSON.
    #[test]
    fn trace_spans_and_drift_surface_in_snapshot() {
        let reg = reg2();
        let t = Arc::new(Telemetry::new(2));
        let sink = TelemetrySink::resolve(&t, 0);
        let ts = |class, q, w, e, r| TraceSample {
            class,
            queue_wait_us: q,
            window_wait_us: w,
            exec_us: e,
            reply_us: r,
        };
        sink.record_trace(
            &[ts(Priority::Interactive, 10, 3, 100, 2)],
            Some(DriftSample { pred_us: 90.0, obs_us: 100 }),
        );
        sink.record_trace(
            &[ts(Priority::Interactive, 40, 5, 110, 2), ts(Priority::Batch, 9000, 1, 110, 3)],
            Some(DriftSample { pred_us: 100.0, obs_us: 110 }),
        );
        let snap = t.snapshot(&reg);
        let inter = snap.classes[0].stages.as_ref().expect("interactive stages");
        assert_eq!(inter[0].count, 2, "two interactive queue_wait spans");
        assert_eq!(inter[0].sum_us, 50);
        assert_eq!(inter[2].count, 2);
        assert!(snap.classes[1].stages.is_none(), "standard saw no samples");
        let b0 = &snap.per_board[0];
        let stages = b0.stages.as_ref().expect("board stages");
        assert_eq!(stages[0].count, 3, "board merges all classes");
        let drift = b0.drift.expect("board drift");
        assert_eq!(drift.batches, 2);
        assert!((drift.predicted_exec_us - 190.0).abs() < 1e-9);
        assert!((drift.observed_exec_us - 210.0).abs() < 1e-9);
        assert!((drift.ratio - 210.0 / 190.0).abs() < 1e-9);
        assert!(snap.per_board[1].stages.is_none(), "board 1 untraced");
        let json = snap.to_json().to_json();
        assert!(json.contains("\"stages\""), "{json}");
        assert!(json.contains("\"queue_wait\""), "{json}");
        assert!(json.contains("\"drift\""), "{json}");
        crate::report::json::Value::parse(&json).expect("snapshot JSON must parse");
        let rendered = snap.render();
        assert!(rendered.contains("queue_wait"), "{rendered}");
        assert!(rendered.contains("flow-vs-measured"), "{rendered}");
    }
}
