//! Fleet-level observability: per-board counters + latency reservoirs,
//! aggregated into p50/p99 latency, throughput, energy per inference, and
//! queue depths — renderable as a table or as [`crate::report::json`].
//!
//! The board set is *growable*: [`Telemetry::add_board`] appends a slot
//! when the autoscaler spins up a replica, and retired replicas keep
//! their slots so their history stays in the final report (the snapshot
//! marks them inactive).  Scale events and fleet board-seconds ride the
//! [`FleetSnapshot`] into `report::json` alongside the latency and
//! energy aggregates.
//!
//! The collector is also **class- and tenant-aware**: every reply sample
//! carries its [`Priority`] and tenant ([`ReplySample`]), feeding
//! fleet-wide per-class latency reservoirs, per-class shed counters
//! (admission rejections recorded by the submit path), per-tenant served
//! counts, and per-class queue peak depths per board — all of which ride
//! the snapshot into the JSON report (`classes` / `tenants` fields).

use super::autoscale::ScaleEvent;
use super::cache::CacheStats;
use super::queue::{Priority, N_CLASSES};
use super::registry::Registry;
use crate::data::prng::SplitMix64;
use crate::report::json::{num, obj, s, Value};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

/// Latency samples kept per board (reservoir-sampled beyond this).
const RESERVOIR_CAP: usize = 8192;

/// Distinct tenants tracked in the per-tenant served map.  Beyond this,
/// new tenant ids are counted in fleet/class aggregates but get no
/// per-tenant row — the map (cloned into every snapshot and serialized
/// into the JSON report) must not grow without bound when callers use
/// high-cardinality tenant ids.
const TENANT_CAP: usize = 1024;

/// One served request as the worker reports it to telemetry.
#[derive(Clone, Copy, Debug)]
pub struct ReplySample {
    pub tenant: u32,
    pub priority: Priority,
    /// End-to-end latency (enqueue → reply), µs.
    pub latency_us: f64,
}

/// Reservoir-sampled latency stream (Algorithm R).
#[derive(Debug)]
struct Reservoir {
    lat_us: Vec<f64>,
    seen: u64,
    rng: SplitMix64,
}

impl Reservoir {
    fn new(seed: u64) -> Self {
        Reservoir { lat_us: Vec::new(), seen: 0, rng: SplitMix64::new(seed) }
    }

    fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.lat_us.len() < RESERVOIR_CAP {
            self.lat_us.push(v);
        } else {
            // Algorithm R: keep each of the first n samples w.p. cap/n.
            let j = self.rng.next_below(self.seen) as usize;
            if j < RESERVOIR_CAP {
                self.lat_us[j] = v;
            }
        }
    }
}

#[derive(Debug)]
struct BoardStats {
    served: u64,
    batches: u64,
    stolen: u64,
    queue_us_sum: u128,
    exec_us_sum: u128,
    energy_uj_sum: f64,
    /// End-to-end request latencies (µs), reservoir-sampled.
    lat: Reservoir,
    depth_peak: usize,
    depth_peak_class: [usize; N_CLASSES],
}

impl BoardStats {
    fn new(id: usize) -> Self {
        BoardStats {
            served: 0,
            batches: 0,
            stolen: 0,
            queue_us_sum: 0,
            exec_us_sum: 0,
            energy_uj_sum: 0.0,
            lat: Reservoir::new(0x7E1E_0000 + id as u64),
            depth_peak: 0,
            depth_peak_class: [0; N_CLASSES],
        }
    }
}

/// Fleet-wide per-class aggregate (latency reservoir + served count;
/// sheds live in lock-free counters beside it).
#[derive(Debug)]
struct ClassAgg {
    served: u64,
    lat: Reservoir,
}

/// Shared collector; workers record, anyone can snapshot.  Slots are
/// append-only: [`Self::add_board`] grows the set while workers are
/// recording (the autoscaler's scale-up path).
pub struct Telemetry {
    boards: RwLock<Vec<Mutex<BoardStats>>>,
    /// Fleet-wide per-class latency/served aggregates.
    classes: [Mutex<ClassAgg>; N_CLASSES],
    /// Admission rejections per class (recorded by the submit path when
    /// a request is definitively refused — the shed counters the bench
    /// asserts on).
    shed: [AtomicU64; N_CLASSES],
    /// Served count per tenant, fleet-wide.
    tenants: Mutex<BTreeMap<u32, u64>>,
    t0: Instant,
}

impl Telemetry {
    pub fn new(n_boards: usize) -> Self {
        Telemetry {
            boards: RwLock::new(
                (0..n_boards).map(|i| Mutex::new(BoardStats::new(i))).collect(),
            ),
            classes: [
                Mutex::new(ClassAgg { served: 0, lat: Reservoir::new(0xC1A5_0000) }),
                Mutex::new(ClassAgg { served: 0, lat: Reservoir::new(0xC1A5_0001) }),
                Mutex::new(ClassAgg { served: 0, lat: Reservoir::new(0xC1A5_0002) }),
            ],
            shed: Default::default(),
            tenants: Mutex::new(BTreeMap::new()),
            t0: Instant::now(),
        }
    }

    /// One admission rejection (`Overloaded` / `SloUnattainable`) of a
    /// `class` request.
    pub fn record_shed(&self, class: Priority) {
        self.shed[class.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Append a slot for a newly spawned replica; returns its id.
    pub fn add_board(&self) -> usize {
        let mut boards = self.boards.write().unwrap();
        let id = boards.len();
        boards.push(Mutex::new(BoardStats::new(id)));
        id
    }

    pub fn len(&self) -> usize {
        self.boards.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One executed device batch on board `id`.  `peak` / `peak_class`
    /// are the owning queue's push-time high-water marks (total and per
    /// class).
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        id: usize,
        samples: &[ReplySample],
        queue_us_sum: u128,
        exec_us: u128,
        energy_uj: f64,
        stolen: u64,
        peak: usize,
        peak_class: [usize; N_CLASSES],
    ) {
        {
            let boards = self.boards.read().unwrap();
            let mut b = boards[id].lock().unwrap();
            b.served += samples.len() as u64;
            b.batches += 1;
            b.stolen += stolen;
            b.queue_us_sum += queue_us_sum;
            b.exec_us_sum += exec_us;
            b.energy_uj_sum += energy_uj;
            b.depth_peak = b.depth_peak.max(peak);
            for c in 0..N_CLASSES {
                b.depth_peak_class[c] = b.depth_peak_class[c].max(peak_class[c]);
            }
            for s in samples {
                b.lat.push(s.latency_us);
            }
        }
        // One lock per class per batch, not per sample: the class aggs
        // are fleet-global, so per-sample locking would multiply
        // contention by the batch size on the hot serve path.
        for p in Priority::ALL {
            let mut it = samples.iter().filter(|s| s.priority == p).peekable();
            if it.peek().is_none() {
                continue;
            }
            let mut agg = self.classes[p.idx()].lock().unwrap();
            for s in it {
                agg.served += 1;
                agg.lat.push(s.latency_us);
            }
        }
        {
            let mut tenants = self.tenants.lock().unwrap();
            for s in samples {
                if let Some(n) = tenants.get_mut(&s.tenant) {
                    *n += 1;
                } else if tenants.len() < TENANT_CAP {
                    tenants.insert(s.tenant, 1);
                }
            }
        }
    }

    /// Cumulative device-execution µs per board — the autoscaler's
    /// utilization signal (it differences consecutive reads over its
    /// sampling interval).
    pub fn exec_us_totals(&self) -> Vec<u128> {
        self.boards
            .read()
            .unwrap()
            .iter()
            .map(|m| m.lock().unwrap().exec_us_sum)
            .collect()
    }

    /// Roll per-board queue-depth peaks (total and per class) over to
    /// zero; paired with [`super::queue::BoardQueue::reset_peak`] at
    /// snapshot/phase boundaries so `depth_peak` reads per-phase, not
    /// since-birth.
    pub fn reset_depth_peaks(&self) {
        for m in self.boards.read().unwrap().iter() {
            let mut b = m.lock().unwrap();
            b.depth_peak = 0;
            b.depth_peak_class = [0; N_CLASSES];
        }
    }

    pub fn snapshot(&self, reg: &Registry) -> FleetSnapshot {
        let elapsed_s = self.t0.elapsed().as_secs_f64().max(1e-9);
        let mut per_board = Vec::new();
        // Fleet percentiles weight each board's reservoir samples by the
        // traffic they represent (served / samples-kept): once a hot
        // board's reservoir saturates, its samples each stand for many
        // requests, and a flat merge would overrepresent idle boards.
        let mut weighted: Vec<(f64, f64)> = Vec::new();
        let mut served = 0u64;
        let mut energy = 0.0f64;
        let boards = self.boards.read().unwrap();
        for (i, m) in boards.iter().enumerate().take(reg.len()) {
            let b = m.lock().unwrap();
            let inst = &reg.instances[i];
            let mut lat = b.lat.lat_us.clone();
            if !lat.is_empty() {
                let w = b.served as f64 / lat.len() as f64;
                weighted.extend(lat.iter().map(|&v| (v, w)));
            }
            served += b.served;
            energy += b.energy_uj_sum;
            lat.sort_by(|a, c| a.total_cmp(c));
            per_board.push(BoardSnapshot {
                label: inst.label.clone(),
                task: inst.task.clone(),
                active: true,
                served: b.served,
                batches: b.batches,
                stolen: b.stolen,
                mean_batch: if b.batches > 0 {
                    b.served as f64 / b.batches as f64
                } else {
                    0.0
                },
                mean_queue_us: if b.served > 0 {
                    b.queue_us_sum as f64 / b.served as f64
                } else {
                    0.0
                },
                p50_us: percentile(&lat, 0.50),
                p99_us: percentile(&lat, 0.99),
                energy_per_inference_uj: if b.served > 0 {
                    b.energy_uj_sum / b.served as f64
                } else {
                    0.0
                },
                depth_peak: b.depth_peak,
                depth_peak_class: b.depth_peak_class,
            });
        }
        weighted.sort_by(|a, c| a.0.total_cmp(&c.0));
        let classes = Priority::ALL
            .iter()
            .map(|p| {
                let agg = self.classes[p.idx()].lock().unwrap();
                let mut lat = agg.lat.lat_us.clone();
                lat.sort_by(|a, c| a.total_cmp(c));
                ClassSnapshot {
                    class: p.name(),
                    served: agg.served,
                    shed: self.shed[p.idx()].load(Ordering::Relaxed),
                    p50_us: percentile(&lat, 0.50),
                    p99_us: percentile(&lat, 0.99),
                }
            })
            .collect();
        let tenants = self
            .tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(&tenant, &served)| TenantSnapshot { tenant, served })
            .collect();
        FleetSnapshot {
            elapsed_s,
            served,
            throughput_rps: served as f64 / elapsed_s,
            p50_us: weighted_percentile(&weighted, 0.50),
            p99_us: weighted_percentile(&weighted, 0.99),
            energy_per_inference_uj: if served > 0 { energy / served as f64 } else { 0.0 },
            cache: CacheStats::default(),
            classes,
            tenants,
            // The fleet layer grafts these on: board lifecycle and scale
            // history live beside the queues, not in the per-board stats.
            board_seconds: 0.0,
            scale_events: Vec::new(),
            per_board,
        }
    }
}

/// Percentile over a pre-sorted slice (nearest-rank).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Percentile over (value, weight) pairs pre-sorted by value: the first
/// value whose cumulative weight reaches `q` of the total.
fn weighted_percentile(sorted: &[(f64, f64)], q: f64) -> f64 {
    let total: f64 = sorted.iter().map(|&(_, w)| w).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let target = total * q;
    let mut cum = 0.0;
    for &(v, w) in sorted {
        cum += w;
        if cum >= target {
            return v;
        }
    }
    sorted.last().map(|&(v, _)| v).unwrap_or(0.0)
}

/// Per-board aggregate view.
#[derive(Clone, Debug)]
pub struct BoardSnapshot {
    pub label: String,
    pub task: String,
    /// `false` once the replica has been retired (history retained).
    pub active: bool,
    pub served: u64,
    pub batches: u64,
    pub stolen: u64,
    pub mean_batch: f64,
    pub mean_queue_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub energy_per_inference_uj: f64,
    pub depth_peak: usize,
    /// Push-time queue peak per priority class
    /// (`[interactive, standard, batch]`), rolled over with
    /// `depth_peak` at phase boundaries.
    pub depth_peak_class: [usize; N_CLASSES],
}

/// Fleet-wide per-priority-class aggregate: latency percentiles over the
/// class's own reservoir, served count, and sheds (admission
/// rejections).
#[derive(Clone, Debug)]
pub struct ClassSnapshot {
    pub class: &'static str,
    pub served: u64,
    pub shed: u64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl ClassSnapshot {
    /// The one JSON shape for per-class stats — shared by
    /// [`FleetSnapshot::to_json`] and the bench reports so the schema
    /// cannot drift between them.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("class", s(self.class)),
            ("served", num(self.served as f64)),
            ("shed", num(self.shed as f64)),
            ("p50_us", num(self.p50_us)),
            ("p99_us", num(self.p99_us)),
        ])
    }
}

/// Served count for one tenant.
#[derive(Clone, Copy, Debug)]
pub struct TenantSnapshot {
    pub tenant: u32,
    pub served: u64,
}

/// Fleet aggregate view.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub elapsed_s: f64,
    pub served: u64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub energy_per_inference_uj: f64,
    /// Result-cache counters (all zero when caching is disabled);
    /// `served` counts only board-executed requests, so total traffic is
    /// `served + cache.hits`.
    pub cache: CacheStats,
    /// Per-priority-class p50/p99/served/shed, always all three classes
    /// in `[interactive, standard, batch]` order.
    pub classes: Vec<ClassSnapshot>,
    /// Served count per tenant (tenant 0 is the untagged default; only
    /// the first `TENANT_CAP` distinct ids get a row).
    pub tenants: Vec<TenantSnapshot>,
    /// Total board-alive time: Σ over replicas of (retired-or-now −
    /// started).  The autoscaler's cost axis — an elastic fleet should
    /// serve the same trace with fewer board-seconds than a fixed one.
    pub board_seconds: f64,
    /// Scale-up/-down history (empty without the autoscaler).
    pub scale_events: Vec<ScaleEvent>,
    pub per_board: Vec<BoardSnapshot>,
}

impl FleetSnapshot {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("elapsed_s", num(self.elapsed_s)),
            ("served", num(self.served as f64)),
            ("throughput_rps", num(self.throughput_rps)),
            ("p50_us", num(self.p50_us)),
            ("p99_us", num(self.p99_us)),
            ("energy_per_inference_uj", num(self.energy_per_inference_uj)),
            ("cache_hits", num(self.cache.hits as f64)),
            ("cache_misses", num(self.cache.misses as f64)),
            ("cache_entries", num(self.cache.entries as f64)),
            ("cache_hit_rate", num(self.cache.hit_rate())),
            (
                "cache_per_task",
                Value::Arr(
                    self.cache
                        .per_task
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("task", s(&t.task)),
                                ("hits", num(t.hits as f64)),
                                ("misses", num(t.misses as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "classes",
                Value::Arr(self.classes.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "tenants",
                Value::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("tenant", num(t.tenant as f64)),
                                ("served", num(t.served as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("board_seconds", num(self.board_seconds)),
            (
                "scale_events",
                Value::Arr(self.scale_events.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "boards",
                Value::Arr(
                    self.per_board
                        .iter()
                        .map(|b| {
                            obj(vec![
                                ("label", s(&b.label)),
                                ("task", s(&b.task)),
                                ("active", Value::Bool(b.active)),
                                ("served", num(b.served as f64)),
                                ("batches", num(b.batches as f64)),
                                ("stolen", num(b.stolen as f64)),
                                ("mean_batch", num(b.mean_batch)),
                                ("mean_queue_us", num(b.mean_queue_us)),
                                ("p50_us", num(b.p50_us)),
                                ("p99_us", num(b.p99_us)),
                                (
                                    "energy_per_inference_uj",
                                    num(b.energy_per_inference_uj),
                                ),
                                ("depth_peak", num(b.depth_peak as f64)),
                                (
                                    "depth_peak_class",
                                    Value::Arr(
                                        b.depth_peak_class
                                            .iter()
                                            .map(|&p| num(p as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        writeln!(
            out,
            "fleet: {} served in {:.3} s = {:.0} req/s | p50 {:.1} us  p99 {:.1} us | {:.2} uJ/inf",
            self.served,
            self.elapsed_s,
            self.throughput_rps,
            self.p50_us,
            self.p99_us,
            self.energy_per_inference_uj
        )
        .ok();
        if self.cache.hits + self.cache.misses > 0 {
            writeln!(
                out,
                "  cache: {} hits / {} misses ({:.1}% hit rate, {} of {} entries)",
                self.cache.hits,
                self.cache.misses,
                100.0 * self.cache.hit_rate(),
                self.cache.entries,
                self.cache.cap
            )
            .ok();
            for t in &self.cache.per_task {
                writeln!(
                    out,
                    "    {:<4} {} hits / {} misses",
                    t.task, t.hits, t.misses
                )
                .ok();
            }
        }
        // Per-class breakdown, shown once any non-default class has
        // traffic or anything was shed (all-Standard runs stay terse).
        let classful = self
            .classes
            .iter()
            .any(|c| c.shed > 0 || (c.class != "standard" && c.served > 0));
        if classful {
            writeln!(
                out,
                "  {:<12} {:>7} {:>7} {:>9} {:>9}",
                "class", "served", "shed", "p50(us)", "p99(us)"
            )
            .ok();
            for c in &self.classes {
                writeln!(
                    out,
                    "  {:<12} {:>7} {:>7} {:>9.1} {:>9.1}",
                    c.class, c.served, c.shed, c.p50_us, c.p99_us
                )
                .ok();
            }
        }
        if self.tenants.len() > 1 {
            let list: Vec<String> = self
                .tenants
                .iter()
                .map(|t| format!("t{}:{}", t.tenant, t.served))
                .collect();
            writeln!(out, "  tenants: {} ({})", self.tenants.len(), list.join(" ")).ok();
        }
        if !self.scale_events.is_empty() {
            writeln!(
                out,
                "  autoscale: {} events, {:.3} board-seconds",
                self.scale_events.len(),
                self.board_seconds
            )
            .ok();
            for e in &self.scale_events {
                writeln!(out, "    {e}").ok();
            }
        }
        writeln!(
            out,
            "  {:<26} {:>6} {:>7} {:>7} {:>9} {:>9} {:>9} {:>6} {:>6}",
            "board", "served", "batches", "stolen", "p50(us)", "p99(us)", "uJ/inf", "avg_b", "peakQ"
        )
        .ok();
        for b in &self.per_board {
            let label = if b.active {
                b.label.clone()
            } else {
                format!("{} (retired)", b.label)
            };
            writeln!(
                out,
                "  {:<26} {:>6} {:>7} {:>7} {:>9.1} {:>9.1} {:>9.2} {:>6.2} {:>6}",
                label,
                b.served,
                b.batches,
                b.stolen,
                b.p50_us,
                b.p99_us,
                b.energy_per_inference_uj,
                b.mean_batch,
                b.depth_peak
            )
            .ok();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::{BoardInstance, Registry};

    fn reg2() -> Registry {
        Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 100.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 400.0, 40.0, 1.8),
            ],
        }
    }

    fn smp(priority: Priority, latency_us: f64) -> ReplySample {
        ReplySample { tenant: 0, priority, latency_us }
    }

    #[test]
    fn snapshot_aggregates_and_serializes() {
        let reg = reg2();
        let t = Telemetry::new(2);
        t.record_batch(
            0,
            &[
                smp(Priority::Standard, 100.0),
                smp(Priority::Interactive, 120.0),
                smp(Priority::Standard, 140.0),
            ],
            30,
            90,
            450.0,
            1,
            3,
            [1, 2, 0],
        );
        t.record_batch(1, &[smp(Priority::Batch, 400.0)], 10, 380, 720.0, 0, 0, [0, 0, 0]);
        t.record_shed(Priority::Batch);
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 4);
        assert!(snap.p50_us >= 100.0 && snap.p50_us <= 400.0);
        assert!(snap.p99_us >= snap.p50_us);
        let e = snap.energy_per_inference_uj;
        assert!((e - (450.0 + 720.0) / 4.0).abs() < 1e-9, "{e}");
        // Per-class split: 1 interactive, 2 standard, 1 batch (+1 shed).
        assert_eq!(snap.classes.len(), 3);
        assert_eq!(
            snap.classes.iter().map(|c| c.served).collect::<Vec<_>>(),
            vec![1, 2, 1]
        );
        assert_eq!(
            snap.classes.iter().map(|c| c.shed).collect::<Vec<_>>(),
            vec![0, 0, 1]
        );
        assert_eq!(snap.classes[0].p50_us, 120.0);
        assert_eq!(snap.classes[2].p99_us, 400.0);
        assert_eq!(snap.per_board[0].depth_peak_class, [1, 2, 0]);
        let json = snap.to_json().to_json();
        assert!(json.contains("\"throughput_rps\""));
        assert!(json.contains("synthetic#1/kws"));
        assert!(json.contains("\"classes\""), "{json}");
        assert!(json.contains("\"class\":\"interactive\""), "{json}");
        assert!(json.contains("\"shed\""), "{json}");
        let parsed = crate::report::json::Value::parse(&json).unwrap();
        assert_eq!(parsed.u64_of("served").unwrap(), 4);
        assert_eq!(parsed.req("classes").unwrap().as_arr().unwrap().len(), 3);
        let rendered = snap.render();
        assert!(rendered.contains("fleet: 4 served"));
        assert!(rendered.contains("interactive"), "{rendered}");
    }

    #[test]
    fn boards_grow_at_runtime_and_peaks_roll_over() {
        let mut reg = reg2();
        let t = Telemetry::new(2);
        assert_eq!(t.len(), 2);
        let id = t.add_board();
        assert_eq!(id, 2);
        reg.instances.push(BoardInstance::synthetic(2, "kws", 100.0, 10.0, 1.5));
        t.record_batch(2, &[smp(Priority::Standard, 50.0)], 5, 45, 100.0, 0, 4, [0, 4, 0]);
        let snap = t.snapshot(&reg);
        assert_eq!(snap.per_board.len(), 3);
        assert_eq!(snap.per_board[2].served, 1);
        assert_eq!(snap.per_board[2].depth_peak, 4);
        assert_eq!(snap.per_board[2].depth_peak_class, [0, 4, 0]);
        assert_eq!(t.exec_us_totals(), vec![0, 0, 45]);
        t.reset_depth_peaks();
        let rolled = t.snapshot(&reg);
        assert_eq!(rolled.per_board[2].depth_peak, 0);
        assert_eq!(rolled.per_board[2].depth_peak_class, [0, 0, 0]);
    }

    #[test]
    fn fleet_percentiles_weight_by_traffic() {
        // 99% of traffic at 1 us (hot board, saturated reservoir stands
        // for many requests), 1% at 100 us: weighted median must stay
        // at the hot board's latency.
        let samples = vec![(1.0, 99.0), (100.0, 1.0)];
        assert_eq!(weighted_percentile(&samples, 0.50), 1.0);
        assert_eq!(weighted_percentile(&samples, 0.999), 100.0);
        assert_eq!(weighted_percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn reservoir_keeps_percentiles_bounded() {
        let reg = reg2();
        let t = Telemetry::new(2);
        for i in 0..20_000u64 {
            t.record_batch(
                0,
                &[smp(Priority::Standard, (i % 1000) as f64)],
                1,
                1,
                1.0,
                0,
                0,
                [0, 0, 0],
            );
        }
        let snap = t.snapshot(&reg);
        assert_eq!(snap.served, 20_000);
        assert!(snap.per_board[0].p50_us >= 300.0 && snap.per_board[0].p50_us <= 700.0);
        assert!(snap.per_board[0].p99_us >= 900.0);
    }
}
