//! Deterministic cross-language PRNG (splitmix64 + Box-Muller).
//!
//! Bit-exact mirror of `python/compile/prng.py`; the reference vectors in
//! the tests below are asserted identically by
//! `python/tests/test_prng_synthdata.py`.  Class templates generated here
//! match the ones the models were trained on, which is what makes the
//! Rust-side accuracy numbers meaningful.

/// splitmix64 — tiny, fast, trivially portable.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Box-Muller, cosine branch only (matches the Python impl exactly).
    pub fn next_gaussian(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        let u2 = self.next_f64();
        if u1 <= 0.0 {
            u1 = 2.0_f64.powi(-53);
        }
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_gaussian()).collect()
    }

    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_f64()).collect()
    }

    /// Uniform integer in [0, n) (Lemire-free simple modulo; fine for data
    /// generation, not cryptography).
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }
}

/// Per-(task, class) stream seed; must match `python/compile/prng.py`.
pub fn template_seed(task_seed: u64, class_id: u64) -> u64 {
    task_seed
        .wrapping_mul(0x0000_0100_0000_01B3)
        .wrapping_add(class_id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(1)
}

/// The deterministic class template both languages agree on.
pub fn class_template(task_seed: u64, class_id: u64, dim: usize) -> Vec<f64> {
    SplitMix64::new(template_seed(task_seed, class_id)).gaussian_vec(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same vectors as python/tests/test_prng_synthdata.py.
    #[test]
    fn splitmix_vectors() {
        let mut rng = SplitMix64::new(0xDEAD_BEEF);
        let got: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                0x4ADF_B90F_68C9_EB9B,
                0xDE58_6A31_41A1_0922,
                0x021F_BC2F_8E1C_FC1D,
                0x7466_CE73_7BE1_6790,
            ]
        );
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = SplitMix64::new(12345);
        let v = rng.uniform_vec(1000);
        assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        assert!((0.4..0.6).contains(&mean), "{mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = SplitMix64::new(99);
        let v = rng.gaussian_vec(4000);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.08, "{mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.08, "{var}");
    }

    #[test]
    fn templates_deterministic_and_distinct() {
        let a = class_template(7, 3, 64);
        let b = class_template(7, 3, 64);
        assert_eq!(a, b);
        let c = class_template(7, 4, 64);
        assert!(a.iter().zip(&c).any(|(x, y)| (x - y).abs() > 0.1));
    }
}
