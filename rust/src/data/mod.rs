//! Synthetic datasets (request-path side) + ROC/AUC.
//!
//! Mirrors `python/compile/synthdata.py` — identical class templates via
//! the shared splitmix64 stream, independent per-sample noise.  See
//! DESIGN.md §Hardware-Adaptation for the dataset substitutions
//! (CIFAR-10 → class-template images, ToyADMOS → spectral-profile frames,
//! Speech Commands v2 → MFCC-like keyword vectors).

pub mod prng;

use prng::{class_template, SplitMix64};

pub const IC_SEED: u64 = 0xC1FA_0001;
pub const AD_SEED: u64 = 0x70AD_0002;
pub const KWS_SEED: u64 = 0x5EEC_0003;

pub const IC_CLASSES: usize = 10;
pub const IC_DIM: usize = 32 * 32 * 3;
pub const KWS_CLASSES: usize = 12;
pub const KWS_DIM: usize = 490;
pub const KWS_SILENCE: usize = 10;
pub const KWS_UNKNOWN: usize = 11;
pub const KWS_N_UNKNOWN_TEMPLATES: usize = 25;
pub const AD_DIM: usize = 128;
pub const AD_SMOOTH_WINDOW: usize = 9;

pub const IC_TEMPLATE_SCALE: f64 = 0.18;
pub const IC_NOISE: f64 = 2.0;
pub const KWS_NOISE: f64 = 1.25;
pub const AD_NOISE: f64 = 0.35;
pub const AD_BUMP_AMP: f64 = 1.2;
pub const AD_BUMP_WIDTH: f64 = 5.0;

/// Centered moving average with edge clamping (mirror of Python).
fn moving_average(x: &[f64], window: usize) -> Vec<f64> {
    let n = x.len();
    let half = window / 2;
    (0..n)
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(n);
            x[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect()
}

/// Template-matching logits, `dot(x, template) / dim` per class.
///
/// This is the *naive f32 reference* for the packed quantized kernels in
/// [`crate::kernels`]: the surrogate executors (`runtime::sim`,
/// `fleet::worker`) now run `PackedLinear` packed from the same
/// templates, and the kernel property tests + `benches/kernels.rs`
/// check against (and race against) this implementation.
pub fn template_logits(x: &[f32], templates: &[Vec<f32>]) -> Vec<f32> {
    let scale = 1.0 / x.len().max(1) as f32;
    templates
        .iter()
        .map(|t| x.iter().zip(t).map(|(a, b)| a * b).sum::<f32>() * scale)
        .collect()
}

/// f32 class templates for a classification task (KWS keywords / IC
/// classes), shared by the surrogate executors.
pub fn class_templates_f32(task: &str, n_out: usize) -> Vec<Vec<f32>> {
    (0..n_out)
        .map(|c| {
            let t = match task {
                "kws" => kws_template(c),
                _ => ic_template(c % IC_CLASSES),
            };
            t.iter().map(|&v| v as f32).collect()
        })
        .collect()
}

/// f32 variant of the same kernel — the *naive O(n·window) reference*
/// for [`crate::kernels::SmoothKernel`], which both surrogate executors
/// now run; the kernel property tests assert exact agreement on
/// grid-quantized inputs.
pub fn moving_average_f32(x: &[f32], window: usize) -> Vec<f32> {
    let n = x.len();
    let half = window / 2;
    (0..n)
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(n);
            x[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
        })
        .collect()
}

pub fn ic_template(class: usize) -> Vec<f64> {
    class_template(IC_SEED, class as u64, IC_DIM)
}

pub fn kws_template(class: usize) -> Vec<f64> {
    class_template(KWS_SEED, class as u64, KWS_DIM)
}

pub fn ad_profile(machine_id: usize) -> Vec<f64> {
    let raw = class_template(AD_SEED, machine_id as u64, AD_DIM);
    moving_average(&raw, AD_SMOOTH_WINDOW)
}

/// A labeled sample: flattened f32 features + integer label.
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Vec<f32>,
    pub label: i32,
}

/// Deterministic test-set generator for a task.
pub struct TestSet {
    pub task: String,
    pub samples: Vec<Sample>,
}

/// IC: template image + amplitude jitter + gaussian noise, clipped to [0,1].
pub fn ic_sample(rng: &mut SplitMix64, class: usize, templates: &[Vec<f64>]) -> Sample {
    let amp = rng.uniform_range(0.8, 1.2);
    let t = &templates[class];
    let x = (0..IC_DIM)
        .map(|i| {
            let v = 0.5 + IC_TEMPLATE_SCALE * (amp * t[i] + IC_NOISE * rng.next_gaussian());
            v.clamp(0.0, 1.0) as f32
        })
        .collect();
    Sample { x, label: class as i32 }
}

pub fn kws_sample(
    rng: &mut SplitMix64,
    class: usize,
    keyword_templates: &[Vec<f64>],
    unknown_templates: &[Vec<f64>],
) -> Sample {
    let x: Vec<f32> = if class < 10 {
        let t = &keyword_templates[class];
        (0..KWS_DIM)
            .map(|i| (t[i] + KWS_NOISE * rng.next_gaussian()) as f32)
            .collect()
    } else if class == KWS_SILENCE {
        (0..KWS_DIM).map(|_| (0.15 * rng.next_gaussian()) as f32).collect()
    } else {
        let j = rng.next_below(KWS_N_UNKNOWN_TEMPLATES as u64) as usize;
        let t = &unknown_templates[j];
        (0..KWS_DIM)
            .map(|i| (t[i] + KWS_NOISE * rng.next_gaussian()) as f32)
            .collect()
    };
    Sample { x, label: class as i32 }
}

pub fn ad_sample(rng: &mut SplitMix64, anomalous: bool, profile: &[f64]) -> Sample {
    let mut x: Vec<f64> = (0..AD_DIM)
        .map(|i| profile[i] + AD_NOISE * rng.next_gaussian())
        .collect();
    if anomalous {
        let center = rng.uniform_range(8.0, (AD_DIM - 8) as f64);
        let sign = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
        for (i, v) in x.iter_mut().enumerate() {
            let d = (i as f64 - center) / AD_BUMP_WIDTH;
            *v += sign * AD_BUMP_AMP * (-0.5 * d * d).exp();
        }
    }
    Sample {
        x: x.into_iter().map(|v| v as f32).collect(),
        label: anomalous as i32,
    }
}

/// Build the benchmark test set for a task.  Sizes follow the MLPerf Tiny
/// on-device subsets (IC: 200 balanced, KWS: 1000, AD: 2459→scaled 250+250).
pub fn test_set(task: &str, n: usize, seed: u64) -> TestSet {
    let mut rng = SplitMix64::new(seed);
    let samples = match task {
        "ic" => {
            let templates: Vec<_> = (0..IC_CLASSES).map(ic_template).collect();
            // Class-balanced, like the v0.7 subset (§2.1).
            (0..n).map(|i| ic_sample(&mut rng, i % IC_CLASSES, &templates)).collect()
        }
        "kws" => {
            let kw: Vec<_> = (0..10).map(kws_template).collect();
            let unk: Vec<_> = (0..KWS_N_UNKNOWN_TEMPLATES)
                .map(|j| kws_template(100 + j))
                .collect();
            (0..n)
                .map(|i| kws_sample(&mut rng, i % KWS_CLASSES, &kw, &unk))
                .collect()
        }
        "ad" => {
            let profile = ad_profile(0);
            (0..n)
                .map(|i| ad_sample(&mut rng, i % 2 == 1, &profile))
                .collect()
        }
        other => panic!("unknown task {other}"),
    };
    TestSet { task: task.to_string(), samples }
}

/// Training-batch generator (for the Rust-driven SGD loop).
pub fn train_batch(task: &str, rng: &mut SplitMix64, n: usize) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * feature_dim(task));
    let mut ys = Vec::with_capacity(n);
    match task {
        "ic" => {
            let templates: Vec<_> = (0..IC_CLASSES).map(ic_template).collect();
            for _ in 0..n {
                let c = rng.next_below(IC_CLASSES as u64) as usize;
                let s = ic_sample(rng, c, &templates);
                xs.extend_from_slice(&s.x);
                ys.push(s.label);
            }
        }
        "kws" => {
            let kw: Vec<_> = (0..10).map(kws_template).collect();
            let unk: Vec<_> = (0..KWS_N_UNKNOWN_TEMPLATES)
                .map(|j| kws_template(100 + j))
                .collect();
            for _ in 0..n {
                let c = rng.next_below(KWS_CLASSES as u64) as usize;
                let s = kws_sample(rng, c, &kw, &unk);
                xs.extend_from_slice(&s.x);
                ys.push(s.label);
            }
        }
        "ad" => {
            // Unsupervised: train on normal data only (§2.2).
            let profile = ad_profile(0);
            for _ in 0..n {
                let s = ad_sample(rng, false, &profile);
                xs.extend_from_slice(&s.x);
                ys.push(0);
            }
        }
        other => panic!("unknown task {other}"),
    }
    (xs, ys)
}

pub fn feature_dim(task: &str) -> usize {
    match task {
        "ic" => IC_DIM,
        "kws" => KWS_DIM,
        "ad" => AD_DIM,
        other => panic!("unknown task {other}"),
    }
}

/// Non-panicking [`feature_dim`]: `None` for task names outside the
/// MLPerf Tiny trio (hand-built registries may host anything; callers
/// that can't know the dimension skip validation instead of panicking).
pub fn feature_dim_of(task: &str) -> Option<usize> {
    match task {
        "ic" => Some(IC_DIM),
        "kws" => Some(KWS_DIM),
        "ad" => Some(AD_DIM),
        _ => None,
    }
}

/// ROC AUC from (score, is_anomaly) pairs — the AD quality metric (§2.2).
pub fn roc_auc(scores: &[(f32, bool)]) -> f64 {
    let mut pos: Vec<f32> = scores.iter().filter(|s| s.1).map(|s| s.0).collect();
    let mut neg: Vec<f32> = scores.iter().filter(|s| !s.1).map(|s| s.0).collect();
    if pos.is_empty() || neg.is_empty() {
        return 0.5;
    }
    pos.sort_by(|a, b| a.total_cmp(b));
    neg.sort_by(|a, b| a.total_cmp(b));
    // Mann-Whitney U via merge counting (ties get half credit).
    let mut wins = 0.0f64;
    for &p in &pos {
        let below = neg.partition_point(|&x| x < p);
        let equal = neg.partition_point(|&x| x <= p) - below;
        wins += below as f64 + 0.5 * equal as f64;
    }
    wins / (pos.len() as f64 * neg.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ic_samples_in_unit_range() {
        let ts = test_set("ic", 20, 1);
        for s in &ts.samples {
            assert_eq!(s.x.len(), IC_DIM);
            assert!(s.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn ad_anomalies_deviate_more() {
        let profile = ad_profile(0);
        let mut rng = SplitMix64::new(3);
        let dev = |s: &Sample| -> f64 {
            s.x.iter()
                .enumerate()
                .map(|(i, &v)| (v as f64 - profile[i]).abs())
                .fold(0.0, f64::max)
        };
        let dn: f64 = (0..100)
            .map(|_| dev(&ad_sample(&mut rng, false, &profile)))
            .sum::<f64>()
            / 100.0;
        let da: f64 = (0..100)
            .map(|_| dev(&ad_sample(&mut rng, true, &profile)))
            .sum::<f64>()
            / 100.0;
        assert!(da > dn * 1.3, "dn={dn} da={da}");
    }

    #[test]
    fn auc_perfect_and_random() {
        let perfect: Vec<(f32, bool)> =
            (0..50).map(|i| (i as f32, i >= 25)).collect();
        assert!((roc_auc(&perfect) - 1.0).abs() < 1e-9);
        let mut rng = SplitMix64::new(0);
        let random: Vec<(f32, bool)> = (0..2000)
            .map(|_| (rng.next_f64() as f32, rng.next_f64() < 0.5))
            .collect();
        let auc = roc_auc(&random);
        assert!((auc - 0.5).abs() < 0.05, "{auc}");
    }

    #[test]
    fn test_set_balanced_classes() {
        let ts = test_set("ic", 200, 7);
        let mut counts = [0usize; IC_CLASSES];
        for s in &ts.samples {
            counts[s.label as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn kws_silence_quieter() {
        let ts = test_set("kws", 240, 9);
        let energy = |s: &Sample| -> f64 {
            s.x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / s.x.len() as f64
        };
        let sil: f64 = ts.samples.iter().filter(|s| s.label == 10).map(energy).sum::<f64>();
        let spk: f64 = ts.samples.iter().filter(|s| s.label < 10).map(energy).sum::<f64>();
        assert!(sil * 5.0 < spk, "sil={sil} spk={spk}");
    }

    #[test]
    fn templates_match_python_profile_smoothness() {
        let p = ad_profile(0);
        let raw = class_template(AD_SEED, 0, AD_DIM);
        let rough = |v: &[f64]| -> f64 {
            v.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (v.len() - 1) as f64
        };
        assert!(rough(&p) < 0.5 * rough(&raw));
    }
}
