//! QONNX-like graph IR.
//!
//! Parsed from the `*_topology.json` files emitted by `python/compile/aot.py`
//! (the Python side of the QONNX interchange of §4.1).  All four submitted
//! models are chains (the chosen v0.7 IC model has no skip connections),
//! so the IR is an ordered node list; the compiler passes in [`crate::passes`]
//! rewrite it, and [`crate::dataflow`] / [`crate::resources`] consume it.

use crate::report::json::Value;
use crate::error::{bail, Context, Result};
use std::path::Path;

/// One layer/operator in the chain.
#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    Conv2D {
        name: String,
        in_hw: usize,
        out_hw: usize,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: String,
        weight_bits: u32,
        params: u64,
        /// Set by the ReLU-merge pass (§3.1.3): activation fused into this
        /// dataflow stage instead of occupying its own stage + FIFO.
        fused_relu: bool,
        /// Set by the BN-fold pass: BN absorbed into the kernel (eq. 3-4).
        folded_bn: bool,
        /// Set by the accumulator-minimization pass (§3.5); 0 = not yet
        /// minimized (synthesis default: 32-bit accumulators).
        acc_bits: u32,
        /// Input activation precision, set by datatype inference.
        in_bits: u32,
    },
    Dense {
        name: String,
        in_features: usize,
        out_features: usize,
        weight_bits: u32,
        has_bias: bool,
        params: u64,
        fused_relu: bool,
        folded_bn: bool,
        acc_bits: u32,
        in_bits: u32,
    },
    BatchNorm {
        name: String,
        channels: usize,
        params: u64,
    },
    ReLU {
        name: String,
        channels: usize,
        act_bits: u32,
        params: u64,
    },
    BipolarAct {
        name: String,
        channels: usize,
        params: u64,
    },
    MaxPool {
        name: String,
        in_hw: usize,
        out_hw: usize,
        channels: usize,
        size: usize,
        params: u64,
    },
    Flatten {
        name: String,
        features: usize,
        params: u64,
    },
    Softmax {
        name: String,
        channels: usize,
        params: u64,
    },
    /// Created by the streamlining pass (§3.5): BN + quantized activation
    /// folded into per-channel integer thresholds.
    MultiThreshold {
        name: String,
        channels: usize,
        /// Number of thresholds = 2^act_bits - 1 (1 for bipolar).
        levels: u32,
        params: u64,
    },
    /// Created by the softmax-removal pass (§3.1.1): in-hardware top-k.
    TopK {
        name: String,
        channels: usize,
        k: usize,
        params: u64,
    },
}

impl Node {
    pub fn from_json(v: &Value) -> Result<Node> {
        let op = v.str_of("op")?;
        let name = v.str_of("name")?;
        let params = v.u64_of("params")?;
        Ok(match op.as_str() {
            "Conv2D" => Node::Conv2D {
                name,
                in_hw: v.usize_of("in_hw")?,
                out_hw: v.usize_of("out_hw")?,
                in_ch: v.usize_of("in_ch")?,
                out_ch: v.usize_of("out_ch")?,
                kernel: v.usize_of("kernel")?,
                stride: v.usize_of("stride")?,
                padding: v.str_of("padding")?,
                weight_bits: v.u64_of("weight_bits")? as u32,
                params,
                fused_relu: v.bool_of_or("fused_relu", false),
                folded_bn: v.bool_of_or("folded_bn", false),
                acc_bits: v.get("acc_bits").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
                in_bits: v.get("in_bits").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
            },
            "Dense" => Node::Dense {
                name,
                in_features: v.usize_of("in_features")?,
                out_features: v.usize_of("out_features")?,
                weight_bits: v.u64_of("weight_bits")? as u32,
                has_bias: v.bool_of_or("has_bias", false),
                params,
                fused_relu: v.bool_of_or("fused_relu", false),
                folded_bn: v.bool_of_or("folded_bn", false),
                acc_bits: v.get("acc_bits").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
                in_bits: v.get("in_bits").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
            },
            "BatchNorm" => Node::BatchNorm { name, channels: v.usize_of("channels")?, params },
            "ReLU" => Node::ReLU {
                name,
                channels: v.usize_of("channels")?,
                act_bits: v.u64_of("act_bits")? as u32,
                params,
            },
            "BipolarAct" => Node::BipolarAct { name, channels: v.usize_of("channels")?, params },
            "MaxPool" => Node::MaxPool {
                name,
                in_hw: v.usize_of("in_hw")?,
                out_hw: v.usize_of("out_hw")?,
                channels: v.usize_of("channels")?,
                size: v.usize_of("size")?,
                params,
            },
            "Flatten" => Node::Flatten { name, features: v.usize_of("features")?, params },
            "Softmax" => Node::Softmax { name, channels: v.usize_of("channels")?, params },
            "MultiThreshold" => Node::MultiThreshold {
                name,
                channels: v.usize_of("channels")?,
                levels: v.u64_of("levels")? as u32,
                params,
            },
            "TopK" => Node::TopK {
                name,
                channels: v.usize_of("channels")?,
                k: v.usize_of("k")?,
                params,
            },
            other => bail!("unknown op '{other}'"),
        })
    }

    pub fn name(&self) -> &str {
        match self {
            Node::Conv2D { name, .. }
            | Node::Dense { name, .. }
            | Node::BatchNorm { name, .. }
            | Node::ReLU { name, .. }
            | Node::BipolarAct { name, .. }
            | Node::MaxPool { name, .. }
            | Node::Flatten { name, .. }
            | Node::Softmax { name, .. }
            | Node::MultiThreshold { name, .. }
            | Node::TopK { name, .. } => name,
        }
    }

    pub fn op(&self) -> &'static str {
        match self {
            Node::Conv2D { .. } => "Conv2D",
            Node::Dense { .. } => "Dense",
            Node::BatchNorm { .. } => "BatchNorm",
            Node::ReLU { .. } => "ReLU",
            Node::BipolarAct { .. } => "BipolarAct",
            Node::MaxPool { .. } => "MaxPool",
            Node::Flatten { .. } => "Flatten",
            Node::Softmax { .. } => "Softmax",
            Node::MultiThreshold { .. } => "MultiThreshold",
            Node::TopK { .. } => "TopK",
        }
    }

    pub fn params(&self) -> u64 {
        match self {
            Node::Conv2D { params, .. }
            | Node::Dense { params, .. }
            | Node::BatchNorm { params, .. }
            | Node::ReLU { params, .. }
            | Node::BipolarAct { params, .. }
            | Node::MaxPool { params, .. }
            | Node::Flatten { params, .. }
            | Node::Softmax { params, .. }
            | Node::MultiThreshold { params, .. }
            | Node::TopK { params, .. } => *params,
        }
    }

    /// Is this a weight-bearing compute node (MVAU on the FPGA)?
    pub fn is_compute(&self) -> bool {
        matches!(self, Node::Conv2D { .. } | Node::Dense { .. })
    }

    /// MAC count for one inference.
    pub fn macs(&self) -> u64 {
        match self {
            Node::Conv2D { out_hw, in_ch, out_ch, kernel, .. } => {
                (out_hw * out_hw * kernel * kernel * in_ch * out_ch) as u64
            }
            Node::Dense { in_features, out_features, .. } => {
                (in_features * out_features) as u64
            }
            _ => 0,
        }
    }

    /// Output token count (one token = one spatial position's channel
    /// vector for 2-D layers, one full vector for 1-D layers).
    pub fn out_tokens(&self) -> usize {
        match self {
            Node::Conv2D { out_hw, .. } => out_hw * out_hw,
            Node::MaxPool { out_hw, .. } => out_hw * out_hw,
            _ => 1,
        }
    }

    /// Output elements per inference (for elementwise stages).
    pub fn out_elems(&self) -> usize {
        match self {
            Node::Conv2D { out_hw, out_ch, .. } => out_hw * out_hw * out_ch,
            Node::MaxPool { out_hw, channels, .. } => out_hw * out_hw * channels,
            Node::Dense { out_features, .. } => *out_features,
            Node::BatchNorm { channels, .. }
            | Node::ReLU { channels, .. }
            | Node::BipolarAct { channels, .. }
            | Node::Softmax { channels, .. }
            | Node::MultiThreshold { channels, .. } => *channels,
            Node::Flatten { features, .. } => *features,
            Node::TopK { k, .. } => *k,
        }
    }
}

/// A whole model graph + metadata, as exported from Python.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub task: String,
    pub flow: String, // "hls4ml" | "finn"
    pub input_shape: Vec<usize>,
    pub input_bits: u32,
    pub folded_bn: bool,
    pub reuse_factor: u32,
    pub nodes: Vec<Node>,
    pub total_params: u64,
}

impl Graph {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let nodes = v
            .req("nodes")?
            .as_arr()
            .context("'nodes' not an array")?
            .iter()
            .map(Node::from_json)
            .collect::<Result<Vec<_>>>()?;
        let input_shape = v
            .req("input_shape")?
            .as_arr()
            .context("'input_shape' not an array")?
            .iter()
            .map(|x| x.as_usize().context("bad input_shape entry"))
            .collect::<Result<Vec<_>>>()?;
        let g = Graph {
            name: v.str_of("name")?,
            task: v.str_of("task")?,
            flow: v.str_of("flow")?,
            input_shape,
            input_bits: v.u64_of("input_bits")? as u32,
            folded_bn: v.bool_of_or("folded_bn", false),
            reuse_factor: v.get("reuse_factor").and_then(|x| x.as_u64()).unwrap_or(1) as u32,
            nodes,
            total_params: v.u64_of("total_params")?,
        };
        g.validate()?;
        Ok(g)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading topology {}", path.display()))?;
        Self::from_json_str(&text)
            .with_context(|| format!("parsing topology {}", path.display()))
    }

    /// Structural validation: channel/feature counts must chain, params
    /// totals must be consistent.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            bail!("{}: empty graph", self.name);
        }
        let mut prev_elems: Option<usize> = None;
        let mut prev_ch: Option<usize> = None;
        for node in &self.nodes {
            match node {
                Node::Conv2D { name, in_hw, in_ch, out_hw, kernel, stride, padding, .. } => {
                    if let Some(c) = prev_ch {
                        if c != *in_ch {
                            bail!("{}: {} expects {} channels, got {}", self.name, name, in_ch, c);
                        }
                    }
                    let expect = match padding.as_str() {
                        "SAME" => in_hw.div_ceil(*stride),
                        _ => (*in_hw - *kernel) / *stride + 1,
                    };
                    if expect != *out_hw {
                        bail!("{}: {} out_hw {} != expected {}", self.name, name, out_hw, expect);
                    }
                }
                Node::Dense { name, in_features, .. } => {
                    if let Some(e) = prev_elems {
                        if e != *in_features {
                            bail!(
                                "{}: {} expects {} features, got {}",
                                self.name, name, in_features, e
                            );
                        }
                    }
                }
                _ => {}
            }
            // Elementwise nodes pass the element count through unchanged
            // (their `channels` field is per-position in 2-D context).
            prev_elems = match node {
                Node::BatchNorm { .. }
                | Node::ReLU { .. }
                | Node::BipolarAct { .. }
                | Node::MultiThreshold { .. } => prev_elems.or(Some(node.out_elems())),
                _ => Some(node.out_elems()),
            };
            prev_ch = match node {
                Node::Conv2D { out_ch, .. } => Some(*out_ch),
                Node::MaxPool { channels, .. } => Some(*channels),
                Node::BatchNorm { channels, .. }
                | Node::ReLU { channels, .. }
                | Node::BipolarAct { channels, .. }
                | Node::MultiThreshold { channels, .. } => prev_ch.or(Some(*channels)),
                Node::Flatten { .. } | Node::Dense { .. } | Node::Softmax { .. }
                | Node::TopK { .. } => None,
            };
        }
        let total: u64 = self.nodes.iter().map(|n| n.params()).sum();
        if total != self.total_params {
            bail!("{}: total_params {} != sum {}", self.name, self.total_params, total);
        }
        Ok(())
    }

    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.macs()).sum()
    }

    pub fn compute_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.is_compute())
    }

    /// Number of dataflow stages (post-pass view): every node except
    /// Flatten (free reshape) occupies a stage.
    pub fn stage_count(&self) -> usize {
        self.nodes.iter().filter(|n| !matches!(n, Node::Flatten { .. })).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_json() -> &'static str {
        r#"{
        "name":"tiny","task":"kws","flow":"finn","input_shape":[8],
        "input_bits":8,"nodes":[
          {"op":"Dense","name":"fc1","in_features":8,"out_features":4,
           "weight_bits":3,"params":32},
          {"op":"BatchNorm","name":"bn1","channels":4,"params":16},
          {"op":"ReLU","name":"r1","channels":4,"act_bits":3,"params":0},
          {"op":"Dense","name":"fc2","in_features":4,"out_features":2,
           "weight_bits":3,"params":8}
        ],"total_params":56}"#
    }

    pub(crate) fn tiny_graph() -> Graph {
        Graph::from_json_str(tiny_json()).unwrap()
    }

    #[test]
    fn parse_and_validate() {
        let g = tiny_graph();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.input_bits, 8);
        assert_eq!(g.reuse_factor, 1); // default
    }

    #[test]
    fn validate_rejects_feature_mismatch() {
        let mut g = tiny_graph();
        if let Node::Dense { in_features, .. } = &mut g.nodes[3] {
            *in_features = 5;
        }
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_param_total_mismatch() {
        let mut g = tiny_graph();
        g.total_params = 1;
        assert!(g.validate().is_err());
    }

    #[test]
    fn macs_and_tokens() {
        let g = tiny_graph();
        assert_eq!(g.total_macs(), 8 * 4 + 4 * 2);
        assert_eq!(g.nodes[0].out_tokens(), 1);
        assert_eq!(g.nodes[0].out_elems(), 4);
    }

    #[test]
    fn unknown_op_rejected() {
        let bad = tiny_json().replace("BatchNorm", "Mystery");
        assert!(Graph::from_json_str(&bad).is_err());
    }
}
