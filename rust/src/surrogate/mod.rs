//! Calibrated accuracy surrogates for the NAS scans (Fig. 2 / Fig. 3).
//!
//! DESIGN.md §Hardware-Adaptation: the paper trains 300 CIFAR models for
//! the Fig. 2 BO scans and a large ASHA population for Fig. 3 on real GPUs;
//! that is not affordable on one CPU with interpret-mode Pallas, so these
//! scans run against analytic accuracy surrogates anchored to the paper's
//! own reported points:
//!
//! * Fig. 2 anchors: 1-stack 75.0% @ 2.5 MFLOPs; 2-stack 83.5% @ 12.8
//!   MFLOPs; ResNet-8 reference 87.0% @ 25.0 MFLOPs.
//! * Fig. 3 anchors: CNV-W1A1 at inference cost C = 1 reaches 84.5% (100
//!   epochs); accuracy degrades smoothly as C shrinks, saturates above.
//!
//! The KWS quantization scan (Fig. 4) does NOT use a surrogate — it trains
//! the real WnAm variants through the PJRT runtime.
//!
//! Deterministic per-configuration noise comes from the shared splitmix64
//! stream, so scans are reproducible.

use crate::data::prng::SplitMix64;

/// Saturating-accuracy curve: acc(FLOPs) with diminishing returns.
fn saturating(base: f64, gain: f64, mflops: f64, tau: f64) -> f64 {
    base + gain * (1.0 - (-mflops / tau).exp())
}

/// Deterministic per-config noise in [-0.5, 0.5] scaled by `scale`.
fn config_noise(seed: u64, scale: f64) -> f64 {
    let mut rng = SplitMix64::new(seed ^ 0xACC0_5EED);
    (rng.next_f64() - 0.5) * scale
}

/// IC NAS surrogate (Fig. 2): accuracy of a ResNet-style model after 10
/// epochs as a function of stacks and FLOPs, with filter-count the main
/// driver (the paper's observation in §3.1.1).
pub fn ic_nas_accuracy(stacks: usize, mflops: f64, filters_max: usize, seed: u64) -> f64 {
    // Depth raises the ceiling slightly; FLOPs buy accuracy with
    // diminishing returns; too-few filters cap accuracy hard.
    let (base, gain, tau) = match stacks {
        1 => (40.0, 40.0, 1.1),
        2 => (34.0, 54.0, 5.0),
        _ => (32.0, 57.5, 8.0),
    };
    let filter_cap = match filters_max {
        0..=2 => -18.0,
        3..=4 => -9.0,
        5..=8 => -3.5,
        _ => 0.0,
    };
    let acc = saturating(base, gain, mflops, tau) + filter_cap + config_noise(seed, 3.0);
    acc.clamp(10.0, 92.0)
}

/// ASHA/CNV surrogate (Fig. 3): validation accuracy as a function of the
/// inference cost C (eq. 2) and precision, at a training budget of
/// `epochs` (ASHA promotes by epochs — the rung budget).
pub fn cnv_asha_accuracy(cost_c: f64, weight_bits: u32, epochs: u32, seed: u64) -> f64 {
    // At C = 1 (CNV-W1A1), 100 epochs => ~84.5%.
    let scale = 84.5 + 2.0 * (weight_bits.min(2) as f64 - 1.0); // W2 slightly better
    let size_term = 9.5 * cost_c.min(4.0).ln_1p() - 9.5 * 1.0f64.ln_1p();
    let budget_term = -14.0 * (-(epochs as f64) / 18.0).exp();
    let acc = scale + size_term + budget_term + config_noise(seed, 2.0);
    acc.clamp(10.0, 91.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_anchor_points() {
        // 1-stack @ 2.5 MFLOPs ≈ 75% (±4).
        let a1 = ic_nas_accuracy(1, 2.5, 16, 1);
        assert!((70.0..80.0).contains(&a1), "{a1}");
        // 2-stack @ 12.8 MFLOPs ≈ 83.5% (±4).
        let a2 = ic_nas_accuracy(2, 12.8, 16, 2);
        assert!((79.0..88.0).contains(&a2), "{a2}");
        // 3-stack @ 25 MFLOPs ≈ 87% (±4).
        let a3 = ic_nas_accuracy(3, 25.0, 16, 3);
        assert!((83.0..91.0).contains(&a3), "{a3}");
    }

    #[test]
    fn more_flops_more_accuracy_on_average() {
        let lo: f64 =
            (0..20).map(|s| ic_nas_accuracy(2, 1.0, 16, s)).sum::<f64>() / 20.0;
        let hi: f64 =
            (0..20).map(|s| ic_nas_accuracy(2, 20.0, 16, s)).sum::<f64>() / 20.0;
        assert!(hi > lo + 10.0, "lo={lo} hi={hi}");
    }

    #[test]
    fn tiny_filter_counts_capped() {
        let starved = ic_nas_accuracy(2, 12.8, 2, 4);
        let healthy = ic_nas_accuracy(2, 12.8, 16, 4);
        assert!(healthy > starved + 10.0);
    }

    #[test]
    fn fig3_anchor_and_shape() {
        let ref_acc = cnv_asha_accuracy(1.0, 1, 100, 5);
        assert!((80.0..88.0).contains(&ref_acc), "{ref_acc}");
        // Cheaper models lose accuracy; bigger ones gain little.
        let small = cnv_asha_accuracy(0.1, 1, 100, 6);
        let big = cnv_asha_accuracy(2.5, 1, 100, 7);
        assert!(small < ref_acc - 5.0, "small={small} ref={ref_acc}");
        assert!(big < ref_acc + 9.0);
    }

    #[test]
    fn asha_budget_matters() {
        let early = cnv_asha_accuracy(1.0, 1, 2, 8);
        let late = cnv_asha_accuracy(1.0, 1, 100, 8);
        assert!(late > early + 5.0, "early={early} late={late}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(ic_nas_accuracy(2, 5.0, 8, 42), ic_nas_accuracy(2, 5.0, 8, 42));
    }
}
