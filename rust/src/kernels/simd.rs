//! Runtime-dispatched SIMD inner loops for the packed i8 kernel core.
//!
//! The paper's MVAU dataflow engines get their speed from PE×SIMD lane
//! parallelism over quantized weights; this module is the software
//! mirror of the SIMD axis: the i8×i8→i32 dot product at the heart of
//! [`super::PackedLinear`] gets explicit `std::arch` implementations —
//! AVX2 and a baseline SSE2 fallback on x86_64, NEON on aarch64 —
//! selected **once per process** into a dispatch table
//! ([`dispatch`]) by runtime CPU-feature detection.
//!
//! Correctness story: every implementation computes the *exact* i32 sum
//! `Σ a[i]·b[i]`.  Integer accumulation is associative, so lane order
//! and horizontal-reduction order cannot change the result — the scalar
//! loop ([`dot_i8_scalar`]) is therefore both the universal fallback
//! and a **bit-exactness oracle**: SIMD-vs-scalar equivalence is tested
//! exactly (see the `simd` proptests), never within a tolerance.
//!
//! Why the products cannot overflow: i8×i8 products are bounded by
//! `(-128)·(-128) = 16384`, so
//! * `pmaddwd` / `_mm256_madd_epi16` over sign-extended i8 sums two
//!   such products into one i32 lane — max `32768`, exact (the i16
//!   saturation edge case `(-32768)²` is unreachable from i8 inputs);
//! * NEON `vmull_s8` widens to i16 (max 16384 < 32767, exact) and
//!   `vpadalq_s16` pairwise-accumulates into i32 lanes.
//! Per-lane i32 accumulation over [`super::PackedLinear`]'s maximum row
//! width (131072 columns) stays below `131072/8 · 32768 ≈ 5.4e8`, far
//! inside i32 range; arbitrary-i8 test inputs (including -128) are
//! covered by the same bound.
//!
//! Kill switch: `TINYML_FORCE_SCALAR=1` (read **once** at dispatch
//! init) pins the table to the scalar path — the A/B control for
//! benches and the way CI exercises the oracle path on any hardware,
//! mirroring the `global_hotpath`/`fifo_queues` controls of PRs 4–5.
//!
//! Safety: this is the only module in the crate containing `unsafe`.
//! Each `#[target_feature]` function is reachable only through a
//! dispatch path that proved its precondition with the matching
//! `is_x86_feature_detected!`/`is_aarch64_feature_detected!` check
//! ([`dot_i8_for`] returns `None` otherwise), and every intrinsic
//! block operates strictly inside slice bounds.

use std::sync::OnceLock;

/// One SIMD capability tier of the kernel core, ordered by preference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loop — universal fallback and bit-exactness oracle.
    Scalar,
    /// x86_64 baseline: 128-bit sign-extend + `pmaddwd` (always present
    /// on x86_64; selected when AVX2 is not).
    Sse2,
    /// x86_64 AVX2: 256-bit `vpmovsxbw` + `vpmaddwd`, 16 i8 lanes/step.
    Avx2,
    /// aarch64 NEON: `vmull_s8` + `vpadalq_s16`, 16 i8 lanes/step.
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name (used in bench JSON and logs).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// A *wide* path is one expected to clear the bench's
    /// `simd_over_scalar_speedup` floor (AVX2 or NEON).  SSE2 is used
    /// when present but not held to the floor — the bench emits
    /// `simd_unavailable: true` and the gate skips the headline,
    /// following the `parallelism_limited` precedent.
    pub fn is_wide(self) -> bool {
        matches!(self, SimdLevel::Avx2 | SimdLevel::Neon)
    }
}

/// The dispatched inner-loop signature: exact i32 dot of two i8 slices.
pub type DotFn = fn(&[i8], &[i8]) -> i32;

/// Exact i32 dot product — scalar oracle and universal fallback.
/// Integer adds reassociate freely, so this loop auto-vectorizes in
/// release builds *and* defines the bit-exact result every `std::arch`
/// path must reproduce.
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&p, &q) in a.iter().zip(b.iter()) {
        acc += p as i32 * q as i32;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// AVX2 dot: 16 i8 per step, sign-extended to 16 i16 lanes
    /// (`vpmovsxbw`), pair-multiplied-and-added into 8 i32 lanes
    /// (`vpmaddwd`), horizontally reduced once at the end.
    ///
    /// # Safety
    /// Caller must have verified `is_x86_feature_detected!("avx2")`
    /// (enforced by [`super::dot_i8_for`], the only constructor that
    /// hands this function out).
    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2_impl(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: i + 16 <= n bounds both 16-byte loads.
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let wa = _mm256_cvtepi8_epi16(va);
            let wb = _mm256_cvtepi8_epi16(vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
            i += 16;
        }
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256::<1>(acc);
        let mut sum = hsum_epi32(_mm_add_epi32(lo, hi));
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// SSE2 dot: 16 i8 per step, sign-extended by interleaving with a
    /// `cmpgt`-derived sign mask (SSE2 has no `pmovsxbw`), then two
    /// `pmaddwd` accumulations into 4 i32 lanes.
    ///
    /// # Safety
    /// Caller must have verified `is_x86_feature_detected!("sse2")`
    /// (baseline on x86_64, still checked by [`super::dot_i8_for`]).
    #[target_feature(enable = "sse2")]
    unsafe fn dot_sse2_impl(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let zero = _mm_setzero_si128();
        let mut acc = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: i + 16 <= n bounds both 16-byte loads.
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let sa = _mm_cmpgt_epi8(zero, va);
            let sb = _mm_cmpgt_epi8(zero, vb);
            let a_lo = _mm_unpacklo_epi8(va, sa);
            let a_hi = _mm_unpackhi_epi8(va, sa);
            let b_lo = _mm_unpacklo_epi8(vb, sb);
            let b_hi = _mm_unpackhi_epi8(vb, sb);
            acc = _mm_add_epi32(acc, _mm_madd_epi16(a_lo, b_lo));
            acc = _mm_add_epi32(acc, _mm_madd_epi16(a_hi, b_hi));
            i += 16;
        }
        let mut sum = hsum_epi32(acc);
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// Horizontal sum of 4 i32 lanes (exact: i32 wrapping adds are
    /// unreachable given the accumulation bounds in the module docs).
    ///
    /// # Safety
    /// SSE2 shuffles/adds — baseline on every x86_64 CPU; only called
    /// from the `#[target_feature]` dot impls above.  (Declared
    /// `unsafe fn` rather than wrapping an `unsafe` block so it builds
    /// warning-free both before and after the stabilization of safe
    /// register-only `std::arch` intrinsics.)
    #[inline(always)]
    unsafe fn hsum_epi32(v: __m128i) -> i32 {
        let s = _mm_add_epi32(v, _mm_shuffle_epi32::<0b01_00_11_10>(v));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
        _mm_cvtsi128_si32(s)
    }

    pub fn dot_avx2(a: &[i8], b: &[i8]) -> i32 {
        // SAFETY: handed out by `dot_i8_for(Avx2)` only after
        // `is_x86_feature_detected!("avx2")` returned true.
        unsafe { dot_avx2_impl(a, b) }
    }

    pub fn dot_sse2(a: &[i8], b: &[i8]) -> i32 {
        // SAFETY: handed out by `dot_i8_for(Sse2)` only after
        // `is_x86_feature_detected!("sse2")` returned true.
        unsafe { dot_sse2_impl(a, b) }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    /// NEON dot: 16 i8 per step — `vmull_s8` widens each half to 8
    /// exact i16 products, `vpadalq_s16` pairwise-accumulates them into
    /// 4 i32 lanes, one `vaddvq_s32` reduction at the end.
    ///
    /// # Safety
    /// Caller must have verified
    /// `is_aarch64_feature_detected!("neon")` (enforced by
    /// [`super::dot_i8_for`]).
    #[target_feature(enable = "neon")]
    unsafe fn dot_neon_impl(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: i + 16 <= n bounds both 16-byte loads.
            let va = vld1q_s8(a.as_ptr().add(i));
            let vb = vld1q_s8(b.as_ptr().add(i));
            let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
            let hi = vmull_high_s8(va, vb);
            acc = vpadalq_s16(acc, lo);
            acc = vpadalq_s16(acc, hi);
            i += 16;
        }
        let mut sum = vaddvq_s32(acc);
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    pub fn dot_neon(a: &[i8], b: &[i8]) -> i32 {
        // SAFETY: handed out by `dot_i8_for(Neon)` only after
        // `is_aarch64_feature_detected!("neon")` returned true.
        unsafe { dot_neon_impl(a, b) }
    }
}

/// The dot implementation for `level`, or `None` when this CPU (or this
/// compilation target) does not support it.  This is the **only** place
/// that hands out the `std::arch` paths, and it performs the runtime
/// feature check that proves each one's `#[target_feature]`
/// precondition — callers can never reach an intrinsic the CPU lacks.
pub fn dot_i8_for(level: SimdLevel) -> Option<DotFn> {
    match level {
        SimdLevel::Scalar => Some(dot_i8_scalar),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            is_x86_feature_detected!("avx2").then_some(x86::dot_avx2 as DotFn)
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            is_x86_feature_detected!("sse2").then_some(x86::dot_sse2 as DotFn)
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            std::arch::is_aarch64_feature_detected!("neon")
                .then_some(arm::dot_neon as DotFn)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => None,
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        _ => None,
    }
}

/// Every level this process can actually run, scalar first — the
/// proptests iterate this so SIMD-vs-scalar bit-identity is checked on
/// each compiled-in path the host CPU supports.
pub fn available_levels() -> Vec<SimdLevel> {
    [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon]
        .into_iter()
        .filter(|&l| dot_i8_for(l).is_some())
        .collect()
}

/// Best level the CPU supports (ignoring the kill switch).
fn detect_best() -> SimdLevel {
    for level in [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Sse2] {
        if dot_i8_for(level).is_some() {
            return level;
        }
    }
    SimdLevel::Scalar
}

/// Does the environment ask for the scalar path?  Read once by
/// [`dispatch`] init; exposed so tests can assert the kill switch is
/// honored when CI sets it for a whole process.
pub fn force_scalar_from_env() -> bool {
    std::env::var("TINYML_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false)
}

/// Pure selection rule: the kill switch beats detection.  Split out of
/// [`dispatch`] so the policy is unit-testable without racing the
/// process-wide `OnceLock` or mutating the environment mid-test.
pub fn select_level(force_scalar: bool) -> SimdLevel {
    if force_scalar {
        SimdLevel::Scalar
    } else {
        detect_best()
    }
}

/// The per-process kernel dispatch table: the selected level plus the
/// inner-loop function pointers the packed kernels call.
pub struct Dispatch {
    pub level: SimdLevel,
    pub dot_i8: DotFn,
}

static DISPATCH: OnceLock<Dispatch> = OnceLock::new();

/// The process-wide dispatch table, initialized exactly once: read the
/// `TINYML_FORCE_SCALAR` kill switch, detect CPU features, install the
/// best proven implementation.
pub fn dispatch() -> &'static Dispatch {
    DISPATCH.get_or_init(|| {
        let level = select_level(force_scalar_from_env());
        // `dot_i8_for` re-proves the feature precondition; `select_level`
        // only ever names levels it found available, so the fallback arm
        // is unreachable in practice but keeps the init total.
        let dot_i8 = dot_i8_for(level).unwrap_or(dot_i8_scalar);
        Dispatch { level, dot_i8 }
    })
}

/// The SIMD level the packed kernels are actually running at.
pub fn active_level() -> SimdLevel {
    dispatch().level
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn random_i8(rng: &mut SplitMix64, len: usize) -> Vec<i8> {
        // Full i8 range including -128 — the SIMD paths must be exact
        // beyond the |q| <= 127 range the quantizer actually emits.
        (0..len).map(|_| rng.next_below(256) as u8 as i8).collect()
    }

    #[test]
    fn every_available_level_matches_the_scalar_oracle() {
        let mut rng = SplitMix64::new(0x51D0);
        let levels = available_levels();
        assert!(levels.contains(&SimdLevel::Scalar));
        // Ragged tails around the 16-lane width, plus empty and long.
        for len in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 127, 490, 3072] {
            let a = random_i8(&mut rng, len);
            let b = random_i8(&mut rng, len);
            let want = dot_i8_scalar(&a, &b);
            for &level in &levels {
                let got = dot_i8_for(level).unwrap()(&a, &b);
                assert_eq!(got, want, "level {} diverged at len {len}", level.name());
            }
        }
    }

    #[test]
    fn extreme_values_cannot_overflow_the_lanes() {
        // All-(-128) × all-(-128): the largest possible per-product
        // magnitude at the widest pairwise accumulation.
        let a = vec![-128i8; 1024];
        let want = 1024 * 16384;
        for &level in &available_levels() {
            assert_eq!(dot_i8_for(level).unwrap()(&a, &a), want, "{}", level.name());
        }
    }

    #[test]
    fn kill_switch_selects_scalar() {
        assert_eq!(select_level(true), SimdLevel::Scalar);
        // Without the switch, selection picks something available.
        assert!(dot_i8_for(select_level(false)).is_some());
    }

    #[test]
    fn env_kill_switch_is_honored_by_the_live_dispatch() {
        // Meaningful when the whole process runs under
        // TINYML_FORCE_SCALAR=1 (ci.sh does exactly that rerun);
        // otherwise it only pins that the table initialized coherently.
        if force_scalar_from_env() {
            assert_eq!(active_level(), SimdLevel::Scalar);
        }
        assert_eq!(
            active_level().name(),
            dispatch().level.name(),
            "dispatch table must be internally consistent"
        );
    }
}
