//! O(n) prefix-sum moving average (the AD autoencoder's smoothing pass).
//!
//! The seed recomputed every window from scratch — O(n·window) serial
//! f32 adds.  This kernel builds one f64 prefix-sum array and reads each
//! window as `prefix[hi] - prefix[lo]`, O(n) regardless of window size.
//!
//! Numerics: the window sum is narrowed to f32 *before* the division so
//! the final divide is the same f32 operation the naive implementation
//! performs.  Whenever the f64 prefix sums are exact (inputs on a
//! bounded dyadic grid — see the property tests) the kernel is
//! bit-identical to the naive `moving_average_f32`; on arbitrary inputs
//! it is at least as accurate (f64 accumulation vs f32).

use super::ScratchArena;

/// Centered moving average with edge clamping, window fixed at build
/// time (`window / 2` taps on each side, mirror of the Python side).
pub struct SmoothKernel {
    window: usize,
}

impl SmoothKernel {
    pub const fn new(window: usize) -> Self {
        SmoothKernel { window }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Smooth `x` into `out` (same length) using the arena's prefix-sum
    /// buffer.  Allocation-free in steady state.
    pub fn smooth_into(&self, x: &[f32], out: &mut [f32], scratch: &mut ScratchArena) {
        let n = x.len();
        assert_eq!(out.len(), n, "output length mismatch");
        let half = self.window / 2;
        let p = ScratchArena::grown(&mut scratch.prefix, n + 1, 0.0);
        p[0] = 0.0;
        for i in 0..n {
            p[i + 1] = p[i] + x[i] as f64;
        }
        for (i, o) in out.iter_mut().enumerate() {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(n);
            let sum = (p[hi] - p[lo]) as f32;
            *o = sum / (hi - lo) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Exact and tolerance-bounded equivalence against the naive moving
    // average live as randomized properties in rust/tests/proptests.rs;
    // this module pins down only the structural edge cases.

    #[test]
    fn empty_and_window_one() {
        let mut a = ScratchArena::new();
        let mut out = vec![];
        SmoothKernel::new(9).smooth_into(&[], &mut out, &mut a);
        // window 1: half = 0 → identity.
        let x = vec![3.0f32, -1.0, 7.0];
        let mut out = vec![0.0f32; 3];
        SmoothKernel::new(1).smooth_into(&x, &mut out, &mut a);
        assert_eq!(out, x);
    }
}
