//! Row-major i8 packed linear kernel with per-row scales.
//!
//! Packing format (mirrors a FINN MVAU weight memory): an `R x C` weight
//! matrix is quantized **once** to i8 with symmetric per-row scales
//! (`w ≈ q * w_scale`, `|q| ≤ 127` — the full i8 range minus -128 so the
//! grid is symmetric) and stored contiguously row-major.  Activations
//! are quantized per sample with one symmetric scale each, dot products
//! accumulate exactly in i32, and a single f32 multiply per output
//! dequantizes: `y[r] = acc * (w_scale[r] * out_scale) * x_scale`.
//!
//! Integer accumulation is associative, so loop order cannot change the
//! result: the batched, weight-tiled path is bit-identical to the
//! single-sample path.

use super::ScratchArena;

/// Row tile for the batched path: a tile of rows stays hot in L1 while
/// every sample in the batch streams past it, so the weight matrix is
/// walked once per batch rather than once per sample.
const ROW_TILE: usize = 8;

/// Widest supported row: guarantees `cols * 127 * 127` fits an i32
/// accumulator with headroom (the largest shipped shape, IC, is 3072).
const MAX_COLS: usize = 131_072;

/// Exact i32 dot product over two i8 slices.  Integer adds reassociate
/// freely, so this loop vectorizes in release builds (unlike the f32
/// `.sum::<f32>()` chain it replaces, which is a serial dependency).
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&p, &q) in a.iter().zip(b.iter()) {
        acc += p as i32 * q as i32;
    }
    acc
}

/// Symmetric i8 quantization of one vector; returns the dequantization
/// scale (`v ≈ q * scale`).  All-zero (or non-finite) input quantizes to
/// zeros with scale 0, which reproduces the exact f32 result (0) for
/// every output.
fn quantize_i8(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let mut max_abs = 0.0f32;
    for &v in src {
        max_abs = max_abs.max(v.abs());
    }
    if max_abs == 0.0 || !max_abs.is_finite() {
        dst.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (d, &v) in dst.iter_mut().zip(src) {
        // |v * inv| ≤ 127 (+1 ulp); float→int casts saturate, so the
        // clamp to the i8 range is implicit.
        *d = (v * inv).round() as i8;
    }
    max_abs / 127.0
}

/// Worst-case absolute error of one quantized output against the f32
/// reference `out_scale * Σ x_i w_i`, given the activation/weight
/// magnitudes of the row.  Used by the property tests to bound the
/// equivalence check and to gate argmax assertions.
pub fn quantized_max_abs_error(
    x_max: f32,
    w_max: f32,
    cols: usize,
    out_scale: f32,
) -> f32 {
    let sx = x_max / 127.0;
    let sw = w_max / 127.0;
    // Per element: |xw - (sx qx)(sw qw)| ≤ w_max·sx/2 + x_max·sw/2 + sx·sw/4.
    cols as f32 * out_scale.abs() * (w_max * sx / 2.0 + x_max * sw / 2.0 + sx * sw / 4.0)
}

/// An `R x C` f32 matrix packed once into contiguous i8 (see module
/// docs).  Build it at load time, share it freely (`&self` methods), and
/// drive it with a per-thread [`ScratchArena`].
pub struct PackedLinear {
    rows: usize,
    cols: usize,
    /// Row-major quantized weights, `rows * cols` elements.
    q: Vec<i8>,
    /// Per-row dequantization scale with the global output scale folded
    /// in: `scales[r] = w_scale[r] * out_scale`.
    scales: Vec<f32>,
}

impl PackedLinear {
    /// Quantize and pack `rows` (all the same length).  `out_scale` is a
    /// global factor folded into the per-row scales — pass `1.0 / cols`
    /// to reproduce the `dot(x, w) / dim` semantics of
    /// [`crate::data::template_logits`].
    pub fn pack(rows: &[Vec<f32>], out_scale: f32) -> Self {
        let cols = rows.first().map(Vec::len).unwrap_or(0);
        assert!(cols <= MAX_COLS, "row width {cols} would overflow the i32 accumulator");
        let mut q = vec![0i8; rows.len() * cols];
        let mut scales = Vec::with_capacity(rows.len());
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), cols, "row {r} has width {} != {cols}", row.len());
            let s = quantize_i8(row, &mut q[r * cols..(r + 1) * cols]);
            scales.push(s * out_scale);
        }
        PackedLinear { rows: rows.len(), cols, q, scales }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident weight bytes (i8 matrix + f32 scales) — 1/4 of the f32
    /// Vec-of-Vec it replaces, before even counting per-Vec overhead.
    pub fn packed_bytes(&self) -> usize {
        self.q.len() + 4 * self.scales.len()
    }

    /// Single-sample matvec: `out[r] = dequant(q[r] · q8(x))`.
    /// Bit-identical to the corresponding slice of [`Self::gemm_batch`].
    pub fn gemv(&self, x: &[f32], out: &mut [f32], scratch: &mut ScratchArena) {
        self.gemm_batch(x, out, scratch);
    }

    /// Batched matvec over `x.len() / cols` samples packed contiguously
    /// in `x`; writes `rows` outputs per sample into `out`.  Activations
    /// are quantized once per sample, then the weight matrix is walked
    /// once per batch in row tiles (every sample streams past the hot
    /// tile).  Allocation-free in steady state: all intermediates live
    /// in the caller's arena.
    pub fn gemm_batch(&self, x: &[f32], out: &mut [f32], scratch: &mut ScratchArena) {
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let n = x.len() / self.cols;
        assert_eq!(x.len(), n * self.cols, "input is not a whole number of samples");
        assert_eq!(out.len(), n * self.rows, "output buffer size mismatch");

        let xq = ScratchArena::grown(&mut scratch.xq, n * self.cols, 0);
        let xs = ScratchArena::grown(&mut scratch.xscale, n, 0.0);
        for s in 0..n {
            xs[s] = quantize_i8(
                &x[s * self.cols..(s + 1) * self.cols],
                &mut xq[s * self.cols..(s + 1) * self.cols],
            );
        }

        for r0 in (0..self.rows).step_by(ROW_TILE) {
            let r1 = (r0 + ROW_TILE).min(self.rows);
            for s in 0..n {
                let xq_s = &xq[s * self.cols..(s + 1) * self.cols];
                let out_s = &mut out[s * self.rows..(s + 1) * self.rows];
                for r in r0..r1 {
                    let acc = dot_i8(&self.q[r * self.cols..(r + 1) * self.cols], xq_s);
                    out_s[r] = acc as f32 * self.scales[r] * xs[s];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn gaussian_rows(rng: &mut SplitMix64, r: usize, c: usize) -> Vec<Vec<f32>> {
        (0..r)
            .map(|_| (0..c).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    // Tolerance-bounded equivalence vs the f32 reference, batched-vs-
    // single bit-exactness, and argmax preservation are covered by the
    // randomized properties in rust/tests/proptests.rs; the tests here
    // pin down the exact-arithmetic edge cases only.

    fn naive(x: &[f32], rows: &[Vec<f32>], out_scale: f32) -> Vec<f32> {
        rows.iter()
            .map(|t| x.iter().zip(t).map(|(a, b)| a * b).sum::<f32>() * out_scale)
            .collect()
    }

    #[test]
    fn exactly_representable_weights_round_trip() {
        // Weights and activations already on the i8 grid with max-abs
        // exactly 127 (so both dequantization scales are exactly 1.0):
        // quantization is lossless and every f32 op is exact, so the
        // kernel must reproduce the f32 reference bit-for-bit.
        let rows = vec![vec![127.0f32, -64.0, 1.0, 0.0], vec![2.0, 2.0, 2.0, 127.0]];
        let x = vec![127.0f32, 1.0, -127.0, 64.0];
        let p = PackedLinear::pack(&rows, 0.25);
        let mut out = vec![0.0f32; 2];
        let mut a = ScratchArena::new();
        p.gemv(&x, &mut out, &mut a);
        let want = naive(&x, &rows, 0.25);
        assert_eq!(out, want);
    }

    #[test]
    fn zero_rows_and_zero_inputs_are_exact() {
        let rows = vec![vec![0.0f32; 16], vec![1.0f32; 16]];
        let p = PackedLinear::pack(&rows, 1.0);
        let mut a = ScratchArena::new();
        let mut out = vec![9.0f32; 2];
        p.gemv(&[0.0f32; 16], &mut out, &mut a);
        assert_eq!(out, vec![0.0, 0.0]);
        p.gemv(&[2.0f32; 16], &mut out, &mut a);
        assert_eq!(out[0], 0.0, "all-zero row must stay exactly zero");
        // Scale factors (1/127, 2/127) are not exact in f32 — a few ulp
        // of rounding is expected around the true value 32.
        assert!((out[1] - 32.0).abs() < 1e-3, "{}", out[1]);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        // After a warm-up call the arena must not grow again for the
        // same shape (pointer + capacity stable).
        let mut rng = SplitMix64::new(0xA11C);
        let rows = gaussian_rows(&mut rng, 4, 32);
        let p = PackedLinear::pack(&rows, 1.0);
        let mut a = ScratchArena::new();
        let x: Vec<f32> = (0..3 * 32).map(|_| rng.next_gaussian() as f32).collect();
        let mut out = vec![0.0f32; 3 * 4];
        p.gemm_batch(&x, &mut out, &mut a);
        let (ptr, cap) = (a.xq.as_ptr(), a.xq.capacity());
        for _ in 0..5 {
            p.gemm_batch(&x, &mut out, &mut a);
        }
        assert_eq!((a.xq.as_ptr(), a.xq.capacity()), (ptr, cap));
    }
}
