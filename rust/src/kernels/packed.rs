//! Row-major i8 packed linear kernel with per-row scales.
//!
//! Packing format (mirrors a FINN MVAU weight memory): an `R x C` weight
//! matrix is quantized **once** to i8 with symmetric per-row scales
//! (`w ≈ q * w_scale`, `|q| ≤ 127` — the full i8 range minus -128 so the
//! grid is symmetric) and stored contiguously row-major.  Activations
//! are quantized per sample with one symmetric scale each, dot products
//! accumulate exactly in i32, and a single f32 multiply per output
//! dequantizes: `y[r] = acc * (w_scale[r] * out_scale) * x_scale`.
//!
//! Integer accumulation is associative, so loop order cannot change the
//! result: the batched, weight-tiled, column-blocked path is
//! bit-identical to the single-sample path, **and** every
//! [`super::simd`] lane implementation of the inner dot is bit-identical
//! to the scalar oracle — equivalence is testable exactly, never within
//! a tolerance.
//!
//! Blocking (the software mirror of hls4ml's reuse-factor knob): the
//! batched path keeps the existing [`ROW_TILE`]-row outer tile and adds
//! a [`COL_BLOCK`]-column inner block, so one weight panel
//! (`ROW_TILE x COL_BLOCK` i8) plus one quantized activation strip
//! (`COL_BLOCK` i8) stay resident in L1 while every sample of the batch
//! streams past; per-(row, sample) partial sums ride an i32 accumulator
//! strip in the arena across column blocks.

use super::simd::{self, DotFn};
use super::ScratchArena;

/// Row tile for the batched path: a tile of rows stays hot in L1 while
/// every sample in the batch streams past it, so the weight matrix is
/// walked once per batch rather than once per sample.
const ROW_TILE: usize = 8;

/// Column block for the batched path: `ROW_TILE * COL_BLOCK` weight
/// bytes (16 KiB) + one `COL_BLOCK`-byte activation strip ≈ 18 KiB —
/// comfortably inside a 32 KiB L1d with room for the accumulators.
const COL_BLOCK: usize = 2048;

/// Widest supported row: guarantees `cols * 127 * 127` fits an i32
/// accumulator with headroom (the largest shipped shape, IC, is 3072).
const MAX_COLS: usize = 131_072;

/// Non-finite f32s (±Inf, NaN) have magnitude bits `>= 0x7f80_0000`;
/// for finite values the magnitude bits order exactly like `|v|`, which
/// is what lets the quantizer's scan be one branch-free u32 max.
const NON_FINITE_BITS: u32 = 0x7f80_0000;

/// Branch-free max-abs scan, in magnitude-bits space: returns
/// `max(bits(v) & 0x7fff_ffff)`.  For finite inputs this *is* the
/// max-abs (IEEE-754 magnitude bits are monotone in `|v|`); any NaN or
/// Inf element — not just an all-input overflow — surfaces as a value
/// `>= NON_FINITE_BITS`.  A single u32 max per element with no
/// data-dependent branches, so the loop auto-vectorizes.
fn max_abs_bits(src: &[f32]) -> u32 {
    let mut m = 0u32;
    for &v in src {
        m = m.max(v.to_bits() & 0x7fff_ffff);
    }
    m
}

/// Symmetric i8 quantization of one vector; returns the dequantization
/// scale (`v ≈ q * scale`).  All-zero input — or **any** non-finite
/// element (a single NaN or Inf anywhere in the sample, pinned by unit
/// test) — quantizes to zeros with scale 0, so one corrupt element
/// can never smuggle garbage through the integer path.
///
/// Split into two passes on purpose: the max-abs scan is a branch-free
/// u32 max ([`max_abs_bits`]) and the scale/round loop is a
/// straight-line multiply-round-cast, so both auto-vectorize instead of
/// serializing on the scan's compare-and-select chain.
pub(crate) fn quantize_i8(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let bits = max_abs_bits(src);
    if bits == 0 || bits >= NON_FINITE_BITS {
        dst.fill(0);
        return 0.0;
    }
    let max_abs = f32::from_bits(bits);
    let inv = 127.0 / max_abs;
    for (d, &v) in dst.iter_mut().zip(src) {
        // |v * inv| ≤ 127 (+1 ulp); float→int casts saturate, so the
        // clamp to the i8 range is implicit.
        *d = (v * inv).round() as i8;
    }
    max_abs / 127.0
}

/// Worst-case absolute error of one quantized output against the f32
/// reference `out_scale * Σ x_i w_i`, given the activation/weight
/// magnitudes of the row.  Used by the property tests to bound the
/// equivalence check and to gate argmax assertions.
pub fn quantized_max_abs_error(
    x_max: f32,
    w_max: f32,
    cols: usize,
    out_scale: f32,
) -> f32 {
    let sx = x_max / 127.0;
    let sw = w_max / 127.0;
    // Per element: |xw - (sx qx)(sw qw)| ≤ w_max·sx/2 + x_max·sw/2 + sx·sw/4.
    cols as f32 * out_scale.abs() * (w_max * sx / 2.0 + x_max * sw / 2.0 + sx * sw / 4.0)
}

/// An `R x C` f32 matrix packed once into contiguous i8 (see module
/// docs).  Build it at load time, share it freely (`&self` methods), and
/// drive it with a per-thread [`ScratchArena`].
pub struct PackedLinear {
    rows: usize,
    cols: usize,
    /// Row-major quantized weights, `rows * cols` elements.
    q: Vec<i8>,
    /// Per-row dequantization scale with the global output scale folded
    /// in: `scales[r] = w_scale[r] * out_scale`.
    scales: Vec<f32>,
}

impl PackedLinear {
    /// Quantize and pack `rows` (all the same length).  `out_scale` is a
    /// global factor folded into the per-row scales — pass `1.0 / cols`
    /// to reproduce the `dot(x, w) / dim` semantics of
    /// [`crate::data::template_logits`].
    pub fn pack(rows: &[Vec<f32>], out_scale: f32) -> Self {
        let cols = rows.first().map(Vec::len).unwrap_or(0);
        assert!(cols <= MAX_COLS, "row width {cols} would overflow the i32 accumulator");
        let mut q = vec![0i8; rows.len() * cols];
        let mut scales = Vec::with_capacity(rows.len());
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), cols, "row {r} has width {} != {cols}", row.len());
            let s = quantize_i8(row, &mut q[r * cols..(r + 1) * cols]);
            scales.push(s * out_scale);
        }
        PackedLinear { rows: rows.len(), cols, q, scales }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident weight bytes (i8 matrix + f32 scales) — 1/4 of the f32
    /// Vec-of-Vec it replaces, before even counting per-Vec overhead.
    pub fn packed_bytes(&self) -> usize {
        self.q.len() + 4 * self.scales.len()
    }

    /// Single-sample matvec: `out[r] = dequant(q[r] · q8(x))`.
    /// Bit-identical to the corresponding slice of [`Self::gemm_batch`].
    pub fn gemv(&self, x: &[f32], out: &mut [f32], scratch: &mut ScratchArena) {
        self.gemm_batch(x, out, scratch);
    }

    /// Batched matvec over `x.len() / cols` samples packed contiguously
    /// in `x`; writes `rows` outputs per sample into `out`.  Activations
    /// are quantized once per sample, then the weight matrix is walked
    /// once per batch in row tiles × column blocks (see module docs),
    /// with the inner dot running at the process-wide
    /// [`simd::dispatch`] level (AVX2 / SSE2 / NEON, or scalar under
    /// `TINYML_FORCE_SCALAR=1`).  Allocation-free in steady state: all
    /// intermediates live in the caller's arena.
    pub fn gemm_batch(&self, x: &[f32], out: &mut [f32], scratch: &mut ScratchArena) {
        self.gemm_with_dot(simd::dispatch().dot_i8, x, out, scratch);
    }

    /// [`Self::gemm_batch`] pinned to the scalar inner loop regardless
    /// of the dispatch table — the **bit-exactness oracle** the SIMD
    /// proptests and the `simd_over_scalar_speedup` bench A/B against.
    /// Identical blocking, identical quantization, scalar dot.
    pub fn gemm_batch_scalar(
        &self,
        x: &[f32],
        out: &mut [f32],
        scratch: &mut ScratchArena,
    ) {
        self.gemm_with_dot(simd::dot_i8_scalar, x, out, scratch);
    }

    fn gemm_with_dot(
        &self,
        dot: DotFn,
        x: &[f32],
        out: &mut [f32],
        scratch: &mut ScratchArena,
    ) {
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let n = x.len() / self.cols;
        assert_eq!(x.len(), n * self.cols, "input is not a whole number of samples");
        assert_eq!(out.len(), n * self.rows, "output buffer size mismatch");

        let xq = ScratchArena::grown(&mut scratch.xq, n * self.cols, 0);
        let xs = ScratchArena::grown(&mut scratch.xscale, n, 0.0);
        for s in 0..n {
            xs[s] = quantize_i8(
                &x[s * self.cols..(s + 1) * self.cols],
                &mut xq[s * self.cols..(s + 1) * self.cols],
            );
        }
        let acc = ScratchArena::grown(&mut scratch.acc, n * ROW_TILE, 0);

        for r0 in (0..self.rows).step_by(ROW_TILE) {
            let r1 = (r0 + ROW_TILE).min(self.rows);
            let tile = r1 - r0;
            let acc_t = &mut acc[..n * tile];
            acc_t.fill(0);
            // Column blocks: the ROW_TILE x COL_BLOCK weight panel and
            // each sample's COL_BLOCK activation strip stay L1-resident;
            // i32 partial sums are exact, so block boundaries cannot
            // change a single bit of the result.
            for c0 in (0..self.cols).step_by(COL_BLOCK) {
                let c1 = (c0 + COL_BLOCK).min(self.cols);
                for s in 0..n {
                    let xq_s = &xq[s * self.cols + c0..s * self.cols + c1];
                    let acc_s = &mut acc_t[s * tile..(s + 1) * tile];
                    for (t, r) in (r0..r1).enumerate() {
                        acc_s[t] +=
                            dot(&self.q[r * self.cols + c0..r * self.cols + c1], xq_s);
                    }
                }
            }
            for s in 0..n {
                let out_s = &mut out[s * self.rows..(s + 1) * self.rows];
                let acc_s = &acc_t[s * tile..(s + 1) * tile];
                for (t, r) in (r0..r1).enumerate() {
                    out_s[r] = acc_s[t] as f32 * self.scales[r] * xs[s];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn gaussian_rows(rng: &mut SplitMix64, r: usize, c: usize) -> Vec<Vec<f32>> {
        (0..r)
            .map(|_| (0..c).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    // Tolerance-bounded equivalence vs the f32 reference, batched-vs-
    // single bit-exactness, SIMD-vs-scalar bit-identity, and argmax
    // preservation are covered by the randomized properties in
    // rust/tests/proptests.rs; the tests here pin down the
    // exact-arithmetic edge cases only.

    fn naive(x: &[f32], rows: &[Vec<f32>], out_scale: f32) -> Vec<f32> {
        rows.iter()
            .map(|t| x.iter().zip(t).map(|(a, b)| a * b).sum::<f32>() * out_scale)
            .collect()
    }

    #[test]
    fn exactly_representable_weights_round_trip() {
        // Weights and activations already on the i8 grid with max-abs
        // exactly 127 (so both dequantization scales are exactly 1.0):
        // quantization is lossless and every f32 op is exact, so the
        // kernel must reproduce the f32 reference bit-for-bit.
        let rows = vec![vec![127.0f32, -64.0, 1.0, 0.0], vec![2.0, 2.0, 2.0, 127.0]];
        let x = vec![127.0f32, 1.0, -127.0, 64.0];
        let p = PackedLinear::pack(&rows, 0.25);
        let mut out = vec![0.0f32; 2];
        let mut a = ScratchArena::new();
        p.gemv(&x, &mut out, &mut a);
        let want = naive(&x, &rows, 0.25);
        assert_eq!(out, want);
    }

    #[test]
    fn zero_rows_and_zero_inputs_are_exact() {
        let rows = vec![vec![0.0f32; 16], vec![1.0f32; 16]];
        let p = PackedLinear::pack(&rows, 1.0);
        let mut a = ScratchArena::new();
        let mut out = vec![9.0f32; 2];
        p.gemv(&[0.0f32; 16], &mut out, &mut a);
        assert_eq!(out, vec![0.0, 0.0]);
        p.gemv(&[2.0f32; 16], &mut out, &mut a);
        assert_eq!(out[0], 0.0, "all-zero row must stay exactly zero");
        // Scale factors (1/127, 2/127) are not exact in f32 — a few ulp
        // of rounding is expected around the true value 32.
        assert!((out[1] - 32.0).abs() < 1e-3, "{}", out[1]);
    }

    #[test]
    fn single_non_finite_element_zeroes_the_sample() {
        // Pre-split-scan behavior: `f32::max` ignores NaN, so one NaN
        // element slipped past the guard and quantized as 0 while its
        // neighbors carried garbage-scaled values.  The magnitude-bits
        // scan catches *any* non-finite element — the whole sample
        // quantizes to zeros with scale 0, pinned here per kind.
        let rows = vec![vec![1.0f32; 8]];
        let p = PackedLinear::pack(&rows, 1.0);
        let mut a = ScratchArena::new();
        let mut out = vec![9.0f32; 1];
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut x = vec![1.0f32; 8];
            x[3] = bad;
            p.gemv(&x, &mut out, &mut a);
            assert_eq!(out, vec![0.0], "element {bad} must zero the sample");
        }
        // And in a batch, only the corrupt sample is zeroed — its
        // neighbors still quantize normally.
        let mut xb = vec![1.0f32; 3 * 8];
        xb[8 + 2] = f32::NAN;
        let mut outb = vec![9.0f32; 3];
        p.gemm_batch(&xb, &mut outb, &mut a);
        assert_eq!(outb[1], 0.0, "NaN sample must be zeroed");
        assert!(outb[0] > 0.5 && outb[2] > 0.5, "clean samples must survive: {outb:?}");
    }

    #[test]
    fn quantize_scan_orders_magnitudes_exactly() {
        // The u32 magnitude-bits max must agree with the f32 max-abs on
        // finite data (IEEE-754 monotonicity), including negatives,
        // zeros, and denormals.
        let src = [0.0f32, -3.5, 2.25, -0.0, 1e-40, -1e-39, 3.0];
        let bits = max_abs_bits(&src);
        assert_eq!(f32::from_bits(bits), 3.5);
        assert!(bits < NON_FINITE_BITS);
        assert_eq!(max_abs_bits(&[]), 0);
        assert!(max_abs_bits(&[0.0, f32::NAN]) >= NON_FINITE_BITS);
        assert!(max_abs_bits(&[f32::NEG_INFINITY]) >= NON_FINITE_BITS);
    }

    #[test]
    fn column_blocking_is_bit_identical_across_the_block_boundary() {
        // A shape wider than COL_BLOCK forces multi-block accumulation;
        // partial i32 sums must reproduce the single-dot result exactly
        // (compare the blocked batched path against per-sample gemv and
        // the scalar oracle).
        let mut rng = SplitMix64::new(0xB10C);
        let cols = COL_BLOCK + 37; // ragged second block
        let rows = gaussian_rows(&mut rng, 3, cols);
        let p = PackedLinear::pack(&rows, 1.0 / cols as f32);
        let mut a = ScratchArena::new();
        let x: Vec<f32> = (0..2 * cols).map(|_| rng.next_gaussian() as f32).collect();
        let mut batched = vec![0.0f32; 2 * 3];
        p.gemm_batch(&x, &mut batched, &mut a);
        let mut oracle = vec![0.0f32; 2 * 3];
        p.gemm_batch_scalar(&x, &mut oracle, &mut a);
        assert_eq!(batched, oracle, "dispatched path diverged from scalar oracle");
        let mut single = vec![0.0f32; 3];
        for s in 0..2 {
            p.gemv(&x[s * cols..(s + 1) * cols], &mut single, &mut a);
            assert_eq!(&batched[s * 3..(s + 1) * 3], &single[..]);
        }
    }

    #[test]
    fn steady_state_is_allocation_free() {
        // After a warm-up call the arena must not grow again for the
        // same shape (pointer + capacity stable) — including the i32
        // accumulator strip the column-blocked path added.
        let mut rng = SplitMix64::new(0xA11C);
        let rows = gaussian_rows(&mut rng, 4, 32);
        let p = PackedLinear::pack(&rows, 1.0);
        let mut a = ScratchArena::new();
        let x: Vec<f32> = (0..3 * 32).map(|_| rng.next_gaussian() as f32).collect();
        let mut out = vec![0.0f32; 3 * 4];
        p.gemm_batch(&x, &mut out, &mut a);
        let (ptr, cap) = (a.xq.as_ptr(), a.xq.capacity());
        let (aptr, acap) = (a.acc.as_ptr(), a.acc.capacity());
        for _ in 0..5 {
            p.gemm_batch(&x, &mut out, &mut a);
        }
        assert_eq!((a.xq.as_ptr(), a.xq.capacity()), (ptr, cap));
        assert_eq!((a.acc.as_ptr(), a.acc.capacity()), (aptr, acap));
    }
}
