//! Packed quantized kernel core — the surrogate inference hot path.
//!
//! The paper's submissions win on latency/energy because hls4ml/FINN
//! lower quantized layers into tightly packed spatial MVAU dataflow
//! kernels: weights live in on-chip memory as a few bits per element,
//! packed once at synthesis time, and every inference streams activations
//! past them with integer accumulators.  This module mirrors that
//! execution model in software for the serving plane's surrogate
//! executors, replacing the seed's Vec-of-Vec f32 dot products (one heap
//! allocation per request, full weight walk per sample):
//!
//! * [`PackedLinear`] — templates/projections packed **once at load**
//!   into a contiguous row-major i8 matrix with per-row dequantization
//!   scales (the software analogue of the paper's 4–8-bit MVAU weight
//!   memories).  [`PackedLinear::gemm_batch`] tiles over the weight
//!   matrix once per *batch* instead of once per sample, accumulating in
//!   i32; because the accumulation is exact integer arithmetic, the
//!   batched path is bit-identical to the single-sample path.
//! * [`SmoothKernel`] — the AD autoencoder's 9-tap moving average as an
//!   O(n) prefix-sum pass (the seed recomputed each window from scratch,
//!   O(n·window)).
//! * [`simd`] — runtime-dispatched `std::arch` implementations of the
//!   i8 inner loops (x86_64 AVX2 with an SSE2 fallback, aarch64 NEON),
//!   selected once per process into a dispatch table, with the scalar
//!   loop as both universal fallback and **bit-exactness oracle**
//!   (integer accumulation is associative, so SIMD-vs-scalar
//!   equivalence is exact, not a tolerance).  `TINYML_FORCE_SCALAR=1`
//!   pins the scalar path for A/B runs and CI.
//! * [`ScratchArena`] — caller-owned scratch for everything the kernels
//!   need at runtime (quantized activations, per-sample scales, i32
//!   partial-sum strips for the column-blocked GEMM, prefix sums).
//!   Buffers grow to their high-water mark and are then reused, so the
//!   steady-state serve loop performs **zero heap allocations** inside
//!   the kernels.
//!
//! Scratch-arena contract: one arena per executor (they are cheap);
//! kernels may clobber any arena buffer, so never hand one arena to two
//! kernels concurrently — sequential reuse within a thread is the
//! intended pattern.  All `*_into` entry points write into caller-owned
//! output slices and never allocate.

mod packed;
pub mod simd;
mod smooth;

pub use packed::{quantized_max_abs_error, PackedLinear};
pub use simd::SimdLevel;
pub use smooth::SmoothKernel;

/// Caller-owned scratch backing the kernel hot paths.
///
/// Every buffer is grown on demand (never shrunk) and reused across
/// calls, so after the first batch of a given shape the kernels allocate
/// nothing.  The arena is deliberately dumb — plain `Vec`s with a
/// grow-to-fit helper — because the contract (exclusive use, sequential
/// reuse) makes anything smarter unnecessary.
#[derive(Default)]
pub struct ScratchArena {
    /// Quantized activations, one i8 per input element per sample.
    pub(crate) xq: Vec<i8>,
    /// Per-sample activation dequantization scales.
    pub(crate) xscale: Vec<f32>,
    /// i32 partial sums for the column-blocked GEMM, `n * ROW_TILE`
    /// elements (exact across block boundaries by associativity).
    pub(crate) acc: Vec<i32>,
    /// f64 prefix sums for [`SmoothKernel`] (len n + 1).
    pub(crate) prefix: Vec<f64>,
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow `v` to at least `len` elements (filled with `fill`) and hand
    /// back the `[..len]` window.  Steady state: no allocation.
    fn grown<T: Copy>(v: &mut Vec<T>, len: usize, fill: T) -> &mut [T] {
        if v.len() < len {
            v.resize(len, fill);
        }
        &mut v[..len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_buffers_grow_monotonically_and_are_reused() {
        let mut a = ScratchArena::new();
        let ptr1 = {
            let b = ScratchArena::grown(&mut a.xq, 64, 0);
            assert_eq!(b.len(), 64);
            b.as_ptr()
        };
        // Smaller request reuses the same backing storage.
        let ptr2 = ScratchArena::grown(&mut a.xq, 16, 0).as_ptr();
        assert_eq!(ptr1, ptr2);
        assert!(a.xq.len() >= 64);
    }
}
