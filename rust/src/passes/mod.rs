//! Compiler optimization passes over the IR (paper §3 + §3.5).
//!
//! Pass pipeline per flow:
//!
//! * **hls4ml**: `fold_flatten` → `fold_bn_into_linear` (QDenseBatchnorm,
//!   §3.3.1) → `merge_relu` (§3.1.3) → `remove_softmax_insert_topk`
//!   (§3.1.1) → `minimize_accumulators` → `infer_datatypes`.
//! * **FINN**: `fold_flatten` (constant folding analogue) → `streamline`
//!   (BN + quantized act → MultiThreshold, Umuroglu & Jahre 2017) →
//!   `remove_softmax_insert_topk` (in-hardware TopK, §3.2) →
//!   `minimize_accumulators` → `infer_datatypes`.
//!
//! Every pass is `Graph -> Graph` and idempotent (asserted in tests); the
//! [`PassManager`] records which passes ran so resource reports (Tables 3-4)
//! can diff optimized vs unoptimized designs.

use crate::ir::{Graph, Node};

/// A named graph-rewriting pass.
pub type Pass = (&'static str, fn(&Graph) -> Graph);

/// Remove Flatten nodes: a pure reshape is free in a dataflow architecture
/// (the stream is already serialized).  This is the chain-IR analogue of
/// FINN's constant folding: nodes that do no runtime work are removed at
/// compile time.
pub fn fold_flatten(g: &Graph) -> Graph {
    let mut out = g.clone();
    out.nodes.retain(|n| !matches!(n, Node::Flatten { .. }));
    out.total_params = out.nodes.iter().map(|n| n.params()).sum();
    out
}

/// hls4ml QDenseBatchnorm (§3.3.1): fold BatchNorm into the preceding
/// Dense/Conv2D per eq. 3-4.  The BN node disappears; the linear node gains
/// a bias (if it had none) and is marked `folded_bn`.
pub fn fold_bn_into_linear(g: &Graph) -> Graph {
    let mut nodes: Vec<Node> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        if let Node::BatchNorm { .. } = node {
            if let Some(prev) = nodes.last_mut() {
                match prev {
                    Node::Dense { folded_bn, has_bias, params, out_features, .. } => {
                        if !*folded_bn {
                            if !*has_bias {
                                *has_bias = true;
                                *params += *out_features as u64;
                            }
                            *folded_bn = true;
                            continue; // BN absorbed
                        }
                    }
                    Node::Conv2D { folded_bn, params, out_ch, .. } => {
                        if !*folded_bn {
                            *folded_bn = true;
                            *params += *out_ch as u64; // folded bias
                            continue;
                        }
                    }
                    _ => {}
                }
            }
        }
        nodes.push(node.clone());
    }
    let mut out = g.clone();
    out.nodes = nodes;
    out.folded_bn = true;
    out.total_params = out.nodes.iter().map(|n| n.params()).sum();
    out
}

/// hls4ml ReLU merging (§3.1.3): fuse each ReLU into the preceding
/// compute/pool stage so it stops costing its own dataflow stage + FIFO.
pub fn merge_relu(g: &Graph) -> Graph {
    let mut nodes: Vec<Node> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        if let Node::ReLU { .. } = node {
            if let Some(prev) = nodes.last_mut() {
                match prev {
                    Node::Dense { fused_relu, .. } | Node::Conv2D { fused_relu, .. } => {
                        if !*fused_relu {
                            *fused_relu = true;
                            continue; // ReLU absorbed
                        }
                    }
                    _ => {}
                }
            }
        }
        nodes.push(node.clone());
    }
    let mut out = g.clone();
    out.nodes = nodes;
    out.total_params = out.nodes.iter().map(|n| n.params()).sum();
    out
}

/// FINN streamlining (§3.5, Umuroglu & Jahre 2017): BatchNorm followed by a
/// uniform quantized activation collapses into one integer MultiThreshold
/// node — removing the floating-point BN from the datapath entirely.
pub fn streamline(g: &Graph) -> Graph {
    let mut nodes: Vec<Node> = Vec::with_capacity(g.nodes.len());
    let mut i = 0;
    while i < g.nodes.len() {
        let here = &g.nodes[i];
        let next = g.nodes.get(i + 1);
        if let Node::BatchNorm { name, channels, .. } = here {
            match next {
                Some(Node::ReLU { act_bits, .. }) if *act_bits < 32 => {
                    let levels = (1u64 << *act_bits.min(&30)) as u32 - 1;
                    nodes.push(Node::MultiThreshold {
                        name: format!("{name}_mt"),
                        channels: *channels,
                        levels,
                        // Thresholds: channels * levels stored values.
                        params: (*channels as u64) * levels as u64,
                    });
                    i += 2;
                    continue;
                }
                Some(Node::BipolarAct { .. }) => {
                    nodes.push(Node::MultiThreshold {
                        name: format!("{name}_mt"),
                        channels: *channels,
                        levels: 1,
                        params: *channels as u64,
                    });
                    i += 2;
                    continue;
                }
                _ => {}
            }
        }
        nodes.push(here.clone());
        i += 1;
    }
    let mut out = g.clone();
    out.nodes = nodes;
    out.total_params = out.nodes.iter().map(|n| n.params()).sum();
    out
}

/// Softmax removal (§3.1.1): softmax is monotonic in the logits, so the
/// top-1 class is unchanged by replacing it with an in-hardware TopK node
/// (what FINN inserts for its IC/KWS submissions, §3.2).
pub fn remove_softmax_insert_topk(g: &Graph) -> Graph {
    let mut out = g.clone();
    let mut replaced = false;
    out.nodes = g
        .nodes
        .iter()
        .map(|n| match n {
            Node::Softmax { name, channels, .. } => {
                replaced = true;
                Node::TopK {
                    name: format!("{name}_topk"),
                    channels: *channels,
                    k: 1,
                    params: 0,
                }
            }
            other => other.clone(),
        })
        .collect();
    // Classification chains that end in raw logits get a TopK appended
    // (AD is regression — reconstruction error — and is left alone).
    if !replaced && g.task != "ad" && !matches!(out.nodes.last(), Some(Node::TopK { .. })) {
        let ch = out.nodes.last().map(|n| n.out_elems()).unwrap_or(0);
        out.nodes.push(Node::TopK {
            name: "topk".into(),
            channels: ch,
            k: 1,
            params: 0,
        });
    }
    out.total_params = out.nodes.iter().map(|n| n.params()).sum();
    out
}

/// FINN accumulator minimization (§3.5): shrink each MVAU's accumulator
/// from the synthesis default (32) to the provably-sufficient width
/// `wbits + in_bits + ceil(log2(fan_in))`.  Requires datatype inference to
/// have run (uses `in_bits`; falls back to the graph input precision).
pub fn minimize_accumulators(g: &Graph) -> Graph {
    let mut out = g.clone();
    let mut cur_bits = g.input_bits;
    for node in &mut out.nodes {
        match node {
            Node::Conv2D { kernel, in_ch, weight_bits, acc_bits, in_bits, .. } => {
                let fan_in = (*kernel * *kernel * *in_ch) as f64;
                *in_bits = cur_bits;
                *acc_bits = *weight_bits + cur_bits + fan_in.log2().ceil() as u32;
            }
            Node::Dense { in_features, weight_bits, acc_bits, in_bits, .. } => {
                let fan_in = *in_features as f64;
                *in_bits = cur_bits;
                *acc_bits = *weight_bits + cur_bits + fan_in.log2().ceil() as u32;
            }
            Node::ReLU { act_bits, .. } => cur_bits = *act_bits,
            Node::BipolarAct { .. } => cur_bits = 1,
            Node::MultiThreshold { levels, .. } => {
                cur_bits = (32 - levels.leading_zeros()).max(1);
            }
            _ => {}
        }
    }
    out
}

/// Datatype inference: propagate activation precision down the chain into
/// each compute node's `in_bits` (resource + BOPs models depend on it).
pub fn infer_datatypes(g: &Graph) -> Graph {
    // minimize_accumulators already performs the propagation; this pass
    // exists separately so unoptimized designs still get `in_bits` set
    // (with default 32-bit accumulators).
    let mut out = g.clone();
    let mut cur_bits = g.input_bits;
    for node in &mut out.nodes {
        match node {
            Node::Conv2D { in_bits, acc_bits, .. } | Node::Dense { in_bits, acc_bits, .. } => {
                *in_bits = cur_bits;
                if *acc_bits == 0 {
                    *acc_bits = 32;
                }
            }
            Node::ReLU { act_bits, .. } => cur_bits = *act_bits,
            Node::BipolarAct { .. } => cur_bits = 1,
            Node::MultiThreshold { levels, .. } => {
                cur_bits = (32 - levels.leading_zeros()).max(1);
            }
            _ => {}
        }
    }
    out
}

/// Ordered pass pipeline with a run log.
pub struct PassManager {
    pub passes: Vec<Pass>,
    pub log: Vec<String>,
}

impl PassManager {
    /// The paper's full optimization pipeline for a flow ("hls4ml"|"finn").
    pub fn for_flow(flow: &str) -> Self {
        let passes: Vec<Pass> = match flow {
            "hls4ml" => vec![
                ("fold_flatten", fold_flatten),
                ("fold_bn_into_linear", fold_bn_into_linear),
                ("merge_relu", merge_relu),
                ("remove_softmax_insert_topk", remove_softmax_insert_topk),
                ("minimize_accumulators", minimize_accumulators),
                ("infer_datatypes", infer_datatypes),
            ],
            _ => vec![
                ("fold_flatten", fold_flatten),
                ("streamline", streamline),
                ("remove_softmax_insert_topk", remove_softmax_insert_topk),
                ("minimize_accumulators", minimize_accumulators),
                ("infer_datatypes", infer_datatypes),
            ],
        };
        Self { passes, log: Vec::new() }
    }

    /// Baseline pipeline: only what is needed for analysis (datatype
    /// inference), no optimizations — the "Without opt." rows of Tables 3-4.
    pub fn baseline() -> Self {
        Self {
            passes: vec![("infer_datatypes", infer_datatypes)],
            log: Vec::new(),
        }
    }

    pub fn run(&mut self, g: &Graph) -> Graph {
        let mut cur = g.clone();
        for (name, pass) in &self.passes {
            let next = pass(&cur);
            self.log.push(format!(
                "{name}: {} -> {} nodes, {} -> {} params",
                cur.nodes.len(),
                next.nodes.len(),
                cur.total_params,
                next.total_params
            ));
            cur = next;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Graph;

    fn kws_like() -> Graph {
        Graph::from_json_str(
            r#"{
            "name":"t","task":"kws","flow":"finn","input_shape":[8],
            "input_bits":8,"nodes":[
              {"op":"Dense","name":"fc1","in_features":8,"out_features":4,
               "weight_bits":3,"params":32},
              {"op":"BatchNorm","name":"bn1","channels":4,"params":16},
              {"op":"ReLU","name":"r1","channels":4,"act_bits":3,"params":0},
              {"op":"Flatten","name":"fl","features":4,"params":0},
              {"op":"Dense","name":"fc2","in_features":4,"out_features":2,
               "weight_bits":3,"params":8},
              {"op":"BatchNorm","name":"bn2","channels":2,"params":8},
              {"op":"Softmax","name":"sm","channels":2,"params":0}
            ],"total_params":64}"#,
        )
        .unwrap()
    }

    #[test]
    fn streamline_creates_multithreshold() {
        let g = streamline(&kws_like());
        let ops: Vec<_> = g.nodes.iter().map(|n| n.op()).collect();
        assert!(ops.contains(&"MultiThreshold"));
        assert!(!ops
            .iter()
            .zip(ops.iter().skip(1))
            .any(|(a, b)| *a == "BatchNorm" && *b == "ReLU"));
        // 3-bit act -> 7 thresholds per channel.
        let mt = g.nodes.iter().find(|n| n.op() == "MultiThreshold").unwrap();
        if let Node::MultiThreshold { levels, params, channels, .. } = mt {
            assert_eq!(*levels, 7);
            assert_eq!(*params, (*channels as u64) * 7);
        }
    }

    #[test]
    fn streamline_idempotent() {
        let once = streamline(&kws_like());
        let twice = streamline(&once);
        assert_eq!(once.nodes, twice.nodes);
    }

    #[test]
    fn fold_bn_marks_linear_and_removes_bn() {
        let g = fold_bn_into_linear(&kws_like());
        assert!(!g.nodes.iter().any(|n| n.op() == "BatchNorm"));
        match &g.nodes[0] {
            Node::Dense { folded_bn, has_bias, params, .. } => {
                assert!(*folded_bn && *has_bias);
                assert_eq!(*params, 32 + 4);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn fold_bn_idempotent() {
        let once = fold_bn_into_linear(&kws_like());
        let twice = fold_bn_into_linear(&once);
        assert_eq!(once.nodes, twice.nodes);
    }

    #[test]
    fn merge_relu_removes_standalone_relu() {
        // hls4ml order: fold BN first, then the ReLU is adjacent to Dense.
        let g = merge_relu(&fold_bn_into_linear(&kws_like()));
        assert!(!g.nodes.iter().any(|n| n.op() == "ReLU"));
        assert!(matches!(g.nodes[0], Node::Dense { fused_relu: true, .. }));
    }

    #[test]
    fn softmax_becomes_topk() {
        let g = remove_softmax_insert_topk(&kws_like());
        assert!(!g.nodes.iter().any(|n| n.op() == "Softmax"));
        assert!(matches!(g.nodes.last(), Some(Node::TopK { k: 1, .. })));
    }

    #[test]
    fn topk_appended_when_missing() {
        let mut base = kws_like();
        base.nodes.pop(); // drop softmax
        base.total_params = base.nodes.iter().map(|n| n.params()).sum();
        let g = remove_softmax_insert_topk(&base);
        assert!(matches!(g.nodes.last(), Some(Node::TopK { .. })));
    }

    #[test]
    fn accumulator_widths_are_sufficient_and_small() {
        let g = minimize_accumulators(&infer_datatypes(&kws_like()));
        for n in g.compute_nodes() {
            if let Node::Dense { acc_bits, weight_bits, in_bits, in_features, .. } = n {
                let need = weight_bits + in_bits + (*in_features as f64).log2().ceil() as u32;
                assert_eq!(*acc_bits, need);
                assert!(*acc_bits < 32);
            }
        }
    }

    #[test]
    fn full_finn_pipeline_runs_and_validates() {
        let mut pm = PassManager::for_flow("finn");
        let g = pm.run(&kws_like());
        g.validate().unwrap();
        assert_eq!(pm.log.len(), 5);
    }

    #[test]
    fn full_hls4ml_pipeline_runs_and_validates() {
        let mut pm = PassManager::for_flow("hls4ml");
        let g = pm.run(&kws_like());
        g.validate().unwrap();
        assert!(!g.nodes.iter().any(|n| n.op() == "BatchNorm"));
        assert!(!g.nodes.iter().any(|n| n.op() == "ReLU"));
    }

    #[test]
    fn datatype_inference_propagates_bits() {
        let g = infer_datatypes(&kws_like());
        if let Node::Dense { in_bits, .. } = &g.nodes[0] {
            assert_eq!(*in_bits, 8); // graph input precision
        }
        if let Node::Dense { in_bits, .. } = &g.nodes[4] {
            assert_eq!(*in_bits, 3); // after the 3-bit ReLU
        }
    }
}
