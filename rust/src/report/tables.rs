//! Paper table/figure renderers (ours vs paper, side by side).
//!
//! Every table and figure in the paper's evaluation section has a
//! generator here; `benches/` and the CLI call these.  Paper values are
//! embedded so each row prints `ours | paper` — absolute agreement is not
//! expected (the substrate is a simulator; see DESIGN.md), the *shape* is
//! asserted by the benches.

use crate::board::{all_boards, arty_a7_100t, pynq_z2, Board};
use crate::coordinator::flow::{run_flow, FlowOptions, FlowReport};
use crate::dataflow::schedule::ScheduleConfig;
use crate::dse;
use crate::ir::Graph;
use crate::metrics;
use crate::error::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// The four submitted designs (Table 5 rows).  IC/FINN uses the full-size
/// CNV topology for hardware estimation (the paper's 1.54 M-param design);
/// the width-scaled variant is what gets *trained* (see DESIGN.md).
pub const SUBMITTED: [(&str, &str); 4] = [
    ("IC (hls4ml)", "ic_hls4ml"),
    ("IC (FINN)", "ic_finn_full"),
    ("AD", "ad_autoencoder"),
    ("KWS", "kws_mlp_w3a3"),
];

pub fn load_topology(art_dir: &Path, name: &str) -> Result<Graph> {
    Graph::load(&art_dir.join(format!("{name}_topology.json")))
        .with_context(|| format!("loading {name} topology"))
}

pub fn flow_for(art_dir: &Path, name: &str, board: &Board) -> Result<FlowReport> {
    let g = load_topology(art_dir, name)?;
    run_flow(&g, board, &FlowOptions::default(), &ScheduleConfig::default())
}

// ---------------------------------------------------------------------------
// Table 1 — model summary.
// ---------------------------------------------------------------------------

/// Paper Table 1 rows: (benchmark, flow, precision, params, performance).
pub const TABLE1_PAPER: [(&str, &str, &str, u64, &str); 4] = [
    ("IC", "hls4ml", "8-12", 58_115, "83.5%"),
    ("IC", "FINN", "1", 1_542_848, "84.5%"),
    ("AD", "hls4ml", "6-12", 22_285, "0.83 AUC"),
    ("KWS", "FINN", "3", 259_584, "82.5%"),
];

/// Our models; `measured` maps model name -> measured accuracy/AUC string.
pub fn table1(art_dir: &Path, measured: &[(String, String)]) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "Table 1 — submitted models (ours vs paper)").ok();
    writeln!(
        out,
        "{:<12} {:<7} {:<7} {:>12} {:>18} | {:>10} {:>10}",
        "Benchmark", "Flow", "Prec.", "Params(ours)", "Perf(ours)", "Params(pap)", "Perf(pap)"
    )
    .ok();
    let ours = [
        ("IC", "hls4ml", "8-12", "ic_hls4ml"),
        ("IC", "FINN", "1", "ic_finn"),
        ("AD", "hls4ml", "6-12", "ad_autoencoder"),
        ("KWS", "FINN", "3", "kws_mlp_w3a3"),
    ];
    for (i, (bench, flow, prec, name)) in ours.iter().enumerate() {
        let g = load_topology(art_dir, name)?;
        let weights: u64 = g.compute_nodes().map(|n| n.params()).sum();
        let acc = measured
            .iter()
            .find(|(m, _)| m == name)
            .map(|(_, a)| a.clone())
            .unwrap_or_else(|| "n/a (train first)".into());
        let p = TABLE1_PAPER[i];
        writeln!(
            out,
            "{bench:<12} {flow:<7} {prec:<7} {weights:>12} {acc:>18} | {:>10} {:>10}",
            p.3, p.4
        )
        .ok();
    }
    writeln!(out, "note: IC/FINN trains width-scaled CNV (DESIGN.md §Hardware-Adaptation)").ok();
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 2 — FIFO sizes after optimization.
// ---------------------------------------------------------------------------

pub const TABLE2_PAPER: [(&str, &str, &str, &str); 4] = [
    ("IC", "hls4ml", "enabled", "1-1066"),
    ("IC", "FINN", "enabled", "2-512"),
    ("AD", "hls4ml", "disabled", "1"),
    ("KWS", "FINN", "enabled", "32-64"),
];

pub fn table2(art_dir: &Path) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "Table 2 — FIFO buffer sizes after depth optimization").ok();
    writeln!(
        out,
        "{:<12} {:<7} {:<10} {:>12} | {:>10}",
        "Benchmark", "Flow", "FIFO opt", "sizes(ours)", "paper"
    )
    .ok();
    let board = pynq_z2();
    for (i, (label, name)) in SUBMITTED.iter().enumerate() {
        let g = load_topology(art_dir, name)?;
        // AD shipped without FIFO optimization (paper Table 2).
        let mut opts = FlowOptions::default();
        if g.task == "ad" {
            opts.fifo_opt = false;
        }
        let r = run_flow(&g, &board, &opts, &ScheduleConfig::default())?;
        let sizes = if g.task == "ad" {
            "1".to_string() // depth-1 defaults, like the submission
        } else {
            format!("{}-{}", r.fifo_range.0, r.fifo_range.1)
        };
        let p = TABLE2_PAPER[i];
        writeln!(
            out,
            "{:<12} {:<7} {:<10} {:>12} | {:>10}",
            label,
            g.flow,
            if g.task == "ad" { "disabled" } else { "enabled" },
            sizes,
            p.3
        )
        .ok();
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 3 — IC/hls4ml resource ablation (Pynq-Z2).
// ---------------------------------------------------------------------------

pub const TABLE3_PAPER: [(&str, f64, u64, u64); 4] = [
    ("Without opt.", 477.0 / 2.0, 79_177, 66_838), // BRAM printed in 36kb here
    ("With FIFO opt.", 278.0 / 2.0, 72_686, 58_515),
    ("With ReLU opt.", 345.0 / 2.0, 72_921, 55_292),
    ("With all opt.", 146.0 / 2.0, 66_430, 46_969),
];

pub fn table3(art_dir: &Path) -> Result<String> {
    let g = load_topology(art_dir, "ic_hls4ml")?;
    let board = pynq_z2();
    let cfg = ScheduleConfig::default();
    let rows: [(&str, FlowOptions); 4] = [
        ("Without opt.", FlowOptions { run_passes: true, fifo_opt: false, relu_merge: false, bn_fold: true }),
        ("With FIFO opt.", FlowOptions { run_passes: true, fifo_opt: true, relu_merge: false, bn_fold: true }),
        ("With ReLU opt.", FlowOptions { run_passes: true, fifo_opt: false, relu_merge: true, bn_fold: true }),
        ("With all opt.", FlowOptions::default()),
    ];
    let mut out = String::new();
    writeln!(out, "Table 3 — IC/hls4ml resources vs optimizations (Pynq-Z2, accelerator only)").ok();
    writeln!(
        out,
        "{:<16} {:>12} {:>10} {:>10} | {:>10} {:>8} {:>8}",
        "", "BRAM36(ours)", "FF(ours)", "LUT(ours)", "BRAM36(p)", "FF(p)", "LUT(p)"
    )
    .ok();
    for (i, (label, opts)) in rows.iter().enumerate() {
        let r = run_flow(&g, &board, opts, &cfg)?;
        let a = &r.resources.accelerator;
        let p = TABLE3_PAPER[i];
        writeln!(
            out,
            "{label:<16} {:>12.1} {:>10.0} {:>10.0} | {:>10.1} {:>8} {:>8}",
            a.bram36, a.ffs, a.luts, p.1, p.2, p.3
        )
        .ok();
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 4 — AD optimization ablation (Pynq-Z2, RF 144).
// ---------------------------------------------------------------------------

pub const TABLE4_PAPER: [(&str, &str, &str, &str); 4] = [
    ("Reference", "87.1% AUC", "-", "- (too large)"),
    ("With folding", "68.1% AUC", "161228", "221063"),
    ("With downsampling", "81.4% AUC", "55341", "35366"),
    ("With all opt.", "83.3% AUC", "44300", "31094"),
];

pub fn table4(art_dir: &Path, measured_auc: Option<f64>) -> Result<String> {
    let board = pynq_z2();
    let cfg = ScheduleConfig::default();
    // Cumulative rows, like the paper: fold -> +downsample -> +shrink.
    let rows = [
        ("Reference", "ad_reference"),
        ("With folding", "ad_folded"),
        ("With downsampling", "ad_downsampled"),
        ("With all opt.", "ad_autoencoder"),
    ];
    let mut out = String::new();
    writeln!(out, "Table 4 — AD resources vs optimizations (Pynq-Z2, RF 144)").ok();
    writeln!(
        out,
        "{:<20} {:>12} {:>10} {:>10} {:>6} | {:>10} {:>10} {:>10}",
        "", "AUC(ours)", "FF(ours)", "LUT(ours)", "fits", "AUC(p)", "FF(p)", "LUT(p)"
    )
    .ok();
    for (i, (label, name)) in rows.iter().enumerate() {
        let g = load_topology(art_dir, name)?;
        let r = run_flow(&g, &board, &FlowOptions::default(), &cfg)?;
        let a = &r.resources.accelerator;
        let auc = if *name == "ad_autoencoder" {
            measured_auc
                .map(|v| format!("{v:.3}"))
                .unwrap_or_else(|| "n/a".into())
        } else {
            "(not trained)".into()
        };
        let p = TABLE4_PAPER[i];
        writeln!(
            out,
            "{label:<20} {auc:>12} {:>10.0} {:>10.0} {:>6} | {:>10} {:>10} {:>10}",
            a.ffs,
            a.luts,
            if r.fits { "yes" } else { "NO" },
            p.1,
            p.2,
            p.3
        )
        .ok();
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 5 — resources, latency, energy per board.
// ---------------------------------------------------------------------------

/// Paper Table 5: (model, board, lut, lutram, ff, bram36, dsp, latency_ms,
/// energy_uj).
pub const TABLE5_PAPER: [(&str, &str, u64, u64, u64, f64, u64, f64, f64); 8] = [
    ("IC (hls4ml)", "Pynq-Z2", 28_544, 3_756, 49_215, 42.0, 4, 27.3, 44_330.0),
    ("IC (FINN)", "Pynq-Z2", 24_502, 2_086, 34_354, 100.0, 0, 1.5, 2_535.0),
    ("AD", "Pynq-Z2", 40_658, 3_659, 51_879, 14.5, 205, 0.019, 30.1),
    ("KWS", "Pynq-Z2", 33_732, 1_033, 34_405, 37.0, 1, 0.017, 30.9),
    ("IC (hls4ml)", "Arty A7-100T", 39_126, 5_877, 59_184, 50.0, 6, 33.1, 73_166.0),
    ("IC (FINN)", "Arty A7-100T", 32_096, 3_154, 39_962, 113.5, 2, 1.5, 3_419.0),
    ("AD", "Arty A7-100T", 51_429, 5_780, 61_639, 22.5, 207, 0.045, 98.4),
    ("KWS", "Arty A7-100T", 42_518, 1_634, 43_157, 59.5, 2, 0.033, 53.7),
];

pub fn table5(art_dir: &Path) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "Table 5 — resources, latency, energy per inference").ok();
    writeln!(
        out,
        "{:<14} {:<14} {:>8} {:>8} {:>8} {:>7} {:>5} {:>11} {:>12} | {:>11} {:>12}",
        "Model", "Board", "LUT", "LUTRAM", "FF", "BRAM36", "DSP", "Lat[ms]", "E/inf[uJ]",
        "Lat(p)[ms]", "E(p)[uJ]"
    )
    .ok();
    let mut idx = 0;
    for board in all_boards() {
        for (label, name) in SUBMITTED.iter() {
            let r = flow_for(art_dir, name, &board)?;
            let t = &r.resources.total;
            let p = TABLE5_PAPER[idx];
            writeln!(
                out,
                "{label:<14} {:<14} {:>8.0} {:>8.0} {:>8.0} {:>7.1} {:>5.0} {:>11.3} {:>12.1} | {:>11.3} {:>12.1}",
                board.name,
                t.luts,
                t.lutram,
                t.ffs,
                t.bram36,
                t.dsps,
                r.latency_s * 1e3,
                r.energy_per_inference_uj,
                p.7,
                p.8
            )
            .ok();
            idx += 1;
        }
    }
    writeln!(out, "(IC/FINN rows estimate the paper's full-size CNV-W1A1 topology)").ok();
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 2 / Fig. 3 — DSE scans as CSV series.
// ---------------------------------------------------------------------------

pub fn fig2(models_per_scan: usize, seed: u64) -> String {
    let mut out = String::new();
    writeln!(out, "Fig. 2 — BO NAS scans: accuracy vs MFLOPs (CSV)").ok();
    writeln!(out, "stacks,mflops,accuracy").ok();
    for stacks in 1..=3 {
        for p in dse::run_ic_bo_scan(stacks, models_per_scan, seed + stacks as u64) {
            writeln!(out, "{stacks},{:.3},{:.2}", p.mflops, p.accuracy).ok();
        }
    }
    writeln!(out, "# paper anchors: (1stk,2.5,75.0) (2stk,12.8,83.5) (ref,25.0,87.0)").ok();
    out
}

pub fn fig3(configs: usize, seed: u64) -> String {
    let mut out = String::new();
    writeln!(out, "Fig. 3 — ASHA scan: accuracy vs inference cost C (CSV)").ok();
    writeln!(out, "inference_cost,accuracy,epochs,rung").ok();
    for p in dse::run_cnv_asha_scan(configs, seed) {
        writeln!(
            out,
            "{:.4},{:.2},{},{}",
            p.inference_cost, p.accuracy, p.budget_epochs, p.rung
        )
        .ok();
    }
    writeln!(out, "# paper anchor: CNV-W1A1 at C=1.0 -> 84.5% after 100 epochs").ok();
    out
}

/// Fig. 4 x-axis: BOPs of each KWS WnAm variant (training happens in the
/// example/bench; this provides the metric side).
pub fn fig4_costs(art_dir: &Path) -> Result<Vec<(String, f64, f64)>> {
    let variants = ["w1a1", "w2a2", "w3a3", "w4a4", "w8a8", "fp32"];
    let mut rows = Vec::new();
    for v in variants {
        let g = load_topology(art_dir, &format!("kws_mlp_{v}"))?;
        let g = crate::passes::infer_datatypes(&g);
        rows.push((
            v.to_string(),
            metrics::bops(&g),
            metrics::weight_memory_bits(&g) as f64,
        ));
    }
    Ok(rows)
}

/// Comparison helper for the §4.2.3 claim: hls4ml-IC vs FINN-IC.
pub fn ic_comparison(art_dir: &Path) -> Result<String> {
    let board = pynq_z2();
    let h = flow_for(art_dir, "ic_hls4ml", &board)?;
    let f = flow_for(art_dir, "ic_finn_full", &board)?;
    let mut out = String::new();
    writeln!(out, "§4.2.3 IC comparison (paper: hls4ml uses 58% fewer BRAM, 18.2x latency)").ok();
    writeln!(
        out,
        "BRAM36: hls4ml {:.1} vs FINN {:.1} ({:.0}% fewer) | latency ratio {:.1}x",
        h.resources.total.bram36,
        f.resources.total.bram36,
        100.0 * (1.0 - h.resources.total.bram36 / f.resources.total.bram36),
        h.latency_s / f.latency_s
    )
    .ok();
    let _ = arty_a7_100t();
    Ok(out)
}
