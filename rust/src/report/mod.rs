//! Report rendering (tables/figures) + the in-tree JSON implementation.
pub mod json;
pub mod tables;
