//! Report rendering (tables/figures), the in-tree JSON implementation,
//! and the bench-regression gate over the recorded `BENCH_*.json`
//! trajectory.
pub mod gate;
pub mod json;
pub mod tables;
