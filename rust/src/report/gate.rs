//! Bench-regression gate: hold the recorded `BENCH_*.json` numbers as a
//! CI floor.
//!
//! The self-checking benches (`benches/kernels.rs`, `benches/fleet.rs`,
//! `benches/hotpath.rs`, `benches/scenarios.rs`) already assert
//! *absolute* floors inline (packed >= naive, elastic p99 <= fixed,
//! interactive ratio <= 0.5, sharded plane >= 1.3x the global-lock
//! plane, coalesced flash crowd >= 1.2x the uncoalesced plane, zero
//! lost requests under a replica kill, ...).  This module adds
//! the *trajectory* guarantee on top: the dimensionless **headline
//! ratios** of a fresh bench run are diffed against committed baselines
//! (`baselines/BENCH_*.json`) and CI fails on a regression beyond
//! [`DEFAULT_TOLERANCE`] — so a PR that quietly gives back half of a
//! recorded speedup is caught even when it still clears the benches'
//! own absolute asserts.
//!
//! Two escape hatches: a current bench document carrying
//! `"parallelism_limited": true` (emitted by `benches/hotpath.rs` on
//! machines with fewer than 4 hardware threads, where lock-contention
//! ratios measure the scheduler instead of the locks) is reported but
//! not gated — its baselines stay committed and gate again on real
//! hardware.  Likewise a `BENCH_kernels.json` carrying
//! `"simd_unavailable": true` (no AVX2/NEON wide path, or the
//! `TINYML_FORCE_SCALAR=1` kill switch) skips only the
//! `simd_over_scalar_speedup` headlines — the packed-vs-naive speedups
//! still gate, and the SIMD baselines keep gating on capable CPUs.
//!
//! Only dimensionless ratios are gated (speedups, elastic/fixed ratios,
//! the priority interactive-p99 ratio), never raw ns/µs numbers: ratios
//! transfer across machines far better than absolute timings — but the
//! timing-derived tail ratios still carry run-to-run noise, so a
//! blessed baseline should keep headroom.  The committed baselines
//! start at the benches' own assert floors — guaranteed consistent on a
//! first CI run — and are tightened with `tinyml-codesign bench-gate
//! --update` on a reference machine; when doing so, bless the *worst*
//! of several runs (or round toward the floor), not a lucky best:
//! blessing a single fast run removes the noise margin by construction
//! and turns the 10% tolerance into a flake generator.
//!
//! Entry points: `tinyml-codesign bench-gate [--baseline-dir D]
//! [--bench-dir D] [--tol F] [--update | --self-test]`, wrapped by
//! `tools/bench_gate.sh`, wired into `ci.sh` after the benches run.
//! `--self-test` proves the gate has teeth by injecting an artificial
//! just-over-tolerance regression into every headline metric and
//! checking each one is rejected.

use crate::error::{anyhow, bail, Result};
use crate::report::json::Value;
use std::path::Path;

/// Relative regression allowed before the gate fails (10%).
pub const DEFAULT_TOLERANCE: f64 = 0.10;

/// The bench documents the gate knows how to extract headlines from,
/// keyed by their `"bench"` field.
const BENCH_FILES: [&str; 4] = [
    "BENCH_kernels.json",
    "BENCH_fleet.json",
    "BENCH_hotpath.json",
    "BENCH_scenarios.json",
];

/// One gated headline number.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    /// `"<file-stem>.<path>"`, e.g. `"kernels.kws.packed_batch_speedup"`.
    pub name: String,
    pub value: f64,
    /// Direction: `true` = bigger is better (speedups), `false` =
    /// smaller is better (elastic/fixed and priority/fifo ratios).
    pub higher_is_better: bool,
}

/// One gate failure.
#[derive(Clone, Debug)]
pub struct Regression {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// Relative change in the *bad* direction (positive = worse).
    pub loss: f64,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {:.4} -> {:.4} ({:+.1}% vs baseline)",
            self.name,
            self.baseline,
            self.current,
            100.0 * self.loss
        )
    }
}

fn f64_of(doc: &Value, key: &str) -> Result<f64> {
    doc.req(key)?.as_f64().ok_or_else(|| anyhow!("'{key}' not a number"))
}

/// Extract the gated headline metrics from one parsed `BENCH_*.json`
/// document (dispatches on its `"bench"` field).
pub fn headline_metrics(doc: &Value) -> Result<Vec<Metric>> {
    let bench = doc.str_of("bench")?;
    let mut out = Vec::new();
    match bench.as_str() {
        "kernels" => {
            let shapes =
                doc.req("shapes")?.as_arr().ok_or_else(|| anyhow!("'shapes' not an array"))?;
            // SIMD headlines are extracted only from runs that had a
            // wide path: a `simd_unavailable: true` document (scalar
            // kill switch, SSE2-only or exotic CPU) measures nothing —
            // `run_gate` drops the corresponding baselines too, the
            // metric-scoped analogue of `parallelism_limited`.
            let simd_unavailable = doc.bool_of_or("simd_unavailable", false);
            for shape in shapes {
                let task = shape.str_of("task")?;
                for key in ["packed_single_speedup", "packed_batch_speedup"] {
                    out.push(Metric {
                        name: format!("kernels.{task}.{key}"),
                        value: f64_of(shape, key)?,
                        higher_is_better: true,
                    });
                }
                if !simd_unavailable {
                    if let Some(v) =
                        shape.get("simd_over_scalar_speedup").and_then(Value::as_f64)
                    {
                        out.push(Metric {
                            name: format!("kernels.{task}.simd_over_scalar_speedup"),
                            value: v,
                            higher_is_better: true,
                        });
                    }
                }
            }
            out.push(Metric {
                name: "kernels.smooth.speedup".to_string(),
                value: f64_of(doc.req("smooth")?, "speedup")?,
                higher_is_better: true,
            });
        }
        "fleet" => {
            // Routing: load-aware placement over blind rotation.
            let policies = doc
                .req("policies")?
                .as_arr()
                .ok_or_else(|| anyhow!("'policies' not an array"))?;
            let throughput_of = |name: &str| -> Result<f64> {
                policies
                    .iter()
                    .find(|p| p.str_of("policy").is_ok_and(|n| n == name))
                    .map(|p| f64_of(p, "throughput_rps"))
                    .ok_or_else(|| anyhow!("no '{name}' entry in policies"))?
            };
            out.push(Metric {
                name: "fleet.least_loaded_over_round_robin_throughput".to_string(),
                value: throughput_of("least-loaded")? / throughput_of("round-robin")?.max(1e-9),
                higher_is_better: true,
            });
            // Autoscaling: elastic tail and cost vs the fixed fleet.
            let auto = doc.req("autoscale")?;
            for key in ["p99_ratio_elastic_over_fixed", "board_seconds_ratio_elastic_over_fixed"] {
                out.push(Metric {
                    name: format!("fleet.{key}"),
                    value: f64_of(auto, key)?,
                    higher_is_better: false,
                });
            }
            // Priority scheduling: the interactive tail vs the FIFO
            // control.
            out.push(Metric {
                name: "fleet.interactive_p99_ratio_classful_over_fifo".to_string(),
                value: f64_of(
                    doc.req("priority")?,
                    "interactive_p99_ratio_classful_over_fifo",
                )?,
                higher_is_better: false,
            });
        }
        "hotpath" => {
            // Serving-plane saturation: the lock-sharded hot path vs
            // the global-lock A/B control (cache-on leg — see
            // benches/hotpath.rs).
            out.push(Metric {
                name: "hotpath.sharded_over_global_throughput".to_string(),
                value: f64_of(doc, "sharded_over_global_throughput")?,
                higher_is_better: true,
            });
            // Tracing overhead: 1-in-16 sampled lifecycle tracing vs
            // the untraced sharded plane (part 4; inline floor 0.9).
            out.push(Metric {
                name: "hotpath.traced_over_untraced_throughput".to_string(),
                value: f64_of(doc, "traced_over_untraced_throughput")?,
                higher_is_better: true,
            });
            // Single-flight coalescing vs the uncoalesced flash crowd
            // (part 5; inline floor 1.2).  Optional for older bench
            // documents; once the committed baseline carries it, a
            // current run missing it fails the gate (missing-headline
            // rule in `compare`).
            if let Some(v) = doc.get("coalesced_over_uncoalesced_throughput") {
                out.push(Metric {
                    name: "hotpath.coalesced_over_uncoalesced_throughput"
                        .to_string(),
                    value: v
                        .as_f64()
                        .ok_or_else(|| anyhow!(
                            "coalesced_over_uncoalesced_throughput is not a number"
                        ))?,
                    higher_is_better: true,
                });
            }
        }
        "scenarios" => {
            // Resilience: conservation and detection under a replica
            // kill (both fractions of 1.0 — any loss regresses them)...
            let kill = doc.req("kill")?;
            for key in ["resolved_fraction", "ejected"] {
                out.push(Metric {
                    name: format!("scenarios.kill_{key}"),
                    value: f64_of(kill, key)?,
                    higher_is_better: true,
                });
            }
            // ...bounded tail degradation during a brownout...
            out.push(Metric {
                name: "scenarios.p99_under_failure_ratio".to_string(),
                value: f64_of(doc.req("brownout")?, "p99_under_failure_ratio")?,
                higher_is_better: false,
            });
            // ...and full service through a flash crowd on a degraded
            // fleet.  `time_to_recover_ms` is deliberately not gated:
            // absolute timings do not transfer across machines.
            out.push(Metric {
                name: "scenarios.recovery_served_fraction".to_string(),
                value: f64_of(doc.req("flash_crowd")?, "recovery_served_fraction")?,
                higher_is_better: true,
            });
            // Tail-latency hedging (scenario 4): hedged p99 over the
            // unhedged control under an undetectable brownout, lower is
            // better.  Optional for pre-hedging documents; once the
            // committed baseline carries it, a current run missing it
            // fails the gate (missing-headline rule in `compare`).
            if let Some(h) = doc.get("hedge") {
                out.push(Metric {
                    name: "scenarios.hedged_p99_over_unhedged".to_string(),
                    value: f64_of(h, "hedged_p99_over_unhedged")?,
                    higher_is_better: false,
                });
            }
        }
        other => bail!("bench-gate does not know bench '{other}'"),
    }
    Ok(out)
}

/// Diff current metrics against the baseline at `tol`.  A baseline
/// metric missing from the current run is itself a regression (a
/// headline must not silently disappear); metrics new in the current
/// run are ignored (they become gated once `--update` blesses them).
pub fn compare(baseline: &[Metric], current: &[Metric], tol: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    for base in baseline {
        let Some(cur) = current.iter().find(|m| m.name == base.name) else {
            out.push(Regression {
                name: format!("{} (missing from current run)", base.name),
                baseline: base.value,
                current: f64::NAN,
                loss: f64::INFINITY,
            });
            continue;
        };
        let loss = if base.higher_is_better {
            // Worse = smaller.  loss > 0 when current < baseline.
            (base.value - cur.value) / base.value.abs().max(1e-12)
        } else {
            // Worse = bigger.
            (cur.value - base.value) / base.value.abs().max(1e-12)
        };
        if loss > tol {
            out.push(Regression {
                name: base.name.clone(),
                baseline: base.value,
                current: cur.value,
                loss,
            });
        }
    }
    out
}

fn load_doc(path: &Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read {}: {e}", path.display()))?;
    Value::parse(&text).map_err(|e| anyhow!("cannot parse {}: {e}", path.display()))
}

fn load_metrics(path: &Path) -> Result<Vec<Metric>> {
    headline_metrics(&load_doc(path)?)
}

/// Gate fresh `BENCH_*.json` files in `bench_dir` against the committed
/// baselines in `baseline_dir`.  Ok(report) when everything holds;
/// Err listing every regressed headline otherwise.
pub fn run_gate(bench_dir: &Path, baseline_dir: &Path, tol: f64) -> Result<String> {
    let mut report = String::new();
    let mut regressions: Vec<Regression> = Vec::new();
    let mut gated = 0usize;
    for file in BENCH_FILES {
        let mut baseline = load_metrics(&baseline_dir.join(file))?;
        let cur_doc = load_doc(&bench_dir.join(file))?;
        if cur_doc.bool_of_or("parallelism_limited", false) {
            // Contention ratios from a <4-thread machine measure the
            // scheduler, not the locks: report, don't gate (the
            // committed baseline keeps gating on real hardware).
            report.push_str(&format!(
                "  {file}: parallelism-limited run — contention headlines not gated\n"
            ));
            continue;
        }
        if cur_doc.bool_of_or("simd_unavailable", false) {
            // A run without a wide SIMD path (AVX2/NEON) — or one
            // pinned scalar by TINYML_FORCE_SCALAR=1 — cannot measure
            // the SIMD speedup: drop those headlines (and only those)
            // from the comparison; the committed baselines keep gating
            // them on capable hardware.
            baseline.retain(|m| !m.name.contains("simd_over_scalar_speedup"));
            report.push_str(&format!(
                "  {file}: no wide SIMD path in this run — simd_over_scalar \
                 headlines not gated\n"
            ));
        }
        let current = headline_metrics(&cur_doc)?;
        gated += baseline.len();
        regressions.extend(compare(&baseline, &current, tol));
        for m in &baseline {
            if let Some(c) = current.iter().find(|c| c.name == m.name) {
                report.push_str(&format!(
                    "  {:<55} baseline {:>8.4}  current {:>8.4}  ({})\n",
                    m.name,
                    m.value,
                    c.value,
                    if m.higher_is_better { "higher is better" } else { "lower is better" }
                ));
            }
        }
    }
    if regressions.is_empty() {
        Ok(format!(
            "bench-gate OK: {gated} headline metrics within {:.0}% of baseline\n{report}",
            tol * 100.0
        ))
    } else {
        let lines: Vec<String> =
            regressions.iter().map(|r| format!("  REGRESSED {r}")).collect();
        // Keep the full comparison table in the failure message: judging
        // whether a regression is isolated or part of a broad slowdown
        // needs the metrics that *didn't* trip too.
        bail!(
            "bench-gate FAILED: {} of {gated} headline metrics regressed more than \
             {:.0}%:\n{}\nall gated metrics:\n{report}\
             (intentional? re-bless with `tinyml-codesign bench-gate --update`)",
            regressions.len(),
            tol * 100.0,
            lines.join("\n")
        )
    }
}

/// Bless the current `BENCH_*.json` files as the new baselines.
/// Bless conservatively: run the benches several times and bless the
/// worst run, so the gate's relative tolerance keeps absorbing normal
/// run-to-run noise in the timing-derived ratios (see module docs).
pub fn update_baselines(bench_dir: &Path, baseline_dir: &Path) -> Result<String> {
    std::fs::create_dir_all(baseline_dir)
        .map_err(|e| anyhow!("cannot create {}: {e}", baseline_dir.display()))?;
    // Two phases — validate EVERY file before copying ANY — so a bad
    // document cannot leave baselines/ half-blessed behind an error.
    let mut counts = Vec::new();
    for file in BENCH_FILES {
        let src = bench_dir.join(file);
        // A truncated or hand-edited file must not become the floor —
        // and neither may a contention ratio measured without real
        // parallelism.
        let doc = load_doc(&src)?;
        if doc.bool_of_or("parallelism_limited", false) {
            bail!(
                "refusing to bless {}: parallelism-limited run (re-run the bench \
                 on a machine with >= 4 hardware threads); nothing was blessed",
                src.display()
            );
        }
        // A scalar-pinned or SSE2-only kernels run would bless a
        // baseline with no SIMD headlines at all, silently de-gating
        // them everywhere.
        if doc.bool_of_or("simd_unavailable", false) {
            bail!(
                "refusing to bless {}: no wide SIMD path in this run (re-run on \
                 an AVX2/NEON machine without TINYML_FORCE_SCALAR); nothing was \
                 blessed",
                src.display()
            );
        }
        counts.push(headline_metrics(&doc)?.len());
    }
    let mut report = String::new();
    for (file, n) in BENCH_FILES.iter().zip(counts) {
        let src = bench_dir.join(file);
        let dst = baseline_dir.join(file);
        std::fs::copy(&src, &dst)
            .map_err(|e| anyhow!("cannot copy {} -> {}: {e}", src.display(), dst.display()))?;
        report.push_str(&format!(
            "  blessed {} -> {} ({n} headline metrics)\n",
            src.display(),
            dst.display()
        ));
    }
    Ok(format!("bench-gate baselines updated:\n{report}"))
}

/// Prove the gate has teeth: for every headline metric in the committed
/// baselines, inject an artificial regression just past the tolerance
/// and check it is rejected (and that an unperturbed run passes).
pub fn self_test(baseline_dir: &Path, tol: f64) -> Result<String> {
    let mut total = 0usize;
    for file in BENCH_FILES {
        let baseline = load_metrics(&baseline_dir.join(file))?;
        if baseline.is_empty() {
            bail!("{file}: no headline metrics — gate would be vacuous");
        }
        // Identity must pass.
        let clean = compare(&baseline, &baseline, tol);
        if !clean.is_empty() {
            bail!("{file}: identical metrics flagged as regressed: {:?}", clean);
        }
        // Each metric, worsened ~2% past the tolerance, must fail.
        for (i, m) in baseline.iter().enumerate() {
            let mut bad = baseline.clone();
            bad[i].value = if m.higher_is_better {
                m.value * (1.0 - tol - 0.02)
            } else {
                m.value * (1.0 + tol + 0.02)
            };
            let caught = compare(&baseline, &bad, tol);
            if caught.len() != 1 || !caught[0].name.contains(&m.name) {
                bail!(
                    "{file}: artificial {:.0}% regression of '{}' not caught (got {:?})",
                    (tol + 0.02) * 100.0,
                    m.name,
                    caught
                );
            }
            // And a missing headline must be caught too.
            let mut gone = baseline.clone();
            gone.remove(i);
            if compare(&baseline, &gone, tol).len() != 1 {
                bail!("{file}: silently dropped headline '{}' not caught", m.name);
            }
            total += 1;
        }
    }
    Ok(format!(
        "bench-gate self-test OK: {total} injected regressions all rejected at \
         {:.0}% tolerance",
        tol * 100.0
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(name: &str, value: f64, higher: bool) -> Metric {
        Metric { name: name.to_string(), value, higher_is_better: higher }
    }

    #[test]
    fn compare_is_direction_aware() {
        let base = vec![metric("speedup", 2.0, true), metric("ratio", 0.8, false)];
        // Within tolerance both ways.
        let ok = vec![metric("speedup", 1.85, true), metric("ratio", 0.86, false)];
        assert!(compare(&base, &ok, 0.10).is_empty());
        // Speedup collapsing fails; ratio shrinking (improving) passes.
        let worse = vec![metric("speedup", 1.7, true), metric("ratio", 0.4, false)];
        let r = compare(&base, &worse, 0.10);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].name, "speedup");
        assert!(r[0].loss > 0.10);
        // Ratio growing fails.
        let worse2 = vec![metric("speedup", 2.2, true), metric("ratio", 0.9, false)];
        let r2 = compare(&base, &worse2, 0.10);
        assert_eq!(r2.len(), 1);
        assert_eq!(r2[0].name, "ratio");
    }

    #[test]
    fn missing_headline_is_a_regression() {
        let base = vec![metric("a", 1.0, true), metric("b", 1.0, false)];
        let cur = vec![metric("a", 1.0, true)];
        let r = compare(&base, &cur, 0.10);
        assert_eq!(r.len(), 1);
        assert!(r[0].name.contains("b"));
        assert!(r[0].loss.is_infinite());
    }

    #[test]
    fn extracts_kernels_and_fleet_headlines() {
        let kernels = Value::parse(
            r#"{"bench":"kernels","shapes":[
                {"task":"kws","packed_single_speedup":3.0,"packed_batch_speedup":5.0,
                 "simd_over_scalar_speedup":1.8},
                {"task":"ic","packed_single_speedup":2.0,"packed_batch_speedup":4.0,
                 "simd_over_scalar_speedup":2.5}],
                "smooth":{"speedup":6.0}}"#,
        )
        .unwrap();
        let m = headline_metrics(&kernels).unwrap();
        assert_eq!(m.len(), 7);
        assert!(m.iter().all(|x| x.higher_is_better));
        assert!(m.iter().any(|x| x.name == "kernels.kws.packed_batch_speedup"
            && x.value == 5.0));
        assert!(m.iter().any(|x| x.name == "kernels.kws.simd_over_scalar_speedup"
            && x.value == 1.8));

        // Flagged simd_unavailable: the simd headlines disappear from
        // extraction (the packed speedups stay), and a pre-SIMD document
        // without the keys still parses.
        let kernels_scalar = Value::parse(
            r#"{"bench":"kernels","simd_unavailable":true,"shapes":[
                {"task":"kws","packed_single_speedup":3.0,"packed_batch_speedup":5.0,
                 "simd_over_scalar_speedup":1.0}],
                "smooth":{"speedup":6.0}}"#,
        )
        .unwrap();
        let m = headline_metrics(&kernels_scalar).unwrap();
        assert_eq!(m.len(), 3);
        assert!(m.iter().all(|x| !x.name.contains("simd_over_scalar_speedup")));

        let fleet = Value::parse(
            r#"{"bench":"fleet",
                "policies":[{"policy":"round-robin","throughput_rps":100.0},
                            {"policy":"least-loaded","throughput_rps":150.0},
                            {"policy":"energy-aware","throughput_rps":90.0}],
                "autoscale":{"p99_ratio_elastic_over_fixed":0.9,
                             "board_seconds_ratio_elastic_over_fixed":0.7},
                "priority":{"interactive_p99_ratio_classful_over_fifo":0.3}}"#,
        )
        .unwrap();
        let m = headline_metrics(&fleet).unwrap();
        assert_eq!(m.len(), 4);
        let ll = m
            .iter()
            .find(|x| x.name == "fleet.least_loaded_over_round_robin_throughput")
            .unwrap();
        assert!((ll.value - 1.5).abs() < 1e-9);
        assert!(ll.higher_is_better);
        assert!(m
            .iter()
            .any(|x| x.name == "fleet.interactive_p99_ratio_classful_over_fifo"
                && !x.higher_is_better));

        let hotpath = Value::parse(
            r#"{"bench":"hotpath","sharded_over_global_throughput":1.8,
                "traced_over_untraced_throughput":0.95}"#,
        )
        .unwrap();
        let m = headline_metrics(&hotpath).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "hotpath.sharded_over_global_throughput");
        assert!((m[0].value - 1.8).abs() < 1e-9);
        assert!(m.iter().all(|x| x.higher_is_better));
        assert!(m
            .iter()
            .any(|x| x.name == "hotpath.traced_over_untraced_throughput"
                && (x.value - 0.95).abs() < 1e-9));

        // The coalescing headline is optional (pre-coalescing documents
        // still parse, as above) but extracted when present.
        let hotpath_v5 = Value::parse(
            r#"{"bench":"hotpath","sharded_over_global_throughput":1.8,
                "traced_over_untraced_throughput":0.95,
                "coalesced_over_uncoalesced_throughput":2.6}"#,
        )
        .unwrap();
        let m = headline_metrics(&hotpath_v5).unwrap();
        assert_eq!(m.len(), 3);
        assert!(m
            .iter()
            .any(|x| x.name == "hotpath.coalesced_over_uncoalesced_throughput"
                && (x.value - 2.6).abs() < 1e-9
                && x.higher_is_better));

        let scenarios = Value::parse(
            r#"{"bench":"scenarios",
                "kill":{"resolved_fraction":1.0,"ejected":1.0},
                "brownout":{"p99_under_failure_ratio":3.5},
                "flash_crowd":{"recovery_served_fraction":0.98}}"#,
        )
        .unwrap();
        let m = headline_metrics(&scenarios).unwrap();
        assert_eq!(m.len(), 4);
        assert!(m.iter().any(|x| x.name == "scenarios.kill_resolved_fraction"
            && x.value == 1.0
            && x.higher_is_better));
        assert!(m.iter().any(|x| x.name == "scenarios.p99_under_failure_ratio"
            && (x.value - 3.5).abs() < 1e-9
            && !x.higher_is_better));
        assert!(m.iter().any(|x| x.name == "scenarios.recovery_served_fraction"
            && x.higher_is_better));

        // The hedging headline is optional (pre-hedging documents still
        // parse, as above) but extracted when present.
        let scenarios_v2 = Value::parse(
            r#"{"bench":"scenarios",
                "kill":{"resolved_fraction":1.0,"ejected":1.0},
                "brownout":{"p99_under_failure_ratio":3.5},
                "flash_crowd":{"recovery_served_fraction":0.98},
                "hedge":{"hedged_p99_over_unhedged":0.4}}"#,
        )
        .unwrap();
        let m = headline_metrics(&scenarios_v2).unwrap();
        assert_eq!(m.len(), 5);
        assert!(m.iter().any(|x| x.name == "scenarios.hedged_p99_over_unhedged"
            && (x.value - 0.4).abs() < 1e-9
            && !x.higher_is_better));

        assert!(headline_metrics(&Value::parse(r#"{"bench":"nope"}"#).unwrap()).is_err());
    }

    /// A current hotpath document flagged `parallelism_limited` is
    /// reported but not gated — even with a catastrophic ratio — while
    /// the same numbers without the flag fail the gate.
    #[test]
    fn parallelism_limited_runs_are_reported_not_gated() {
        let dir = std::env::temp_dir().join(format!(
            "tinyml_gate_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let (base, cur) = (dir.join("baselines"), dir.join("bench"));
        std::fs::create_dir_all(&base).unwrap();
        std::fs::create_dir_all(&cur).unwrap();
        let kernels = r#"{"bench":"kernels","shapes":[
            {"task":"kws","packed_single_speedup":1.0,"packed_batch_speedup":2.0}],
            "smooth":{"speedup":1.0}}"#;
        let fleet = r#"{"bench":"fleet",
            "policies":[{"policy":"round-robin","throughput_rps":100.0},
                        {"policy":"least-loaded","throughput_rps":100.0}],
            "autoscale":{"p99_ratio_elastic_over_fixed":1.0,
                         "board_seconds_ratio_elastic_over_fixed":1.0},
            "priority":{"interactive_p99_ratio_classful_over_fifo":0.5}}"#;
        let scenarios = r#"{"bench":"scenarios",
            "kill":{"resolved_fraction":1.0,"ejected":1.0},
            "brownout":{"p99_under_failure_ratio":8.0},
            "flash_crowd":{"recovery_served_fraction":0.95}}"#;
        for d in [&base, &cur] {
            std::fs::write(d.join("BENCH_kernels.json"), kernels).unwrap();
            std::fs::write(d.join("BENCH_fleet.json"), fleet).unwrap();
            std::fs::write(d.join("BENCH_scenarios.json"), scenarios).unwrap();
        }
        std::fs::write(
            base.join("BENCH_hotpath.json"),
            r#"{"bench":"hotpath","sharded_over_global_throughput":1.3,
                "traced_over_untraced_throughput":0.9}"#,
        )
        .unwrap();
        // Terrible ratio, but flagged: the gate must pass and say why.
        std::fs::write(
            cur.join("BENCH_hotpath.json"),
            r#"{"bench":"hotpath","parallelism_limited":true,
                "sharded_over_global_throughput":0.9}"#,
        )
        .unwrap();
        let report = run_gate(&cur, &base, DEFAULT_TOLERANCE).expect("flagged run gates");
        assert!(report.contains("parallelism-limited"), "{report}");
        // Same ratio unflagged: a real regression.
        std::fs::write(
            cur.join("BENCH_hotpath.json"),
            r#"{"bench":"hotpath","sharded_over_global_throughput":0.9,
                "traced_over_untraced_throughput":0.9}"#,
        )
        .unwrap();
        let err = run_gate(&cur, &base, DEFAULT_TOLERANCE).unwrap_err().to_string();
        assert!(err.contains("sharded_over_global_throughput"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A current kernels document flagged `simd_unavailable` skips the
    /// SIMD headlines (and only those) — the same numbers unflagged are
    /// a regression against the committed SIMD baseline.
    #[test]
    fn simd_unavailable_runs_skip_only_simd_headlines() {
        let dir = std::env::temp_dir().join(format!(
            "tinyml_gate_simd_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let (base, cur) = (dir.join("baselines"), dir.join("bench"));
        std::fs::create_dir_all(&base).unwrap();
        std::fs::create_dir_all(&cur).unwrap();
        let fleet = r#"{"bench":"fleet",
            "policies":[{"policy":"round-robin","throughput_rps":100.0},
                        {"policy":"least-loaded","throughput_rps":100.0}],
            "autoscale":{"p99_ratio_elastic_over_fixed":1.0,
                         "board_seconds_ratio_elastic_over_fixed":1.0},
            "priority":{"interactive_p99_ratio_classful_over_fifo":0.5}}"#;
        let hotpath = r#"{"bench":"hotpath","sharded_over_global_throughput":1.3,
            "traced_over_untraced_throughput":0.9}"#;
        let scenarios = r#"{"bench":"scenarios",
            "kill":{"resolved_fraction":1.0,"ejected":1.0},
            "brownout":{"p99_under_failure_ratio":8.0},
            "flash_crowd":{"recovery_served_fraction":0.95}}"#;
        for d in [&base, &cur] {
            std::fs::write(d.join("BENCH_fleet.json"), fleet).unwrap();
            std::fs::write(d.join("BENCH_hotpath.json"), hotpath).unwrap();
            std::fs::write(d.join("BENCH_scenarios.json"), scenarios).unwrap();
        }
        std::fs::write(
            base.join("BENCH_kernels.json"),
            r#"{"bench":"kernels","shapes":[
                {"task":"kws","packed_single_speedup":1.0,"packed_batch_speedup":2.0,
                 "simd_over_scalar_speedup":1.2}],
                "smooth":{"speedup":1.0}}"#,
        )
        .unwrap();
        // Scalar-pinned run (the ci.sh TINYML_FORCE_SCALAR rerun, or an
        // SSE2-only machine): ratio ~1.0, but flagged — must gate.
        std::fs::write(
            cur.join("BENCH_kernels.json"),
            r#"{"bench":"kernels","simd_unavailable":true,"shapes":[
                {"task":"kws","packed_single_speedup":1.0,"packed_batch_speedup":2.0,
                 "simd_over_scalar_speedup":1.0}],
                "smooth":{"speedup":1.0}}"#,
        )
        .unwrap();
        let report = run_gate(&cur, &base, DEFAULT_TOLERANCE).expect("flagged run gates");
        assert!(report.contains("simd_over_scalar headlines not gated"), "{report}");
        // Same ratio unflagged: a real regression of the SIMD headline.
        std::fs::write(
            cur.join("BENCH_kernels.json"),
            r#"{"bench":"kernels","shapes":[
                {"task":"kws","packed_single_speedup":1.0,"packed_batch_speedup":2.0,
                 "simd_over_scalar_speedup":1.0}],
                "smooth":{"speedup":1.0}}"#,
        )
        .unwrap();
        let err = run_gate(&cur, &base, DEFAULT_TOLERANCE).unwrap_err().to_string();
        assert!(err.contains("kernels.kws.simd_over_scalar_speedup"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The committed baselines must stay parseable and self-consistent:
    /// the gate run against them verbatim passes, and the self-test's
    /// injected regressions are all caught.  (This is the in-tree
    /// version of `bench-gate --self-test`, so `cargo test` alone
    /// exercises the gate logic end to end.)
    #[test]
    fn committed_baselines_pass_identity_and_self_test() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("baselines");
        let report = run_gate(&dir, &dir, DEFAULT_TOLERANCE)
            .expect("baselines must gate cleanly against themselves");
        assert!(report.contains("bench-gate OK"), "{report}");
        let st = self_test(&dir, DEFAULT_TOLERANCE).expect("self-test must pass");
        assert!(st.contains("self-test OK"), "{st}");
        // The priority, hot-path, tracing, and SIMD headlines are part
        // of the committed floor.
        assert!(report.contains("interactive_p99_ratio_classful_over_fifo"), "{report}");
        assert!(report.contains("hotpath.sharded_over_global_throughput"), "{report}");
        assert!(report.contains("hotpath.traced_over_untraced_throughput"), "{report}");
        assert!(
            report.contains("hotpath.coalesced_over_uncoalesced_throughput"),
            "{report}"
        );
        assert!(report.contains("kernels.kws.simd_over_scalar_speedup"), "{report}");
    }
}
