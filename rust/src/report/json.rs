//! Minimal JSON parser + writer (std-only).
//!
//! The build image is fully offline with no serde in its vendored crate
//! set, so the coordinator carries its own RFC-8259 subset implementation:
//! everything `python/compile/aot.py` emits (objects, arrays, strings with
//! escapes, numbers, booleans, null) parses; output is used by the report
//! generators.  Property-tested round-trip in the test module.

use crate::error::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("'{key}' not a string"))?
            .to_string())
    }

    pub fn u64_of(&self, key: &str) -> Result<u64> {
        self.req(key)?.as_u64().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn bool_of_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-sync to char boundaries for multibyte UTF-8.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    /// Property: random value trees survive to_json -> parse round-trips.
    #[test]
    fn round_trip_property() {
        fn gen(rng: &mut SplitMix64, depth: usize) -> Value {
            match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.next_f64() < 0.5),
                2 => Value::Num((rng.next_below(2_000_001) as f64 - 1e6) / 8.0),
                3 => {
                    let n = rng.next_below(8) as usize;
                    Value::Str(
                        (0..n)
                            .map(|_| {
                                let opts = ['a', '"', '\\', '\n', 'é', '𝛼', '\t', 'z'];
                                opts[rng.next_below(8) as usize]
                            })
                            .collect(),
                    )
                }
                4 => Value::Arr((0..rng.next_below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..rng.next_below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = SplitMix64::new(0xC0FFEE);
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            let text = v.to_json();
            let back = Value::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(v, back, "{text}");
        }
    }

    #[test]
    fn parses_aot_style_manifest() {
        let text = r#"{
          "name": "kws_mlp_w3a3", "task": "kws",
          "params": [{"name": "l01_bn.beta", "shape": [256], "file": "params/x/000.bin"}],
          "artifacts": {"fwd1": {"file": "a.hlo.txt", "batch": 1}}
        }"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.str_of("name").unwrap(), "kws_mlp_w3a3");
        let p = &v.req("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.req("shape").unwrap().as_arr().unwrap()[0].as_u64(), Some(256));
    }
}
