//! In-crate error type (std-only `anyhow` subset).
//!
//! The build image is fully offline with no anyhow in its vendored crate
//! set, so the crate carries its own drop-in subset: a string-backed
//! [`Error`], the [`anyhow!`]/[`bail!`] macros, and a [`Context`]
//! extension trait for both `Result` and `Option`.  Semantics mirror
//! anyhow's: context wraps outside-in (`"outer: inner"`), and any
//! `std::error::Error` converts via `?`.

use std::fmt;

/// A string-backed error with layered context.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prefix this error with an outer context layer.
    pub fn wrap(self, outer: impl fmt::Display) -> Self {
        Error { msg: format!("{outer}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that keeps
// this blanket conversion coherent with core's reflexive `From<T> for T`
// (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::error::Error::msg(format!($($t)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::error::Error::msg(format!($($t)*)))
    };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/definitely/missing")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
    }

    #[test]
    fn macros_and_option_context() {
        let e: Error = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let r: Result<u32> = None.context("missing key");
        assert_eq!(r.unwrap_err().to_string(), "missing key");
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn context_layers_outside_in() {
        let inner: Result<()> = Err(Error::msg("inner"));
        let e = inner.with_context(|| format!("outer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "outer 2: inner");
    }
}
