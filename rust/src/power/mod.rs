//! Power and energy-per-inference model (§4.4.2, Table 5).
//!
//! Substitute for the Joulescope JS110 measurements: total board power =
//! platform static power + fabric dynamic power, with dynamic power
//! proportional to active resources × clock × switching activity (the
//! standard XPE-style first-order model).  Energy per inference =
//! power × latency.  Coefficients are calibrated so total board power
//! lands in the paper's observed 1.6-1.8 W (Pynq-Z2) / 1.6-2.2 W (Arty)
//! band; the *shape* — energy tracking latency across designs, FINN-IC
//! ~17x cheaper per inference than hls4ml-IC — is what Table 5 checks.

use crate::board::Board;
use crate::resources::Resources;


/// Dynamic power coefficients (W per resource-unit per GHz).
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub w_per_lut_ghz: f64,
    pub w_per_ff_ghz: f64,
    pub w_per_bram_ghz: f64,
    pub w_per_dsp_ghz: f64,
    /// Fraction of logic toggling per cycle.
    pub activity: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            w_per_lut_ghz: 1.9e-5,
            w_per_ff_ghz: 0.6e-5,
            w_per_bram_ghz: 7.5e-3,
            w_per_dsp_ghz: 4.2e-3,
            activity: 0.125,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    pub static_w: f64,
    pub dynamic_w: f64,
    pub total_w: f64,
}

impl PowerModel {
    pub fn power(&self, res: &Resources, board: &Board) -> PowerReport {
        let ghz = board.clock_hz / 1e9;
        let dynamic = self.activity
            * ghz
            * (res.luts * self.w_per_lut_ghz
                + res.ffs * self.w_per_ff_ghz
                + res.bram36 * self.w_per_bram_ghz
                + res.dsps * self.w_per_dsp_ghz);
        PowerReport {
            static_w: board.static_power_w,
            dynamic_w: dynamic,
            total_w: board.static_power_w + dynamic,
        }
    }

    /// Energy per inference in microjoules, given latency in seconds.
    pub fn energy_per_inference_uj(
        &self,
        res: &Resources,
        board: &Board,
        latency_s: f64,
    ) -> f64 {
        self.power(res, board).total_w * latency_s * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::{arty_a7_100t, pynq_z2};

    fn typical() -> Resources {
        Resources { luts: 30_000.0, lutram: 3_000.0, ffs: 45_000.0, bram36: 40.0, dsps: 100.0 }
    }

    #[test]
    fn total_power_in_paper_band() {
        let pm = PowerModel::default();
        let p = pm.power(&typical(), &pynq_z2());
        assert!((1.4..2.2).contains(&p.total_w), "{p:?}");
        let a = pm.power(&typical(), &arty_a7_100t());
        assert!((1.6..2.6).contains(&a.total_w), "{a:?}");
    }

    #[test]
    fn energy_tracks_latency() {
        let pm = PowerModel::default();
        let e_fast = pm.energy_per_inference_uj(&typical(), &pynq_z2(), 20e-6);
        let e_slow = pm.energy_per_inference_uj(&typical(), &pynq_z2(), 27.3e-3);
        // ~20 us -> tens of uJ; ~27 ms -> tens of mJ (Table 5 extremes).
        assert!((20.0..70.0).contains(&e_fast), "{e_fast}");
        assert!((20_000.0..80_000.0).contains(&e_slow), "{e_slow}");
    }

    #[test]
    fn more_resources_more_power() {
        let pm = PowerModel::default();
        let small = pm.power(&Resources::default(), &pynq_z2()).total_w;
        let big = pm.power(&typical(), &pynq_z2()).total_w;
        assert!(big > small);
    }
}
